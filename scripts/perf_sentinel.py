#!/usr/bin/env python
"""Perf-regression sentinel over the serving benchmark trajectory.

Each CI run appends the headline metrics of ``BENCH_serving.json`` to
``BENCH_history.jsonl`` (one JSON object per line) and gates the CURRENT
run against the median of the last ``--window`` recorded runs.  The
median-of-recent rule with generous per-metric relative tolerances is
deliberately noise-tolerant: CI runs on shared CPU runners where single
runs jitter by tens of percent, so only a sustained collapse (current run
far outside the recent median) fails the build — one slow neighbour on
the runner does not.

Headline metrics (extractor -> direction -> relative tolerance):

* ``warm_tokens_per_s``   — ``paged_warm.tokens_per_s`` (higher is
  better, 40% tolerance: pure wall-clock, noisiest).
* ``wdos_rounds_to_drain``— ``par.wdos.rounds_to_drain`` (lower is
  better, 34% tolerance: round counts are deterministic per seed but
  move when the workload or scheduler changes).
* ``tree_accepted_per_round`` — ``tree_spec.arms.tree.
  accepted_per_request_round`` (higher is better, 25% tolerance).
* ``ttft_p50_s``          — ``async_load`` wdos-side TTFT p50 at the
  highest arrival rate (lower is better, 100% tolerance: open-loop
  latency percentiles on 6 smoke requests are the jitteriest number in
  the file).

Metrics missing from the current bench record are SKIPPED, not failed —
a bench invocation without ``--spec-mode both`` simply has no tree arm.
With fewer than ``--min-runs`` prior history entries for a metric the
gate BOOTSTRAPS (passes and records); the second run onward is gated.
On regression the run is NOT appended — a collapsed run must not drag
the baseline down with it — and the process exits 1 with a markdown
diff table (``scripts/ci.sh`` fails on it).

    python scripts/perf_sentinel.py --bench BENCH_serving.json \
        --history BENCH_history.jsonl [--window 8] [--no-append]
    python scripts/perf_sentinel.py --self-test
"""
import argparse
import json
import os
import statistics
import sys
import tempfile
import time


def _path(*keys):
    """Extractor for a nested dict path; None when absent/non-numeric."""
    def get(rec):
        cur = rec
        for k in keys:
            if not isinstance(cur, dict) or k not in cur:
                return None
            cur = cur[k]
        return float(cur) if isinstance(cur, (int, float)) else None
    return get


def _ttft_p50(rec):
    """wdos-side TTFT p50 at the highest arrival rate in async_load."""
    side = rec.get("async_load", {}).get("wdos")
    if not isinstance(side, dict):
        return None
    rates = []
    for k in side:
        try:
            rates.append((float(k), k))
        except (TypeError, ValueError):
            continue
    if not rates:
        return None
    entry = side[max(rates)[1]]
    try:
        return float(entry["ttft_s"]["p50"])
    except (TypeError, KeyError, ValueError):
        return None


# (name, extractor, higher_is_better, relative tolerance vs the median)
HEADLINE = (
    ("warm_tokens_per_s", _path("paged_warm", "tokens_per_s"), True, 0.40),
    ("wdos_rounds_to_drain", _path("par", "wdos", "rounds_to_drain"),
     False, 0.34),
    ("tree_accepted_per_round",
     _path("tree_spec", "arms", "tree", "accepted_per_request_round"),
     True, 0.25),
    ("ttft_p50_s", _ttft_p50, False, 1.00),
)


def extract_headline(bench_record):
    """Pull the headline metric dict out of a BENCH_serving.json record."""
    return {name: fn(bench_record) for name, fn, _, _ in HEADLINE}


def load_history(path):
    """Read BENCH_history.jsonl; corrupt lines are skipped, not fatal."""
    entries = []
    if not os.path.exists(path):
        return entries
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(e, dict) and isinstance(e.get("headline"), dict):
                entries.append(e)
    return entries


def gate(history, headline, window=8, min_runs=2):
    """Gate ``headline`` against the median of the last ``window`` history
    entries per metric.  Returns (rows, failed): ``rows`` is one dict per
    headline metric with status in {"ok", "REGRESSION", "bootstrap",
    "skipped"}; ``failed`` is True iff any metric regressed."""
    rows = []
    failed = False
    for name, _, higher, tol in HEADLINE:
        cur = headline.get(name)
        if cur is None:
            rows.append({"metric": name, "status": "skipped"})
            continue
        recent = [
            e["headline"][name]
            for e in history[-window:]
            if isinstance(e["headline"].get(name), (int, float))
        ]
        if len(recent) < min_runs:
            rows.append({
                "metric": name, "current": cur, "status": "bootstrap",
                "runs": len(recent),
            })
            continue
        base = statistics.median(recent)
        if higher:
            threshold = base * (1.0 - tol)
            bad = cur < threshold
        else:
            threshold = base * (1.0 + tol)
            bad = cur > threshold
        failed = failed or bad
        rows.append({
            "metric": name, "current": cur, "baseline": base,
            "runs": len(recent), "threshold": threshold,
            "direction": "higher" if higher else "lower",
            "status": "REGRESSION" if bad else "ok",
        })
    return rows, failed


def render(rows):
    """Markdown diff table for the gate result."""
    def num(v):
        return f"{v:.4g}" if isinstance(v, (int, float)) else "-"
    lines = [
        "| metric | current | baseline (median) | runs | threshold "
        "| direction | status |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        status = r["status"]
        mark = f"**{status}**" if status == "REGRESSION" else status
        lines.append(
            f"| {r['metric']} | {num(r.get('current'))} "
            f"| {num(r.get('baseline'))} | {r.get('runs', '-')} "
            f"| {num(r.get('threshold'))} | {r.get('direction', '-')} "
            f"| {mark} |"
        )
    return "\n".join(lines)


def append_history(path, headline, meta=None):
    entry = {"t": time.time(), "headline": headline, "meta": meta or {}}
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def check(bench_path, history_path, window=8, min_runs=2, append=True,
          out=sys.stdout):
    """Full sentinel pass: load, gate, print, append-on-pass.

    Returns the process exit code (0 pass / 1 regression)."""
    with open(bench_path) as f:
        bench = json.load(f)
    history = load_history(history_path)
    headline = extract_headline(bench)
    rows, failed = gate(history, headline, window=window, min_runs=min_runs)
    print(render(rows), file=out)
    if failed:
        print(
            f"perf_sentinel: REGRESSION vs median of last "
            f"{min(len(history), window)} runs in {history_path} "
            f"(run NOT appended)", file=out,
        )
        return 1
    if append:
        append_history(history_path, headline,
                       meta=bench.get("meta", {}))
        print(
            f"perf_sentinel: ok ({sum(1 for r in rows if r['status'] == 'ok')}"
            f" gated, {sum(1 for r in rows if r['status'] == 'bootstrap')}"
            f" bootstrapped, {sum(1 for r in rows if r['status'] == 'skipped')}"
            f" skipped) -> appended to {history_path}", file=out,
        )
    else:
        print("perf_sentinel: ok (append disabled)", file=out)
    return 0


def _synthetic_bench(warm=100.0, rounds=6, tree=1.5, ttft=0.05):
    return {
        "meta": {"smoke": True},
        "paged_warm": {"tokens_per_s": warm},
        "par": {"wdos": {"rounds_to_drain": rounds}},
        "tree_spec": {"arms": {"tree": {
            "accepted_per_request_round": tree}}},
        "async_load": {"wdos": {"8.0": {"ttft_s": {"p50": ttft}}}},
    }


def self_test():
    """Prove the gate on synthetic trajectories: first run bootstraps,
    ±10% noise passes, a collapse fails (and is not appended), and a
    lower-is-better blowup fails too.  Exit 0 iff all hold."""
    import io

    with tempfile.TemporaryDirectory() as d:
        bench = os.path.join(d, "bench.json")
        hist = os.path.join(d, "hist.jsonl")

        def run(rec):
            with open(bench, "w") as f:
                json.dump(rec, f)
            buf = io.StringIO()
            rc = check(bench, hist, out=buf)
            return rc, buf.getvalue()

        # 1. empty history bootstraps cleanly (and appends run #1)
        rc, txt = run(_synthetic_bench())
        assert rc == 0 and "bootstrap" in txt, f"bootstrap failed:\n{txt}"
        # 2. second run still below min_runs=2 for gating -> bootstraps
        rc, _ = run(_synthetic_bench(warm=95.0))
        assert rc == 0
        # 3. ±10% noise around the median is tolerated
        for warm in (92.0, 108.0, 99.0):
            rc, txt = run(_synthetic_bench(warm=warm))
            assert rc == 0, f"noise flagged as regression:\n{txt}"
        n_before = len(load_history(hist))
        # 4. a collapse (higher-is-better metric at -70%) fails ...
        rc, txt = run(_synthetic_bench(warm=30.0))
        assert rc == 1 and "REGRESSION" in txt, f"collapse missed:\n{txt}"
        # ... and the collapsed run was NOT appended to the baseline
        assert len(load_history(hist)) == n_before, "regressed run appended"
        # 5. lower-is-better blowup (rounds 6 -> 12, tol 34%) fails
        rc, txt = run(_synthetic_bench(rounds=12))
        assert rc == 1 and "wdos_rounds_to_drain" in txt
        # 6. healthy run still passes after the failures above
        rc, _ = run(_synthetic_bench(warm=101.0))
        assert rc == 0
        # 7. a bench without the tree arm skips it instead of failing
        rec = _synthetic_bench()
        del rec["tree_spec"]
        rc, txt = run(rec)
        assert rc == 0 and "skipped" in txt
    print("perf_sentinel self-test: ok (bootstrap, noise, collapse, "
          "lower-is-better, skip all behave)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--bench", default="BENCH_serving.json",
                    help="bench record to gate (BENCH_serving.json)")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="JSONL trajectory file (appended on pass)")
    ap.add_argument("--window", type=int, default=8,
                    help="gate vs the median of the last N runs")
    ap.add_argument("--min-runs", type=int, default=2,
                    help="bootstrap (pass) below this many prior runs")
    ap.add_argument("--no-append", action="store_true",
                    help="gate only; never write to --history")
    ap.add_argument("--self-test", action="store_true",
                    help="run the synthetic-trajectory proof and exit")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    return check(args.bench, args.history, window=args.window,
                 min_runs=args.min_runs, append=not args.no_append)


if __name__ == "__main__":
    raise SystemExit(main())
