#!/usr/bin/env python
"""Docs link/reference checker (scripts/ci.sh gate).

Two classes of rot this catches:

1. Internal markdown links — every relative ``[text](target)`` in
   ``docs/*.md`` and ``README.md`` must point at an existing file
   (anchors and external URLs are skipped).
2. Module references — every backticked ``*.py`` path in the checked
   files (e.g. the paper-concept table in docs/ARCHITECTURE.md) must
   resolve to a real file, either repo-relative (``src/repro/core/...``)
   or serving-relative shorthand (``serving/engine.py`` ->
   ``src/repro/serving/engine.py``).

Exit 0 when clean, 1 with a listing of every dangling reference.
"""
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PYREF_RE = re.compile(r"`([\w\-./]*\w\.py)\b")


def _resolve_pyref(ref: str):
    """A backticked module path resolves repo-relative or under src/repro."""
    candidates = [REPO / ref, REPO / "src" / "repro" / ref]
    return any(c.is_file() for c in candidates)


def check_file(path: Path):
    errors = []
    text = path.read_text()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        resolved = (path.parent / target.split("#")[0]).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    for ref in PYREF_RE.findall(text):
        if not _resolve_pyref(ref):
            errors.append(
                f"{path.relative_to(REPO)}: references missing module `{ref}`"
            )
    return errors


def main():
    files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    errors = []
    for f in files:
        if f.exists():
            errors.extend(check_file(f))
    if not (REPO / "docs" / "ARCHITECTURE.md").exists():
        errors.append("docs/ARCHITECTURE.md is missing")
    if not (REPO / "docs" / "SERVING.md").exists():
        errors.append("docs/SERVING.md is missing")
    if not (REPO / "docs" / "OBSERVABILITY.md").exists():
        errors.append("docs/OBSERVABILITY.md is missing")
    if errors:
        print("docs check FAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs check OK ({len(files)} files, links + module references)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
