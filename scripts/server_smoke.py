#!/usr/bin/env python
"""CI smoke for the HTTP serving front-end (scripts/ci.sh gate).

Spins up ``CompletionServer`` on a free port over the smoke-scale toy pair
and drives it through the shared ``repro.serving.http_client`` — the same
raw HTTP/1.1 protocol layer the tests and examples use:

1. **bit-identity through the wire** — a streamed SSE completion and a
   non-streamed one must both reproduce the synchronous ``Engine.run``
   tokens exactly (greedy, fixed seed);
2. **stop + top_p end-to-end** — the sampling satellites applied via the
   HTTP payload;
3. **disconnect → abort** — a client hangs up mid-stream; ``/stats`` must
   show every pool page returned;
4. **backpressure** — an over-limit ``"wait": false`` submit must get
   HTTP 429 while the queue is saturated;
5. **observability** — ``GET /metrics`` serves Prometheus text with the
   core series populated by the traffic above; the headline gauges merge
   into ``BENCH_serving.json`` under ``"observability"``.

Exit 0 on success, non-zero (with an assertion message) on any failure.

    PYTHONPATH=src python scripts/server_smoke.py [--json BENCH_serving.json]
"""
import argparse
import asyncio
import json
import os
import sys

import numpy as np

# /metrics must expose at least these family names after the smoke traffic
# (the ISSUE floor is 12 distinct series; the engine registers more).
CORE_SERIES = (
    "serving_ttft_seconds",
    "serving_itl_seconds",
    "serving_round_wall_seconds",
    "serving_admission_wait_seconds",
    "serving_round_acceptance",
    "serving_acceptance_rate",
    "serving_rounds_total",
    "serving_steps_total",
    "serving_queue_depth",
    "serving_active_requests",
    "serving_pool_pages",
    "serving_requests_submitted_total",
    "serving_requests_finished_total",
    "serving_tokens_emitted_total",
    "serving_http_requests_total",
    "serving_http_429_total",
    # tree-speculation families: registered unconditionally (zero-valued
    # under chain drafting, live under spec_mode="tree") so the scrape
    # shape never depends on engine config.
    "serving_tree_nodes_total",
    "serving_tree_branches_total",
    "serving_tree_accept_depth",
    "serving_tree_compactions_total",
    # flight-recorder anomaly counter (labelled by kind, all kinds at 0)
    "serving_anomalies_total",
)


def _headline(metrics) -> dict:
    """The gauges worth tracking across PRs, pulled from the registry."""
    v = metrics.value
    ttft = metrics.get("ttft_seconds")
    itl = metrics.get("itl_seconds")
    return {
        "requests_finished": v("requests_finished_total", reason="length")
        + v("requests_finished_total", reason="stop")
        + v("requests_finished_total", reason="abort"),
        "tokens_emitted": v("tokens_emitted_total"),
        "acceptance_rate": v("acceptance_rate"),
        "ttft_mean_s": ttft.sum_value() / max(ttft.value(), 1),
        "itl_mean_s": itl.sum_value() / max(itl.value(), 1),
        "http_429": v("http_429_total"),
        "series_families": len(list(metrics.series_names())),
    }


async def run(json_path=None):
    from repro.launch.serve import build_pair
    from repro.serving import (
        AsyncEngine, CompletionServer, Engine, EngineConfig, SamplingParams,
    )
    from repro.serving import http_client as hc

    print("building smoke pair ...")
    target, draft = build_pair(seed=0, s_max=128, quantize=False)
    rng = np.random.RandomState(0)
    prompts = [
        [int(t) for t in rng.randint(0, 512, size=5)] for _ in range(4)
    ]

    # synchronous reference for the bit-identity check
    ref_eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    ref_outs, _ = ref_eng.run([np.asarray(prompts[0], np.int32)],
                              SamplingParams(max_tokens=10))
    ref = [int(t) for t in ref_outs[0]]

    engine = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, max_model_len=128,
    ))
    server = CompletionServer(AsyncEngine(engine, max_queued=1))
    await server.start(port=0)
    port = server.port
    serve_task = asyncio.ensure_future(server.serve_forever())
    print(f"server up on :{port}")

    status, decoded = await hc.get_json(port, "/healthz")
    assert status == 200 and decoded["status"] == "ok"

    # 1. bit-identity: streamed and whole completions == Engine.run
    status, _, chunks = await hc.sse_request(
        port, {"prompt": prompts[0], "max_tokens": 10}
    )
    toks = [c["token"] for c in chunks if c["token"] is not None]
    assert status == 200 and toks == ref, f"SSE tokens {toks} != ref {ref}"
    assert chunks[-1]["finish_reason"] == "length"
    status, _, body = await hc.request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[0], "max_tokens": 10},
    )
    assert status == 200 and json.loads(body)["token_ids"] == ref
    print("bit-identity through HTTP OK")

    # 2. stop + top_p through the payload
    stop_s = f"{ref[4]} "
    status, _, body = await hc.request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[0], "max_tokens": 10, "stop": stop_s},
    )
    obj = json.loads(body)
    assert obj["token_ids"] == ref[:4] and obj["finish_reason"] == "stop", obj
    status, _, body = await hc.request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[0], "max_tokens": 10,
         "temperature": 0.8, "top_p": 1e-6, "seed": 3},
    )
    assert json.loads(body)["token_ids"] == ref  # nucleus->argmax == greedy
    print("stop + top_p through HTTP OK")

    # 3. disconnect mid-stream -> abort -> pages return
    reader, writer = await hc.open_request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[1], "max_tokens": 100, "stream": True},
    )
    await hc.read_head(reader)
    await reader.readuntil(b"\n\n")  # first token chunk
    writer.close()  # hang up mid-generation
    st = {}
    for _ in range(200):
        status, st = await hc.get_json(port, "/stats")
        if st["target_pool"]["used_pages"] == 0 and st["active"] == 0:
            break
        await asyncio.sleep(0.05)
    assert st["target_pool"]["used_pages"] == 0, st["target_pool"]
    assert st["target_pool"]["reserved_pages"] == 0, st["target_pool"]
    assert st["draft_pool"]["used_pages"] == 0, st["draft_pool"]
    print("disconnect -> abort returned every pool page OK")

    # 4. backpressure: saturate the 1-deep admission queue, expect 429
    hog_tasks = [
        asyncio.ensure_future(hc.sse_request(
            port, {"prompt": prompts[i], "max_tokens": 40, "seed": i}
        ))
        for i in range(3)  # 2 slots + 1 queued = gate full
    ]
    got_429 = False
    for _ in range(200):
        status, _, _chunks = await hc.sse_request(
            port, {"prompt": prompts[3], "max_tokens": 4, "wait": False}
        )
        if status == 429:
            got_429 = True
            break
        await asyncio.sleep(0.02)
    await asyncio.gather(*hog_tasks)
    assert got_429, "never observed HTTP 429 while the queue was saturated"
    print("backpressure 429 OK")

    # 5. observability: scrape /metrics, assert the core series populated
    status, head, body = await hc.request(port, "GET", "/metrics")
    assert status == 200, status
    assert "text/plain; version=0.0.4" in head, head
    text = body.decode()
    families = {
        line.split()[2] for line in text.splitlines()
        if line.startswith("# TYPE ")
    }
    for name in CORE_SERIES:
        assert name in families, f"/metrics missing {name}"
    assert len(families) >= 12, sorted(families)
    m = engine.metrics
    assert m.value("requests_submitted_total") >= 5
    assert m.value("ttft_seconds") >= 5  # histogram value() == obs count
    assert m.value("http_429_total") >= 1
    print(f"/metrics exposes {len(families)} series families OK")

    serve_task.cancel()
    try:
        await serve_task
    except asyncio.CancelledError:
        pass
    await server.stop()

    if json_path:
        # merge the headline gauges into the serving trajectory file
        # (same pattern as bench_server's "async_load" block)
        merged = {}
        if os.path.exists(json_path):
            try:
                with open(json_path) as f:
                    merged = json.load(f)
            except (json.JSONDecodeError, OSError):
                merged = {}
        merged["observability"] = _headline(m)
        with open(json_path, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
        print(f"observability gauges merged into {json_path}")

    print("server smoke PASSED")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json", default="BENCH_serving.json", metavar="PATH",
        help="merge headline observability gauges into this trajectory "
             "file under 'observability'; '' disables",
    )
    args = ap.parse_args(argv)
    return asyncio.run(run(json_path=args.json or None))


if __name__ == "__main__":
    sys.exit(main())
