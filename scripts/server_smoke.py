#!/usr/bin/env python
"""CI smoke for the HTTP serving front-end (scripts/ci.sh gate).

Spins up ``CompletionServer`` on a free port over the smoke-scale toy pair
and drives it with raw-socket HTTP clients:

1. **bit-identity through the wire** — a streamed SSE completion and a
   non-streamed one must both reproduce the synchronous ``Engine.run``
   tokens exactly (greedy, fixed seed);
2. **stop + top_p end-to-end** — the sampling satellites applied via the
   HTTP payload;
3. **disconnect → abort** — a client hangs up mid-stream; ``/stats`` must
   show every pool page returned;
4. **backpressure** — an over-limit ``"wait": false`` submit must get
   HTTP 429 while the queue is saturated.

Exit 0 on success, non-zero (with an assertion message) on any failure.

    PYTHONPATH=src python scripts/server_smoke.py
"""
import asyncio
import json
import sys

import numpy as np


async def _request(port, method, path, payload=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode() if payload is not None else b""
    writer.write(
        (
            f"{method} {path} HTTP/1.1\r\nHost: ci\r\n"
            f"Content-Length: {len(body)}\r\n\r\n"
        ).encode() + body
    )
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    return status, rest


async def _stream(port, payload):
    """POST a streaming completion; return (status, [chunk dicts])."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(dict(payload, stream=True)).encode()
    writer.write(
        (
            "POST /v1/completions HTTP/1.1\r\nHost: ci\r\n"
            f"Content-Length: {len(body)}\r\n\r\n"
        ).encode() + body
    )
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    if status != 200:
        return status, []
    events = [e for e in rest.decode().split("\n\n") if e.strip()]
    assert events[-1] == "data: [DONE]", f"missing [DONE]: {events[-1]!r}"
    assert all(e.startswith("data: ") for e in events), "bad SSE framing"
    return status, [json.loads(e[len("data: "):]) for e in events[:-1]]


async def main():
    from repro.launch.serve import build_pair
    from repro.serving import (
        AsyncEngine, CompletionServer, Engine, EngineConfig, SamplingParams,
    )

    print("building smoke pair ...")
    target, draft = build_pair(seed=0, s_max=128, quantize=False)
    rng = np.random.RandomState(0)
    prompts = [
        [int(t) for t in rng.randint(0, 512, size=5)] for _ in range(4)
    ]

    # synchronous reference for the bit-identity check
    ref_eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    ref_outs, _ = ref_eng.run([np.asarray(prompts[0], np.int32)],
                              SamplingParams(max_tokens=10))
    ref = [int(t) for t in ref_outs[0]]

    engine = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, max_model_len=128,
    ))
    server = CompletionServer(AsyncEngine(engine, max_queued=1))
    await server.start(port=0)
    port = server.port
    serve_task = asyncio.ensure_future(server.serve_forever())
    print(f"server up on :{port}")

    status, body = await _request(port, "GET", "/healthz")
    assert status == 200 and json.loads(body)["status"] == "ok"

    # 1. bit-identity: streamed and whole completions == Engine.run
    status, chunks = await _stream(
        port, {"prompt": prompts[0], "max_tokens": 10}
    )
    toks = [c["token"] for c in chunks if c["token"] is not None]
    assert status == 200 and toks == ref, f"SSE tokens {toks} != ref {ref}"
    assert chunks[-1]["finish_reason"] == "length"
    status, body = await _request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[0], "max_tokens": 10},
    )
    assert status == 200 and json.loads(body)["token_ids"] == ref
    print("bit-identity through HTTP OK")

    # 2. stop + top_p through the payload
    stop_s = f"{ref[4]} "
    status, body = await _request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[0], "max_tokens": 10, "stop": stop_s},
    )
    obj = json.loads(body)
    assert obj["token_ids"] == ref[:4] and obj["finish_reason"] == "stop", obj
    status, body = await _request(
        port, "POST", "/v1/completions",
        {"prompt": prompts[0], "max_tokens": 10,
         "temperature": 0.8, "top_p": 1e-6, "seed": 3},
    )
    assert json.loads(body)["token_ids"] == ref  # nucleus->argmax == greedy
    print("stop + top_p through HTTP OK")

    # 3. disconnect mid-stream -> abort -> pages return
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps({
        "prompt": prompts[1], "max_tokens": 100, "stream": True,
    }).encode()
    writer.write(
        (
            "POST /v1/completions HTTP/1.1\r\nHost: ci\r\n"
            f"Content-Length: {len(body)}\r\n\r\n"
        ).encode() + body
    )
    await writer.drain()
    await reader.readuntil(b"\r\n\r\n")
    await reader.readuntil(b"\n\n")  # first token chunk
    writer.close()  # hang up mid-generation
    st = {}
    for _ in range(200):
        status, body = await _request(port, "GET", "/stats")
        st = json.loads(body)
        if st["target_pool"]["used_pages"] == 0 and st["active"] == 0:
            break
        await asyncio.sleep(0.05)
    assert st["target_pool"]["used_pages"] == 0, st["target_pool"]
    assert st["target_pool"]["reserved_pages"] == 0, st["target_pool"]
    assert st["draft_pool"]["used_pages"] == 0, st["draft_pool"]
    print("disconnect -> abort returned every pool page OK")

    # 4. backpressure: saturate the 1-deep admission queue, expect 429
    hog_tasks = [
        asyncio.ensure_future(_stream(
            port, {"prompt": prompts[i], "max_tokens": 40, "seed": i}
        ))
        for i in range(3)  # 2 slots + 1 queued = gate full
    ]
    got_429 = False
    for _ in range(200):
        status, _chunks = await _stream(
            port, {"prompt": prompts[3], "max_tokens": 4, "wait": False}
        )
        if status == 429:
            got_429 = True
            break
        await asyncio.sleep(0.02)
    await asyncio.gather(*hog_tasks)
    assert got_429, "never observed HTTP 429 while the queue was saturated"
    print("backpressure 429 OK")

    serve_task.cancel()
    try:
        await serve_task
    except asyncio.CancelledError:
        pass
    await server.stop()
    print("server smoke PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(asyncio.run(main()))
