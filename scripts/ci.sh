#!/usr/bin/env bash
# CI entry point: tier-1 tests + smoke serving benchmarks.
# Mirrors .github/workflows/ci.yml so the same command runs locally.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== docs check (internal links + paper-concept table module refs) =="
python scripts/check_docs.py

echo "== serving benchmark (smoke, Engine over device-resident paged KV) =="
# Emits machine-readable BENCH_serving.json (tokens/s, rounds, acceptance
# rate, copy telemetry) so the perf trajectory is tracked across PRs.
# --par-mode both also A/Bs the fused cross-request PAR scheduler against
# two-phase rounds on a staggered workload (the PAR smoke: rounds-to-drain
# + fused-slot occupancy land in the JSON).  --trace-out records the wdos
# arm with the span tracer and exports the staggered round timeline as
# Perfetto-loadable Chrome-trace JSON (validated below).  --kv-quant both
# A/Bs int8 KV pools against dense at a fixed pool byte budget (bytes/token,
# resident-request capacity, acceptance delta — gated below).  --spec-mode
# both A/Bs tree-structured speculation against single-chain drafting on a
# low-acceptance sampled workload (accepted tokens per request-round +
# greedy bit-identity — gated below).
python -m benchmarks.bench_serving --smoke --kv-path paged --par-mode both \
    --kv-quant both --spec-mode both \
    --json BENCH_serving.json --trace-out TRACE_wdos.json

echo "== paged-path kernel smoke (batch 4, Pallas interpret mode) =="
# Exercises the kernel-wired decode path end to end every run: the Engine
# dispatching decode+verify attention through kernels/paged_attn.py.
python -m benchmarks.bench_serving --smoke --kv-path paged --paged-attn pallas \
    --json BENCH_serving_pallas.json

echo "== HTTP serving front-end smoke (stream, stop/top_p, disconnect->abort, 429, /metrics) =="
# Spins up serving/server.py over asyncio streams and drives it through the
# shared serving/http_client.py: SSE bit-identity vs Engine.run, a
# mid-stream disconnect that must return every pool page, a fail-fast 429
# under saturation, and a GET /metrics scrape asserting the core Prometheus
# series; headline observability gauges merge into BENCH_serving.json.
python scripts/server_smoke.py --json BENCH_serving.json

echo "== open-loop Poisson load harness (TTFT/ITL/E2E percentiles) =="
# Appends "async_load" latency percentiles (A/B par_mode off vs wdos at
# several arrival rates) into the BENCH_serving.json written above.
python -m benchmarks.bench_server --smoke --json BENCH_serving.json

echo "== shared-prefix workload A/B (prefix_cache on vs off) =="
# Multi-tenant Poisson workload (N system prompts x M users) against two
# engines fed the SAME arrival schedule; appends the "prefix_cache" record
# (hit rate, prefill tokens saved, TTFT A/B, bit-identity) — gated below.
python -m benchmarks.bench_server --smoke --shared-prefix \
    --json BENCH_serving.json

echo "== serving perf record =="
python - <<'EOF'
import json
for p in ("BENCH_serving.json", "BENCH_serving_pallas.json"):
    r = json.load(open(p))
    cfgs = {(c["kv_path"], c["max_batch"]): c["tokens_per_s"] for c in r["configs"]}
    print(p, {k: round(v, 1) for k, v in cfgs.items()})
par = json.load(open("BENCH_serving.json")).get("par")
if par:
    print("PAR A/B rounds-to-drain:",
          {m: par[m]["rounds_to_drain"] for m in par},
          "fused occupancy:",
          round(par["wdos"].get("fused", {}).get("occupancy", 0.0), 3))
load = json.load(open("BENCH_serving.json")).get("async_load")
if load:
    for mode in load["meta"]["modes"]:
        for rate, e in sorted(load[mode].items(), key=lambda kv: float(kv[0])):
            print(f"async {mode} @{rate} req/s:",
                  f"{e['tokens_per_s']:.1f} tok/s,",
                  f"TTFT p99 {e['ttft_s']['p99']*1e3:.0f} ms,",
                  f"E2E p99 {e['e2e_s']['p99']*1e3:.0f} ms")
obs = json.load(open("BENCH_serving.json")).get("observability")
if obs:
    print("observability:", {k: round(v, 4) if isinstance(v, float) else v
                             for k, v in sorted(obs.items())})
att = json.load(open("BENCH_serving.json")).get("attribution")
if att:
    print("device-time attribution (modeled vs measured, wdos arm):")
    print(att["table"])
EOF

echo "== compressed-KV gate (int8 capacity win + acceptance bound) =="
# int8 KV must (a) store >= 1.8x fewer bytes per token, (b) fit >= 1.8x
# more resident requests at the same pool byte budget, and (c) keep the
# speculative acceptance rate within 0.05 absolute of dense storage — the
# contract that makes kv_quant="int8" a safe opt-in.
python - <<'EOF'
import json
kvq = json.load(open("BENCH_serving.json"))["kv_quant"]
bytes_ratio = kvq["bytes_per_token_ratio"]
resident_ratio = kvq["resident_requests_ratio"]
delta = kvq["acceptance_delta"]
assert bytes_ratio >= 1.8, f"bytes/token ratio {bytes_ratio:.2f}x < 1.8x"
assert resident_ratio >= 1.8, \
    f"resident-request ratio {resident_ratio:.2f}x < 1.8x"
assert delta <= 0.05, f"int8 acceptance delta {delta:.3f} > 0.05"
print(f"kv_quant OK: {bytes_ratio:.2f}x fewer bytes/token, "
      f"{resident_ratio:.2f}x resident requests @ fixed budget, "
      f"acceptance delta {delta:.3f} <= 0.05")
EOF

echo "== prefix-cache gate (sharing must hit, save prefill, stay bit-identical) =="
# The shared-prefix A/B is only a win if (a) the radix tree actually hits,
# (b) sharing skips a majority of prefill rows, and (c) the emitted tokens
# are bit-identical to sharing off — the determinism contract that makes
# prefix_cache=True a safe default for multi-tenant serving.
python - <<'EOF'
import json
pc = json.load(open("BENCH_serving.json"))["prefix_cache"]
hit = pc["hit_rate"]
saved = pc["prefill_tokens_saved_frac"]
assert pc["bit_identical"], "prefix sharing changed emitted tokens"
assert hit > 0.0, f"prefix hit rate {hit:.2f} — cache never hit"
assert saved > 0.5, f"prefill tokens saved {saved:.2%} <= 50%"
print(f"prefix_cache OK: hit_rate {hit:.2f}, "
      f"{saved:.0%} prefill rows skipped, "
      f"TTFT p50 {pc['ttft_p50_off_s']*1e3:.0f} -> "
      f"{pc['ttft_p50_on_s']*1e3:.0f} ms, bit-identical")
EOF

echo "== tree-speculation gate (branch trees must out-accept chains, losslessly) =="
# Tree speculation pays for its extra verified nodes only if it commits more
# tokens per round than chain drafting on the SAME workload — and it is only
# shippable if greedy output is untouched (branching changes rounds, never
# content).  Gate both, on the A/B the bench just recorded.
python - <<'EOF'
import json
ts = json.load(open("BENCH_serving.json"))["tree_spec"]
chain = ts["arms"]["chain"]["accepted_per_request_round"]
tree = ts["arms"]["tree"]["accepted_per_request_round"]
assert ts["greedy_bit_identical"], "greedy tree stream != greedy chain stream"
assert tree > chain, \
    f"tree accepted/round {tree:.3f} <= chain {chain:.3f}"
print(f"tree_spec OK: {chain:.3f} -> {tree:.3f} accepted tok/request-round "
      f"({ts['accepted_per_round_ratio']:.2f}x), greedy bit-identical")
EOF

echo "== wdos round-timeline trace (Chrome-trace schema gate) =="
# The bench's --trace-out must round-trip through the Chrome-trace schema
# checker non-empty — the same JSON a developer drops into Perfetto.  The
# checker also enforces the device-track rules (thread-name metadata for
# every tid, non-overlapping device spans); this stanza additionally
# asserts the device track EXISTS and carries the fused wdos program,
# with its modeled-vs-measured row landed in BENCH_serving.json.
python - <<'EOF'
import json
from repro.serving import validate_chrome_trace
trace = json.load(open("TRACE_wdos.json"))
problems = validate_chrome_trace(trace)
assert not problems, problems[:5]
events = trace["traceEvents"]
assert len(events) > 10, f"trace suspiciously small: {len(events)} events"
meta = {e["tid"]: e["args"]["name"] for e in events if e["ph"] == "M"}
tracks = set(meta.values())
assert "engine" in tracks and any(t.startswith("row") for t in tracks), tracks
assert "device" in tracks, f"no device track in {sorted(tracks)}"
dev_tids = {tid for tid, name in meta.items() if name == "device"}
dev_progs = {e["name"] for e in events
             if e["ph"] == "X" and e["tid"] in dev_tids}
assert "fused_wdos" in dev_progs, f"device track spans: {sorted(dev_progs)}"
att = json.load(open("BENCH_serving.json"))["attribution"]["programs"]
assert "fused_wdos" in att, sorted(att)
assert att["fused_wdos"]["calls"] >= 1 and "utilization_pct" in att["fused_wdos"]
print(f"TRACE_wdos.json OK: {len(events)} events across "
      f"{len(tracks)} tracks {sorted(tracks)}; device programs "
      f"{sorted(dev_progs)}; attribution rows {sorted(att)}")
EOF

echo "== perf-regression sentinel (BENCH_history.jsonl trajectory gate) =="
# First PROVE the gate works on synthetic trajectories (an injected -70%
# collapse must exit 1; ±10% noise and first-run bootstrap must pass),
# then gate the real record vs the median of recent runs and append it.
python scripts/perf_sentinel.py --self-test
python scripts/perf_sentinel.py --bench BENCH_serving.json \
    --history BENCH_history.jsonl

echo "== property-based suites (hypothesis-randomized oracles) =="
# hypothesis is a first-class dev dependency (requirements-dev.txt): with
# it installed the dedicated property module runs here as a gate, and the
# @given oracles embedded in test_kernels/test_quantization/test_rotation/
# test_paged_attn run inside the tier-1 suite below.  A bare runtime env
# (requirements.txt only) degrades to per-test skips via tests/_optional.py
# instead of failing collection — so this stanza notices, never breaks.
if python -c "import hypothesis" >/dev/null 2>&1; then
    python -m pytest -x -q tests/test_properties.py
else
    echo "hypothesis not installed: property tests skip individually in the tier-1 run"
fi

echo "== tier-1 tests (gate) =="
# Mesh-dependent tests in test_launch.py / test_models.py run on every JAX
# via launch/mesh.py:activate_mesh (presence-keyed jax.set_mesh ->
# jax.sharding.use_mesh -> legacy Mesh-context fallback); only the
# genuinely multi-device test_substrate.py case stays skipif-guarded on
# single-device CPU, so the whole suite gates.
python -m pytest -x -q
