#!/usr/bin/env bash
# CI entry point: tier-1 tests + a smoke serving benchmark.
# Mirrors .github/workflows/ci.yml so the same command runs locally.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== serving benchmark (smoke) =="
python -m benchmarks.bench_serving --smoke

# Modules with known seed failures on single-device CPU (ROADMAP open
# items) run informationally so regressions elsewhere still gate CI.
echo "== known-failing seed modules (informational) =="
python -m pytest -q tests/test_launch.py tests/test_models.py \
  tests/test_substrate.py || true

echo "== tier-1 tests (gate) =="
python -m pytest -x -q --ignore=tests/test_launch.py \
  --ignore=tests/test_models.py --ignore=tests/test_substrate.py
