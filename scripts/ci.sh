#!/usr/bin/env bash
# CI entry point: tier-1 tests + smoke serving benchmarks.
# Mirrors .github/workflows/ci.yml so the same command runs locally.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== serving benchmark (smoke, device-resident paged KV) =="
python -m benchmarks.bench_serving --smoke --kv-path paged

echo "== paged-path kernel smoke (batch 4, Pallas interpret mode) =="
# Exercises the kernel-wired decode path end to end every run: serve_batch
# dispatching decode+verify attention through kernels/paged_attn.py.
python -m benchmarks.bench_serving --smoke --kv-path paged --paged-attn pallas

echo "== tier-1 tests (gate) =="
# Pre-existing mesh/JAX-version-dependent seed failures in test_launch.py /
# test_models.py / test_substrate.py are now pytest.mark.skipif-guarded on
# single-device CPU, so the whole suite gates.
python -m pytest -x -q
