"""Step builders for the dry-run / trainer / server: construct the jitted
(train | prefill | decode) function for an (arch config x shape) cell plus
abstract (ShapeDtypeStruct) inputs and shardings — nothing here allocates
device memory; ``.lower().compile()`` on the results is the multi-pod
dry-run.

``input_specs(cfg, shape, mesh)`` is the assignment-required entry point:
ShapeDtypeStruct stand-ins for every model input of the cell.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.models import whisper as W
from repro.models.common import Family, ModelConfig, SHAPES, ShapeConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, optimizer_specs
from repro.optim import linear_warmup_cosine

__all__ = ["abstract_model", "input_specs", "build_cell", "CellSpec", "param_counts"]


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Analytic parameter counts: total and flops-active-per-token.

    active: MoE counts router + top_k experts; hybrid counts the shared attn
    block once per application; whisper counts encoder + decoder (the
    encoder runs over frames, an approximation noted in EXPERIMENTS.md)."""
    d, f, v, hd = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.hd
    attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv * hd * 2
    n_mats = 3 if cfg.act == "swiglu" else 2
    mlp = n_mats * d * f
    embed = 2 * v * d  # untied in/out embeddings
    if cfg.family is Family.SSM:
        din = cfg.d_inner
        per = d * (2 * din + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads) + din * d
        total = embed + cfg.n_layers * per
        return {"total": total, "active": total}
    if cfg.family is Family.HYBRID:
        din = cfg.d_inner
        per = d * (2 * din + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads) + din * d
        napp = cfg.n_layers // cfg.attn_every
        shared = attn + mlp
        total = embed + cfg.n_layers * per + shared
        active = embed + cfg.n_layers * per + napp * shared
        return {"total": total, "active": active}
    if cfg.family is Family.MOE:
        router = d * cfg.n_experts
        total = embed + cfg.n_layers * (attn + router + cfg.n_experts * mlp)
        active = embed + cfg.n_layers * (attn + router + cfg.top_k * mlp)
        return {"total": total, "active": active}
    if cfg.family is Family.AUDIO:
        enc = cfg.n_encoder_layers * (attn + mlp)
        dec = cfg.n_layers * (2 * attn + mlp)  # self + cross
        total = embed + enc + dec
        return {"total": total, "active": total}
    total = embed + cfg.n_layers * (attn + mlp)
    return {"total": total, "active": total}


def _ns(mesh, spec):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec, is_leaf=lambda s: isinstance(s, P)
    )


def batch_axes(mesh, batch=None):
    if batch is None:
        return ("pod", "data") if "pod" in mesh.shape else "data"
    from repro.models.layers import pick_batch_axes

    return pick_batch_axes(mesh, batch)


# ---------------------------------------------------------------------------
# Abstract params/opt/caches (no allocation)
# ---------------------------------------------------------------------------


def abstract_model(cfg: ModelConfig, tp: int):
    """(abstract params, param specs) via shape-only tracing."""
    holder: Dict[str, Any] = {}

    def shapes_only(key):
        if cfg.family is Family.AUDIO:
            p, s = W.init_whisper(key, cfg, tp)
        else:
            p, s = lm.init_lm(key, cfg, tp)
        holder["specs"] = s
        return p

    aparams = jax.eval_shape(shapes_only, jax.random.PRNGKey(0))
    return aparams, holder["specs"]


def abstract_opt(aparams, ocfg: AdamWConfig):
    return jax.eval_shape(lambda p: adamw_init(p, ocfg), aparams)


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int, tp: int):
    if cfg.family is Family.AUDIO:
        return jax.eval_shape(
            lambda: W.init_whisper_cache(cfg, batch, s_max, tp)
        )
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, s_max, tp))


def _cache_specs(cfg: ModelConfig, tp: int, ba):
    if cfg.family is Family.AUDIO:
        return W.whisper_cache_specs(cfg, tp, ba)
    return lm.cache_specs(cfg, tp, ba)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, mesh
) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, Any]]:
    """-> ({name: ShapeDtypeStruct}, {name: NamedSharding}) for the cell's
    data inputs (params/opt/cache handled by build_cell)."""
    b = shape.global_batch
    ba = batch_axes(mesh, b)
    structs: Dict[str, jax.ShapeDtypeStruct] = {}
    shardings: Dict[str, Any] = {}
    tok_spec = NamedSharding(mesh, P(ba, None))
    if shape.kind == "train":
        s = shape.seq_len
        if cfg.family is Family.VLM:
            structs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.n_vision_tokens + 1), jnp.int32)
            structs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), cfg.jdtype
            )
            shardings["vision_embeds"] = NamedSharding(mesh, P(ba, None, None))
        elif cfg.family is Family.AUDIO:
            structs["tokens"] = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
            structs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), cfg.jdtype
            )
            shardings["frames"] = NamedSharding(mesh, P(ba, None, None))
        else:
            structs["tokens"] = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
        shardings["tokens"] = tok_spec
    elif shape.kind == "prefill":
        s = shape.seq_len
        if cfg.family is Family.VLM:
            structs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.n_vision_tokens), jnp.int32)
            structs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), cfg.jdtype
            )
            shardings["vision_embeds"] = NamedSharding(mesh, P(ba, None, None))
        elif cfg.family is Family.AUDIO:
            structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            structs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), cfg.jdtype
            )
            shardings["frames"] = NamedSharding(mesh, P(ba, None, None))
        else:
            structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        shardings["tokens"] = tok_spec
    else:  # decode: one new token against a seq_len-deep cache
        structs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        shardings["tokens"] = tok_spec
    return structs, shardings


# ---------------------------------------------------------------------------
# Cell builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    fn: Callable  # jitted
    args: Tuple[Any, ...]  # abstract args (ShapeDtypeStruct trees)
    kind: str


def build_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    ocfg: Optional[AdamWConfig] = None,
    donate: bool = True,
) -> CellSpec:
    tp = mesh.shape["model"]
    ba = batch_axes(mesh, shape.global_batch)
    aparams, pspecs = abstract_model(cfg, tp)
    param_sh = _ns(mesh, pspecs)
    structs, data_sh = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        ocfg = ocfg or AdamWConfig(moment_dtype=cfg.optim_dtype)
        aopt = abstract_opt(aparams, ocfg)
        zero1 = None if cfg.fsdp else "data"
        opt_sh = _ns(
            mesh,
            optimizer_specs(
                pspecs, aparams, zero1_axis=zero1, axis_size=mesh.shape["data"]
            ),
        )
        opt_sh["count"] = NamedSharding(mesh, P())

        if cfg.family is Family.AUDIO:

            def train_step(params, opt_state, step, tokens, frames):
                lscale = linear_warmup_cosine(step, 100, 10000)
                loss, grads = jax.value_and_grad(W.whisper_loss_fn)(
                    params, cfg, mesh, tokens, frames
                )
                params, opt_state, m = adamw_update(params, grads, opt_state, ocfg, lscale)
                return params, opt_state, {"loss": loss, **m}

            args = (aparams, aopt, jax.ShapeDtypeStruct((), jnp.int32),
                    structs["tokens"], structs["frames"])
            in_sh = (param_sh, opt_sh, NamedSharding(mesh, P()),
                     data_sh["tokens"], data_sh["frames"])
        elif cfg.family is Family.VLM:

            def train_step(params, opt_state, step, tokens, vision):
                lscale = linear_warmup_cosine(step, 100, 10000)
                loss, grads = jax.value_and_grad(lm.loss_fn)(
                    params, cfg, mesh, tokens, vision_embeds=vision
                )
                params, opt_state, m = adamw_update(params, grads, opt_state, ocfg, lscale)
                return params, opt_state, {"loss": loss, **m}

            args = (aparams, aopt, jax.ShapeDtypeStruct((), jnp.int32),
                    structs["tokens"], structs["vision_embeds"])
            in_sh = (param_sh, opt_sh, NamedSharding(mesh, P()),
                     data_sh["tokens"], data_sh["vision_embeds"])
        else:

            def train_step(params, opt_state, step, tokens):
                lscale = linear_warmup_cosine(step, 100, 10000)
                loss, grads = jax.value_and_grad(lm.loss_fn)(
                    params, cfg, mesh, tokens
                )
                if cfg.grad_barrier:
                    # keep the gradient reduction in bf16: without this the
                    # partitioner hoists the optimizer's f32 cast above the
                    # cross-device reduce, doubling its bytes
                    grads = jax.lax.optimization_barrier(grads)
                if cfg.grad_constraint:
                    # pin gradients to the parameter sharding BEFORE the
                    # update: the partitioner then reduce-scatters the
                    # backward partials instead of all-reducing full grads
                    flat_s, tdef = jax.tree.flatten(
                        pspecs, is_leaf=lambda x: isinstance(x, P)
                    )
                    flat_g = tdef.flatten_up_to(grads)
                    grads = tdef.unflatten([
                        jax.lax.with_sharding_constraint(g, sp)
                        for g, sp in zip(flat_g, flat_s)
                    ])
                params, opt_state, m = adamw_update(params, grads, opt_state, ocfg, lscale)
                return params, opt_state, {"loss": loss, **m}

            args = (aparams, aopt, jax.ShapeDtypeStruct((), jnp.int32),
                    structs["tokens"])
            in_sh = (param_sh, opt_sh, NamedSharding(mesh, P()),
                     data_sh["tokens"])
        fn = jax.jit(
            train_step,
            in_shardings=in_sh,
            donate_argnums=(0, 1) if donate else (),
        )
        return CellSpec(fn=fn, args=args, kind="train")

    # ---- serving cells
    cache_sh = _ns(mesh, _cache_specs(cfg, tp, ba))
    if shape.kind == "prefill":
        acache = abstract_cache(cfg, shape.global_batch, shape.seq_len, tp)
        if cfg.family is Family.AUDIO:

            def prefill(params, tokens, frames, cache):
                return W.apply_whisper(
                    params, cfg, mesh, tokens, frames=frames, cache=cache,
                    last_logit_only=True,
                )

            args = (aparams, structs["tokens"], structs["frames"], acache)
            in_sh = (param_sh, data_sh["tokens"], data_sh["frames"], cache_sh)
        elif cfg.family is Family.VLM:

            def prefill(params, tokens, vision, cache):
                return lm.apply_lm(
                    params, cfg, mesh, tokens, cache=cache,
                    vision_embeds=vision, last_logit_only=True,
                )

            args = (aparams, structs["tokens"], structs["vision_embeds"], acache)
            in_sh = (param_sh, data_sh["tokens"], data_sh["vision_embeds"], cache_sh)
        else:

            def prefill(params, tokens, cache):
                return lm.apply_lm(
                    params, cfg, mesh, tokens, cache=cache, last_logit_only=True
                )

            args = (aparams, structs["tokens"], acache)
            in_sh = (param_sh, data_sh["tokens"], cache_sh)
        fn = jax.jit(
            prefill,
            in_shardings=in_sh,
            donate_argnums=(3,) if cfg.family in (Family.AUDIO, Family.VLM) and donate else ((2,) if donate else ()),
        )
        return CellSpec(fn=fn, args=args, kind="prefill")

    # decode: one token against a seq_len-deep cache
    acache = abstract_cache(cfg, shape.global_batch, shape.seq_len, tp)
    if cfg.family is Family.AUDIO:

        def decode(params, tokens, cache):
            return W.apply_whisper(params, cfg, mesh, tokens, cache=cache)

    else:

        def decode(params, tokens, cache):
            return lm.apply_lm(params, cfg, mesh, tokens, cache=cache)

    args = (aparams, structs["tokens"], acache)
    in_sh = (param_sh, data_sh["tokens"], cache_sh)
    fn = jax.jit(decode, in_shardings=in_sh, donate_argnums=(2,) if donate else ())
    return CellSpec(fn=fn, args=args, kind="decode")
