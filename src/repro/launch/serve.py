"""Serving driver: the paper's full pipeline on real weights.

    python -m repro.launch.serve --mode apsd --tokens 64

Builds a (smoke-scale) TLM/DLM pair, quantizes the TLM to W4A8 with the
LRU rotation, compresses the DLM with BVQ, and decodes with vanilla SD or
APSD.  Greedy decoding is LOSSLESS: the output equals plain autoregressive
decoding of the bf16 TLM quantized model (asserted with --check).

On a TPU mesh the same ServingModel wiring dispatches draft and verify as
one program over disjoint mesh slices (the WDOS overlap); here on CPU it
runs serially but bit-identically.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.paper_pair import DLM_SMOKE, TLM_SMOKE
from repro.core import bvq as bvq_mod
from repro.core.apsd import APSDConfig
from repro.core.speculative import SDConfig
from repro.models import lm
from repro.serving import quantized_lm as qlm
from repro.serving.engine import ServingModel, make_interface, serve_apsd, serve_sd

__all__ = ["build_pair", "main"]


def build_pair(seed: int = 0, s_max: int = 256, quantize: bool = True):
    """(target ServingModel, draft ServingModel) at smoke scale."""
    key = jax.random.PRNGKey(seed)
    kt, kd = jax.random.split(key)
    tparams, _ = lm.init_lm(kt, TLM_SMOKE, tp=1)
    # the draft is a BVQ-compressed clone of a same-vocab small model
    dparams, _ = lm.init_lm(kd, DLM_SMOKE, tp=1)
    if quantize:
        tq = qlm.quantize_dense_lm(tparams, TLM_SMOKE, bits=4, rotate=True)
        target = ServingModel(cfg=TLM_SMOKE, params=tq, mode="w4a8", s_max=s_max)
        bcfg = bvq_mod.BVQConfig(
            vec_dim=4, codebook_size=64, block_cols=32, kmeans_iters=8, qat_steps=0
        )
        dq = qlm.bvq_compress_lm(dparams, DLM_SMOKE, bcfg, jax.random.PRNGKey(7))
        draft = ServingModel(cfg=DLM_SMOKE, params=dq, mode="bvq", s_max=s_max)
    else:
        target = ServingModel(cfg=TLM_SMOKE, params=tparams, mode="bf16", s_max=s_max)
        draft = ServingModel(cfg=DLM_SMOKE, params=dparams, mode="bf16", s_max=s_max)
    return target, draft


def greedy_reference(target: ServingModel, prompt, n: int):
    """Plain autoregressive greedy decode of the target model."""
    iface = make_interface(target)
    _, cache = iface.prefill(target.params, prompt[:, :-1])
    cur = prompt[0, -1]
    out = []
    for _ in range(n):
        lg, cache = iface.extend(target.params, cur.reshape(1, 1), cache)
        cur = jnp.argmax(lg[0, -1]).astype(jnp.int32)
        out.append(int(cur))
    return jnp.asarray(out, jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["sd", "apsd", "ad"], default="apsd")
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--check", action="store_true", help="assert losslessness")
    args = ap.parse_args(argv)

    target, draft = build_pair(quantize=not args.no_quant)
    prompt = jnp.asarray([[5, 17, 3, 99]], jnp.int32)
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    if args.mode == "ad":
        toks = greedy_reference(target, prompt, args.tokens)
        stats = None
    elif args.mode == "sd":
        toks, stats = serve_sd(
            key, target, draft, prompt,
            SDConfig(draft_len=args.draft_len, temperature=0.0, max_tokens=args.tokens),
        )
    else:
        toks, stats = serve_apsd(
            key, target, draft, prompt,
            APSDConfig(short_dl=2, long_dl=6, temperature=0.0, max_tokens=args.tokens),
        )
    dt = time.time() - t0
    print(f"mode={args.mode} tokens={len(toks)} wall={dt:.2f}s")
    print("output:", [int(t) for t in toks])
    if stats is not None:
        if hasattr(stats, "acceptance_rate"):
            print(f"acceptance={float(stats.acceptance_rate):.3f}")
        else:
            print(f"rejected_ratio={stats.rejected_ratio:.3f} "
                  f"par_rounds={stats.par_rounds}/{stats.rounds}")
    if args.check and args.mode in ("sd", "apsd"):
        ref = greedy_reference(target, prompt, args.tokens)
        assert bool(jnp.all(ref == toks)), "speculative output != AD reference"
        print("LOSSLESS: speculative output == autoregressive reference")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
