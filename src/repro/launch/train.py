"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires the full production stack: config -> mesh -> sharded init ->
data pipeline -> jitted train step (remat + scan + ZeRO-1) -> async
checkpointing -> fault-tolerant elastic loop.  On this CPU container use
--smoke (reduced config, 1-device mesh); the same code path drives the
TPU fleet with the production mesh.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.mesh import (activate_mesh, make_cpu_mesh,
                               make_production_mesh)
from repro.launch.steps import batch_axes, param_counts
from repro.models import lm
from repro.models import whisper as W
from repro.models.common import Family, ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, optimizer_specs
from repro.optim import linear_warmup_cosine

__all__ = ["Trainer", "main"]


class Trainer:
    """Mesh-aware trainer with checkpoint-restart."""

    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        seq_len: int = 128,
        global_batch: int = 8,
        ocfg: Optional[AdamWConfig] = None,
        ckpt_dir: Optional[str] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.ocfg = ocfg or AdamWConfig(lr=1e-3, moment_dtype=cfg.optim_dtype)
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_dir = ckpt_dir
        tp = mesh.shape["model"]
        self.data = SyntheticLMDataset(
            DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
                       seed=seed)
        )

        key = jax.random.PRNGKey(seed)
        with activate_mesh(mesh):
            if cfg.family is Family.AUDIO:
                params, specs = W.init_whisper(key, cfg, tp)
            else:
                params, specs = lm.init_lm(key, cfg, tp)
        self.param_specs = specs
        self.params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                 is_leaf=lambda s: isinstance(s, P))
        )
        self.opt_state = adamw_init(self.params, self.ocfg)
        self.step = 0
        self._jit_step = self._build_step()

    def _build_step(self):
        cfg, mesh, ocfg = self.cfg, self.mesh, self.ocfg

        if cfg.family is Family.AUDIO:
            def step_fn(params, opt_state, step, tokens, frames):
                lscale = linear_warmup_cosine(step, 20, 2000)
                loss, grads = jax.value_and_grad(W.whisper_loss_fn)(
                    params, cfg, mesh, tokens, frames
                )
                params, opt_state, m = adamw_update(params, grads, opt_state, ocfg, lscale)
                return params, opt_state, {"loss": loss, **m}
        else:
            def step_fn(params, opt_state, step, tokens):
                lscale = linear_warmup_cosine(step, 20, 2000)
                loss, grads = jax.value_and_grad(lm.loss_fn)(params, cfg, mesh, tokens)
                params, opt_state, m = adamw_update(params, grads, opt_state, ocfg, lscale)
                return params, opt_state, {"loss": loss, **m}

        return jax.jit(step_fn, donate_argnums=(0, 1))

    def restore(self) -> bool:
        if not self.ckpt_dir or latest_step(self.ckpt_dir) is None:
            return False
        step, tree, _ = load_checkpoint(self.ckpt_dir)
        self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
        self.step = step + 1
        return True

    def run(self, steps: int, ckpt_every: int = 50, log_every: int = 10):
        history = []
        with activate_mesh(self.mesh):
            for _ in range(steps):
                batch = jnp.asarray(self.data.batch(self.step))
                if self.cfg.family is Family.AUDIO:
                    frames = jax.random.normal(
                        jax.random.PRNGKey(self.step),
                        (self.global_batch, self.cfg.n_audio_frames, self.cfg.d_model),
                        self.cfg.jdtype,
                    )
                    self.params, self.opt_state, m = self._jit_step(
                        self.params, self.opt_state, jnp.asarray(self.step), batch, frames
                    )
                else:
                    self.params, self.opt_state, m = self._jit_step(
                        self.params, self.opt_state, jnp.asarray(self.step), batch
                    )
                loss = float(m["loss"])
                history.append({"step": self.step, "loss": loss})
                if self.step % log_every == 0:
                    print(f"step {self.step:5d} loss {loss:.4f}", flush=True)
                if self.ckpt and self.step % ckpt_every == 0:
                    self.ckpt.save(
                        self.step, {"params": self.params, "opt": self.opt_state}
                    )
                self.step += 1
        if self.ckpt:
            self.ckpt.wait()
        return history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config, CPU mesh")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    if args.smoke:
        cfg = get_smoke(args.arch)
        mesh = make_cpu_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    pc = param_counts(cfg)
    print(f"arch={cfg.name} params~{pc['total']/1e6:.1f}M active~{pc['active']/1e6:.1f}M")
    tr = Trainer(cfg, mesh, seq_len=args.seq_len, global_batch=args.batch,
                 ckpt_dir=args.ckpt_dir)
    tr.restore()
    hist = tr.run(args.steps)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
