"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  Single-pod: 16 x 16 = 256 chips (data, model);
multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model) — data-parallel
replicas across pods, tensor/expert parallelism within a pod (ICI), pod
axis crossing DCI.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_cpu_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 real device unless XLA_FLAGS says more)."""
    return jax.make_mesh((data, model), ("data", "model"))
