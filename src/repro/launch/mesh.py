"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  Single-pod: 16 x 16 = 256 chips (data, model);
multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model) — data-parallel
replicas across pods, tensor/expert parallelism within a pod (ICI), pod
axis crossing DCI.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_cpu_mesh", "activate_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 real device unless XLA_FLAGS says more)."""
    return jax.make_mesh((data, model), ("data", "model"))


def activate_mesh(mesh):
    """Context manager making `mesh` the ambient mesh for jit/sharding.

    jax has moved this API across releases (`with mesh:` on the Mesh
    object -> `jax.sharding.use_mesh` -> `jax.set_mesh`); version-string
    checks rot, so select on API PRESENCE: the newest entry point this
    jax exposes, falling back to the legacy Mesh context manager, which
    every supported jax still implements.  All launch entry points and
    mesh-dependent tests route through here — never call `jax.set_mesh`
    directly.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # legacy: the Mesh object is itself a context manager
