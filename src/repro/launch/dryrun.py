import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms from the compiled artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes --out dryrun.json

Loop-body accounting: XLA's cost_analysis on the CPU backend counts a
while-loop body ONCE, and scan-over-layers puts the whole stack in one
loop.  Every cell is therefore lowered twice more at depth 1 and depth 2
(same weight shapes, tiny graphs): metric(L) = a + b*L is fitted and
extrapolated to the full depth — exact for homogeneous stacks (the hybrid
tail scan, 3 of 81 layers, stays once-counted; noted in EXPERIMENTS.md).
Collective bytes inside the loop get the same correction; ring factors per
collective kind are applied in the roofline terms.

Per cell this records: memory_analysis (fit proof), corrected HLO FLOPs /
bytes, the collective schedule, and the three roofline terms against TPU
v5e constants (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

# ring-bandwidth factors on a 16-wide axis: bytes crossing the busiest link
# per shard-byte of collective payload
RING_FACTOR = {
    "all-reduce": 2.0 * 15 / 16,
    "all-gather": 15 / 16,
    "reduce-scatter": 15 / 16,
    "all-to-all": 15 / 16,
    "collective-permute": 1.0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(
    r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64|u16|s16)\[([\d,]*)\]"
)
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "u16": 2, "s16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-kind totals of collective OUTPUT shard bytes in the compiled HLO."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(3)
        shapes_str = m.group(1) if m.group(1) is not None else m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes_str or ""):
            n = 1
            for d in sm.group(2).split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[sm.group(1)]
        slot = out.setdefault(kind, {"bytes": 0.0, "count": 0})
        slot["bytes"] += float(nbytes)
        slot["count"] += 1
    return out


# ---------------------------------------------------------------------------
# Cell measurement
# ---------------------------------------------------------------------------


def _measure(cfg, shape, mesh, want_memory: bool) -> Dict[str, Any]:
    import jax
    from repro.launch.mesh import activate_mesh
    from repro.launch.steps import build_cell

    t0 = time.time()
    with activate_mesh(mesh):
        cell = build_cell(cfg, shape, mesh)
        lowered = cell.fn.lower(*cell.args)
        compiled = lowered.compile()
    elapsed = time.time() - t0
    cost_raw = compiled.cost_analysis()
    cost = cost_raw if isinstance(cost_raw, dict) else (cost_raw[0] if cost_raw else {})
    coll = parse_collectives(compiled.as_text())
    rec = {
        "kind": cell.kind,
        "compile_s": round(elapsed, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
    }
    if want_memory:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    return rec


def _cal_configs(cfg) -> Tuple[Any, Any, int]:
    """Two shallow UNROLLED configs + the full trip count for the linear
    extrapolation (unrolling makes per-layer cost visible to cost_analysis;
    weight shapes stay identical to the full config)."""
    from repro.models.common import Family

    if cfg.family is Family.HYBRID:
        rem = cfg.n_layers % cfg.attn_every
        mk = lambda ng: dataclasses.replace(
            cfg, n_layers=cfg.attn_every * ng + rem, scan_layers=False
        )
        return mk(1), mk(2), cfg.n_layers // cfg.attn_every
    if cfg.family is Family.AUDIO:
        mk = lambda L: dataclasses.replace(
            cfg, n_layers=L, n_encoder_layers=L, scan_layers=False
        )
        return mk(1), mk(2), cfg.n_layers
    mk = lambda L: dataclasses.replace(cfg, n_layers=L, scan_layers=False)
    return mk(1), mk(2), cfg.n_layers


def _extrapolate(f1: Dict, f2: Dict, trips: int) -> Dict[str, Any]:
    """metric(T) = a + b*T fitted on T=1,2 -> value at T=trips."""

    def lin(v1, v2):
        b = v2 - v1
        a = v1 - b
        return max(a + b * trips, 0.0)

    kinds = set(f1["collectives"]) | set(f2["collectives"])
    coll = {}
    for k in kinds:
        b1 = f1["collectives"].get(k, {"bytes": 0.0, "count": 0})
        b2 = f2["collectives"].get(k, {"bytes": 0.0, "count": 0})
        coll[k] = {
            "bytes": lin(b1["bytes"], b2["bytes"]),
            "count": int(lin(b1["count"], b2["count"])),
        }
    return {
        "flops": lin(f1["flops"], f2["flops"]),
        "bytes": lin(f1["bytes"], f2["bytes"]),
        "collectives": coll,
    }


def roofline_terms(flops: float, bytes_: float, coll: Dict) -> Dict[str, float]:
    """Three-term roofline; inputs are PER-DEVICE (the compiled module is the
    per-device program after SPMD partitioning)."""
    t_coll = 0.0
    coll_bytes = 0.0
    for k, v in coll.items():
        t_coll += v["bytes"] * RING_FACTOR.get(k, 1.0) / ICI_BW
        coll_bytes += v["bytes"]
    return {
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_ / HBM_BW,
        "t_collective": t_coll,
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll_bytes,
    }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train / 2*N_active*D forward (per device)."""
    from repro.launch.steps import param_counts

    n_active = param_counts(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        total = 2.0 * n_active * tokens
    return total


def dryrun_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    calibrate: bool = True,
) -> Dict[str, Any]:
    import jax
    from repro.configs import get_config, shape_applicable
    from repro.launch.mesh import activate_mesh, make_production_mesh
    from repro.models.common import SHAPES

    if not shape_applicable(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention"}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))

    full = _measure(cfg, shape, mesh, want_memory=True)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
        "n_chips": n_chips, "status": "ok", "kind": full["kind"],
        "compile_s": full["compile_s"], "memory": full["memory"],
        "raw": {"flops": full["flops"], "bytes": full["bytes"],
                "collectives": full["collectives"]},
    }
    if calibrate:
        c1, c2, trips = _cal_configs(cfg)
        f1 = _measure(c1, shape, mesh, want_memory=False)
        f2 = _measure(c2, shape, mesh, want_memory=False)
        corr = _extrapolate(f1, f2, trips)
    else:
        corr = rec["raw"]
    rec["corrected"] = corr
    rec["roofline"] = roofline_terms(corr["flops"], corr["bytes"], corr["collectives"])
    mf = model_flops(cfg, shape) / n_chips
    rec["roofline"]["model_flops_per_device"] = mf
    rec["roofline"]["useful_flops_ratio"] = (
        mf / corr["flops"] if corr["flops"] else 0.0
    )
    terms = {k: rec["roofline"][f"t_{k}"] for k in ("compute", "memory", "collective")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import cells

    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for arch, shape in todo:
        for mp in meshes:
            label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            print(f"=== {label}", flush=True)
            try:
                rec = dryrun_cell(arch, shape, multi_pod=mp,
                                  calibrate=not args.no_calibrate)
            except Exception as e:  # a failing cell is a bug — surface it
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
            records.append(rec)
            if args.out:  # checkpoint progress after every cell
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(
                    f"    kind={rec['kind']} compile={rec['compile_s']}s "
                    f"flops/dev={r['flops_per_device']:.3e} "
                    f"bytes/dev={r['bytes_per_device']:.3e} "
                    f"coll/dev={r['collective_bytes_per_device']:.3e}B\n"
                    f"    t_comp={r['t_compute']*1e3:.2f}ms "
                    f"t_mem={r['t_memory']*1e3:.2f}ms "
                    f"t_coll={r['t_collective']*1e3:.2f}ms "
                    f"bottleneck={r['bottleneck']} "
                    f"useful={r['useful_flops_ratio']:.2f}",
                    flush=True,
                )
            elif rec["status"] == "skipped":
                print(f"    skipped: {rec['reason']}", flush=True)
            else:
                print(f"    FAILED: {rec.get('error')}", flush=True)
    failed = [r for r in records if r["status"] == "FAILED"]
    print(f"done: {len(records)} cells, {len(failed)} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
