import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import.

"""Perf-iteration harness (§Perf hillclimbing): re-lower a dry-run cell
under config variants and diff the roofline terms.

    python -m repro.launch.perf --arch deepseek-67b --shape decode_32k \\
        --variant baseline --variant kvq8 --variant w4a8 --variant w4a8+kvq8
    python -m repro.launch.perf --arch llama3-405b --shape train_4k \\
        --set seq_shard=False

Named variants:
  baseline       the dry-run configuration as-is
  kvq8           INT8 KV cache with per-token-per-head scales
  w4a8           W4A8 weights + LRU rotation (serving path; decode, dense)
  w4a8+kvq8      both
  nosp           seq_shard=False (replicated residual, Megatron-SP off)
  noremat        remat=False
  nofsdp         fsdp=False
  capacity1      MoE capacity_factor=1.0
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

NAMED_VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    "kvq8": {"kv_quant": True},
    "w4a8": {"__quant__": "w4a8"},
    "w4a8+kvq8": {"__quant__": "w4a8", "kv_quant": True},
    "w4a8+kvq8+nofsdp": {"__quant__": "w4a8", "kv_quant": True, "fsdp": False},
    "nosp": {"seq_shard": False},
    "noremat": {"remat": False},
    "nofsdp": {"fsdp": False},
    "capacity1": {"capacity_factor": 1.0},
}


def build_quantized_decode_cell(cfg, shape, mesh):
    """W4A8 serving cell: the paper's technique at pod scale (dense + MoE);
    handles both decode (B,1) and prefill (B,S) shapes."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.steps import CellSpec, abstract_cache, batch_axes, _cache_specs, _ns
    from repro.models.common import Family
    from repro.serving.quantized_lm import (
        abstract_quantized, abstract_quantized_moe,
        apply_quantized_lm, apply_quantized_moe_lm,
    )

    tp = mesh.shape["model"]
    ba = batch_axes(mesh, shape.global_batch)
    if cfg.family is Family.MOE:
        aparams, pspecs = abstract_quantized_moe(cfg, tp)
        apply_fn = apply_quantized_moe_lm
    else:
        aparams, pspecs = abstract_quantized(cfg, tp)
        apply_fn = apply_quantized_lm
    param_sh = _ns(mesh, pspecs)
    acache = abstract_cache(cfg, shape.global_batch, shape.seq_len, tp)
    cache_sh = _ns(mesh, _cache_specs(cfg, tp, ba))
    tok_len = 1 if shape.kind == "decode" else shape.seq_len
    tok = jax.ShapeDtypeStruct((shape.global_batch, tok_len), jnp.int32)
    tok_sh = NamedSharding(mesh, P(ba, None))

    def step(params, tokens, cache):
        return apply_fn(
            params, cfg, mesh, tokens, cache=cache, use_pallas=False,
            last_logit_only=shape.kind == "prefill",
        )

    fn = jax.jit(step, in_shardings=(param_sh, tok_sh, cache_sh),
                 donate_argnums=(2,))
    return CellSpec(fn=fn, args=(aparams, tok, acache), kind=f"{shape.kind}-w4a8")


def measure_variant(arch: str, shape_name: str, overrides: Dict[str, Any],
                    multi_pod: bool = False) -> Dict[str, Any]:
    import jax
    from repro.configs import get_config
    from repro.launch.dryrun import (
        _cal_configs, _extrapolate, _measure, parse_collectives, roofline_terms,
    )
    from repro.launch.mesh import activate_mesh, make_production_mesh
    from repro.launch.steps import build_cell
    from repro.models.common import SHAPES

    overrides = dict(overrides)
    quant = overrides.pop("__quant__", None)
    cfg = dataclasses.replace(get_config(arch), **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    def measure_cfg(c, want_memory):
        import time as _t

        t0 = _t.time()
        with activate_mesh(mesh):
            if quant == "w4a8":
                cell = build_quantized_decode_cell(c, shape, mesh)
            else:
                cell = build_cell(c, shape, mesh)
            lowered = cell.fn.lower(*cell.args)
            compiled = lowered.compile()
        el = _t.time() - t0
        cost_raw = compiled.cost_analysis()
        cost = cost_raw if isinstance(cost_raw, dict) else (cost_raw[0] if cost_raw else {})
        rec = {
            "kind": cell.kind, "compile_s": round(el, 1),
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": parse_collectives(compiled.as_text()),
        }
        if want_memory:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            }
        return rec

    full = measure_cfg(cfg, True)
    c1, c2, trips = _cal_configs(cfg)
    f1 = measure_cfg(c1, False)
    f2 = measure_cfg(c2, False)
    corr = _extrapolate(f1, f2, trips)
    rl = roofline_terms(corr["flops"], corr["bytes"], corr["collectives"])
    return {"arch": arch, "shape": shape_name, "overrides": overrides,
            "quant": quant, "memory": full["memory"], "corrected": corr,
            "roofline": rl}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--set", action="append", default=[],
                    help="field=value config override")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    variants = []
    for v in args.variant or []:
        variants.append((v, dict(NAMED_VARIANTS[v])))
    if args.set:
        ov = {}
        for kv in args.set:
            k, val = kv.split("=", 1)
            ov[k] = {"True": True, "False": False}.get(val, val)
            if isinstance(ov[k], str):
                try:
                    ov[k] = int(val)
                except ValueError:
                    try:
                        ov[k] = float(val)
                    except ValueError:
                        pass
        variants.append(("custom:" + ",".join(args.set), ov))
    if not variants:
        variants = [("baseline", {})]

    results = []
    base = None
    for name, ov in variants:
        print(f"=== {args.arch} x {args.shape} [{name}]", flush=True)
        rec = measure_variant(args.arch, args.shape, ov, multi_pod=args.multi_pod)
        rec["variant"] = name
        results.append(rec)
        r = rec["roofline"]
        line = (f"    t_comp={r['t_compute']*1e3:.2f}ms "
                f"t_mem={r['t_memory']*1e3:.2f}ms "
                f"t_coll={r['t_collective']*1e3:.2f}ms "
                f"args={rec['memory']['argument_bytes']}")
        if base is None:
            base = r
        else:
            line += (f"  | vs baseline: comp x{r['t_compute']/max(base['t_compute'],1e-12):.3f} "
                     f"mem x{r['t_memory']/max(base['t_memory'],1e-12):.3f} "
                     f"coll x{r['t_collective']/max(base['t_collective'],1e-12):.3f}")
        print(line, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
