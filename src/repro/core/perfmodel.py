"""Analytic + event-driven performance/energy model of the paper's chip
(Figs. 31.1.2/31.1.6).

This is the quantitative reproduction of the measured results: given the
paper's hardware constants (2.33 TOPS logic die, 25.6 GB/s / 8 MB stacked
ReRAM per chip, LPDDR3 EMA [21], 4-chip system) and a TLM/DLM pair, it
prices decode throughput and energy under the four cumulative configurations

    BF16 SD  ->  +LRU W4A8  ->  +RS-PNM/BVQ  ->  +APSD/WDOS

and must land inside the paper's measured bands:

    LRU:   3.82-3.93x   BVQ: 1.10-1.46x   APSD: 1.10-1.29x
    total: 4.46-7.17x   throughput: 14.08-135.69 token/s
    energy: 3.74-4.85x  rejected-token reduction vs PEARL: 10-14%

Modeling decisions (documented in DESIGN.md §7):
  * Decode is EMA-bound; per-step latency = max(memory, compute) with
    double-buffered load/compute pipelining (+ one pipeline fill), matching
    the RS-PNM/WDOS dataflow.
  * BVQ splits DLM traffic across TWO buses: block indices (log2(C)/v bits
    per weight) stream over LPDDR while codebook lines come from the stacked
    ReRAM; tile fusion halves the ReRAM side (Fig. 31.1.4).  Only codebooks
    must fit the 8/32 MB ReRAM — consistent with 0.35-1B-class DLMs.
  * The paper's premise "over 60% of SD latency stems from TLM" puts the
    BF16 DLM share near 30-40%, i.e. DLMs of 0.35-1B with draft windows of
    ~5; first-token agreement alpha ~ 0.75-0.92 (EAGLE-class drafts [9]).
  * Rounds are priced through the same APSDPolicy state machine as the real
    serving driver, with Bernoulli(alpha) acceptance streams.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.apsd import APSDPolicy, NONPAR, PAR

__all__ = [
    "HWConfig",
    "LMSpec",
    "Precision",
    "SDMode",
    "step_time",
    "verify_time",
    "program_model",
    "simulate_decoding",
    "DecodingResult",
    "fig6_pairs",
    "fig6_table",
    "PAPER_BANDS",
]


# ---------------------------------------------------------------------------
# Hardware + model descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """Paper hardware constants (Fig. 31.1.6/31.1.7)."""

    n_chips: int = 4
    tops: float = 2.33e12  # INT8 ops/s per logic die @ 285 MHz
    compute_eff: float = 0.55  # achieved MAC utilization on GEMV-ish decode
    lpddr_gbps: float = 8.5e9  # LPDDR3 EMA bandwidth per chip [21]
    reram_gbps: float = 25.6e9  # stacked ReRAM read bw per chip @ 100 MHz
    reram_bytes: int = 8 << 20  # 8 MB stacked ReRAM per chip
    sram_bytes: int = int(3.43 * (1 << 20))
    # energy constants (pJ/byte, pJ/MAC) — edge-class LPDDR3 + stacked ReRAM
    e_lpddr_pj_b: float = 80.0
    e_reram_pj_b: float = 12.0
    e_sram_pj_b: float = 1.2
    e_mac_pj: float = 0.35  # INT8 MAC; BF16 scaled in the model
    # static/background power: baseline (PLLs, MCU, LPDDR refresh, leakage)
    # plus the RS-PNM adder when the stacked ReRAM dies are powered
    # (4 x 49.54 mW per chip, Fig. 31.1.6) and the logic clocks up to 285 MHz
    # @ 1.40 V to keep pace with the stacking bandwidth.
    p_static_w: float = 0.6
    p_reram_w: float = 1.2
    xcvr_gbps: float = 16.0e9  # inter-chip transceiver (4-chip TP sync)

    @property
    def agg_lpddr(self) -> float:
        return self.lpddr_gbps * self.n_chips

    @property
    def agg_reram(self) -> float:
        return self.reram_gbps * self.n_chips

    @property
    def agg_tops(self) -> float:
        return self.tops * self.compute_eff * self.n_chips


@dataclasses.dataclass(frozen=True)
class LMSpec:
    name: str
    n_params: float  # total weights
    n_layers: int
    d_model: int


class Precision(enum.Enum):
    BF16 = "bf16"
    W4A8 = "w4a8"  # LRU-rotated INT4 weights, INT8 dynamic activations
    BVQ = "bvq"  # blockwise VQ: LPDDR indices + ReRAM codebooks (DLM only)


# BVQ traffic constants (v=8, C=256 defaults from core/bvq.py)
BVQ_IDX_BYTES_PER_PARAM = 1.0 / 8.0  # log2(256)/8 bits
BVQ_CB_BYTES_PER_PARAM = 0.03  # amortized codebook line reads, tile-fused


class SDMode(enum.Enum):
    BF16_SD = 0  # vanilla SD baseline, both models BF16 over LPDDR
    W4A8_SD = 1  # + LRU: both models W4A8, still LPDDR
    BVQ_SD = 2  # + RS-PNM: DLM indices over LPDDR, codebooks in ReRAM
    APSD = 3  # + adaptive parallel draft-and-verify with WDOS
    PEARL = 9  # reference: always-parallel long-DL ([14])
    AD = 10  # no speculation — plain autoregressive TLM decode


@dataclasses.dataclass(frozen=True)
class StepBreakdown:
    t_lpddr: float
    t_reram: float
    t_compute: float

    def total(self, pipelined: bool, n_layers: int) -> float:
        parts = [self.t_lpddr, self.t_reram, self.t_compute]
        if pipelined:
            # per-layer double buffering: bounded by the slowest stream plus
            # one pipeline fill of the rest
            slow = max(parts)
            fill = (sum(parts) - slow) / max(n_layers, 1)
            return slow + fill
        return sum(parts)


def _breakdown(
    lm: LMSpec,
    hw: HWConfig,
    precision: Precision,
    window: int,
    tile_fusion: bool = True,
) -> StepBreakdown:
    """One forward over ``window`` tokens; weights are read exactly once
    (that is the point of batch-verify)."""
    if precision is Precision.BF16:
        lpddr_bytes = 2.0 * lm.n_params
        reram_bytes = 0.0
    elif precision is Precision.W4A8:
        lpddr_bytes = 0.5 * lm.n_params
        reram_bytes = 0.0
    else:  # BVQ
        lpddr_bytes = BVQ_IDX_BYTES_PER_PARAM * lm.n_params
        reram_bytes = BVQ_CB_BYTES_PER_PARAM * lm.n_params
        if not tile_fusion:
            reram_bytes *= 2.0  # redundant CB reads (vertical mapping)
    # activation traffic (A8/BF16), qkvo+mlp streams, both directions
    act_bytes = 8.0 * lm.d_model * lm.n_layers * window
    act_bytes *= 2.0 if precision is Precision.BF16 else 1.0
    macs = 2.0 * lm.n_params * window
    t_comp = macs / hw.agg_tops
    if precision is Precision.BF16:
        t_comp *= 4.0  # BF16 through the INT8 array
    return StepBreakdown(
        t_lpddr=(lpddr_bytes + act_bytes) / hw.agg_lpddr,
        t_reram=reram_bytes / hw.agg_reram,
        t_compute=t_comp,
    )


def step_time(
    lm: LMSpec,
    hw: HWConfig,
    precision: Precision,
    window: int = 1,
    pipelined: bool = True,
    tile_fusion: bool = True,
    rotation_overhead: float = 0.0,
) -> float:
    bd = _breakdown(lm, hw, precision, window, tile_fusion)
    return bd.total(pipelined, lm.n_layers) * (1.0 + rotation_overhead)


def verify_time(
    lm: LMSpec, hw: HWConfig, precision: Precision, window: int, **kw
) -> float:
    return step_time(lm, hw, precision, window=window, **kw)


def program_model(
    target_lm: LMSpec,
    draft_lm: LMSpec,
    hw: Optional[HWConfig] = None,
    precision: Precision = Precision.W4A8,
    *,
    verify_window: int,
    draft_window: int = 1,
    tree_window: Optional[int] = None,
    pipelined: bool = True,
) -> Dict[str, float]:
    """Modeled seconds per dispatch for each program the serving engine
    executes — the MODELED side of the measured-vs-modeled attribution
    join (``benchmarks/roofline_report.attribution`` divides the engine's
    ``profile_summary()`` walls by these).

    Program names match ``Engine._profiled``'s: ``draft``/``verify`` are
    the two-phase dispatches, ``fused_wdos`` the cross-request PAR slot —
    modeled as ``max(verify, draft)``, i.e. the paper's claim that the
    draft subgraph rides inside the verify slot's shadow (THE overlap
    question the device track answers empirically) — ``draft_slot`` the
    masked draft-only micro-step, and the ``tree_*`` variants the same
    shapes at the tree window width.  ``prefill`` and ``compaction`` are
    deliberately absent: one is prompt-length-dependent, the other a pure
    page copy with no weight traffic — neither fits the weight-bound
    step model."""
    hw = hw if hw is not None else HWConfig()
    draft = step_time(draft_lm, hw, precision, window=draft_window,
                      pipelined=pipelined)
    verify = step_time(target_lm, hw, precision, window=verify_window,
                       pipelined=pipelined)
    out = {
        "draft": draft,
        "verify": verify,
        "fused_wdos": max(verify, draft),
        "draft_slot": draft,
    }
    if tree_window is not None:
        t_draft = step_time(draft_lm, hw, precision, window=tree_window,
                            pipelined=pipelined)
        t_verify = step_time(target_lm, hw, precision, window=tree_window,
                             pipelined=pipelined)
        out.update({
            "tree_draft": t_draft,
            "tree_verify": t_verify,
            "fused_tree": max(t_verify, t_draft),
            "tree_draft_slot": t_draft,
        })
    return out


# ---------------------------------------------------------------------------
# Round-level decoding simulation (shared APSDPolicy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecodingResult:
    mode: "SDMode"
    tokens: int
    seconds: float
    rounds: int
    drafted: int
    accepted: int
    discarded: int
    energy_j: float

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.seconds

    @property
    def rejected_ratio(self) -> float:
        return 1.0 - self.accepted / max(self.drafted, 1)

    @property
    def mj_per_token(self) -> float:
        return 1e3 * self.energy_j / max(self.tokens, 1)


def _round_energy(
    tlm: LMSpec,
    dlm: LMSpec,
    hw: HWConfig,
    t_prec: Precision,
    d_prec: Precision,
    window: int,
    draft_steps: int,
) -> float:
    """Energy of one draft+verify round: data movement + MACs."""

    def model_energy(lm: LMSpec, prec: Precision, win: int, steps: float) -> float:
        if prec is Precision.BF16:
            lp, rr = 2.0 * lm.n_params, 0.0
        elif prec is Precision.W4A8:
            lp, rr = 0.5 * lm.n_params, 0.0
        else:
            lp = BVQ_IDX_BYTES_PER_PARAM * lm.n_params
            rr = BVQ_CB_BYTES_PER_PARAM * lm.n_params
        e = steps * (lp * hw.e_lpddr_pj_b + rr * hw.e_reram_pj_b)
        macs = 2.0 * lm.n_params * win * steps
        e += macs * hw.e_mac_pj * (4.0 if prec is Precision.BF16 else 1.0)
        e += steps * 8.0 * lm.d_model * lm.n_layers * win * hw.e_sram_pj_b
        return e * 1e-12

    return model_energy(tlm, t_prec, window, 1.0) + model_energy(
        dlm, d_prec, 1, float(draft_steps)
    )


_MODE_SETTINGS: Dict["SDMode", Tuple[Precision, Precision, float]] = {}


def _mode_settings(mode: "SDMode") -> Tuple[Precision, Precision, float]:
    """-> (tlm precision, dlm precision, rotation overhead)"""
    if mode in (SDMode.BF16_SD, SDMode.AD):
        return Precision.BF16, Precision.BF16, 0.0
    if mode is SDMode.W4A8_SD:
        return Precision.W4A8, Precision.W4A8, 0.03
    return Precision.W4A8, Precision.BVQ, 0.03


def simulate_decoding(
    tlm: LMSpec,
    dlm: LMSpec,
    hw: HWConfig,
    mode: SDMode,
    alpha: float,
    n_tokens: int = 2048,
    seq_dl: int = 5,
    short_dl: int = 2,
    long_dl: int = 6,
    seed: int = 0,
) -> DecodingResult:
    """Price decoding ``n_tokens`` under a cumulative configuration.

    Acceptance of each draft token ~ Bernoulli(alpha) (i.i.d., standard SD
    analysis); APSD's first-token match also ~ Bernoulli(alpha).
    """
    rng = np.random.default_rng(seed)
    t_prec, d_prec, rot = _mode_settings(mode)
    p_static = hw.p_static_w + (
        hw.p_reram_w if mode in (SDMode.BVQ_SD, SDMode.APSD, SDMode.PEARL) else 0.0
    )
    t_d = step_time(dlm, hw, d_prec, 1, rotation_overhead=rot)
    tv = lambda w: verify_time(tlm, hw, t_prec, w, rotation_overhead=rot)

    tokens = 0
    seconds = 0.0
    rounds = drafted = accepted = discarded = 0
    energy = 0.0

    def draw_prefix(dl: int) -> int:
        acc = 0
        for _ in range(dl):
            if rng.random() < alpha:
                acc += 1
            else:
                break
        return acc

    if mode is SDMode.AD:
        t = tv(1)
        seconds = n_tokens * t
        energy = n_tokens * _round_energy(tlm, dlm, hw, t_prec, d_prec, 1, 0)
        energy += p_static * seconds
        return DecodingResult(mode, n_tokens, seconds, n_tokens, 0, 0, 0, energy)

    if mode in (SDMode.BF16_SD, SDMode.W4A8_SD, SDMode.BVQ_SD):
        # sequential draft -> verify rounds, fixed draft length
        while tokens < n_tokens:
            acc = draw_prefix(seq_dl)
            seconds += seq_dl * t_d + tv(seq_dl + 1)
            energy += _round_energy(tlm, dlm, hw, t_prec, d_prec, seq_dl + 1, seq_dl)
            tokens += acc + 1
            rounds += 1
            drafted += seq_dl
            accepted += acc
        energy += p_static * seconds
        return DecodingResult(
            mode, tokens, seconds, rounds, drafted, accepted, discarded, energy
        )

    if mode is SDMode.PEARL:
        # always-parallel long-DL ([14]): every round costs max(draft, verify);
        # any mismatch throws the concurrent window away.
        while tokens < n_tokens:
            acc = draw_prefix(long_dl)
            all_acc = acc == long_dl
            match = all_acc and (rng.random() < alpha)
            seconds += max(long_dl * t_d, tv(long_dl + 1))
            energy += _round_energy(tlm, dlm, hw, t_prec, d_prec, long_dl + 1, long_dl)
            tokens += acc + 1
            rounds += 1
            drafted += long_dl
            accepted += acc
            if match:
                accepted += 1  # the matched first-token guess is a hit
            else:
                discarded += long_dl
        energy += p_static * seconds
        return DecodingResult(
            mode, tokens, seconds, rounds, drafted, accepted, discarded, energy
        )

    # --- APSD: the paper's adaptive controller (shared state machine)
    assert mode is SDMode.APSD
    state = NONPAR
    while tokens < n_tokens:
        if state == NONPAR:
            dl = short_dl
            acc = draw_prefix(dl)
            all_acc = acc == dl
            match = True
            seconds += dl * t_d + tv(dl + 1)  # sequential in NONPAR
        else:
            dl = long_dl
            acc = draw_prefix(dl)
            all_acc = acc == dl
            match = all_acc and (rng.random() < alpha)
            seconds += max(dl * t_d, tv(dl + 1))  # overlapped via WDOS
            if match:
                accepted += 1  # the matched first-token guess is a hit
            else:
                discarded += dl
        energy += _round_energy(tlm, dlm, hw, t_prec, d_prec, dl + 1, dl)
        tokens += acc + 1
        rounds += 1
        drafted += dl
        accepted += acc
        new_state = APSDPolicy.next_mode(state, all_acc, match)
        if state == NONPAR and new_state == PAR:
            seconds += long_dl * t_d  # seed the first pending window
            drafted += long_dl
            accepted += long_dl  # seed window is counted when verified next
            # (bookkeeping: remove the double count — the seed window IS the
            # next PAR round's pending window)
            drafted -= long_dl
            accepted -= long_dl
        state = new_state
    energy += p_static * seconds
    return DecodingResult(
        mode, tokens, seconds, rounds, drafted, accepted, discarded, energy
    )


# ---------------------------------------------------------------------------
# Fig. 31.1.6 reproduction table
# ---------------------------------------------------------------------------

PAPER_BANDS = {
    "lru_speedup": (3.82, 3.93),
    "bvq_speedup": (1.10, 1.46),
    "apsd_speedup": (1.10, 1.29),
    "total_speedup": (4.46, 7.17),
    "tok_per_s": (14.08, 135.69),
    "energy_savings": (3.74, 4.85),
    "rejected_reduction_pct": (10.0, 14.0),
}


@dataclasses.dataclass(frozen=True)
class PairConfig:
    tlm: LMSpec
    dlm: LMSpec
    alpha: float  # per-token draft/target agreement (EAGLE-class drafts)
    seq_dl: int = 4  # vanilla-SD draft length (stages 1-3)
    short_dl: int = 5  # APSD non-parallel draft length
    long_dl: int = 12  # APSD parallel draft length


def fig6_pairs() -> List[PairConfig]:
    """Representative TLM/DLM pairs spanning the paper's measurement range.

    The paper reports ranges "across various TLM/DLM pairs" without naming
    them; we pick public-scale pairs consistent with its premises: DLMs big
    enough that >30% of BF16-SD latency is drafting ("over 60% stemming from
    TLM"), with per-pair agreement rates in the range measured for such
    pairs in the SD literature [8, 9, 14].  Calibrated so every pair lands
    inside every PAPER_BANDS entry (see tests/test_perfmodel.py).
    """
    return [
        PairConfig(
            LMSpec("llama2-13b", 13.0e9, 40, 5120),
            LMSpec("draft-1b", 1.0e9, 22, 2048), 0.84,
        ),
        PairConfig(
            LMSpec("llama2-7b", 6.74e9, 32, 4096),
            LMSpec("draft-350m", 0.35e9, 24, 1024), 0.82,
        ),
        PairConfig(
            LMSpec("llama3-8b", 8.03e9, 32, 4096),
            LMSpec("draft-350m", 0.35e9, 24, 1024), 0.82,
        ),
        PairConfig(
            LMSpec("llama3-3b", 3.2e9, 28, 3072),
            LMSpec("draft-350m", 0.35e9, 24, 1024), 0.84,
        ),
        PairConfig(
            LMSpec("qwen2.5-1.8b", 1.8e9, 24, 2048),
            LMSpec("draft-160m", 0.16e9, 12, 768), 0.82,
        ),
    ]


def fig6_table(
    hw: Optional[HWConfig] = None, n_tokens: int = 4096
) -> List[Dict[str, float]]:
    """Cumulative-configuration sweep for every pair -> claim-table rows."""
    hw = hw or HWConfig()
    rows: List[Dict[str, float]] = []
    for pc in fig6_pairs():
        tlm, dlm, alpha = pc.tlm, pc.dlm, pc.alpha
        res = {
            m: simulate_decoding(
                tlm, dlm, hw, m, alpha, n_tokens=n_tokens,
                seq_dl=pc.seq_dl, short_dl=pc.short_dl, long_dl=pc.long_dl,
            )
            for m in (
                SDMode.BF16_SD,
                SDMode.W4A8_SD,
                SDMode.BVQ_SD,
                SDMode.APSD,
                SDMode.PEARL,
            )
        }
        base = res[SDMode.BF16_SD]
        rows.append(
            {
                "pair": f"{tlm.name}/{dlm.name}",
                "alpha": alpha,
                "bf16_tok_s": base.tok_per_s,
                "lru_speedup": res[SDMode.W4A8_SD].tok_per_s / base.tok_per_s,
                "bvq_speedup": res[SDMode.BVQ_SD].tok_per_s
                / res[SDMode.W4A8_SD].tok_per_s,
                "apsd_speedup": res[SDMode.APSD].tok_per_s
                / res[SDMode.BVQ_SD].tok_per_s,
                "total_speedup": res[SDMode.APSD].tok_per_s / base.tok_per_s,
                "tok_per_s": res[SDMode.APSD].tok_per_s,
                "energy_savings": base.mj_per_token / res[SDMode.APSD].mj_per_token,
                "mj_per_token": res[SDMode.APSD].mj_per_token,
                "apsd_rejected": res[SDMode.APSD].rejected_ratio,
                "pearl_rejected": res[SDMode.PEARL].rejected_ratio,
                "rejected_reduction_pct": 100.0
                * (res[SDMode.PEARL].rejected_ratio - res[SDMode.APSD].rejected_ratio),
            }
        )
    return rows
