"""BVQ — Blockwise Vector Quantization for draft-LLM weight compression
(paper Fig. 31.1.4).

Unlike classical VQ (GPTVQ / VPTQ) whose giant index buffers and multi-port
decoders dominate area, BVQ clusters weights *block-locally*: each block of
``block_cols`` output channels owns a private codebook of ``codebook_size``
entries of ``vec_dim``-long vectors (cut along the input dim), so the decoder
is a lightweight per-block lookup.  Codebooks are jointly learned with INT4
QAT (straight-through) and the indices with Gumbel-softmax reparameterization
(MaskLLM-style), then frozen to hard assignments.

On the chip the codebooks live in stacked ReRAM ("vertical CB mapping", block
dims constrained to the per-die bank width) and are fetched once per block by
the tile-fusion unit.  On TPU the analogue is: codebooks resident in VMEM,
indices streamed from HBM, the weight tile reconstructed once per grid step
and reused across the token batch (kernels/bvq_matmul.py).

Storage cost per weight: log2(C)/v index bits + amortized 4-bit CB entries —
e.g. v=8, C=256 -> 1 bit + eps vs 16 bit BF16 (~14.8x compression).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quantization as q

__all__ = [
    "BVQConfig",
    "BVQWeight",
    "bvq_compress",
    "bvq_reconstruct",
    "bvq_matmul_ref",
    "bits_per_weight",
    "kmeans",
]


@dataclasses.dataclass(frozen=True)
class BVQConfig:
    vec_dim: int = 8  # sub-vector length along the input (K) dim
    codebook_size: int = 256  # entries per block codebook (uint8 indices)
    block_cols: int = 128  # output channels per block (ReRAM bank width)
    kmeans_iters: int = 16
    qat_steps: int = 60  # Gumbel-softmax refinement steps (0 = k-means only)
    qat_lr: float = 5e-2
    tau_start: float = 2.0  # Gumbel temperature annealing
    tau_end: float = 0.2
    codebook_bits: int = 4  # INT4 QAT on codebook entries


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BVQWeight:
    """Compressed weight: W ~ gather(codebooks, indices).

    codebooks: (nb, C, v) int8 storage of INT4 values
    scales:    (nb, 1, 1) f32 per-block codebook scale
    indices:   (nb, K // v, block_cols) int32 (values < C)
    shape:     original (K, N)
    """

    codebooks: jnp.ndarray
    scales: jnp.ndarray
    indices: jnp.ndarray
    shape: Tuple[int, int]
    vec_dim: int

    def tree_flatten(self):
        return (self.codebooks, self.scales, self.indices), (self.shape, self.vec_dim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        cb, sc, idx = children
        return cls(cb, sc, idx, aux[0], aux[1])

    @property
    def num_blocks(self) -> int:
        return self.codebooks.shape[0]


def bits_per_weight(cfg: BVQConfig, k: int, n: int) -> float:
    """Average storage bits per original weight element."""
    nb = n // cfg.block_cols
    index_bits = math.log2(cfg.codebook_size) / cfg.vec_dim
    cb_bits = nb * cfg.codebook_size * cfg.vec_dim * cfg.codebook_bits / (k * n)
    scale_bits = nb * 32 / (k * n)
    return index_bits + cb_bits + scale_bits


# ---------------------------------------------------------------------------
# k-means (Lloyd) — vmapped over blocks
# ---------------------------------------------------------------------------


def _sq_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(V, v) x (C, v) -> (V, C) squared euclidean distances."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    return x2 - 2.0 * (x @ c.T) + c2[None, :]


def _kmeanspp_init(vectors: jnp.ndarray, k: int, key: jax.Array) -> jnp.ndarray:
    """k-means++ seeding: each next centroid sampled proportional to the
    squared distance from the nearest already-chosen one."""
    v_cnt, dim = vectors.shape
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, v_cnt)
    cents = jnp.zeros((k, dim), vectors.dtype).at[0].set(vectors[first])
    mind = jnp.sum((vectors - vectors[first]) ** 2, axis=-1)

    def body(i, carry):
        cents, mind, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.categorical(sub, jnp.log(mind + 1e-20))
        c = vectors[idx]
        cents = cents.at[i].set(c)
        mind = jnp.minimum(mind, jnp.sum((vectors - c) ** 2, axis=-1))
        return cents, mind, key

    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, mind, key))
    return cents


def kmeans(
    vectors: jnp.ndarray, k: int, iters: int, key: jax.Array
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm on (V, v) vectors -> ((k, v) centroids, (V,) assign).

    k-means++ init; empty clusters are re-seeded to the points currently
    farthest from their centroid."""
    cent = _kmeanspp_init(vectors, k, key)

    def body(_, cent):
        d = _sq_dists(vectors, cent)
        assign = jnp.argmin(d, axis=-1)
        one_hot = jax.nn.one_hot(assign, k, dtype=vectors.dtype)  # (V, C)
        counts = one_hot.sum(axis=0)  # (C,)
        sums = one_hot.T @ vectors  # (C, v)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # re-seed empties with the farthest points
        far = jnp.argsort(-jnp.min(d, axis=-1))[:k]  # (C,) candidate rows
        new = jnp.where(counts[:, None] > 0, new, vectors[far])
        return new

    cent = jax.lax.fori_loop(0, iters, body, cent)
    assign = jnp.argmin(_sq_dists(vectors, cent), axis=-1)
    return cent, assign


# ---------------------------------------------------------------------------
# Gumbel-softmax QAT refinement (joint codebook + index learning)
# ---------------------------------------------------------------------------


def _qat_refine(
    vectors: jnp.ndarray,  # (V, v)
    cent: jnp.ndarray,  # (C, v)
    cfg: BVQConfig,
    key: jax.Array,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Jointly refine codebook (INT4 STE) + assignments (Gumbel-softmax)."""
    c = cfg.codebook_size

    def loss_fn(params, tau, gumbel):
        logits, cb = params
        cbq = q.fake_quant_weight(cb, bits=cfg.codebook_bits, axis=(0, 1))
        soft = jax.nn.softmax((logits + gumbel) / tau, axis=-1)  # (V, C)
        recon = soft @ cbq
        return jnp.mean((recon - vectors) ** 2)

    logits = -_sq_dists(vectors, cent)
    logits = logits / (jnp.std(logits) + 1e-6)
    params = (logits, cent)
    # hand-rolled Adam so core/ has no dependency on optim/
    mom = jax.tree.map(jnp.zeros_like, params)
    vel = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    def step(carry, i):
        params, mom, vel, key = carry
        key, sub = jax.random.split(key)
        tau = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** (
            i / max(cfg.qat_steps - 1, 1)
        )
        gumbel = jax.random.gumbel(sub, logits.shape, dtype=vectors.dtype)
        g = jax.grad(loss_fn)(params, tau, gumbel)
        mom = jax.tree.map(lambda m, gi: b1 * m + (1 - b1) * gi, mom, g)
        vel = jax.tree.map(lambda v, gi: b2 * v + (1 - b2) * gi * gi, vel, g)
        t = i.astype(jnp.float32) + 1.0
        params = jax.tree.map(
            lambda p, m, v: p
            - cfg.qat_lr * (m / (1 - b1**t)) / (jnp.sqrt(v / (1 - b2**t)) + eps),
            params,
            mom,
            vel,
        )
        return (params, mom, vel, key), None

    (params, _, _, _), _ = jax.lax.scan(
        step, (params, mom, vel, key), jnp.arange(cfg.qat_steps)
    )
    logits, cb = params
    assign = jnp.argmax(logits, axis=-1)
    # final Lloyd touch-up of centroids against *hard* assignments
    one_hot = jax.nn.one_hot(assign, c, dtype=vectors.dtype)
    counts = one_hot.sum(axis=0)
    cb = jnp.where(
        counts[:, None] > 0, (one_hot.T @ vectors) / jnp.maximum(counts[:, None], 1.0), cb
    )
    return cb, assign


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def bvq_compress(w: jnp.ndarray, cfg: BVQConfig, key: jax.Array) -> BVQWeight:
    """Compress (K, N) weight into per-block codebooks + indices."""
    k_dim, n_dim = w.shape
    assert k_dim % cfg.vec_dim == 0, (k_dim, cfg.vec_dim)
    assert n_dim % cfg.block_cols == 0, (n_dim, cfg.block_cols)
    nb = n_dim // cfg.block_cols
    rows = k_dim // cfg.vec_dim
    # (K, N) -> (nb, rows * block_cols, v): cut K into v-vectors, group cols
    wb = w.astype(jnp.float32).reshape(rows, cfg.vec_dim, nb, cfg.block_cols)
    wb = wb.transpose(2, 0, 3, 1).reshape(nb, rows * cfg.block_cols, cfg.vec_dim)

    keys = jax.random.split(key, nb)

    def per_block(vecs, bkey):
        k1, k2 = jax.random.split(bkey)
        cent, _ = kmeans(vecs, cfg.codebook_size, cfg.kmeans_iters, k1)
        if cfg.qat_steps > 0:
            cent, assign = _qat_refine(vecs, cent, cfg, k2)
        else:
            assign = jnp.argmin(_sq_dists(vecs, cent), axis=-1)
        cbq, scale = q.quantize_weight_int(cent, bits=cfg.codebook_bits, axis=(0, 1))
        return cbq, scale.reshape(1, 1), assign

    cbs, scales, assigns = jax.vmap(per_block)(wb, keys)
    indices = assigns.reshape(nb, rows, cfg.block_cols).astype(jnp.int32)
    return BVQWeight(
        codebooks=cbs,
        scales=scales,
        indices=indices,
        shape=(k_dim, n_dim),
        vec_dim=cfg.vec_dim,
    )


def dequant_codebooks(bw: BVQWeight, dtype=jnp.float32) -> jnp.ndarray:
    return bw.codebooks.astype(dtype) * bw.scales.astype(dtype)


@jax.jit
def bvq_reconstruct(bw: BVQWeight) -> jnp.ndarray:
    """Gather-decode the full (K, N) weight (the ref.py oracle path)."""
    k_dim, n_dim = bw.shape
    nb, rows, bc = bw.indices.shape
    cb = dequant_codebooks(bw)  # (nb, C, v)
    gathered = jax.vmap(lambda c, i: c[i])(cb, bw.indices.reshape(nb, rows * bc))
    w = gathered.reshape(nb, rows, bc, bw.vec_dim)
    w = w.transpose(1, 3, 0, 2).reshape(k_dim, n_dim)
    return w


def bvq_matmul_ref(x: jnp.ndarray, bw: BVQWeight) -> jnp.ndarray:
    """y = x @ reconstruct(bw) — oracle for kernels/bvq_matmul.py."""
    return x @ bvq_reconstruct(bw).astype(x.dtype)
