"""W4A8 quantization substrate (paper Figs. 31.1.2/31.1.3).

The accelerator runs the target LLM (TLM) at W4A8: INT4 per-output-channel
symmetric weights, INT8 dynamic per-token activations (absmax scaling after
the LRU rotation removes outliers), INT32 MAC accumulation with fused FP16
scale dequantization — the "dynamic quantizer whose scales are bypassed to
the TFTE".  The draft LLM (DLM) additionally goes through BVQ (core/bvq.py)
on top of INT4 QAT.

All functions are jit-safe and used both by the pure-jnp reference path and
as the oracle for kernels/w4a8_matmul.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_act_int8",
    "quantize_weight_int",
    "fake_quant_act",
    "fake_quant_weight",
    "pack_int4",
    "unpack_int4",
    "w4a8_matmul_ref",
    "QuantizedLinear",
    "quantize_linear_weights",
    "quantized_linear_apply",
    "sqnr_db",
]

INT8_QMAX = 127
INT4_QMAX = 7  # symmetric [-7, 7]; keeps -8 unused so negation is closed


def _absmax_scale(x: jnp.ndarray, axis, qmax: int) -> jnp.ndarray:
    s = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / qmax
    return jnp.maximum(s, 1e-8)


def quantize_act_int8(x: jnp.ndarray, axis: int = -1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dynamic per-token symmetric INT8: returns (q int8, scale f32).

    ``axis`` is the channel axis reduced for absmax (per-token scaling)."""
    s = _absmax_scale(x.astype(jnp.float32), axis, INT8_QMAX)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), s.astype(jnp.float32)


def quantize_weight_int(
    w: jnp.ndarray, bits: int = 4, axis: int = 0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-channel symmetric INT<bits> weight quantization.

    ``axis`` is the *input* (reduction) dim; scales broadcast per out-channel.
    Returns (q int8-storage, scale f32)."""
    qmax = (1 << (bits - 1)) - 1
    s = _absmax_scale(w.astype(jnp.float32), axis, qmax)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -qmax, qmax)
    return q.astype(jnp.int8), s.astype(jnp.float32)


def fake_quant_act(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Straight-through-estimator INT8 fake-quant (QAT)."""
    s = _absmax_scale(jax.lax.stop_gradient(x), axis, INT8_QMAX)
    q = jnp.clip(jnp.round(x / s), -INT8_QMAX, INT8_QMAX) * s
    return x + jax.lax.stop_gradient(q - x)


def fake_quant_weight(w: jnp.ndarray, bits: int = 4, axis: int = 0) -> jnp.ndarray:
    """Straight-through-estimator INT<bits> fake-quant (QAT)."""
    qmax = (1 << (bits - 1)) - 1
    s = _absmax_scale(jax.lax.stop_gradient(w), axis, qmax)
    q = jnp.clip(jnp.round(w / s), -qmax, qmax) * s
    return w + jax.lax.stop_gradient(q - w)


def pack_int4(q: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Pack int4 values (int8 storage, [-8, 7]) pairwise into int8 along
    ``axis``: element 2i -> low nibble, 2i+1 -> high nibble."""
    assert q.shape[axis] % 2 == 0
    lo = jax.lax.slice_in_dim(q, 0, q.shape[axis], stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(q, 1, q.shape[axis], stride=2, axis=axis)
    return ((hi.astype(jnp.int32) << 4) | (lo.astype(jnp.int32) & 0xF)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Inverse of pack_int4 (sign-extends nibbles)."""
    p = packed.astype(jnp.int32)
    lo = (p << 28) >> 28  # sign-extend low nibble
    hi = p >> 4  # arithmetic shift sign-extends high nibble
    ax = axis % packed.ndim
    stacked = jnp.stack([lo, hi], axis=ax + 1)  # interleave: 2i=lo, 2i+1=hi
    shape = list(packed.shape)
    shape[ax] *= 2
    return stacked.reshape(shape).astype(jnp.int8)


def w4a8_matmul_ref(
    xq: jnp.ndarray,
    sx: jnp.ndarray,
    wq: jnp.ndarray,
    sw: jnp.ndarray,
) -> jnp.ndarray:
    """Reference W4A8 GEMM: y = (xq int8 @ wq int4) * sx * sw, INT32 accum.

    xq: (..., K) int8, sx: (..., 1) f32, wq: (K, N) int8-storage int4 values,
    sw: (1, N) f32.  Returns f32 (..., N)."""
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32),
        wq.astype(jnp.int32),
        (((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * sx * sw.reshape(1, -1)


@dataclasses.dataclass
class QuantizedLinear:
    """Offline-quantized linear layer (TLM W4A8 serving path).

    ``wq`` stores int4 values in int8; ``packed`` optionally holds the
    nibble-packed form consumed by the Pallas kernel."""

    wq: jnp.ndarray  # (K, N) int8 storage of int4
    sw: jnp.ndarray  # (1, N) f32
    bits: int = 4


def quantize_linear_weights(w: jnp.ndarray, bits: int = 4) -> QuantizedLinear:
    wq, sw = quantize_weight_int(w, bits=bits, axis=0)
    return QuantizedLinear(wq=wq, sw=sw.reshape(1, -1), bits=bits)


def quantized_linear_apply(x: jnp.ndarray, ql: QuantizedLinear) -> jnp.ndarray:
    """Dynamic-A8 x static-W4 linear: quantize x per token, INT GEMM, dequant."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, sx = quantize_act_int8(x2)
    y = w4a8_matmul_ref(xq, sx, ql.wq, ql.sw)
    return y.reshape(*lead, -1).astype(x.dtype)


def sqnr_db(ref: jnp.ndarray, approx: jnp.ndarray) -> jnp.ndarray:
    """Signal-to-quantization-noise ratio in dB."""
    num = jnp.sum(ref.astype(jnp.float32) ** 2)
    den = jnp.sum((ref.astype(jnp.float32) - approx.astype(jnp.float32)) ** 2) + 1e-12
    return 10.0 * jnp.log10(num / den)
