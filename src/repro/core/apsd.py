"""APSD — Adaptive Parallel Speculative Decoding (paper Fig. 31.1.5).

PEARL-style parallel draft-and-verify keeps the DLM busy *while* the TLM
verifies: during verification of window W_i the DLM already drafts W_{i+1}
assuming W_i is fully accepted.  With long draft lengths most of those
speculative drafts are rejected (>90% at long DL per the paper); vanilla
short-DL SD wastes TLM bandwidth instead.  APSD adaptively switches:

  * NONPAR: short-DL sequential draft->verify (safe, low rejection);
  * PAR:    long-DL parallel draft-and-verify.  Stay in PAR only while
        (a) the TLM accepted ALL tokens of the previous window, and
        (b) the TLM's newly emitted (bonus) token equals the FIRST token of
            the concurrently drafted window (the DLM's guess for that same
            position).
    Otherwise the concurrent draft is discarded and APSD reverts to NONPAR.

The controller is a pure state machine (``APSDPolicy``) shared by the real
serving driver below, the WDOS discrete-event simulation
(core/scheduler.py) and the analytic performance model (core/perfmodel.py).
On the chip, "parallel" means the WDOS issues DLM-draft and TLM-verify
instructions to decoupled queues; on a TPU mesh it means both steps are
dispatched in one program against disjoint mesh slices (serving/engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.speculative import (
    LMInterface,
    SDConfig,
    _probs,
    speculative_accept_greedy,
    speculative_sample,
)

__all__ = ["APSDConfig", "APSDPolicy", "RoundRecord", "apsd_generate", "APSDStats"]

NONPAR = 0
PAR = 1


@dataclasses.dataclass(frozen=True)
class APSDConfig:
    short_dl: int = 2  # non-parallel draft length
    long_dl: int = 6  # parallel draft length
    temperature: float = 0.0
    max_tokens: int = 64


class APSDPolicy:
    """The paper's mode-switch rule, isolated for reuse in simulators."""

    @staticmethod
    def next_mode(mode: int, all_accepted: bool, first_match: bool) -> int:
        if mode == NONPAR:
            # a fully-accepted short window is evidence drafting is easy
            return PAR if all_accepted else NONPAR
        return PAR if (all_accepted and first_match) else NONPAR


class RoundRecord(NamedTuple):
    mode: int  # NONPAR / PAR
    drafted: int  # tokens proposed by DLM this round (incl. discarded)
    accepted: int  # draft tokens committed
    emitted: int  # accepted + 1 (bonus/correction)
    discarded: int  # concurrent-draft tokens thrown away


class APSDStats(NamedTuple):
    emitted: int
    rounds: int
    drafted: int
    accepted: int
    discarded: int
    par_rounds: int
    records: Tuple[RoundRecord, ...]

    @property
    def rejected_ratio(self) -> float:
        return 1.0 - self.accepted / max(self.drafted, 1)

    @property
    def tokens_per_round(self) -> float:
        return self.emitted / max(self.rounds, 1)


def _draft_tokens(
    key: Optional[jax.Array],
    draft: LMInterface,
    draft_params: Any,
    d_cache: Any,
    start_tok: jnp.ndarray,
    n: int,
    temperature: float,
):
    """DLM drafts n tokens autoregressively from start_tok."""
    toks, qrows = [], []
    cur = start_tok
    for _ in range(n):
        lg, d_cache = draft.extend(draft_params, cur.reshape(1, 1), d_cache)
        if temperature <= 0.0:
            nxt = jnp.argmax(lg[0, -1])
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg[0, -1] / temperature)
        qrows.append(_probs(lg[0, -1], temperature))
        toks.append(nxt.astype(jnp.int32))
        cur = nxt
    return jnp.stack(toks), jnp.stack(qrows), d_cache, key


def _verify(
    key: Optional[jax.Array],
    target: LMInterface,
    target_params: Any,
    t_cache: Any,
    prev_tok: jnp.ndarray,
    draft_toks: jnp.ndarray,
    q_rows: jnp.ndarray,
    temperature: float,
):
    """TLM scores [prev_tok, drafts] in one pass; accept/rollback."""
    l = int(draft_toks.shape[0])
    window = jnp.concatenate([prev_tok.reshape(1), draft_toks]).reshape(1, -1)
    vg, t_cache = target.extend(target_params, window, t_cache)
    p_logits = vg[0]
    if temperature <= 0.0:
        toks, n_out, n_acc = speculative_accept_greedy(draft_toks, p_logits)
    else:
        key, sub = jax.random.split(key)
        toks, n_out, n_acc = speculative_sample(
            sub, draft_toks, _probs(p_logits, temperature), q_rows
        )
    n_out_i, n_acc_i = int(n_out), int(n_acc)
    # TLM cache holds l+1 new positions; committed = n_acc + 1 but the bonus
    # token itself is re-fed next round, so keep n_acc of the l drafts + the
    # prev_tok position.
    extra = l - n_acc_i
    if extra > 0:
        t_cache = target.rewind(t_cache, extra)
    return toks, n_out_i, n_acc_i, t_cache, key


def apsd_generate(
    key: jax.Array,
    target: LMInterface,
    target_params: Any,
    draft: LMInterface,
    draft_params: Any,
    prompt: jnp.ndarray,  # (1, S) int32
    cfg: APSDConfig,
) -> Tuple[jnp.ndarray, APSDStats]:
    """Reference APSD driver (host loop, batch 1).

    Lossless: emitted tokens follow the TLM distribution exactly; the policy
    only changes *which* drafts get proposed/discarded, never acceptance.
    """
    assert prompt.shape[1] >= 2
    assert cfg.long_dl >= 2, "PAR mode needs long_dl >= 2"
    _, t_cache = target.prefill(target_params, prompt[:, :-1])
    _, d_cache = draft.prefill(draft_params, prompt[:, :-1])
    last_tok = prompt[0, -1].astype(jnp.int32)
    temp = cfg.temperature

    out: List[int] = []
    records: List[RoundRecord] = []
    mode = NONPAR
    # pending = concurrent draft from the previous PAR round, not yet verified
    pending: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None

    while len(out) < cfg.max_tokens:
        discarded = 0
        if mode == NONPAR:
            # ---- sequential: draft short window, then verify
            d_toks, q_rows, d_cache, key = _draft_tokens(
                key, draft, draft_params, d_cache, last_tok, cfg.short_dl, temp
            )
            toks, n_out, n_acc, t_cache, key = _verify(
                key, target, target_params, t_cache, last_tok, d_toks, q_rows, temp
            )
            drafted = cfg.short_dl
            # DLM cache holds [last_tok, d_0..d_{s-2}]; restore the invariant
            # cache == committed[:-1] (see speculative.sd_generate).
            if n_acc == cfg.short_dl:
                _, d_cache = draft.extend(
                    draft_params, d_toks[-1].reshape(1, 1), d_cache
                )
            elif (cfg.short_dl - 1) - n_acc > 0:
                d_cache = draft.rewind(d_cache, (cfg.short_dl - 1) - n_acc)
            all_acc = n_acc == cfg.short_dl
            first_match = True  # no concurrent draft to contradict
            pending = None
        else:
            # ---- parallel: verify `pending` WHILE drafting the next window.
            # Functionally we draft first (DLM cache already sits at the tip
            # of `pending`), then verify; on silicon the WDOS overlaps them.
            assert pending is not None
            p_toks, p_qrows = pending
            tip = p_toks[-1]
            c_toks, c_qrows, d_cache, key = _draft_tokens(
                key, draft, draft_params, d_cache, tip, cfg.long_dl, temp
            )
            toks, n_out, n_acc, t_cache, key = _verify(
                key, target, target_params, t_cache, last_tok, p_toks, p_qrows, temp
            )
            drafted = cfg.long_dl  # the concurrent window proposed this round
            l_pending = int(p_toks.shape[0])
            all_acc = n_acc == l_pending
            bonus = toks[n_acc]  # TLM's newly emitted token
            first_match = bool(all_acc and int(bonus) == int(c_toks[0]))
            if first_match:
                # concurrent draft survives: c_toks[0] is already committed
                # (== bonus); c_toks[1:] await verification next round.
                pending = (c_toks[1:], c_qrows[1:])
                # DLM cache is already at the tip of c_toks — nothing to undo.
            else:
                # throw away the concurrent window + rejected pending drafts.
                # DLM cache = committed + p[0..Lp-1] + c[0..L-2]; desired
                # committed + p[:n_acc]  =>  rewind (Lp - n_acc) + (L - 1).
                discarded = cfg.long_dl
                rewind_n = (l_pending - n_acc) + (cfg.long_dl - 1)
                if rewind_n > 0:
                    d_cache = draft.rewind(d_cache, rewind_n)
                pending = None

        new = [int(t) for t in toks[:n_out]]
        out.extend(new)
        last_tok = jnp.asarray(new[-1], dtype=jnp.int32)
        # a matched first-token guess is itself an accepted draft token:
        # c_toks[0] was proposed by the DLM and committed via the match rule
        acc_stat = n_acc + (1 if (mode == PAR and first_match) else 0)
        records.append(
            RoundRecord(
                mode=mode,
                drafted=drafted,
                accepted=acc_stat,
                emitted=n_out,
                discarded=discarded,
            )
        )
        new_mode = APSDPolicy.next_mode(mode, bool(all_acc), first_match)
        if new_mode == PAR and pending is None:
            # entering PAR from NONPAR: seed the first pending window
            d_toks, q_rows, d_cache, key = _draft_tokens(
                key, draft, draft_params, d_cache, last_tok, cfg.long_dl, temp
            )
            pending = (d_toks, q_rows)
        mode = new_mode
        if mode == NONPAR:
            pending = None

    stats = APSDStats(
        emitted=sum(r.emitted for r in records),
        rounds=len(records),
        drafted=sum(r.drafted for r in records),
        accepted=sum(r.accepted for r in records),
        discarded=sum(r.discarded for r in records),
        par_rounds=sum(1 for r in records if r.mode == PAR),
        records=tuple(records),
    )
    return jnp.asarray(out[: cfg.max_tokens], dtype=jnp.int32), stats
