"""Local Rotation Unit (LRU) — decomposed FWHT rotation for outlier-free
low-bit quantization (paper Fig. 31.1.3).

A global Hadamard rotation over channel dim ``n`` suppresses activation
outliers (QuaRot/SpinQuant) but needs an FWHT of depth ``log2(n/m)`` plus a
dense npot Hadamard GEMM; for n ~ 14336 that deep array is 4.37x the area of
the paper's 4K INT8 MAC array.  The LRU limits FWHT depth to <= 6 and
*approximates* the global rotation with two stages of overlapped local block
rotations.  Every scheme here composes orthonormal block rotations, so the
overall R is exactly orthogonal — computational invariance
``(x R)(R^T W) == x W`` holds exactly; only the outlier-*mixing* radius is
approximate.

Schemes (RotationPlan.kind):

  "exact":     n == m * 2**k with k <= 6 and small m — one block spans the
               whole dim, no approximation needed (e.g. 896 = 28 * 2**5).
  "tiled":     B = m * 2**k divides n.  Stage 1 applies kron(I_{n/B}, H_B)
               ("upper"); stage 2 rolls channels by B/2 and applies the same
               block-diagonal rotation ("lower"), coupling adjacent blocks —
               the overlapped upper/lower decomposition of the deep FWHT.
  "two_block": B >= ceil(n/2); stage 1 rotates channels [0, B), stage 2
               rotates [n-B, n); the 2B-n overlap couples the halves.  Used
               when no small-m block divides n.

Each block rotation H_B = kron(H_m, H_{2^k}) is applied as a depth-k FWHT
(the paper's RFA, reconfigurable 2^1..2^6 butterflies) followed by a +-1
H_m accumulate (the paper's HAU, "MAC-free"); on TPU the +-1 accumulate maps
onto the MXU and the FWHT onto a Pallas VMEM kernel (kernels/fwht.py).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hadamard

__all__ = [
    "RotationPlan",
    "plan_rotation",
    "search_mk",
    "block_hadamard",
    "rotation_matrix",
    "local_rotate",
    "local_rotate_transpose",
    "rotate_weight_in",
    "fwht_jnp",
    "rotation_cost",
    "global_rotation_cost",
    "kurtosis",
]

MAX_DEPTH = 6  # paper: RFA supports 2^1..2^6 FWHT
MAX_NPOT = 64  # largest H_m the HAU accumulates in one pass


@dataclasses.dataclass(frozen=True)
class RotationPlan:
    """How the LRU rotates a channel dimension ``n`` (see module docstring)."""

    n: int
    m: int  # npot Hadamard order (HAU factor)
    k: int  # FWHT depth (RFA factor); block B = m * 2**k
    kind: str  # "exact" | "tiled" | "two_block"

    @property
    def block(self) -> int:
        return self.m * (1 << self.k)

    @property
    def num_blocks(self) -> int:
        if self.kind == "exact":
            return 1
        if self.kind == "tiled":
            return self.n // self.block
        return 2

    @property
    def stages(self) -> int:
        return 1 if self.kind == "exact" else 2


def _stage_cost_per_channel(m: int, k: int) -> float:
    """Add-ops per channel of one block-rotation stage: k butterfly levels
    plus an m-wide +-1 accumulate (the paper's HAU is MAC-free; adds only)."""
    return float(k + m)


def _odd_part(n: int) -> int:
    while n % 2 == 0:
        n //= 2
    return n


def search_mk(
    n: int,
    max_depth: int = MAX_DEPTH,
    max_npot: int = MAX_NPOT,
    min_block: int = 512,
) -> Tuple[int, int, str]:
    """Find the (m, k, kind) realizing the LRU rotation of dim ``n``.

    Preference order (paper Fig. 31.1.3):
      1. "exact" — n == m * 2**k, k <= max_depth, m <= max_npot: a single
         block spans the dim, no approximation (e.g. 896 = 28 * 2**5).
      2. "tiled", npot-faithful — m is the smallest constructible Hadamard
         order containing odd(n) (the paper's pre-computed npot matrix,
         e.g. m=28 for 14336 = 2**9 * 28), k maximal <= max_depth.
      3. "tiled", generic — cheapest (k + m adds/channel) block B = m * 2**k
         dividing n with B >= min(min_block, largest feasible B); mixing
         radius is traded against array area exactly as the paper's search.
      4. "two_block" — two overlapped end-aligned blocks >= n/2 (dims where
         no small block divides n).
    """
    # 1) exact
    best: Optional[Tuple[float, int, int]] = None
    for k in range(max_depth, -1, -1):
        if n % (1 << k) == 0:
            m = n >> k
            if m <= max_npot and hadamard.is_available_order(m):
                c = _stage_cost_per_channel(m, k)
                if best is None or c < best[0]:
                    best = (c, m, k)
    if best is not None:
        return best[1], best[2], "exact"
    # 2) tiled with the natural npot factor
    odd = _odd_part(n)
    if odd > 1:
        m = odd
        while m <= max_npot and not hadamard.is_available_order(m):
            m *= 2
        if m <= max_npot:
            k = max_depth
            while k > 0 and (m * (1 << k) >= n or n % (m * (1 << k)) != 0):
                k -= 1
            b = m * (1 << k)
            if 64 <= b < n and n % b == 0:
                return m, k, "tiled"
    # 3) tiled generic: min cost subject to a mixing-radius floor
    cands = []
    for m in hadamard.available_orders(max_npot):
        for k in range(max_depth + 1):
            b = m * (1 << k)
            if 64 <= b < n and n % b == 0:
                cands.append((b, _stage_cost_per_channel(m, k), m, k))
    if cands:
        floor = min(min_block, max(c[0] for c in cands))
        cands = [c for c in cands if c[0] >= floor]
        cands.sort(key=lambda c: (c[1], -c[0]))
        b, _, m, k = cands[0]
        return m, k, "tiled"
    # 4) two overlapped end blocks
    half = (n + 1) // 2
    best2: Optional[Tuple[float, int, int]] = None
    for m in hadamard.available_orders(1024):
        for k in range(max_depth + 1):
            b = m * (1 << k)
            if half <= b < n:
                c = _stage_cost_per_channel(m, k)
                if best2 is None or c < best2[0]:
                    best2 = (c, m, k)
    if best2 is None:
        raise ValueError(f"no LRU (m,k) decomposition found for n={n}")
    return best2[1], best2[2], "two_block"


@functools.lru_cache(maxsize=None)
def plan_rotation(n: int, max_depth: int = MAX_DEPTH, max_npot: int = MAX_NPOT) -> RotationPlan:
    m, k, kind = search_mk(n, max_depth, max_npot)
    return RotationPlan(n=n, m=m, k=k, kind=kind)


def rotation_cost(plan: RotationPlan) -> float:
    """Total add-ops of the LRU rotation over all stages (per token) —
    energy/latency proxy."""
    per_ch = _stage_cost_per_channel(plan.m, plan.k)
    if plan.kind == "exact":
        return plan.n * per_ch
    if plan.kind == "tiled":
        return 2 * plan.n * per_ch
    return 2 * plan.block * per_ch


def rotation_area(plan: RotationPlan) -> float:
    """Hardware-area proxy (adder count) of the LRU: ONE block-wide array
    (RFA butterflies + HAU +-1 accumulate) reused across blocks and across
    the two stages — this reuse is where the paper's 92.7% saving lives."""
    return plan.block * _stage_cost_per_channel(plan.m, plan.k)


def global_rotation_area(n: int) -> float:
    """Area proxy of the baseline *global* rotation array: a full-width
    depth-log2(n/m) FWHT cascaded with the dense npot H_m stage (the paper's
    "4.37x the area of a 4K INT8 MAC array").  The npot factor is the
    smallest multiple-of-4 Hadamard order containing odd(n) — matrices of
    every such order <= 668 exist in Sloane's library [15]."""
    odd = _odd_part(n)
    if odd == 1:
        m = 1
    else:
        m = odd if odd % 4 == 0 else odd * (4 if odd % 2 else 2)
        while m % 4 != 0:
            m *= 2
    k = int(math.log2(n // m))
    return n * _stage_cost_per_channel(m, k)


def global_rotation_cost(n: int) -> float:
    """Op-count per token of the global rotation (one full-dim stage)."""
    return global_rotation_area(n)


# ---------------------------------------------------------------------------
# Dense reference matrices (tests / small dims only)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def block_hadamard(m: int, k: int) -> np.ndarray:
    """Orthonormal H_B = kron(H_m, H_{2^k}) / sqrt(B), B = m * 2**k."""
    hm = hadamard.hadamard_matrix(m).astype(np.float64)
    h2 = hadamard.hadamard_matrix(1 << k).astype(np.float64)
    hb = np.kron(hm, h2)
    b = m * (1 << k)
    return (hb / math.sqrt(b)).astype(np.float64)


@functools.lru_cache(maxsize=None)
def rotation_matrix(n: int, max_depth: int = MAX_DEPTH, max_npot: int = MAX_NPOT) -> np.ndarray:
    """Dense n x n orthogonal matrix of the full LRU rotation (reference).

    Row-vector convention: y = x @ R.
    """
    plan = plan_rotation(n, max_depth, max_npot)
    hb = block_hadamard(plan.m, plan.k)
    b = plan.block
    if plan.kind == "exact":
        return hb
    if plan.kind == "tiled":
        nb = plan.num_blocks
        stage1 = np.kron(np.eye(nb), hb)
        shift = b // 2
        perm = np.roll(np.eye(plan.n), -shift, axis=1)  # x @ perm rolls left
        stage2 = perm @ np.kron(np.eye(nb), hb) @ perm.T
        return stage1 @ stage2
    up = np.eye(plan.n)
    up[:b, :b] = hb
    lo = np.eye(plan.n)
    lo[plan.n - b :, plan.n - b :] = hb
    return up @ lo


# ---------------------------------------------------------------------------
# JAX application (row-vector convention: y = x @ R)
# ---------------------------------------------------------------------------


def _fwht_sylvester(x: jnp.ndarray, depth: int) -> jnp.ndarray:
    """kron(I_m, H_{2^depth}) applied along the last axis (butterflies)."""
    n = x.shape[-1]
    assert n % (1 << depth) == 0
    m = n >> depth
    lead = x.shape[:-1]
    y = x.reshape(*lead, m, 1 << depth)
    h = 1
    size = 1 << depth
    while h < size:
        y = y.reshape(*lead, m, size // (2 * h), 2, h)
        a = y[..., 0, :] + y[..., 1, :]
        b = y[..., 0, :] - y[..., 1, :]
        y = jnp.stack([a, b], axis=-2)
        h *= 2
    return y.reshape(*lead, n)


def fwht_jnp(x: jnp.ndarray, depth: Optional[int] = None) -> jnp.ndarray:
    """Unnormalized FWHT along the last axis (Sylvester order).

    With ``depth`` given, the last axis must be ``m * 2**depth`` and the
    transform acts within each contiguous 2**depth group, i.e. the
    kron(I_m, H_{2^depth}) factor.
    """
    n = x.shape[-1]
    if depth is None:
        depth = n.bit_length() - 1
        assert 1 << depth == n, "full FWHT needs power-of-two length"
    return _fwht_sylvester(x, depth)


def _apply_blocks(x: jnp.ndarray, m: int, k: int, transpose: bool = False) -> jnp.ndarray:
    """y = x @ kron(I_nb, H_B / sqrt(B)) along the last axis, B = m * 2**k.

    The FWHT (RFA) handles the 2^k factor; a +-1 H_m matmul (HAU / MXU on
    TPU) handles the npot factor.  kron index convention within a block:
    i = a * 2^k + r — H_m mixes ``a`` (stride 2^k), H_{2^k} mixes ``r``.
    """
    b = m * (1 << k)
    n = x.shape[-1]
    assert n % b == 0
    nb = n // b
    lead = x.shape[:-1]
    y = _fwht_sylvester(x, k)  # kron(I, H_{2^k}); Sylvester H is symmetric
    hm = jnp.asarray(hadamard.hadamard_matrix(m).astype(np.float32), dtype=x.dtype)
    if transpose:
        hm = hm.T
    y = y.reshape(*lead, nb, m, 1 << k)
    # y[g, b, r] <- sum_a y[g, a, r] * H_m[a, b]
    y = jnp.einsum("...gar,ab->...gbr", y, hm)
    y = y.reshape(*lead, n)
    return y * jnp.asarray(1.0 / math.sqrt(b), dtype=x.dtype)


def local_rotate(x: jnp.ndarray, plan: RotationPlan) -> jnp.ndarray:
    """y = x @ R along the last axis (the LRU's 1- or 2-stage rotation)."""
    n, b = plan.n, plan.block
    assert x.shape[-1] == n, (x.shape, n)
    if plan.kind == "exact":
        return _apply_blocks(x, plan.m, plan.k)
    if plan.kind == "tiled":
        y = _apply_blocks(x, plan.m, plan.k)  # stage 1 "upper"
        shift = b // 2
        y = jnp.roll(y, -shift, axis=-1)  # stage 2 "lower", offset by B/2
        y = _apply_blocks(y, plan.m, plan.k)
        return jnp.roll(y, shift, axis=-1)
    # two_block
    upper = _apply_blocks(x[..., :b], plan.m, plan.k)
    x = jnp.concatenate([upper, x[..., b:]], axis=-1)
    lower = _apply_blocks(x[..., n - b :], plan.m, plan.k)
    return jnp.concatenate([x[..., : n - b], lower], axis=-1)


def local_rotate_transpose(x: jnp.ndarray, plan: RotationPlan) -> jnp.ndarray:
    """y = x @ R^T (inverse rotation; R orthogonal)."""
    n, b = plan.n, plan.block
    assert x.shape[-1] == n
    if plan.kind == "exact":
        return _apply_blocks(x, plan.m, plan.k, transpose=True)
    if plan.kind == "tiled":
        # R = S1 @ P^T S2 P  =>  R^T = P^T S2^T P @ S1^T
        shift = b // 2
        y = jnp.roll(x, -shift, axis=-1)
        y = _apply_blocks(y, plan.m, plan.k, transpose=True)
        y = jnp.roll(y, shift, axis=-1)
        return _apply_blocks(y, plan.m, plan.k, transpose=True)
    # two_block: R = U @ L  =>  R^T = L^T @ U^T — undo lower first
    lower = _apply_blocks(x[..., n - b :], plan.m, plan.k, transpose=True)
    x = jnp.concatenate([x[..., : n - b], lower], axis=-1)
    upper = _apply_blocks(x[..., :b], plan.m, plan.k, transpose=True)
    return jnp.concatenate([upper, x[..., b:]], axis=-1)


def rotate_weight_in(w: jnp.ndarray, plan: RotationPlan) -> jnp.ndarray:
    """Fold R into a weight along its *input* dim (axis 0 of (in, out)):
    (x @ R) @ (R^T w) == x @ w.  Done offline; invariance is exact."""
    assert w.shape[0] == plan.n
    # R^T w == (w^T R)^T — reuse the row-vector apply on w^T
    return local_rotate(w.T, plan).T


def kurtosis(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Pearson kurtosis — outlier metric (3 = Gaussian)."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    d = x - mu
    m2 = jnp.mean(d**2, axis=axis, keepdims=True)
    m4 = jnp.mean(d**4, axis=axis, keepdims=True)
    return jnp.squeeze(m4 / (m2**2 + 1e-12), axis=axis)
