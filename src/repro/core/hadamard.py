"""Hadamard matrix constructions for outlier-free rotation (LRU substrate).

The paper's Local Rotation Unit decomposes a global Hadamard rotation of a
(possibly non-power-of-two) channel dimension ``n`` into FWHT butterflies of
depth <= 6 combined with a small "npot" Hadamard factor H_m, i.e. blocks of
size ``m * 2**k``.  This module provides the H_m constructions:

  * Sylvester (orders 2**j),
  * Paley I   (orders q+1,   q prime, q % 4 == 3),
  * Paley II  (orders 2(q+1), q prime, q % 4 == 1),
  * Kronecker products of the above.

All constructions are verified by ``H @ H.T == n * I`` (exact integer
arithmetic); ``hadamard_matrix`` raises if an order is not reachable.
Matrices are cached; entries are +-1 int8.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import numpy as np

__all__ = [
    "hadamard_matrix",
    "available_orders",
    "is_available_order",
    "fwht",
    "normalized_hadamard",
]


def _is_prime(q: int) -> bool:
    if q < 2:
        return False
    if q % 2 == 0:
        return q == 2
    i = 3
    while i * i <= q:
        if q % i == 0:
            return False
        i += 2
    return True


def _jacobsthal(q: int) -> np.ndarray:
    """Jacobsthal matrix Q[i, j] = chi(j - i) for prime q (quadratic residue
    character chi, chi(0) = 0)."""
    chi = np.full(q, -1, dtype=np.int8)
    chi[(np.arange(1, q, dtype=np.int64) ** 2) % q] = 1
    chi[0] = 0
    idx = (np.arange(q)[None, :] - np.arange(q)[:, None]) % q
    return chi[idx]


def _sylvester(order: int) -> np.ndarray:
    assert order >= 1 and (order & (order - 1)) == 0
    h = np.array([[1]], dtype=np.int8)
    while h.shape[0] < order:
        h = np.block([[h, h], [h, -h]]).astype(np.int8)
    return h


def _paley1(q: int) -> np.ndarray:
    """Order q + 1, q prime with q % 4 == 3."""
    qq = _jacobsthal(q)
    n = q + 1
    s = np.zeros((n, n), dtype=np.int8)
    s[0, 1:] = 1
    s[1:, 0] = -1
    s[1:, 1:] = qq
    h = s + np.eye(n, dtype=np.int8)
    return h.astype(np.int8)


def _paley2(q: int) -> np.ndarray:
    """Order 2 * (q + 1), q prime with q % 4 == 1."""
    qq = _jacobsthal(q)
    n = q + 1
    s = np.zeros((n, n), dtype=np.int8)
    s[0, 1:] = 1
    s[1:, 0] = 1
    s[1:, 1:] = qq
    a = np.array([[1, 1], [1, -1]], dtype=np.int8)
    b = np.array([[1, -1], [-1, -1]], dtype=np.int8)
    h = np.kron(s, a) + np.kron(np.eye(n, dtype=np.int8), b)
    return h.astype(np.int8)


@functools.lru_cache(maxsize=None)
def _base_orders(limit: int = 512) -> Dict[int, Tuple[str, int]]:
    """Orders reachable by a single base construction, -> (kind, param)."""
    out: Dict[int, Tuple[str, int]] = {1: ("sylvester", 1), 2: ("sylvester", 2)}
    o = 4
    while o <= limit:
        out[o] = ("sylvester", o)
        o *= 2
    for q in range(3, limit, 4):  # q % 4 == 3 -> order q+1
        if _is_prime(q) and q + 1 <= limit:
            out.setdefault(q + 1, ("paley1", q))
    for q in range(5, limit, 4):  # q % 4 == 1 -> order 2(q+1)
        if _is_prime(q) and 2 * (q + 1) <= limit:
            out.setdefault(2 * (q + 1), ("paley2", q))
    return out


@functools.lru_cache(maxsize=None)
def available_orders(limit: int = 512) -> Tuple[int, ...]:
    """All Hadamard orders <= limit reachable as products of base orders."""
    base = sorted(_base_orders(limit))
    reach = set(base)
    frontier = list(base)
    while frontier:
        a = frontier.pop()
        for b in base:
            p = a * b
            if p <= limit and p not in reach:
                reach.add(p)
                frontier.append(p)
    return tuple(sorted(reach))


def is_available_order(m: int, limit: int = 512) -> bool:
    return m in available_orders(max(limit, m))


@functools.lru_cache(maxsize=None)
def _factor_plan(order: int, limit: int) -> Tuple[int, ...]:
    """Greedy factorization of ``order`` into base orders (largest first)."""
    base = sorted(_base_orders(limit), reverse=True)

    def rec(rem: int) -> List[int] | None:
        if rem == 1:
            return []
        for b in base:
            if b > 1 and rem % b == 0:
                sub = rec(rem // b)
                if sub is not None:
                    return [b] + sub
        return None

    plan = rec(order)
    if plan is None:
        raise ValueError(f"no Hadamard construction found for order {order}")
    return tuple(plan)


@functools.lru_cache(maxsize=None)
def hadamard_matrix(order: int) -> np.ndarray:
    """A (+-1) Hadamard matrix of the given order, H @ H.T = order * I."""
    limit = max(512, order)
    plan = _factor_plan(order, limit)
    h = np.array([[1]], dtype=np.int8)
    base = _base_orders(limit)
    for o in plan:
        kind, param = base[o]
        if kind == "sylvester":
            piece = _sylvester(o)
        elif kind == "paley1":
            piece = _paley1(param)
        else:
            piece = _paley2(param)
        h = np.kron(h, piece).astype(np.int8)
    gram = h.astype(np.int64) @ h.astype(np.int64).T
    if not np.array_equal(gram, order * np.eye(order, dtype=np.int64)):
        raise AssertionError(f"construction for order {order} failed verification")
    return h


def normalized_hadamard(order: int, dtype=np.float32) -> np.ndarray:
    """Orthonormal Hadamard: Q @ Q.T = I."""
    return hadamard_matrix(order).astype(dtype) / np.sqrt(order).astype(dtype)


def fwht(x: np.ndarray, axis: int = -1) -> np.ndarray:
    """Unnormalized fast Walsh-Hadamard transform (numpy reference).

    Sylvester ordering; length along ``axis`` must be a power of two.
    """
    x = np.moveaxis(np.asarray(x), axis, -1).copy()
    n = x.shape[-1]
    assert n & (n - 1) == 0, "FWHT length must be a power of two"
    h = 1
    while h < n:
        y = x.reshape(*x.shape[:-1], n // (2 * h), 2, h)
        a = y[..., 0, :] + y[..., 1, :]
        b = y[..., 0, :] - y[..., 1, :]
        x = np.stack([a, b], axis=-2).reshape(*x.shape[:-1], n)
        h *= 2
    return np.moveaxis(x, -1, axis)
