"""First-order Markov toy LMs — exact, cheap oracles for the speculative
decoding stack.

A table LM's next-token distribution depends only on the last fed token, so
autoregressive decoding from it has a closed form and `sd_generate` /
`apsd_generate` outputs can be checked for *exact* losslessness (greedy) or
distributional correctness (sampled).  The functional cache is the fed-token
buffer + length, exercising the same rewind semantics as real KV caches.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.speculative import LMInterface

__all__ = ["make_markov_lm", "markov_greedy_decode", "random_transition_logits"]


def random_transition_logits(key: jax.Array, vocab: int, sharpness: float = 2.0):
    """(V, V) logits table: row t = distribution of the token after t."""
    return sharpness * jax.random.normal(key, (vocab, vocab), dtype=jnp.float32)


def make_markov_lm(max_len: int = 4096) -> LMInterface:
    """LMInterface over params = (V, V) transition logits.

    cache = (buffer (1, max_len) int32, length int32); logits at step i are
    table[fed_token_i].
    """

    def prefill(params, tokens):
        b, s = tokens.shape
        assert b == 1
        buf = jnp.zeros((1, max_len), jnp.int32)
        buf = jax.lax.dynamic_update_slice(buf, tokens.astype(jnp.int32), (0, 0))
        logits = params[tokens[0]][None]  # (1, S, V)
        return logits, (buf, jnp.asarray(s, jnp.int32))

    def extend(params, tokens, cache):
        buf, length = cache
        b, l = tokens.shape
        assert b == 1
        buf = jax.lax.dynamic_update_slice(
            buf, tokens.astype(jnp.int32), (0, length)
        )
        logits = params[tokens[0]][None]
        return logits, (buf, length + l)

    def rewind(cache, n):
        buf, length = cache
        return (buf, length - n)

    return LMInterface(prefill=prefill, extend=extend, rewind=rewind)


def markov_greedy_decode(
    params: jnp.ndarray, start: int, n: int
) -> jnp.ndarray:
    """Ground-truth greedy AD decode of the table LM."""
    toks = []
    cur = jnp.asarray(start, jnp.int32)
    for _ in range(n):
        cur = jnp.argmax(params[cur]).astype(jnp.int32)
        toks.append(int(cur))
    return jnp.asarray(toks, jnp.int32)
