"""WDOS — Workload-Decoupled Out-of-order Scheduler (paper Fig. 31.1.5).

The chip decouples APSD work into 4 parallel instruction queues — inter-chip
transceiver (XCVR), compute (COMPUTE), ReRAM load (RERAM) and external memory
access (EMAC).  Each queue issues ITS OWN instructions in order, but the
queues run concurrently; an instruction issues only when all its parents
(possibly in other queues) have completed — the "synchronous counter matrix"
of intra-queue decoders + inter-queue synchronizers.  The result is
out-of-order execution *across* queues with dependency-aware synchronization,
which is what lets DLM drafting (RERAM + COMPUTE) overlap TLM verification
(EMAC + COMPUTE) inside one chip.

This module is a discrete-event simulator of that scheduler.  It is used by
core/perfmodel.py to price SD / PEARL / APSD rounds and reproduces the
paper's utilization claims; the same DAG-building helpers drive the
benchmarks (benchmarks/bench_apsd.py).

On the TPU re-host the WDOS *idea* becomes: draft and verify dispatched in a
single XLA program on disjoint mesh slices so their compute/collectives
overlap (launch/serve.py); the simulator stays as the faithful model of the
silicon behaviour.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Queue",
    "Instr",
    "Schedule",
    "wdos_schedule",
    "inorder_schedule",
    "layer_pipeline_instrs",
]


class Queue(enum.IntEnum):
    XCVR = 0  # inter-chip transceiver
    COMPUTE = 1  # TFTE / NLPU / LRU
    RERAM = 2  # ReRAM load interface (DLM codebooks)
    EMAC = 3  # external memory access controller (TLM weights)


@dataclasses.dataclass(frozen=True)
class Instr:
    uid: int
    queue: Queue
    duration: float
    deps: Tuple[int, ...] = ()
    tag: str = ""


@dataclasses.dataclass
class Schedule:
    makespan: float
    start: Dict[int, float]
    finish: Dict[int, float]
    busy: Dict[Queue, float]

    def utilization(self, q: Queue) -> float:
        return self.busy.get(q, 0.0) / self.makespan if self.makespan > 0 else 0.0


def wdos_schedule(instrs: Sequence[Instr]) -> Schedule:
    """Simulate the 4-queue dependency-aware scheduler.

    Per-queue FIFO issue; cross-queue out-of-order; an instruction starts at
    max(queue free time, parents' finish).  Raises on dependency deadlock
    (cyclic or cross-queue head-of-line cycles)."""
    by_queue: Dict[Queue, List[Instr]] = {q: [] for q in Queue}
    for ins in instrs:
        by_queue[ins.queue].append(ins)
    heads = {q: 0 for q in Queue}
    qfree = {q: 0.0 for q in Queue}
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy = {q: 0.0 for q in Queue}
    remaining = len(instrs)
    while remaining > 0:
        progressed = False
        for q in Queue:
            lst = by_queue[q]
            while heads[q] < len(lst):
                ins = lst[heads[q]]
                if not all(d in finish for d in ins.deps):
                    break
                s = max(qfree[q], max((finish[d] for d in ins.deps), default=0.0))
                start[ins.uid] = s
                finish[ins.uid] = s + ins.duration
                qfree[q] = finish[ins.uid]
                busy[q] += ins.duration
                heads[q] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            raise RuntimeError("WDOS deadlock: unsatisfiable dependency order")
    makespan = max(finish.values(), default=0.0)
    return Schedule(makespan=makespan, start=start, finish=finish, busy=busy)


def inorder_schedule(instrs: Sequence[Instr]) -> Schedule:
    """Baseline: one in-order queue (no workload decoupling) — every
    instruction serializes.  This is the no-WDOS reference."""
    t = 0.0
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy = {q: 0.0 for q in Queue}
    for ins in instrs:
        start[ins.uid] = t
        t += ins.duration
        finish[ins.uid] = t
        busy[ins.queue] += ins.duration
    return Schedule(makespan=t, start=start, finish=finish, busy=busy)


class _Builder:
    """Monotonic uid allocator for DAG construction."""

    def __init__(self) -> None:
        self._uid = 0
        self.instrs: List[Instr] = []

    def add(
        self,
        queue: Queue,
        duration: float,
        deps: Iterable[int] = (),
        tag: str = "",
    ) -> int:
        uid = self._uid
        self._uid += 1
        self.instrs.append(
            Instr(uid=uid, queue=queue, duration=duration, deps=tuple(deps), tag=tag)
        )
        return uid


def layer_pipeline_instrs(
    builder: _Builder,
    n_layers: int,
    load_queue: Queue,
    load_time: float,
    compute_time: float,
    entry_deps: Iterable[int] = (),
    tag: str = "",
) -> Tuple[List[int], int]:
    """Per-layer load->compute pipeline: compute_i depends on load_i and
    compute_{i-1}; loads prefetch ahead (FIFO within the load queue).

    Returns (all uids, final compute uid)."""
    uids: List[int] = []
    prev_compute: Optional[int] = None
    entry = tuple(entry_deps)
    for i in range(n_layers):
        ld = builder.add(load_queue, load_time, entry if i == 0 else (), f"{tag}.load{i}")
        deps = [ld] + ([prev_compute] if prev_compute is not None else list(entry))
        cp = builder.add(Queue.COMPUTE, compute_time, deps, f"{tag}.comp{i}")
        uids.extend([ld, cp])
        prev_compute = cp
    assert prev_compute is not None
    return uids, prev_compute


def new_builder() -> _Builder:
    return _Builder()
