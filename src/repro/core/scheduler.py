"""WDOS — Workload-Decoupled Out-of-order Scheduler (paper Fig. 31.1.5).

The chip decouples APSD work into 4 parallel instruction queues — inter-chip
transceiver (XCVR), compute (COMPUTE), ReRAM load (RERAM) and external memory
access (EMAC).  Each queue issues ITS OWN instructions in order, but the
queues run concurrently; an instruction issues only when all its parents
(possibly in other queues) have completed — the "synchronous counter matrix"
of intra-queue decoders + inter-queue synchronizers.  The result is
out-of-order execution *across* queues with dependency-aware synchronization,
which is what lets DLM drafting (RERAM + COMPUTE) overlap TLM verification
(EMAC + COMPUTE) inside one chip.

This module is a discrete-event simulator of that scheduler.  It is used by
core/perfmodel.py to price SD / PEARL / APSD rounds and reproduces the
paper's utilization claims; the same DAG-building helpers drive the
benchmarks (benchmarks/bench_apsd.py).

On the TPU re-host the WDOS *idea* becomes: draft and verify dispatched in a
single XLA program on disjoint mesh slices so their compute/collectives
overlap (launch/serve.py); the simulator stays as the faithful model of the
silicon behaviour.

Since the fused PAR serving mode (``EngineConfig(par_mode="wdos")``,
serving/engine.py) the scheduler is no longer just a pricing model: the
*mixed phase plan* emitter below (``RowPhase`` / ``MixedSlotPlan`` /
``plan_mixed_slot``) decides, per fused dispatch slot, which batch rows run
a DLM draft micro-step and which run their TLM verify window — out of order
across requests, by per-row readiness.  The engine executes one plan as ONE
fused XLA dispatch (draft and verify subgraphs in the same program, the
TPU analogue of issuing to decoupled RERAM/EMAC queues), and
``mixed_slot_instrs`` prices exactly that slot so the modeled overlap can
be validated against the engine's measured fused-round telemetry
(benchmarks/bench_serving.py).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Queue",
    "Instr",
    "Schedule",
    "wdos_schedule",
    "inorder_schedule",
    "layer_pipeline_instrs",
    "RowPhase",
    "MixedSlotPlan",
    "plan_mixed_slot",
    "mixed_slot_instrs",
]


class Queue(enum.IntEnum):
    XCVR = 0  # inter-chip transceiver
    COMPUTE = 1  # TFTE / NLPU / LRU
    RERAM = 2  # ReRAM load interface (DLM codebooks)
    EMAC = 3  # external memory access controller (TLM weights)


@dataclasses.dataclass(frozen=True)
class Instr:
    uid: int
    queue: Queue
    duration: float
    deps: Tuple[int, ...] = ()
    tag: str = ""


@dataclasses.dataclass
class Schedule:
    makespan: float
    start: Dict[int, float]
    finish: Dict[int, float]
    busy: Dict[Queue, float]

    def utilization(self, q: Queue) -> float:
        return self.busy.get(q, 0.0) / self.makespan if self.makespan > 0 else 0.0


def wdos_schedule(instrs: Sequence[Instr]) -> Schedule:
    """Simulate the 4-queue dependency-aware scheduler.

    Per-queue FIFO issue; cross-queue out-of-order; an instruction starts at
    max(queue free time, parents' finish).  Raises on dependency deadlock
    (cyclic or cross-queue head-of-line cycles)."""
    by_queue: Dict[Queue, List[Instr]] = {q: [] for q in Queue}
    for ins in instrs:
        by_queue[ins.queue].append(ins)
    heads = {q: 0 for q in Queue}
    qfree = {q: 0.0 for q in Queue}
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy = {q: 0.0 for q in Queue}
    remaining = len(instrs)
    while remaining > 0:
        progressed = False
        for q in Queue:
            lst = by_queue[q]
            while heads[q] < len(lst):
                ins = lst[heads[q]]
                if not all(d in finish for d in ins.deps):
                    break
                s = max(qfree[q], max((finish[d] for d in ins.deps), default=0.0))
                start[ins.uid] = s
                finish[ins.uid] = s + ins.duration
                qfree[q] = finish[ins.uid]
                busy[q] += ins.duration
                heads[q] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            raise RuntimeError("WDOS deadlock: unsatisfiable dependency order")
    makespan = max(finish.values(), default=0.0)
    return Schedule(makespan=makespan, start=start, finish=finish, busy=busy)


def inorder_schedule(instrs: Sequence[Instr]) -> Schedule:
    """Baseline: one in-order queue (no workload decoupling) — every
    instruction serializes.  This is the no-WDOS reference."""
    t = 0.0
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy = {q: 0.0 for q in Queue}
    for ins in instrs:
        start[ins.uid] = t
        t += ins.duration
        finish[ins.uid] = t
        busy[ins.queue] += ins.duration
    return Schedule(makespan=t, start=start, finish=finish, busy=busy)


class _Builder:
    """Monotonic uid allocator for DAG construction."""

    def __init__(self) -> None:
        self._uid = 0
        self.instrs: List[Instr] = []

    def add(
        self,
        queue: Queue,
        duration: float,
        deps: Iterable[int] = (),
        tag: str = "",
    ) -> int:
        uid = self._uid
        self._uid += 1
        self.instrs.append(
            Instr(uid=uid, queue=queue, duration=duration, deps=tuple(deps), tag=tag)
        )
        return uid


def layer_pipeline_instrs(
    builder: _Builder,
    n_layers: int,
    load_queue: Queue,
    load_time: float,
    compute_time: float,
    entry_deps: Iterable[int] = (),
    tag: str = "",
) -> Tuple[List[int], int]:
    """Per-layer load->compute pipeline: compute_i depends on load_i and
    compute_{i-1}; loads prefetch ahead (FIFO within the load queue).

    Returns (all uids, final compute uid)."""
    uids: List[int] = []
    prev_compute: Optional[int] = None
    entry = tuple(entry_deps)
    for i in range(n_layers):
        ld = builder.add(load_queue, load_time, entry if i == 0 else (), f"{tag}.load{i}")
        deps = [ld] + ([prev_compute] if prev_compute is not None else list(entry))
        cp = builder.add(Queue.COMPUTE, compute_time, deps, f"{tag}.comp{i}")
        uids.extend([ld, cp])
        prev_compute = cp
    assert prev_compute is not None
    return uids, prev_compute


def new_builder() -> _Builder:
    return _Builder()


# ---------------------------------------------------------------------------
# Mixed phase plans: cross-request PAR (fused draft+verify) scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowPhase:
    """One batch row's position inside its current draft/verify cycle.

    ``window`` is the draft length its APSD controller chose for the
    in-flight window; ``drafted`` counts proposals made so far.  A row is
    ready to VERIFY exactly when the window is full — until then its next
    unit of work is one more DLM draft micro-step."""

    slot: int
    window: int
    drafted: int

    @property
    def verify_ready(self) -> bool:
        return self.drafted >= self.window


@dataclasses.dataclass(frozen=True)
class MixedSlotPlan:
    """Role assignment for ONE fused dispatch slot.

    ``draft_rows`` propose their next draft token (DLM, RERAM-fed);
    ``verify_rows`` score their full window (TLM, EMAC-fed) while their
    DLM side feeds the window's final straggler token — so a verify row
    occupies BOTH queues, which is what makes the slot a true PAR round.
    The two sets are disjoint; rows in neither set are idle this slot."""

    draft_rows: Tuple[int, ...]
    verify_rows: Tuple[int, ...]

    @property
    def fused(self) -> bool:
        """True when DIFFERENT requests' draft and verify work co-reside in
        the dispatch — the cross-request PAR overlap the paper's WDOS buys.
        (A verify row's own straggler also keeps the draft queue busy, but
        that is intra-request overlap; it is not counted here.)"""
        return bool(self.verify_rows) and bool(self.draft_rows)

    @property
    def rows(self) -> Tuple[int, ...]:
        return tuple(self.draft_rows) + tuple(self.verify_rows)


def plan_mixed_slot(rows: Sequence[RowPhase]) -> MixedSlotPlan:
    """Emit the next slot's mixed phase plan, out of order by readiness.

    Every window-full row verifies NOW (verification never benefits from
    waiting: the TLM pass is batched, so co-scheduling all ready rows costs
    one EMAC pipeline) and every other row advances its draft window by one
    token — request A verifies while request B drafts, the paper's
    Fig. 31.1.5 overlap lifted to cross-request granularity.  The plan is a
    pure function of row readiness, so the engine's execution and the
    discrete-event pricing (``mixed_slot_instrs``) see the same schedule."""
    verify = tuple(sorted(r.slot for r in rows if r.verify_ready))
    draft = tuple(sorted(r.slot for r in rows if not r.verify_ready))
    return MixedSlotPlan(draft_rows=draft, verify_rows=verify)


def mixed_slot_instrs(
    builder: _Builder,
    plan: MixedSlotPlan,
    t_layers: int,
    d_layers: int,
    t_costs: Tuple[float, float],  # (per-layer EMAC load, per-layer compute)
    d_costs: Tuple[float, float],  # (per-layer RERAM load, per-layer compute)
    verify_width: int,
    draft_width: int = 1,
) -> None:
    """Price ONE fused slot: a RERAM-fed DLM pipeline per drafting row
    (plus the straggler pipeline each verifying row's DLM side runs) and an
    EMAC-fed TLM pipeline per verifying row, all sharing no edges — the DAG
    the 4-queue WDOS overlaps and the in-order baseline serializes.

    ``draft_width`` scales the DLM compute per layer: chain speculation
    drafts one token per micro-step (width 1), tree speculation re-feeds
    the whole fixed-width draft window each micro-step so every DLM
    pipeline computes ``tree_budget + 1`` tokens wide."""
    d_load, d_comp = d_costs
    t_load, t_comp = t_costs
    for slot in plan.draft_rows:
        layer_pipeline_instrs(
            builder, d_layers, Queue.RERAM, d_load, d_comp * draft_width,
            tag=f"s{slot}.draft",
        )
    for slot in plan.verify_rows:
        layer_pipeline_instrs(
            builder, d_layers, Queue.RERAM, d_load, d_comp * draft_width,
            tag=f"s{slot}.straggler",
        )
        layer_pipeline_instrs(
            builder, t_layers, Queue.EMAC, t_load, t_comp * verify_width,
            tag=f"s{slot}.verify",
        )
