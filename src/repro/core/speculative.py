"""Speculative decoding (SD) — lossless draft-and-verify (paper Fig. 31.1.1).

A small draft LM (DLM) autoregressively proposes ``draft_len`` tokens; the
large target LM (TLM) scores all of them in ONE forward pass; modified
rejection sampling (Leviathan et al.) accepts a prefix and emits one extra
token, so the output distribution is *exactly* the TLM's.  This module is the
algorithmic core shared by the serving path (serving/), the APSD controller
(core/apsd.py) and the performance model (core/perfmodel.py).

Model-agnostic: models enter through ``LMInterface`` (prefill / extend /
decode callables over functional KV caches with an explicit length index, so
"rolling back" rejected tokens is just resetting the length — no copies).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SDConfig",
    "LMInterface",
    "speculative_sample",
    "speculative_accept_greedy",
    "speculative_accept_greedy_host",
    "speculative_sample_host",
    "sample_token_host",
    "sd_generate",
    "SDStats",
    "tree_ancestor_mask",
    "tree_depths",
    "tree_children",
    "topk_tokens_host",
    "speculative_tree_sample_host",
    "speculative_tree_accept_greedy_host",
]


@dataclasses.dataclass(frozen=True)
class SDConfig:
    draft_len: int = 4
    temperature: float = 1.0  # 0 => greedy (deterministic accept rule)
    max_tokens: int = 64


class LMInterface(NamedTuple):
    """Functional LM handle used by every SD driver.

    prefill(params, tokens (B,S))            -> (logits (B,S,V), cache)
    extend(params, tokens (B,L), cache)      -> (logits (B,L,V), cache)
        scores L tokens in one forward (the TLM verify pass); cache length
        advances by L.
    rewind(cache, n)                         -> cache with n tokens dropped
    """

    prefill: Callable[..., Tuple[jnp.ndarray, Any]]
    extend: Callable[..., Tuple[jnp.ndarray, Any]]
    rewind: Callable[[Any, int], Any]


class SDStats(NamedTuple):
    emitted: jnp.ndarray  # total tokens emitted
    rounds: jnp.ndarray  # number of draft/verify rounds
    drafted: jnp.ndarray  # total draft tokens proposed
    accepted: jnp.ndarray  # total draft tokens accepted

    @property
    def acceptance_rate(self):
        return self.accepted / jnp.maximum(self.drafted, 1)

    @property
    def rejection_rate(self):
        return 1.0 - self.acceptance_rate

    @property
    def tokens_per_round(self):
        return self.emitted / jnp.maximum(self.rounds, 1)


def _first_reject(accept: jnp.ndarray) -> jnp.ndarray:
    """Length of the all-accepted prefix of a boolean vector."""
    return jnp.sum(jnp.cumprod(accept.astype(jnp.int32)))


def speculative_sample(
    key: jax.Array,
    draft_tokens: jnp.ndarray,  # (L,) int32, sampled from q
    p_probs: jnp.ndarray,  # (L+1, V) target distribution at each position
    q_probs: jnp.ndarray,  # (L, V) draft distribution at each position
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Lossless speculative rejection sampling for one draft window.

    Returns (out_tokens (L+1,) padded with -1, n_out in [1, L+1],
    n_accepted in [0, L]).  The emitted sequence is distributed exactly as
    autoregressive sampling from p.
    """
    l, v = q_probs.shape
    k_u, k_res = jax.random.split(key)
    idx = jnp.arange(l)
    p_i = p_probs[idx, draft_tokens]
    q_i = q_probs[idx, draft_tokens]
    u = jax.random.uniform(k_u, (l,))
    accept = u * q_i < p_i  # u < p/q without the divide
    n_acc = _first_reject(accept)
    # residual distribution at the first rejected position (or bonus at L)
    p_next = p_probs[n_acc]
    q_next = jnp.where(n_acc < l, q_probs[jnp.minimum(n_acc, l - 1)], 0.0)
    residual = jnp.maximum(p_next - q_next, 0.0)
    res_sum = jnp.sum(residual)
    dist = jnp.where(res_sum > 1e-9, residual / jnp.maximum(res_sum, 1e-9), p_next)
    next_tok = jax.random.categorical(k_res, jnp.log(dist + 1e-20))
    pos = jnp.arange(l + 1)
    padded_draft = jnp.concatenate([draft_tokens, jnp.zeros((1,), draft_tokens.dtype)])
    out = jnp.where(pos < n_acc, padded_draft, -1)
    out = out.at[n_acc].set(next_tok.astype(draft_tokens.dtype))
    return out, n_acc + 1, n_acc


def speculative_accept_greedy(
    draft_tokens: jnp.ndarray,  # (L,)
    p_logits: jnp.ndarray,  # (L+1, V)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Greedy (temperature-0) verify: accept while draft == argmax(target)."""
    l = draft_tokens.shape[0]
    tlm_tok = jnp.argmax(p_logits, axis=-1).astype(draft_tokens.dtype)  # (L+1,)
    accept = tlm_tok[:l] == draft_tokens
    n_acc = _first_reject(accept)
    pos = jnp.arange(l + 1)
    padded_draft = jnp.concatenate([draft_tokens, jnp.zeros((1,), draft_tokens.dtype)])
    out = jnp.where(pos < n_acc, padded_draft, -1)
    out = out.at[n_acc].set(tlm_tok[n_acc])
    return out, n_acc + 1, n_acc


def _probs(logits: jnp.ndarray, temperature: float) -> jnp.ndarray:
    return jax.nn.softmax(logits / max(temperature, 1e-6), axis=-1)


# ---------------------------------------------------------------------------
# Host-side acceptance rules (the batched serving engine's per-row mirrors)
# ---------------------------------------------------------------------------
#
# The continuous-batching engine (serving/engine.py) runs the draft/verify
# forwards batched on device but commits per request on the host, where each
# row has its own draft length, sampling params, and PRNG key stream.  These
# helpers are the host-side mirrors of the jnp rules above, shared so the
# engine, the legacy host-gather baseline, and any future scheduler agree on
# ONE acceptance rule.


def speculative_accept_greedy_host(drafts, p_logits, dl: int):
    """Host mirror of ``speculative_accept_greedy`` for one request's round:
    accept while draft == argmax(target); emit the bonus/correction token.

    drafts: (>= dl,) int draft tokens; p_logits: (>= dl+1, V) target logits.
    np.argmax and jnp.argmax share the first-max tie rule, so this is
    bit-identical to the device rule."""
    tlm_tok = np.argmax(p_logits, axis=-1)  # (L+1,)
    n_acc = 0
    while n_acc < dl and tlm_tok[n_acc] == drafts[n_acc]:
        n_acc += 1
    return [int(t) for t in drafts[:n_acc]] + [int(tlm_tok[n_acc])], n_acc


def _top_k_filter_host(logits: np.ndarray, top_k: int) -> np.ndarray:
    """Keep the top-k logits (ties at the threshold all survive — the set is
    deterministic either way), set the rest to -inf."""
    if top_k <= 0 or top_k >= logits.shape[-1]:
        return logits
    thresh = np.partition(logits, -top_k, axis=-1)[..., -top_k, None]
    return np.where(logits < thresh, -np.inf, logits)


def _top_p_filter_host(logits: np.ndarray, top_p: float) -> np.ndarray:
    """Nucleus filter: keep the minimal set of tokens whose probability mass
    reaches ``top_p``, set the rest to -inf.

    Applied to temperature-scaled logits (the nucleus depends on the
    sampling temperature, unlike top-k).  Ties are broken by token id via a
    stable sort, so the kept set is deterministic — a request's nucleus
    never depends on batch composition.  ``top_p >= 1`` is the identity."""
    if top_p >= 1.0:
        return logits
    probs = _softmax_host(np.asarray(logits, np.float32))
    order = np.argsort(-probs, axis=-1, kind="stable")  # desc, low id first
    sorted_p = np.take_along_axis(probs, order, axis=-1)
    cum = np.cumsum(sorted_p, axis=-1)
    # keep while the mass BEFORE a token is < top_p: the minimal prefix
    # whose inclusive mass reaches top_p (the top token always survives)
    keep_sorted = (cum - sorted_p) < top_p
    keep = np.zeros(probs.shape, bool)
    np.put_along_axis(keep, order, keep_sorted, axis=-1)
    return np.where(keep, logits, -np.inf)


def _softmax_host(logits: np.ndarray) -> np.ndarray:
    x = logits - np.max(logits, axis=-1, keepdims=True)
    e = np.exp(x)
    return e / np.sum(e, axis=-1, keepdims=True)


def sample_token_host(
    key: jax.Array, logits: np.ndarray, temperature: float, top_k: int = 0,
    top_p: float = 1.0,
) -> int:
    """Sample one token from (temperature/top-k/top-p filtered) logits with
    an explicit key — the per-request draft-sampling step of the batched
    engine.  Deterministic in (key, logits, params) only, so a request's
    draw never depends on its batch composition.  ``top_p == 1`` leaves the
    historical temperature/top-k path bitwise untouched."""
    lg = _top_k_filter_host(np.asarray(logits, np.float32), top_k)
    lg = lg / max(temperature, 1e-6)
    if top_p < 1.0:
        lg = _top_p_filter_host(lg, top_p)
    return int(jax.random.categorical(key, jnp.asarray(lg)))


def speculative_sample_host(
    key: jax.Array,
    drafts,  # (>= dl,) int draft tokens sampled via sample_token_host
    p_logits: np.ndarray,  # (>= dl+1, V) target logits over the window
    q_logits: np.ndarray,  # (>= dl, V) draft logits at each draft position
    dl: int,
    temperature: float,
    top_k: int = 0,
    top_p: float = 1.0,
) -> Tuple[list, int]:
    """Host mirror of ``speculative_sample`` for one request's round.

    Applies the same temperature/top-k/top-p filter to both distributions
    that drafting used (filtering q exactly as ``sample_token_host`` drew
    the proposals keeps the rejection rule LOSSLESS: accepted-or-residual
    tokens are distributed exactly as nucleus sampling from the target),
    accepts the u*q < p prefix, and samples the residual (or bonus) token —
    all randomness from `key`, so the round is reproducible for a fixed
    per-request seed.  Returns
    (committed tokens [n_acc accepted drafts + 1 residual/bonus], n_acc)."""
    temp = max(temperature, 1e-6)

    def _filtered(logits):
        lg = _top_k_filter_host(np.asarray(logits, np.float32), top_k) / temp
        if top_p < 1.0:
            lg = _top_p_filter_host(lg, top_p)
        return _softmax_host(lg)

    p = _filtered(p_logits[: dl + 1])
    q = _filtered(q_logits[:dl])
    k_u, k_res = jax.random.split(key)
    u = np.asarray(jax.random.uniform(k_u, (max(dl, 1),)))
    idx = np.arange(dl)
    d = np.asarray(drafts[:dl], np.int64)
    accept = u[:dl] * q[idx, d] < p[idx, d]  # u < p/q without the divide
    n_acc = int(np.cumprod(accept.astype(np.int64)).sum()) if dl else 0
    p_next = p[n_acc]
    q_next = q[min(n_acc, dl - 1)] if n_acc < dl else np.zeros_like(p_next)
    residual = np.maximum(p_next - q_next, 0.0)
    res_sum = float(residual.sum())
    dist = residual / res_sum if res_sum > 1e-9 else p_next
    next_tok = int(
        jax.random.categorical(k_res, jnp.log(jnp.asarray(dist) + 1e-20))
    )
    return [int(t) for t in d[:n_acc]] + [next_tok], n_acc


# ---------------------------------------------------------------------------
# Tree speculation: topology helpers + lossless tree rejection sampling
# ---------------------------------------------------------------------------
#
# A speculation TREE generalizes the draft chain: each drafted node may fan
# out to several candidate children (top-k at low-confidence positions), and
# the target model scores the WHOLE tree in one ancestor-masked dispatch.
#
# Window layout convention (shared with the engine and the paged kernels):
# window slot 0 re-feeds the last committed token (the tree root's context);
# window slot 1+i holds drafted node i.  Nodes are indexed in drafting (BFS)
# order; ``parents[i]`` is the node index of i's parent, or -1 when i's
# parent is the root (last_tok).  Window-indexed logits follow the same
# convention: row 0 is the distribution after last_tok, row 1+i after node i.


def tree_children(parents) -> list:
    """children[w] = node indices whose parent occupies window slot w, in
    drafting order (node i sits at window slot 1+i; root at slot 0)."""
    kids: list = [[] for _ in range(len(parents) + 1)]
    for i, par in enumerate(parents):
        kids[0 if par < 0 else 1 + par].append(i)
    return kids


def tree_ancestor_mask(parents, width: int = None) -> np.ndarray:
    """(W, W) float32 ancestor mask for one request's tree window.

    Row w sees column j iff window slot j is slot w itself or an ancestor of
    it; slot 0 (last_tok) is an ancestor of every node.  ``width`` pads with
    self-visible-only rows (their softmax stays finite via prefix+self and
    their output is ignored) so every round compiles at ONE fixed width."""
    t = len(parents)
    w = t + 1 if width is None else width
    assert w >= t + 1, (w, t)
    m = np.eye(w, dtype=np.float32)
    for i in range(t):
        m[1 + i, 0] = 1.0
        par = parents[i]
        if par >= 0:
            m[1 + i] = np.maximum(m[1 + i], m[1 + par])
    return m


def tree_depths(parents, width: int = None) -> np.ndarray:
    """(W,) int32 window-relative depth of each slot: slot 0 (last_tok) is
    depth 0, node i is depth(parent) + 1.  These are the RoPE position
    offsets of the tree window (BFS slot order != position order).  Padded
    slots repeat depth 0 (garbage rows, positions irrelevant)."""
    t = len(parents)
    w = t + 1 if width is None else width
    d = np.zeros((w,), np.int32)
    for i in range(t):
        d[1 + i] = (d[1 + parents[i]] if parents[i] >= 0 else d[0]) + 1
    return d


def topk_tokens_host(logits: np.ndarray, k: int) -> list:
    """Top-k token ids, highest logit first, first-max-first on ties — so
    element 0 is exactly ``np.argmax(logits)`` (the greedy chain token)."""
    order = np.argsort(-np.asarray(logits, np.float32), kind="stable")
    return [int(t) for t in order[:k]]


def speculative_tree_sample_host(
    key: jax.Array,
    nodes,  # (T,) int drafted token per node, BFS order
    parents,  # (T,) int parent node index per node (-1 = root)
    p_logits: np.ndarray,  # (>= T+1, V) target logits, window-indexed
    q_logits: np.ndarray,  # (>= T+1, V) draft logits, window-indexed
    temperature: float,
    top_k: int = 0,
    top_p: float = 1.0,
) -> Tuple[list, list, int]:
    """Lossless TREE rejection sampling (SpecInfer-style multi-branch
    verify) for one request's round.

    Walks the tree from the root: at each position the residual starts as
    the filtered target distribution; each candidate child (drawn i.i.d.
    from the filtered draft distribution during drafting — with
    replacement, which is what keeps the rule exact) is accepted with
    probability ``min(1, r(x)/q(x))``; on rejection the residual updates to
    ``norm(max(r - q, 0))``.  When every child is rejected (or the position
    has none) the final token samples from the current residual, exactly as
    the chain rule's residual/bonus draw — so for fan-out-1 trees this
    reduces to ``speculative_sample_host`` decision-for-decision, and the
    emitted tokens are always distributed exactly as autoregressive
    sampling from the target.

    Per-decision randomness comes from ``jax.random.fold_in(key, i)`` with
    i counting accept tests and residual draws in walk order, so a round is
    reproducible from the request's accept key alone.

    Returns (committed tokens [path + 1 residual/bonus], accepted node
    indices in path order, n_accepted)."""
    temp = max(temperature, 1e-6)

    def _filtered(logits):
        lg = _top_k_filter_host(np.asarray(logits, np.float32), top_k) / temp
        if top_p < 1.0:
            lg = _top_p_filter_host(lg, top_p)
        return _softmax_host(lg)

    kids = tree_children(parents)
    committed: list = []
    path: list = []
    slot = 0  # current window slot (context position)
    decision = 0
    while True:
        p_w = _filtered(p_logits[slot])
        q_w = _filtered(q_logits[slot])
        r = p_w
        accepted = None
        for c in kids[slot]:
            tok = int(nodes[c])
            u = float(jax.random.uniform(jax.random.fold_in(key, decision)))
            decision += 1
            if u * q_w[tok] < r[tok]:  # u < r/q without the divide
                accepted = c
                break
            residual = np.maximum(r - q_w, 0.0)
            res_sum = float(residual.sum())
            r = residual / res_sum if res_sum > 1e-9 else r
        if accepted is not None:
            committed.append(int(nodes[accepted]))
            path.append(accepted)
            slot = 1 + accepted
            continue
        next_tok = int(
            jax.random.categorical(
                jax.random.fold_in(key, decision),
                jnp.log(jnp.asarray(r) + 1e-20),
            )
        )
        committed.append(next_tok)
        return committed, path, len(path)


def speculative_tree_accept_greedy_host(
    nodes, parents, p_logits: np.ndarray
) -> Tuple[list, list, int]:
    """Greedy (temperature-0) tree verify: descend to the first child that
    matches the target argmax at each position, emit the argmax correction
    when no child does.  Every committed token IS the target argmax at its
    position, so greedy tree and greedy chain emit the identical sequence —
    the tree only changes how many tokens commit per round."""
    kids = tree_children(parents)
    committed: list = []
    path: list = []
    slot = 0
    while True:
        top = int(np.argmax(p_logits[slot]))
        match = next((c for c in kids[slot] if int(nodes[c]) == top), None)
        if match is None:
            committed.append(top)
            return committed, path, len(path)
        committed.append(top)
        path.append(match)
        slot = 1 + match


def sd_generate(
    key: jax.Array,
    target: LMInterface,
    target_params: Any,
    draft: LMInterface,
    draft_params: Any,
    prompt: jnp.ndarray,  # (1, S) int32
    cfg: SDConfig,
) -> Tuple[jnp.ndarray, SDStats]:
    """Reference SD driver (host loop; jitted inner steps come from the
    LMInterface).  Batch 1, greedy or sampled.  Returns (tokens (T,), stats).
    """
    l = cfg.draft_len
    # Prefill all but the last prompt token: the last token is (re)fed as the
    # first element of every verify window / draft step, so the caches never
    # hold a position twice.
    assert prompt.shape[1] >= 2, "prompt must have >= 2 tokens"
    _, t_cache = target.prefill(target_params, prompt[:, :-1])
    _, d_cache = draft.prefill(draft_params, prompt[:, :-1])
    out: list = []
    emitted = drafted = accepted = rounds = 0
    last_tok = prompt[0, -1]

    while len(out) < cfg.max_tokens:
        # --- draft phase: DLM proposes l tokens autoregressively
        d_toks = []
        q_rows = []
        cur = last_tok
        for _ in range(l):
            lg, d_cache = draft.extend(
                draft_params, cur.reshape(1, 1), d_cache
            )
            qp = _probs(lg[0, -1], cfg.temperature)
            if cfg.temperature <= 0.0:
                nxt = jnp.argmax(lg[0, -1])
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lg[0, -1] / cfg.temperature)
            d_toks.append(nxt.astype(jnp.int32))
            q_rows.append(qp)
            cur = nxt
        draft_tokens = jnp.stack(d_toks)
        # --- verify phase: TLM scores [last_tok, draft...] in one forward
        verify_in = jnp.concatenate([last_tok.reshape(1), draft_tokens]).reshape(1, -1)
        vg, t_cache = target.extend(target_params, verify_in, t_cache)
        p_logits = vg[0]  # (l+1, V): position i predicts token after draft i-1
        if cfg.temperature <= 0.0:
            toks, n_out, n_acc = speculative_accept_greedy(draft_tokens, p_logits)
        else:
            key, sub = jax.random.split(key)
            toks, n_out, n_acc = speculative_sample(
                sub,
                draft_tokens,
                _probs(p_logits, cfg.temperature),
                jnp.stack(q_rows),
            )
        n_out_i, n_acc_i = int(n_out), int(n_acc)
        new = [int(t) for t in toks[:n_out_i]]
        out.extend(new)
        rounds += 1
        drafted += l
        accepted += n_acc_i
        emitted += n_out_i
        # --- cache maintenance. Invariant between rounds: each cache holds
        # exactly the committed sequence minus its last token (which is re-fed
        # as the head of the next window).
        # TLM consumed [last_tok, d_0..d_{l-1}] = l+1 positions; keep n_acc
        # drafts + the last_tok position.
        target_extra = l - n_acc_i
        if target_extra > 0:
            t_cache = target.rewind(t_cache, target_extra)
        # DLM consumed [last_tok, d_0..d_{l-2}] = l positions (d_{l-1} was
        # sampled but never fed). Keep n_acc drafts; when everything was
        # accepted, feed the straggler d_{l-1} to complete the cache.
        if n_acc_i == l:
            _, d_cache = draft.extend(
                draft_params, draft_tokens[-1].reshape(1, 1), d_cache
            )
        else:
            draft_extra = (l - 1) - n_acc_i
            if draft_extra > 0:
                d_cache = draft.rewind(d_cache, draft_extra)
        last_tok = jnp.asarray(new[-1], dtype=jnp.int32)

    stats = SDStats(
        emitted=jnp.asarray(emitted),
        rounds=jnp.asarray(rounds),
        drafted=jnp.asarray(drafted),
        accepted=jnp.asarray(accepted),
    )
    return jnp.asarray(out[: cfg.max_tokens], dtype=jnp.int32), stats
