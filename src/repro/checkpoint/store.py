"""Sharding-aware checkpointing: npz shards + JSON manifest, async writer,
restore-with-resharding.

Layout (one directory per step):

    ckpt_dir/step_000120/
        manifest.json     — tree structure, shapes, dtypes, mesh shape
        shard_host0.npz   — this host's param/optim leaves (fully gathered
                            here on the single-host CPU harness; on a real
                            fleet each host writes its addressable shards)

Restore never assumes the saving mesh: leaves are loaded as full arrays and
re-placed with ``jax.device_put(x, NamedSharding(new_mesh, spec))``, so a
checkpoint taken on (16, 16) restarts cleanly on (8, 16) or (2, 16, 16) —
the elastic-scaling path exercised in tests/test_runtime.py.

The async writer snapshots leaves to host memory synchronously (cheap) and
writes the npz on a worker thread (the slow part), double-buffered with a
bounded queue — training never blocks on the filesystem.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "AsyncCheckpointer"]

_SEP = "/"


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + [str(k)])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, path + [str(i)])
        else:
            flat[_SEP.join(path)] = node

    rec(tree, [])
    return flat


def _unflatten_from_paths(manifest_tree, flat: Dict[str, Any]):
    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, path + [k]) for k, v in node.items()}
        return flat[_SEP.join(path)]

    return rec(manifest_tree, [])


def _tree_skeleton(tree):
    if isinstance(tree, dict):
        return {k: _tree_skeleton(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {str(i): _tree_skeleton(v) for i, v in enumerate(tree)}
    return None


def _to_savable(v) -> Tuple[np.ndarray, str]:
    """npz cannot hold ml_dtypes (bfloat16 etc.) — store bit-cast views."""
    a = np.asarray(v)
    name = a.dtype.name
    if name == "bfloat16":
        return a.view(np.uint16), name
    if name not in np.sctypeDict and a.dtype.itemsize == 1:  # fp8 family
        return a.view(np.uint8), name
    return a, name


def _from_savable(a: np.ndarray, name: str) -> np.ndarray:
    if a.dtype.name == name:
        return a
    import ml_dtypes

    return a.view(np.dtype(getattr(ml_dtypes, name)))


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None):
    """Synchronous save: gather leaves to host, write npz + manifest."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    saved = {k: _to_savable(v) for k, v in flat.items()}
    arrays = {k: v[0] for k, v in saved.items()}
    np.savez(os.path.join(tmp, "shard_host0.npz"), **arrays)
    manifest = {
        "step": step,
        "tree": _tree_skeleton(tree),
        "dtypes": {k: v[1] for k, v in saved.items()},
        "shapes": {k: list(v[0].shape) for k, v in saved.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, d)  # atomic publish: partial writes never look valid
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for n in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", n))
    ]
    return max(steps) if steps else None


def load_checkpoint(
    ckpt_dir: str,
    step: Optional[int] = None,
    mesh=None,
    specs=None,
) -> Tuple[int, Any, dict]:
    """Restore (step, tree, extra).  With (mesh, specs) given, every leaf is
    re-placed onto the *current* mesh — resharding is free because leaves
    are stored unsharded."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "shard_host0.npz")) as z:
        flat = {k: _from_savable(z[k], manifest["dtypes"][k]) for k in z.files}
    tree = _unflatten_from_paths(manifest["tree"], flat)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding

        flat_specs = _flatten_with_paths(specs)

        def place(path, x):
            spec = flat_specs.get(path)
            if spec is None:
                return jnp.asarray(x)
            return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))

        flat_t = _flatten_with_paths(tree)
        tree = _unflatten_from_paths(
            manifest["tree"], {k: place(k, v) for k, v in flat_t.items()}
        )
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return step, tree, manifest.get("extra", {})


class AsyncCheckpointer:
    """Double-buffered background writer; ``save`` returns immediately."""

    def __init__(self, ckpt_dir: str, max_pending: int = 2):
        self.ckpt_dir = ckpt_dir
        self._q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
            except BaseException as e:  # surfaced on next save/close
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree, extra: Optional[dict] = None):
        if self._err:
            raise self._err
        # snapshot to host memory NOW (device buffers may be donated later)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._worker.join(timeout=10)
