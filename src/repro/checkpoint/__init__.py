from repro.checkpoint.store import (  # noqa: F401
    AsyncCheckpointer,
    load_checkpoint,
    latest_step,
    save_checkpoint,
)
