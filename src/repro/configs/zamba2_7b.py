"""zamba2-7b — hybrid: mamba2 backbone + ONE shared attention block applied
every 6 mamba blocks [arXiv:2411.15242; unverified]:
81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family=Family.HYBRID,
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_groups=1, ssm_chunk=256,
    attn_every=6,  # 13 shared-attn applications + 3 trailing mamba blocks
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family=Family.HYBRID,
    n_layers=5, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=256,
    ssm_state=16, ssm_headdim=32, ssm_chunk=16, attn_every=2, dtype="float32",
)
