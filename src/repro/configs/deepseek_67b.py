"""deepseek-67b — dense llama-arch [arXiv:2401.02954; hf]:
95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.

d_ff = 22016 = 2^9 * 43 has no constructible small Hadamard factor: the LRU
uses the generic tiled plan (m=8, k=6, B=512)."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="deepseek-67b", family=Family.DENSE,
    n_layers=95, d_model=8192, n_heads=64, n_kv=8, d_ff=22016, vocab=102400,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=172, vocab=256,
    dtype="float32",
)
