"""qwen3-8b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B; hf]:
36L d_model=4096 32H (kv=8) d_ff=12288 vocab=151936."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="qwen3-8b", family=Family.DENSE,
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    qk_norm=True, dtype="float32",
)
