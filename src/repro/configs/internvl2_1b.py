"""internvl2-1b — InternViT frontend (stubbed) + Qwen2-0.5B LM backbone
[arXiv:2404.16821; hf]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 v=151655.

14 heads do not divide the 16-way model axis: attention runs data-parallel
with replicated attention weights; the FFN/vocab stay TP-sharded
(DESIGN.md §Arch-applicability)."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="internvl2-1b", family=Family.VLM,
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864, vocab=151655, pad_vocab_to=16,
    n_vision_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family=Family.VLM,
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    n_vision_tokens=8, dtype="float32",
)
