"""grok-1-314b — MoE 8 experts top-2 [hf:xai-org/grok-1; unverified]:
64L d_model=6144 48H (GQA kv=8) d_ff=32768/expert vocab=131072.

8 experts < 16-way model axis: each expert's FFN splits across 2 shards
(layers.moe_ff_split); weights additionally FSDP-shard over 'data'."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="grok-1-314b", family=Family.MOE,
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, capacity_factor=1.25, moe_impl="a2a",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="grok-smoke", family=Family.MOE,
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    n_experts=4, top_k=2, moe_impl="dense", dtype="float32",
)
