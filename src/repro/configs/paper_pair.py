"""The paper's own TLM/DLM serving pair (Fig. 31.1.6 system config):
LLaMA2-7B target + LLaMA-68M-class draft.  These run the W4A8+LRU (TLM)
and BVQ (DLM) serving paths in serving/quantized_lm.py."""
from repro.models.common import Family, ModelConfig

TLM = ModelConfig(
    name="llama2-7b", family=Family.DENSE,
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_ff=11008, vocab=32000,
)

DLM = ModelConfig(
    name="llama-68m", family=Family.DENSE,
    n_layers=2, d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=32000,
)

TLM_SMOKE = ModelConfig(
    name="llama2-7b-smoke", family=Family.DENSE,
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=344, vocab=512,
    dtype="float32",
)

DLM_SMOKE = ModelConfig(
    name="llama-68m-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=512,
    dtype="float32",
)
