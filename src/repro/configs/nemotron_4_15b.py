"""nemotron-4-15b — dense GQA, squared-ReLU FFN [arXiv:2402.16819;
unverified]: 32L d_model=6144 48H (kv=8) d_ff=24576 vocab=256000."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b", family=Family.DENSE,
    n_layers=32, d_model=6144, n_heads=48, n_kv=8, d_ff=24576, vocab=256000,
    act="squared_relu",
)

SMOKE = ModelConfig(
    name="nemotron-smoke", family=Family.DENSE,
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=256, vocab=256,
    act="squared_relu", dtype="float32",
)
