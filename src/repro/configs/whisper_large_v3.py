"""whisper-large-v3 — encoder-decoder, conv/mel frontend STUBBED
[arXiv:2212.04356; unverified]: 32L enc + 32L dec, d_model=1280 20H (MHA
kv=20) d_ff=5120 vocab=51866, 1500 audio frames.

20 heads do not divide the 16-way model axis: attention is data-parallel
with replicated weights; FFN/vocab TP-shard (DESIGN.md §Arch-applicability).
decode shapes lower the DECODER (self-KV cache + cross-attn onto frames)."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3", family=Family.AUDIO,
    n_layers=32, n_encoder_layers=32, d_model=1280, n_heads=20, n_kv=20,
    d_ff=5120, vocab=51866, pad_vocab_to=16, act="gelu", n_audio_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family=Family.AUDIO,
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv=4,
    d_ff=128, vocab=256, act="gelu", n_audio_frames=16, dtype="float32",
)
