"""mamba2-1.3b — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]: 48L d_model=2048 vocab=50280 ssm_state=128."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="mamba2-1.3b", family=Family.SSM,
    n_layers=48, d_model=2048, n_heads=32, n_kv=32,  # attn fields unused
    d_ff=0, vocab=50280, pad_vocab_to=16,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_groups=1, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family=Family.SSM,
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=0, vocab=256,
    ssm_state=16, ssm_headdim=32, ssm_expand=2, ssm_groups=1, ssm_chunk=16,
    dtype="float32",
)
