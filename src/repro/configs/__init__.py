"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports FULL (the exact published config) and SMOKE (a reduced
same-family config for CPU tests).  ``long_500k`` applicability follows the
assignment: sub-quadratic decode only (SSM / hybrid)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig, ShapeConfig, SHAPES

_MODULES = {
    "mamba2-1.3b": "mamba2_1_3b",
    "internvl2-1b": "internvl2_1b",
    "zamba2-7b": "zamba2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "grok-1-314b": "grok_1_314b",
    "deepseek-67b": "deepseek_67b",
    "llama3-405b": "llama3_405b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-8b": "qwen3_8b",
    "whisper-large-v3": "whisper_large_v3",
}

ARCH_IDS: List[str] = list(_MODULES)

# archs whose decode is sub-quadratic in context (long_500k runs)
LONG_CONTEXT_ARCHS = {"mamba2-1.3b", "zamba2-7b"}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).FULL


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def shape_applicable(arch: str, shape: str) -> bool:
    """The 40-cell grid minus the assignment's documented skips."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            if include_skipped or shape_applicable(a, s):
                out.append((a, s))
    return out
