"""llama3-405b — dense GQA 128k vocab [arXiv:2407.21783; unverified]:
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

FSDP over 'data' + TP over 'model' (weights alone exceed TP-only HBM);
bf16 optimizer moments (DESIGN.md §6); long_500k skipped (full attention)."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="llama3-405b", family=Family.DENSE,
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_ff=53248, vocab=128256,
    rope_theta=500000.0, fsdp=True, optim_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke", family=Family.DENSE,
    n_layers=2, d_model=128, n_heads=8, n_kv=2, d_ff=416, vocab=256,
    dtype="float32",
)
