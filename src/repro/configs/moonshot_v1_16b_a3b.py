"""moonshot-v1-16b-a3b (Moonlight) — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]:
48L d_model=2048 16H (kv=16) d_ff=1408/expert vocab=163840."""
from repro.models.common import Family, ModelConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b", family=Family.MOE,
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, capacity_factor=1.25, moe_impl="a2a",
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family=Family.MOE,
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=96, vocab=256,
    n_experts=8, top_k=2, moe_impl="dense", dtype="float32",
)
