from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    SyntheticLMDataset,
    make_batch_iterator,
)
