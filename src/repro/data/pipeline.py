"""Deterministic synthetic LM data pipeline with host-sharded loading.

Production framing: each host materializes ONLY its shard of the global
batch (`host_slice`), generation is a pure function of (seed, step) so any
host can reproduce any step — which is what makes checkpoint-restart and
elastic re-sharding trivial (no data-loader state to save beyond the step
counter, and a resized fleet re-slices the same global stream).

The token stream is a seeded Zipf-ish unigram mixture with short-range
bigram structure — enough signal for the training loss to fall, which the
end-to-end example asserts.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["DataConfig", "SyntheticLMDataset", "make_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3  # unigram skew
    bigram_strength: float = 0.7  # P(next token from the bigram chain)


class SyntheticLMDataset:
    """Pure-function batches: batch(step) -> (global_batch, seq_len+1)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed unigram distribution (Zipf over a shuffled vocab)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        probs /= probs.sum()
        self._unigram = probs[rng.permutation(cfg.vocab)]
        # deterministic bigram successor table (a permutation => cycles)
        self._succ = rng.permutation(cfg.vocab)

    def batch(self, step: int) -> np.ndarray:
        """Full global batch for a step (any host can compute any slice)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len + 1
        out = np.empty((b, s), dtype=np.int32)
        cur = rng.choice(cfg.vocab, size=b, p=self._unigram)
        out[:, 0] = cur
        for t in range(1, s):
            follow = rng.random(b) < cfg.bigram_strength
            fresh = rng.choice(cfg.vocab, size=b, p=self._unigram)
            cur = np.where(follow, self._succ[cur], fresh)
            out[:, t] = cur
        return out

    def host_slice(self, step: int, host_id: int, n_hosts: int) -> np.ndarray:
        """This host's contiguous rows of the global batch."""
        assert self.cfg.global_batch % n_hosts == 0
        per = self.cfg.global_batch // n_hosts
        return self.batch(step)[host_id * per : (host_id + 1) * per]


def make_batch_iterator(
    cfg: DataConfig,
    start_step: int = 0,
    host_id: int = 0,
    n_hosts: int = 1,
) -> Iterator[Tuple[int, np.ndarray]]:
    """(step, batch) iterator resumable from any step (checkpoint-restart)."""
    ds = SyntheticLMDataset(cfg)
    step = start_step
    while True:
        yield step, ds.host_slice(step, host_id, n_hosts)
        step += 1
