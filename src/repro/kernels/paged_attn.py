"""Paged decode-attention Pallas kernel: attend THROUGH a page table.

The continuous-batching runtime (serving/engine.serve_batch) keeps every
request's KV in a shared block-granular pool (serving/paged_cache.py) that
is now DEVICE-RESIDENT: the model forward scatters new tokens straight into
pool pages and this kernel attends in place through the page table.  The
grid's innermost dimension walks a request's page table and the BlockSpec
index_map reads the page id from a scalar-prefetched table, so each
(request, kv-head) pair streams exactly its own pages pool->VMEM once and
runs online softmax in VREGs — decode attention over the paged pool with
zero gather materialization (the same trick the dense int8 kernel in
decode_attn.py plays on a contiguous cache, plus scalar-prefetch
indirection).

The kernel generalizes to the speculative VERIFY window: q may carry W > 1
query tokens per request (the round's [last_tok, drafts...] span), causally
masked inside the window — query w attends to absolute positions
<= length - W + w.

Layout (one grid step = one (request, kv-head) pair x one page):
  page_table (B, max_pages) int32  — scalar-prefetched; unused slots must
                                     hold any in-range id (masked by length)
  lengths    (B,)           int32  — valid tokens per request INCLUDING the
                                     W window tokens just written
  q          (B, KVS, G, hd)       — single decode token (W = 1), or
             (B, W, KVS, G, hd)    — multi-token verify window
  k_pool     (P, page_size, KVS, hd)
  v_pool     (P, page_size, KVS, hd)
  k_scale    (P, page_size, KVS, 1) f32, optional — per-slot-per-head
  v_scale    (P, page_size, KVS, 1) f32, optional   dequant scales
  out        same shape as q, f32

Compressed pools (``kv_quant="int8"``): pass int8 k/v pools plus the scale
pools and the kernel dequantizes INSIDE the page loop — each page's int8
bytes stream pool->VMEM compressed (≈4x less traffic than f32) and expand
to f32 only in registers, right before the QK^T dot.  Both the 4-D decode
and 5-D verify-window paths share the epilogue.

TPU note: real-hardware efficiency wants hd a multiple of 128 and
page_size a multiple of the sublane tile; interpret mode (CPU tests) takes
any shape.

Invariants (the contract with the serving engine):

* **Page-table lifetime stability** — the scalar-prefetched table is read
  fresh every call but the engine uploads each request's row exactly once
  per lifetime (pages are backed at admission and never move); unused table
  slots must hold ANY in-range page id (the engine points them at its
  scratch page) because the grid dereferences every slot and relies on the
  length mask, not the table, for validity.
* **Causal padding** — the fused PAR path always calls with the engine-wide
  fixed window W = max_dl + 1 and per-row lengths counting exactly the
  tokens written; rows whose real window is shorter arrive zero-padded.
  Query w's horizon is ``length - W + w``, so padded tail queries only ever
  produce garbage in their OWN output rows — earlier positions' outputs are
  bitwise independent of the padding, which is what makes fixed-width
  compilation safe.
* **Role-masked rows** — rows excluded from a fused dispatch arrive with
  an all-scratch table row and length 0: every kv position masks out, the
  softmax degenerates to uniform over -1e30 scores, and the finite garbage
  output is ignored by the caller.  The kernel itself never needs a role
  input.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_attention_pallas"]


def _attend_page(k, v, len_ref, q_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 n_pages: int, page_size: int, window: int, group: int,
                 scale: float, tm=None):
    """One online-softmax step over one (already dequantized, f32) page.

    ``tm`` (optional, (W, W) f32 for this batch row) replaces the causal
    window mask with an arbitrary intra-window visibility relation — the
    speculation-tree ancestor mask.  ``tm=None`` keeps the historical
    causal path bit-exact."""
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (W*G, hd)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (W*G, page_size)
    # mask token slots beyond each query's causal horizon: row (w, g) at
    # absolute position length - W + w sees kv positions <= itself.  This
    # also covers page-table slots past the request's page count (every
    # slot is masked) and reduces to `pos < length` when W == 1.
    pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    w = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) // group
    if tm is None:
        scores = jnp.where(pos <= len_ref[b] - window + w, scores, -1e30)
    else:
        # Tree mask, gather-free (TPU wants matmuls, not dynamic indexing):
        # expand tm to query rows with a row-onehot (W*G, W), then project
        # onto this page's kv slots with a col-onehot (W, page_size) built
        # from each slot's window-relative index.  Committed-prefix slots
        # (pos < length - W) stay visible to every query.
        r_iota = jax.lax.broadcasted_iota(jnp.int32, (window * group, window), 0)
        j_iota = jax.lax.broadcasted_iota(jnp.int32, (window * group, window), 1)
        row_onehot = (r_iota // group == j_iota).astype(jnp.float32)
        mask_rows = jax.lax.dot_general(
            row_onehot, tm, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (W*G, W)
        slot_iota = jax.lax.broadcasted_iota(jnp.int32, (window, page_size), 0)
        col_iota = jax.lax.broadcasted_iota(jnp.int32, (window, page_size), 1)
        rel = p * page_size + col_iota - (len_ref[b] - window)
        col_onehot = (rel == slot_iota).astype(jnp.float32)
        win_vis = jax.lax.dot_general(
            mask_rows, col_onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (W*G, page_size)
        visible = (pos < len_ref[b] - window) | (win_vis > 0.5)
        scores = jnp.where(visible, scores, -1e30)

    m_prev = m_ref[...]  # (W*G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    prob = jnp.exp(scores - m_new)  # (W*G, page_size)
    corr = jnp.exp(m_prev - m_new)  # (W*G, 1)
    l_ref[...] = l_ref[...] * corr + prob.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        prob, v,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (W*G, hd)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, **kw):
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page_size, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    _attend_page(k, v, len_ref, q_ref, o_ref, m_ref, l_ref, acc_ref, **kw)


def _kernel_quant(pt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, **kw):
    """int8 pools: dequantize this page in VREGs (per-slot scale broadcast
    over hd) right before the dots — the page crossed HBM->VMEM as int8."""
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0, :]
    _attend_page(k, v, len_ref, q_ref, o_ref, m_ref, l_ref, acc_ref, **kw)


def _kernel_tree(pt_ref, len_ref, q_ref, k_ref, v_ref, tm_ref, o_ref,
                 m_ref, l_ref, acc_ref, **kw):
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    _attend_page(k, v, len_ref, q_ref, o_ref, m_ref, l_ref, acc_ref,
                 tm=tm_ref[0], **kw)


def _kernel_quant_tree(pt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                       tm_ref, o_ref, m_ref, l_ref, acc_ref, **kw):
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0, :]
    _attend_page(k, v, len_ref, q_ref, o_ref, m_ref, l_ref, acc_ref,
                 tm=tm_ref[0], **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(
    q: jnp.ndarray,  # (B, KVS, G, hd) or (B, W, KVS, G, hd)
    k_pool: jnp.ndarray,  # (P, page_size, KVS, hd)
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, max_pages) int32
    lengths: jnp.ndarray,  # (B,) int32 — valid tokens incl. the window
    interpret: Optional[bool] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (P, page_size, KVS, 1) f32
    v_scale: Optional[jnp.ndarray] = None,
    tree_mask: Optional[jnp.ndarray] = None,  # (B, W, W) window visibility
) -> jnp.ndarray:
    """Attention through the page table (no dense cache copy), f32 out.

    4-D q decodes one token per request (``lengths`` = valid prefix, the
    original contract); 5-D q scores a W-token window causally (``lengths``
    counts the window's tokens too — the dense verify-path convention).

    With ``k_scale``/``v_scale`` (both or neither) the pools are int8 and
    each page is dequantized inside the kernel (``value * scale`` per slot
    per kv-head) — the compressed-at-rest path.

    ``tree_mask`` (5-D q only) replaces the intra-window causal mask with a
    per-row (W, W) visibility relation — query slot w sees window slot j iff
    ``tree_mask[b, w, j]`` — turning the verify window into a speculation
    tree; every query still sees the committed prefix."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    windowed = q.ndim == 5
    if windowed:
        b, w, kvs, g, hd = q.shape
        # (B, W, KVS, G, hd) -> (B, KVS, W*G, hd), rows (w, g) W-major
        qk = q.transpose(0, 2, 1, 3, 4).reshape(b, kvs, w * g, hd)
    else:
        b, kvs, g, hd = q.shape
        w = 1
        qk = q
    _, page_size, pool_kvs, pool_hd = k_pool.shape
    assert (pool_kvs, pool_hd) == (kvs, hd), (k_pool.shape, q.shape)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"
    n_pages = page_table.shape[1]
    rows = w * g
    scale = 1.0 / math.sqrt(hd)
    grid = (b, kvs, n_pages)
    page_spec = lambda width: pl.BlockSpec(
        (1, page_size, 1, width), lambda i, j, p, pt, ln: (pt[i, p], 0, j, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, rows, hd), lambda i, j, p, pt, ln: (i, j, 0, 0)),
        page_spec(hd),
        page_spec(hd),
    ]
    inputs = [qk, k_pool, v_pool]
    if quantized:
        in_specs += [page_spec(1), page_spec(1)]
        inputs += [k_scale, v_scale]
    treed = tree_mask is not None
    if treed:
        assert windowed, "tree_mask requires a 5-D window q"
        assert tree_mask.shape == (b, w, w), (tree_mask.shape, (b, w, w))
        in_specs += [
            pl.BlockSpec((1, w, w), lambda i, j, p, pt, ln: (i, 0, 0))
        ]
        inputs += [tree_mask.astype(jnp.float32)]
    kernels = {
        (False, False): _kernel,
        (True, False): _kernel_quant,
        (False, True): _kernel_tree,
        (True, True): _kernel_quant_tree,
    }
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, lengths
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, rows, hd), lambda i, j, p, pt, ln: (i, j, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            kernels[(quantized, treed)],
            n_pages=n_pages, page_size=page_size,
            window=w, group=g, scale=scale,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvs, rows, hd), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), *inputs)
    if windowed:
        out = out.reshape(b, kvs, w, g, hd).transpose(0, 2, 1, 3, 4)
    return out
