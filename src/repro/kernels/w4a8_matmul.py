"""Pallas TPU kernel for the W4A8 GEMM (the paper's 4K INT8 MAC array with
fused dynamic dequantization).

Weights arrive nibble-packed (two INT4 values per int8 along K); activations
are dynamic per-token INT8 with their scales bypassed into the epilogue —
exactly the paper's TFTE dataflow: INT32 accumulation on the MXU, one
FP multiply per output element at the end.

Grid: (M tiles, N tiles, K tiles), K innermost so a VMEM scratch accumulator
carries partial sums; the unpack (shift/mask) runs on the VPU right before
the MXU dot.  Tile defaults (128, 128, 256-packed) keep the working set
under ~0.5 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["w4a8_matmul_pallas"]


def _unpack_nibbles(wp: jnp.ndarray) -> jnp.ndarray:
    """(bk2, bn) int8 packed -> (2*bk2, bn) int32 sign-extended int4.

    Element 2i of K is the low nibble, 2i+1 the high nibble (matches
    core.quantization.pack_int4 with axis=0)."""
    p = wp.astype(jnp.int32)
    lo = (p << 28) >> 28
    hi = p >> 4
    bk2, bn = wp.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn)


def _w4a8_kernel(xq_ref, wp_ref, sx_ref, sw_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = xq_ref[...].astype(jnp.int32)  # (bm, bk)
    w = _unpack_nibbles(wp_ref[...])  # (bk, bn) int32
    acc_ref[...] += jax.lax.dot_general(
        xq,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * sx_ref[...] * sw_ref[...]).astype(o_ref.dtype)


def _tile(dim: int, want: int) -> int:
    t = min(want, dim)
    while dim % t:
        t -= 1
    return t


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def w4a8_matmul_pallas(
    xq: jnp.ndarray,  # (M, K) int8
    wp: jnp.ndarray,  # (K // 2, N) int8, nibble-packed along K
    sx: jnp.ndarray,  # (M, 1) f32 per-token activation scales
    sw: jnp.ndarray,  # (1, N) f32 per-channel weight scales
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """y = (xq @ unpack_int4(wp)) * sx * sw with INT32 accumulation."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = xq.shape
    k2, n = wp.shape
    assert k == 2 * k2, (k, k2)
    assert sx.shape == (m, 1) and sw.shape == (1, n)
    bm = _tile(m, bm)
    bn = _tile(n, bn)
    bk = _tile(k, bk)
    assert bk % 2 == 0, "K tile must cover whole packed bytes"
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_w4a8_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu_vmem((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, wp, sx, sw)


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocation, tolerant of the CPU interpreter."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
