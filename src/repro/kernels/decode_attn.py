"""Fused decode-attention Pallas kernel over an INT8 KV cache.

The §Perf analysis showed decode cells pinned by cache movement: the
functional update + dequant materialization cost ~8x the analytic floor in
the HLO metric.  This kernel is the TPU-native fix: one grid pass over the
cache streams int8 KV blocks HBM->VMEM exactly once, fuses the per-token
scale dequant into the dot, runs online softmax in VREGs, and never
materializes a float copy of the cache — achieving the floor by
construction.

Layout (one grid step = one (batch, kv-head) pair x one KV block):
  q        (B, KVS, G, hd)   f32/bf16 — G = H / n_kv_store query heads
  k_cache  (B, S, KVS, hd)   int8
  k_scale  (B, S, KVS)       f32 per-token-per-head absmax scales
  v_cache / v_scale          same
  length   ()                int32 — valid prefix (including the new token)
  out      (B, KVS, G, hd)   f32

Scratch carries the online-softmax state (m, l, acc) across KV blocks
(innermost grid dim), the same pattern as the w4a8 kernel's K loop.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_int8_pallas"]


def _kernel(len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, s_blocks: int, block_s: int, scale: float):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_s, hd) int8 -> f32
    ks = ks_ref[0, :, 0].astype(jnp.float32)  # (block_s,)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, block_s)
    scores = scores * ks[None, :]  # fold the per-token K scale (exact)
    # mask positions beyond the valid prefix
    pos = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < len_ref[0], scores, -1e30)

    m_prev = m_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)  # (G, block_s)
    corr = jnp.exp(m_prev - m_new)  # (G, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    vs = vs_ref[0, :, 0].astype(jnp.float32)  # (block_s,)
    pv = jax.lax.dot_general(
        p * vs[None, :],  # fold the per-token V scale into the weights
        v_ref[0, :, 0, :].astype(jnp.float32),  # (block_s, hd)
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, hd)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(sb == s_blocks - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_int8_pallas(
    q: jnp.ndarray,  # (B, KVS, G, hd)
    k_cache: jnp.ndarray,  # (B, S, KVS, hd) int8
    k_scale: jnp.ndarray,  # (B, S, KVS) f32
    v_cache: jnp.ndarray,
    v_scale: jnp.ndarray,
    length: jnp.ndarray,  # () int32
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """out (B, KVS, G, hd) f32 — one decoded token's attention."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, kvs, g, hd = q.shape
    s = k_cache.shape[1]
    block_s = min(block_s, s)
    assert s % block_s == 0, (s, block_s)
    s_blocks = s // block_s
    scale = 1.0 / math.sqrt(hd)
    grid = (b, kvs, s_blocks)
    len_arr = jnp.broadcast_to(length.reshape(1), (1,)).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(
            _kernel, s_blocks=s_blocks, block_s=block_s, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, sb: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda i, j, sb: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda i, j, sb: (i, sb, j, 0)),
            pl.BlockSpec((1, block_s, 1), lambda i, j, sb: (i, sb, j)),
            pl.BlockSpec((1, block_s, 1, hd), lambda i, j, sb: (i, sb, j, 0)),
            pl.BlockSpec((1, block_s, 1), lambda i, j, sb: (i, sb, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j, sb: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvs, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, q, k_cache, k_scale, v_cache, v_scale)
