"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the corresponding kernel's contract exactly; kernel
tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hadamard
from repro.core.bvq import BVQWeight, bvq_reconstruct
from repro.core.quantization import unpack_int4
from repro.core.rotation import _apply_blocks

__all__ = ["block_rotate_ref", "w4a8_matmul_ref2", "bvq_matmul_ref2"]


def block_rotate_ref(x: jnp.ndarray, m: int, k: int, transpose: bool = False):
    """Oracle for kernels.fwht.block_rotate_pallas."""
    return _apply_blocks(x, m, k, transpose=transpose)


def w4a8_matmul_ref2(xq, wp, sx, sw):
    """Oracle for kernels.w4a8_matmul.w4a8_matmul_pallas (packed input)."""
    w = unpack_int4(wp, axis=0).astype(jnp.int32)
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32),
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * sx * sw


def bvq_matmul_ref2(x: jnp.ndarray, bw: BVQWeight):
    """Oracle for kernels.bvq_matmul.bvq_matmul_pallas."""
    return (x.astype(jnp.float32) @ bvq_reconstruct(bw)).astype(jnp.float32)
