"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the corresponding kernel's contract exactly; kernel
tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hadamard
from repro.core.bvq import BVQWeight, bvq_reconstruct
from repro.core.quantization import unpack_int4
from repro.core.rotation import _apply_blocks

__all__ = [
    "block_rotate_ref",
    "w4a8_matmul_ref2",
    "bvq_matmul_ref2",
    "gather_pages_ref",
    "paged_attn_ref",
]


def block_rotate_ref(x: jnp.ndarray, m: int, k: int, transpose: bool = False):
    """Oracle for kernels.fwht.block_rotate_pallas."""
    return _apply_blocks(x, m, k, transpose=transpose)


def w4a8_matmul_ref2(xq, wp, sx, sw):
    """Oracle for kernels.w4a8_matmul.w4a8_matmul_pallas (packed input)."""
    w = unpack_int4(wp, axis=0).astype(jnp.int32)
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32),
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * sx * sw


def bvq_matmul_ref2(x: jnp.ndarray, bw: BVQWeight):
    """Oracle for kernels.bvq_matmul.bvq_matmul_pallas."""
    return (x.astype(jnp.float32) @ bvq_reconstruct(bw)).astype(jnp.float32)


def gather_pages_ref(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(P, ps, KVS, hd) pool + (B, max_pages) table -> (B, max_pages*ps, KVS,
    hd) contiguous per-request K/V (the dense view of a paged cache)."""
    b, mp = page_table.shape
    _, ps, kvs, hd = pool.shape
    return pool[page_table].reshape(b, mp * ps, kvs, hd)


def paged_attn_ref(
    q: jnp.ndarray,  # (B, KVS, G, hd) f32, or (B, W, KVS, G, hd) for a window
    k_pool: jnp.ndarray,  # (P, page_size, KVS, hd)
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, max_pages) int32 (unused slots: any valid id)
    lengths: jnp.ndarray,  # (B,) int32 valid tokens (incl. the window when 5-D)
    k_scale: jnp.ndarray = None,  # (P, page_size, KVS, 1) f32 (int8 pools)
    v_scale: jnp.ndarray = None,
    tree_mask: jnp.ndarray = None,  # (B, W, W) visibility among window slots
) -> jnp.ndarray:
    """Oracle for kernels.paged_attn.paged_decode_attention_pallas: gather
    the pages into a dense cache, then masked softmax attention per row.
    With scales the pools are int8 and dequantize after the gather — the
    reference semantics of the kernel's in-page dequant epilogue.

    A 5-D q is a W-token causally-masked window whose last query sits at
    absolute position ``lengths - 1`` (the speculative verify span).

    ``tree_mask`` generalizes the causal window to a speculation TREE: the
    window occupies absolute kv slots ``lengths - W .. lengths - 1`` and
    query slot w sees kv window slot j iff ``tree_mask[b, w, j]`` (the
    ancestor relation), while every query still sees the whole committed
    prefix (positions < lengths - W).  ``tree_mask=None`` is the bit-exact
    causal-window path above (chain speculation)."""
    windowed = q.ndim == 5
    if not windowed:
        q = q[:, None]  # (B, 1, KVS, G, hd); lengths = prefix == window end
    b, w, kvs, g, hd = q.shape
    k = gather_pages_ref(k_pool, page_table).astype(jnp.float32)  # (B, S, KVS, hd)
    v = gather_pages_ref(v_pool, page_table).astype(jnp.float32)
    if k_scale is not None:
        k = k * gather_pages_ref(k_scale, page_table).astype(jnp.float32)
        v = v * gather_pages_ref(v_scale, page_table).astype(jnp.float32)
    s = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum(
        "bwkgh,bskh->bwkgs", q.astype(jnp.float32) * scale, k,
        preferred_element_type=jnp.float32,
    )
    if tree_mask is None:
        # query w attends kv positions <= lengths - W + w
        horizon = lengths[:, None] - w + jnp.arange(w)[None, :]  # (B, W)
        valid = jnp.arange(s)[None, None] <= horizon[..., None]  # (B, W, S)
    else:
        # window slot of each kv position (clipped; gated by in_window)
        rel = jnp.arange(s)[None, :] - (lengths[:, None] - w)  # (B, S)
        in_window = (rel >= 0) & (rel < w)
        idx = jnp.broadcast_to(jnp.clip(rel, 0, w - 1)[:, None, :], (b, w, s))
        win_vis = jnp.take_along_axis(tree_mask.astype(bool), idx, axis=2)
        prefix = jnp.arange(s)[None, None, :] < (lengths[:, None, None] - w)
        valid = prefix | (in_window[:, None, :] & win_vis)  # (B, W, S)
    scores = jnp.where(valid[:, :, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bwkgs,bskh->bwkgh", p, v, preferred_element_type=jnp.float32)
    if not windowed:
        out = out[:, 0]
    return out.astype(jnp.float32)
