"""Pallas TPU kernel for the BVQ matmul (the paper's RS-PNM + tile fusion).

Layout mirrors the chip: each block of ``block_cols`` output channels owns a
small codebook (the stacked-ReRAM resident data -> here: VMEM resident); the
int32 indices stream in per block; the weight tile is RECONSTRUCTED ONCE per
grid step and reused by every token row in the tile — that grid ordering IS
the tile-fusion unit: one codebook fetch serves the whole token batch, and
blocks are independent (intra-/inter-layer parallelism).

Grid: (M tiles, N blocks).  K is kept whole per step (DLM-scale layers), so
VMEM holds x_tile (bm x K), one codebook (C x v), indices (K/v x bc) and the
reconstructed tile (K x bc) — ~2.5 MB at bm=128, K=4096, bc=128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bvq import BVQWeight, dequant_codebooks

__all__ = ["bvq_matmul_pallas"]


def _bvq_kernel(x_ref, cb_ref, idx_ref, o_ref, *, v: int):
    x = x_ref[...]  # (bm, K)
    cb = cb_ref[0]  # (C, v) — this block's codebook
    idx = idx_ref[0]  # (rows, bc) int32, rows = K // v
    rows, bc = idx.shape
    gathered = cb[idx.reshape(-1)]  # (rows * bc, v)
    w = (
        gathered.reshape(rows, bc, v)
        .transpose(0, 2, 1)  # (rows, v, bc): K index = row * v + t
        .reshape(rows * v, bc)
    )
    o_ref[...] = jax.lax.dot_general(
        x,
        w.astype(x.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def _tile(dim: int, want: int) -> int:
    t = min(want, dim)
    while dim % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def bvq_matmul_pallas(
    x: jnp.ndarray,  # (M, K)
    bw: BVQWeight,
    bm: int = 128,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """y = x @ reconstruct(bw); codebooks decoded once per (tile, block)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = x.shape
    k_w, n = bw.shape
    assert k == k_w, (k, k_w)
    nb, rows, bc = bw.indices.shape
    v = bw.vec_dim
    assert rows * v == k
    bm = _tile(m, bm)
    cb = dequant_codebooks(bw, dtype=jnp.float32)  # (nb, C, v)
    grid = (m // bm, nb)
    return pl.pallas_call(
        functools.partial(_bvq_kernel, v=v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, cb.shape[1], v), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, rows, bc), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, cb, bw.indices)
