"""Pallas TPU kernel for the LRU block rotation (paper's RFA + HAU).

One grid step rotates a (token_tile, B) tile entirely in VMEM:
  * the 2^k factor as in-register radix-2 butterflies (the RFA —
    reconfigurable 2^1..2^6 FWHT, depth <= 6),
  * the npot H_m factor as a +-1 matmul on the MXU (the HAU's "MAC-free
    accumulate" — on TPU the MXU IS the cheap way to do a +-1 GEMM),
  * the 1/sqrt(B) normalization fused with the store.

The grid walks (token tiles) x (channel blocks); the channel dim must be a
multiple of B = m * 2**k.  Two-stage tiled/two-block schemes are composed in
ops.lru_rotate from this single-stage kernel.

TPU notes: B is a multiple of 128 for every assigned dim (so the lane dim is
MXU/VREG aligned); token tiles default to 256 rows and shrink for very large
B to bound VMEM at ~4 MB per input tile.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import hadamard

__all__ = ["block_rotate_pallas"]


def _fwht_in_kernel(y: jnp.ndarray, k: int) -> jnp.ndarray:
    """(t, m, 2^k) -> FWHT along the last axis, unrolled butterflies."""
    t, m, size = y.shape
    h = 1
    while h < size:
        y = y.reshape(t, m, size // (2 * h), 2, h)
        a = y[:, :, :, 0, :] + y[:, :, :, 1, :]
        b = y[:, :, :, 0, :] - y[:, :, :, 1, :]
        y = jnp.stack([a, b], axis=3)
        h *= 2
    return y.reshape(t, m, size)


def _rotate_kernel(x_ref, hm_ref, o_ref, *, m: int, k: int, transpose: bool):
    x = x_ref[...]
    t, b = x.shape
    size = 1 << k
    y = x.reshape(t, m, size)
    y = _fwht_in_kernel(y, k)  # kron(I_m, H_{2^k})
    hm = hm_ref[...]  # (m, m) +-1 in x.dtype
    if transpose:
        hm = hm.T
    # HAU: out[t, b, r] = sum_a y[t, a, r] * hm[a, b]  -> MXU dot
    y = jax.lax.dot_general(
        y,
        hm,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (t, size, m) with contracted axis moved: result dims (t, r, b)
    y = y.transpose(0, 2, 1).reshape(t, b)
    o_ref[...] = (y * (1.0 / math.sqrt(b))).astype(o_ref.dtype)


def _token_tile(n_tokens: int, block: int) -> int:
    # bound VMEM: tile * block * 4B <= ~4 MB
    cap = max(8, (4 << 20) // (4 * block))
    tile = min(256, n_tokens, cap)
    while n_tokens % tile:
        tile -= 1
    return max(tile, 1)


@functools.partial(
    jax.jit, static_argnames=("m", "k", "transpose", "interpret")
)
def block_rotate_pallas(
    x: jnp.ndarray,
    m: int,
    k: int,
    transpose: bool = False,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ kron(I_{n/B}, H_B / sqrt(B)) over the last axis, B = m * 2**k.

    x: (..., n) with n % B == 0.  Leading dims are flattened into a token
    axis; the Pallas grid is (token tiles, channel blocks).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b = m * (1 << k)
    n = x.shape[-1]
    assert n % b == 0, (n, b)
    lead = x.shape[:-1]
    tokens = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(tokens, n)
    bt = _token_tile(tokens, b)
    hm = jnp.asarray(hadamard.hadamard_matrix(m), dtype=x.dtype)
    grid = (tokens // bt, n // b)
    out = pl.pallas_call(
        functools.partial(_rotate_kernel, m=m, k=k, transpose=transpose),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, b), lambda i, j: (i, j)),
            pl.BlockSpec((m, m), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tokens, n), x.dtype),
        interpret=interpret,
    )(x2, hm)
    return out.reshape(*lead, n)
