"""Public jit'd wrappers over the Pallas kernels.

These are the entry points the model/serving layers call; they handle plan
composition (multi-stage rotations), packing, and fall back to the pure-jnp
reference implementations for shapes the kernels do not cover (e.g. channel
dims whose block does not divide them).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as q
from repro.core import rotation as rot
from repro.core.bvq import BVQWeight
from repro.kernels import ref
from repro.kernels.bvq_matmul import bvq_matmul_pallas
from repro.kernels.fwht import block_rotate_pallas
from repro.kernels.w4a8_matmul import w4a8_matmul_pallas

__all__ = ["lru_rotate", "lru_rotate_transpose", "w4a8_linear", "bvq_linear"]


def lru_rotate(
    x: jnp.ndarray, plan: rot.RotationPlan, use_pallas: bool = True
) -> jnp.ndarray:
    """y = x @ R for any RotationPlan, Pallas-kernel backed."""
    apply_block = (
        (lambda t, m, k, tr=False: block_rotate_pallas(t, m, k, transpose=tr))
        if use_pallas
        else (lambda t, m, k, tr=False: rot._apply_blocks(t, m, k, transpose=tr))
    )
    n, b = plan.n, plan.block
    assert x.shape[-1] == n
    if plan.kind == "exact":
        return apply_block(x, plan.m, plan.k)
    if plan.kind == "tiled":
        y = apply_block(x, plan.m, plan.k)
        shift = b // 2
        y = jnp.roll(y, -shift, axis=-1)
        y = apply_block(y, plan.m, plan.k)
        return jnp.roll(y, shift, axis=-1)
    upper = apply_block(x[..., :b], plan.m, plan.k)
    x = jnp.concatenate([upper, x[..., b:]], axis=-1)
    lower = apply_block(x[..., n - b :], plan.m, plan.k)
    return jnp.concatenate([x[..., : n - b], lower], axis=-1)


def lru_rotate_transpose(
    x: jnp.ndarray, plan: rot.RotationPlan, use_pallas: bool = True
) -> jnp.ndarray:
    apply_block = (
        (lambda t, m, k: block_rotate_pallas(t, m, k, transpose=True))
        if use_pallas
        else (lambda t, m, k: rot._apply_blocks(t, m, k, transpose=True))
    )
    n, b = plan.n, plan.block
    assert x.shape[-1] == n
    if plan.kind == "exact":
        return apply_block(x, plan.m, plan.k)
    if plan.kind == "tiled":
        shift = b // 2
        y = jnp.roll(x, -shift, axis=-1)
        y = apply_block(y, plan.m, plan.k)
        y = jnp.roll(y, shift, axis=-1)
        return apply_block(y, plan.m, plan.k)
    lower = apply_block(x[..., n - b :], plan.m, plan.k)
    x = jnp.concatenate([x[..., : n - b], lower], axis=-1)
    upper = apply_block(x[..., :b], plan.m, plan.k)
    return jnp.concatenate([upper, x[..., b:]], axis=-1)


def w4a8_linear(
    x: jnp.ndarray,
    packed_w: jnp.ndarray,  # (K//2, N) int8 nibble-packed
    sw: jnp.ndarray,  # (1, N)
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Dynamic-A8 linear over packed W4 weights: y = Q8(x) @ W4 * sx * sw."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, sx = q.quantize_act_int8(x2)
    if use_pallas:
        y = w4a8_matmul_pallas(xq, packed_w, sx, sw)
    else:
        y = ref.w4a8_matmul_ref2(xq, packed_w, sx, sw)
    return y.reshape(*lead, -1).astype(x.dtype)


def bvq_linear(x: jnp.ndarray, bw: BVQWeight, use_pallas: bool = True) -> jnp.ndarray:
    """y = x @ reconstruct(bw) with on-the-fly codebook decode."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_pallas:
        y = bvq_matmul_pallas(x2, bw)
    else:
        y = ref.bvq_matmul_ref2(x2, bw)
    return y.reshape(*lead, -1).astype(x.dtype)
