"""Serving engine: wires real zoo models into the SD / APSD drivers.

Builds `LMInterface` adapters (prefill / extend / rewind over the functional
caches) for any of: bf16 `lm.apply_lm`, W4A8 `apply_quantized_lm`, BVQ
`apply_bvq_lm` — so the full paper configuration

    TLM = W4A8+LRU target model,  DLM = BVQ draft model,  APSD controller

runs end to end on real weights.  Rewind is O(1): reset the cache length
(stale slots are overwritten and masked).  On a TPU mesh the draft and
verify dispatches overlap (the WDOS idea); on CPU they serialize but are
bit-identical.

`serve_batch` is the multi-request runtime on top of the same models: KV
lives in block-granular paged pools (serving/paged_cache.py), a continuous
batcher (serving/batcher.py) admits/evicts requests under a page budget, and
each draft/verify step runs as ONE vmapped model call over every active
request.  Greedy outputs are bit-identical per request to the single-request
``serve_sd`` path — batching and paging change scheduling, never sampling.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apsd import APSDConfig, apsd_generate
from repro.core.speculative import LMInterface, SDConfig, sd_generate
from repro.models import layers as L
from repro.models import lm
from repro.models.common import ModelConfig
from repro.serving import quantized_lm as qlm
from repro.serving.batcher import BatchConfig, ContinuousBatcher
from repro.serving.paged_cache import PagedKVPool, pages_for
from repro.serving.request import Request, RequestState

__all__ = [
    "make_interface",
    "ServingModel",
    "serve_sd",
    "serve_apsd",
    "serve_batch",
    "BatchConfig",
]


@dataclasses.dataclass
class ServingModel:
    cfg: ModelConfig
    params: Any
    mode: str = "bf16"  # bf16 | w4a8 | bvq
    mesh: Any = None
    s_max: int = 512
    use_pallas: bool = False

    def _apply(self, params, tokens, cache):
        if self.mode == "w4a8":
            return qlm.apply_quantized_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas,
            )
        if self.mode == "bvq":
            return qlm.apply_bvq_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas,
            )
        return lm.apply_lm(params, self.cfg, self.mesh, tokens, cache=cache)


def make_interface(model: ServingModel) -> LMInterface:
    cfg, mesh, s_max = model.cfg, model.mesh, model.s_max

    def fresh_cache(batch):
        if model.mode in ("w4a8", "bvq"):
            # quantized paths use the dense attn cache layout
            c = lm.init_cache(
                dataclasses.replace(cfg),  # same shapes
                batch, s_max, tp=mesh.shape["model"] if mesh else 1,
            )
            return c
        return lm.init_cache(cfg, batch, s_max, tp=mesh.shape["model"] if mesh else 1)

    @jax.jit
    def _prefill(params, tokens, cache):
        return model._apply(params, tokens, cache)

    @jax.jit
    def _extend(params, tokens, cache):
        return model._apply(params, tokens, cache)

    def prefill(params, tokens):
        cache = fresh_cache(tokens.shape[0])
        return _prefill(params, tokens, cache)

    def extend(params, tokens, cache):
        return _extend(params, tokens, cache)

    def rewind(cache, n):
        if n < 0:
            raise ValueError(f"rewind expects n >= 0, got {n}")
        length = cache["length"]
        try:
            if int(length) - n < 0:
                raise ValueError(
                    f"over-rewind: cache length {int(length)} < rewind {n}"
                )
        except jax.errors.ConcretizationTypeError:
            pass  # traced length: fall through to the clamp below
        c = dict(cache)
        c["length"] = jnp.maximum(length - n, 0)
        return c

    return LMInterface(prefill=prefill, extend=extend, rewind=rewind)


def serve_sd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: SDConfig,
):
    return sd_generate(
        key,
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        prompt, cfg,
    )


def serve_apsd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: APSDConfig,
):
    return apsd_generate(
        key,
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        prompt, cfg,
    )


# ---------------------------------------------------------------------------
# Continuous-batching runtime (paged KV + vmapped draft/verify steps)
# ---------------------------------------------------------------------------


def _np_dtype(cfg: ModelConfig):
    return np.asarray(jnp.zeros((), cfg.jdtype)).dtype


def _make_batched_step(model: ServingModel):
    """jit(vmap) of one cache-extending forward: every active request is a
    batch row with its OWN cache length (positions, masking, and the KV
    write offset are per-row).  Returns full updated dense K/V views so the
    engine scatters only the written span back into the page pool."""

    @jax.jit
    def step(params, tokens, k, v, lengths):
        # tokens (B, L) int32; k/v (B, n_layers, 1, S_pad, kvh, hd); lengths (B,)
        def one(tok, kk, vv, ln):
            cache = {"length": ln, "attn": {"k": kk, "v": vv}}
            logits, nc = model._apply(params, tok[None, :], cache)
            return logits[0], nc["attn"]["k"], nc["attn"]["v"]

        return jax.vmap(one)(tokens, k, v, lengths)

    return step


class _PoolGather:
    """Reusable pinned host buffers for pool -> dense batched cache views."""

    def __init__(self, max_batch: int, pool: PagedKVPool, s_pad: int, dtype):
        shape = (max_batch, pool.n_layers, 1, s_pad, pool.kv_heads, pool.head_dim)
        self.k = np.zeros(shape, dtype)
        self.v = np.zeros(shape, dtype)
        self.lengths = np.zeros((max_batch,), np.int32)

    def load(self, rows):
        """rows: iterable of (slot index, PagedSequence)."""
        self.lengths[:] = 0
        for i, seq in rows:
            seq.gather_into(self.k[i, :, 0], self.v[i, :, 0])
            self.lengths[i] = seq.length
        return jnp.asarray(self.k), jnp.asarray(self.v), jnp.asarray(self.lengths)


def _pool_for(model: ServingModel, cfg: BatchConfig, peaks: Sequence[int]):
    """Page pool sized to hold `max_batch` worst-case requests (or the
    explicit cfg.num_pages budget)."""
    mcfg = model.cfg
    if mcfg.kv_quant:
        raise NotImplementedError("paged pools hold dense-dtype KV (kv_quant=False)")
    if model.mesh is not None:
        raise NotImplementedError("serve_batch runs the single-host path (mesh=None)")
    if cfg.num_pages is not None:
        num_pages = cfg.num_pages
    else:
        worst = sorted((pages_for(p, cfg.page_size) for p in peaks), reverse=True)
        num_pages = sum(worst[: cfg.max_batch])
    return PagedKVPool(
        n_layers=mcfg.n_layers,
        kv_heads=L.kv_store_heads(mcfg, 1),
        head_dim=mcfg.hd,
        num_pages=num_pages,
        page_size=cfg.page_size,
        dtype=_np_dtype(mcfg),
    )


def _greedy_accept_host(drafts: np.ndarray, p_logits: np.ndarray, dl: int):
    """Host-side mirror of ``speculative_accept_greedy`` for one request:
    accept while draft == argmax(target); emit the bonus/correction token."""
    tlm_tok = np.argmax(p_logits, axis=-1)  # (L+1,), first-max tie rule == jnp
    n_acc = 0
    while n_acc < dl and tlm_tok[n_acc] == drafts[n_acc]:
        n_acc += 1
    return [int(t) for t in drafts[:n_acc]] + [int(tlm_tok[n_acc])], n_acc


def serve_batch(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompts: Sequence[Any],  # each (S,) or (1, S) int32, S >= 2
    cfg: BatchConfig,
    sinks: Optional[Sequence[Optional[Callable[[int], None]]]] = None,
) -> Tuple[List[jnp.ndarray], dict]:
    """Continuously-batched greedy speculative decoding over paged KV pools.

    Admits up to ``cfg.max_batch`` concurrent requests (more queue behind the
    page budget), runs each SD round as vmapped draft/verify steps over every
    active request, and streams tokens to per-request sinks.  Returns the
    per-request outputs (original submission order) and the batch summary
    (pool stats + the WDOS cross-request overlap model).

    Greedy only: per-request outputs are bit-identical to ``serve_sd`` with
    the same models (asserted in tests/test_serving_batch.py).
    """
    del key  # greedy path is deterministic; kept for API symmetry with serve_sd
    if cfg.temperature != 0.0:
        raise NotImplementedError("serve_batch currently supports temperature=0.0")

    requests = [
        Request(
            rid=i,
            prompt=np.asarray(p).reshape(-1),
            max_new_tokens=cfg.max_tokens,
            sink=sinks[i] if sinks else None,
        )
        for i, p in enumerate(prompts)
    ]
    if not requests:
        return [], {
            "requests": 0, "rounds": 0, "steps": 0, "emitted": 0,
            "acceptance_rate": 0.0, "target_pool": None, "draft_pool": None,
            "wdos_modeled_speedup": 1.0,
            "wdos_utilization": {},
        }
    peaks = [r.peak_cache_len(cfg.max_dl) for r in requests]
    for model in (target, draft):
        if max(peaks) > model.s_max:
            raise ValueError(
                f"peak cache length {max(peaks)} exceeds s_max={model.s_max} "
                f"of {model.cfg.name}"
            )

    t_pool = _pool_for(target, cfg, peaks)
    d_pool = _pool_for(draft, cfg, peaks)

    def _costs(mcfg: ModelConfig) -> Tuple[float, float]:
        load = 12.0 * mcfg.d_model * mcfg.d_model * 1e-6  # ~per-layer weight bytes
        return load, 0.25 * load

    batcher = ContinuousBatcher(
        cfg, t_pool, d_pool,
        t_layers=target.cfg.n_layers, d_layers=draft.cfg.n_layers,
        t_costs=_costs(target.cfg), d_costs=_costs(draft.cfg),
    )
    for r in requests:
        batcher.submit(r)

    t_iface, d_iface = make_interface(target), make_interface(draft)
    t_step, d_step = _make_batched_step(target), _make_batched_step(draft)
    t_gather = _PoolGather(cfg.max_batch, t_pool, target.s_max, _np_dtype(target.cfg))
    d_gather = _PoolGather(cfg.max_batch, d_pool, draft.s_max, _np_dtype(draft.cfg))

    def _prefill_into(req: Request, iface: LMInterface, params, seq):
        # same jitted program as the single-request path => bitwise identical
        plen = req.prompt.shape[0]
        _, cache = iface.prefill(params, jnp.asarray(req.prompt[None, :-1]))
        k = np.asarray(cache["attn"]["k"])[:, 0]  # (n_layers, s_max, kvh, hd)
        v = np.asarray(cache["attn"]["v"])[:, 0]
        seq.append(k[:, : plen - 1], v[:, : plen - 1])

    while not batcher.all_done():
        for _, req in batcher.admit():
            _prefill_into(req, t_iface, target.params, req.t_seq)
            _prefill_into(req, d_iface, draft.params, req.d_seq)
            req.state = RequestState.DECODE
        active = batcher.active()
        if not active:
            batcher.step_count += 1
            continue

        dls = {slot: req.controller.draft_len() for slot, req in active}
        round_dl = max(dls.values())

        # ---- draft phase: round_dl sampled steps + 1 straggler step, all
        # vmapped; the dense draft cache stays on device across the loop.
        dk, dv, d_len0 = d_gather.load((s, r.d_seq) for s, r in active)
        cur = np.zeros((cfg.max_batch,), np.int32)
        for slot, req in active:
            cur[slot] = req.last_tok
        cur_dev = jnp.asarray(cur)
        draft_cols = []
        for j in range(round_dl + 1):
            logits, dk, dv = d_step(
                draft.params, cur_dev[:, None], dk, dv, d_len0 + j
            )
            if j < round_dl:
                cur_dev = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                draft_cols.append(cur_dev)
            # else: straggler — feeds d_{round_dl-1}, completing the cache for
            # fully-accepted rows; over-written rows rewind it away below.
        drafts = np.asarray(jnp.stack(draft_cols, axis=1))  # (B, round_dl)

        # ---- verify phase: one vmapped pass scoring [last_tok, drafts...]
        tk, tv, t_len0 = t_gather.load((s, r.t_seq) for s, r in active)
        window = np.zeros((cfg.max_batch, round_dl + 1), np.int32)
        window[:, 0] = cur
        window[:, 1:] = drafts
        v_logits, tk, tv = t_step(
            target.params, jnp.asarray(window), tk, tv, t_len0
        )
        p_logits = np.asarray(v_logits)  # (B, round_dl+1, V)
        dk_host, dv_host = np.asarray(dk), np.asarray(dv)
        tk_host, tv_host = np.asarray(tk), np.asarray(tv)

        # ---- per-request accept / commit / page maintenance
        work = []
        for slot, req in active:
            dl = dls[slot]
            new, n_acc = _greedy_accept_host(drafts[slot], p_logits[slot], dl)
            req.commit(new)
            req.rounds += 1
            req.drafted += dl
            req.accepted += n_acc
            req.controller.observe(n_acc, dl)
            work.append((req, dl))
            # target wrote round_dl+1 positions at t_len0; keep n_acc + 1
            t0 = int(t_len0[slot])
            req.t_seq.append(
                tk_host[slot, :, 0, t0 : t0 + round_dl + 1],
                tv_host[slot, :, 0, t0 : t0 + round_dl + 1],
            )
            req.t_seq.rewind(round_dl - n_acc)
            # draft wrote round_dl+1 positions at d_len0 (incl. straggler);
            # the invariant cache == committed[:-1] keeps n_acc + 1 of them
            d0 = int(d_len0[slot])
            req.d_seq.append(
                dk_host[slot, :, 0, d0 : d0 + round_dl + 1],
                dv_host[slot, :, 0, d0 : d0 + round_dl + 1],
            )
            req.d_seq.rewind(round_dl - n_acc)
        batcher.model_round(work)
        for slot, req in active:
            if req.done:
                batcher.retire(slot)
        batcher.step_count += 1

    outputs = [
        jnp.asarray(r.out[: r.max_new_tokens], jnp.int32) for r in requests
    ]
    return outputs, batcher.summary()
