"""Serving engine: wires real zoo models into the SD / APSD drivers.

Builds `LMInterface` adapters (prefill / extend / rewind over the functional
caches) for any of: bf16 `lm.apply_lm`, W4A8 `apply_quantized_lm`, BVQ
`apply_bvq_lm` — so the full paper configuration

    TLM = W4A8+LRU target model,  DLM = BVQ draft model,  APSD controller

runs end to end on real weights.  Rewind is O(1): reset the cache length
(stale slots are overwritten and masked).  On a TPU mesh the draft and
verify dispatches overlap (the WDOS idea); on CPU they serialize but are
bit-identical.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.apsd import APSDConfig, apsd_generate
from repro.core.speculative import LMInterface, SDConfig, sd_generate
from repro.models import lm
from repro.models.common import ModelConfig
from repro.serving import quantized_lm as qlm

__all__ = ["make_interface", "ServingModel", "serve_sd", "serve_apsd"]


@dataclasses.dataclass
class ServingModel:
    cfg: ModelConfig
    params: Any
    mode: str = "bf16"  # bf16 | w4a8 | bvq
    mesh: Any = None
    s_max: int = 512
    use_pallas: bool = False

    def _apply(self, params, tokens, cache):
        if self.mode == "w4a8":
            return qlm.apply_quantized_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas,
            )
        if self.mode == "bvq":
            return qlm.apply_bvq_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas,
            )
        return lm.apply_lm(params, self.cfg, self.mesh, tokens, cache=cache)


def make_interface(model: ServingModel) -> LMInterface:
    cfg, mesh, s_max = model.cfg, model.mesh, model.s_max

    def fresh_cache(batch):
        if model.mode in ("w4a8", "bvq"):
            # quantized paths use the dense attn cache layout
            c = lm.init_cache(
                dataclasses.replace(cfg),  # same shapes
                batch, s_max, tp=mesh.shape["model"] if mesh else 1,
            )
            return c
        return lm.init_cache(cfg, batch, s_max, tp=mesh.shape["model"] if mesh else 1)

    @jax.jit
    def _prefill(params, tokens, cache):
        return model._apply(params, tokens, cache)

    @jax.jit
    def _extend(params, tokens, cache):
        return model._apply(params, tokens, cache)

    def prefill(params, tokens):
        cache = fresh_cache(tokens.shape[0])
        return _prefill(params, tokens, cache)

    def extend(params, tokens, cache):
        return _extend(params, tokens, cache)

    def rewind(cache, n):
        c = dict(cache)
        c["length"] = cache["length"] - n
        return c

    return LMInterface(prefill=prefill, extend=extend, rewind=rewind)


def serve_sd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: SDConfig,
):
    return sd_generate(
        key,
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        prompt, cfg,
    )


def serve_apsd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: APSDConfig,
):
    return apsd_generate(
        key,
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        prompt, cfg,
    )
