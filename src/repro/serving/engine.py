"""Serving engine: the stepwise continuous-batching runtime.

``Engine`` is the serving surface: requests are admitted at ANY time
(``add_request``), each ``step()`` runs one WDOS-scheduled draft/verify
round over whatever is active and streams incremental ``RequestOutput``s,
and ``abort()`` frees a request's pool pages immediately.  Nothing drains:
a request submitted after round k is prefilled and scheduled in round k+1
while the rest of the batch keeps decoding — the continuous arrival/retire
pattern the paper's out-of-order WDOS scheduler (Fig. 31.1.5) exploits to
overlap different requests' draft (RERAM) and verify (EMAC) pipelines.

Two execution modes (``EngineConfig.par_mode``, outputs bit-identical):

* ``"off"`` — two-phase rounds: every active row drafts its window in
  lockstep micro-steps, then ONE batched verify pass scores everyone.
* ``"wdos"`` — fused cross-request PAR: each step executes a horizon of
  mixed phase plans emitted by the WDOS planner
  (core/scheduler.plan_mixed_slot).  Per slot, window-full rows VERIFY
  (target model, full window) while the other rows DRAFT their next
  proposal (draft model, one token) — in one fused XLA dispatch whose
  per-row role masks keep each model's pool writes confined to the rows
  that actually use it.  Rows cycle out of phase, so a fast-accepting
  request commits several windows inside one engine round while a
  long-window neighbour is still drafting; requests carry mid-window
  phase state across steps (serving/request.py).

KV lives in DEVICE-RESIDENT block-granular paged pools
(serving/paged_cache.py allocator + JAX pool arrays): prefill scatters
straight into pool pages, each batched draft/verify step scatters new
tokens in place and attends through per-row page tables, and accept/rewind
is a per-row length update — no per-round host gather/scatter of K/V.

Invariants the hot loop relies on (see docs/ARCHITECTURE.md for the map):

* page-table lifetime stability — a request's pages are reserved AND
  backed at admission, so its table row uploads once and stays valid from
  prefill to retirement; only lengths change per round;
* rewind bounds — a round writes at most ``max_dl + 1`` positions past the
  committed prefix and always rewinds back to ``committed - 1`` tokens, so
  the admission-time reservation (prompt + max_tokens + max_dl) is never
  exceeded and stale tail slots are masked-then-overwritten, never read;
* role-mask semantics — in fused dispatches a row participates in a model's
  forward iff its mask bit is set; masked rows are diverted to the pool's
  scratch page inside the traced forward (models/layers.forward_cache_ctx),
  so a drafting row can never pollute the target pool and vice versa;
* per-request determinism — draft/accept PRNG keys are indexed by
  (request seed, round, position) and rounds count COMMITS, so scheduling
  (batch composition, two-phase vs fused) never shifts a request's tokens.

Sampling is per request (``api.SamplingParams``): ``temperature == 0`` is
greedy and bit-identical per request to the single-request reference
drivers (batching, paging, and residency change scheduling, never
sampling); ``temperature > 0`` runs lossless speculative rejection sampling
from a per-request key stream, so a request's sampled tokens are identical
at batch 1 and batch N (tests/test_engine_api.py).

The pre-redesign entry points — ``serve_sd``, ``serve_apsd``,
``serve_batch``, ``serve_batch_host`` — survive as thin DEPRECATED wrappers
over ``Engine`` (each warns once); the legacy host-gather loop itself stays
frozen in serving/host_gather.py as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sch
from repro.core.apsd import PAR, APSDConfig, APSDStats, RoundRecord
from repro.core.speculative import (
    LMInterface,
    SDConfig,
    SDStats,
    sample_token_host,
    speculative_accept_greedy_host,
    speculative_sample_host,
    speculative_tree_accept_greedy_host,
    speculative_tree_sample_host,
    topk_tokens_host,
    tree_ancestor_mask,
    tree_depths,
)
from repro.models import layers as L
from repro.models import lm
from repro.models.common import ModelConfig
from repro.serving import quantized_lm as qlm
from repro.serving.api import (
    CompletionOutput,
    EngineConfig,
    RequestOutput,
    SamplingParams,
    default_detokenize,
    resolve_paged_attn_impl,
    warn_deprecated_once,
)
from repro.serving.batcher import BatchConfig, ContinuousBatcher
from repro.serving.flight_recorder import FlightRecorder
from repro.serving.observability import (
    LATENCY_BUCKETS,
    RATIO_BUCKETS,
    MetricsRegistry,
)
from repro.serving.paged_cache import (
    PagedKVPool,
    device_pool_store,
    num_pages_for_bytes,
    pages_for,
)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, RequestState
from repro.serving.tracing import NULL_TRACER, Tracer

__all__ = [
    "Engine",
    "EngineConfig",
    "SamplingParams",
    "RequestOutput",
    "CompletionOutput",
    "make_interface",
    "ServingModel",
    "serve_sd",
    "serve_apsd",
    "serve_batch",
    "serve_batch_host",
    "BatchConfig",
]


@dataclasses.dataclass
class ServingModel:
    cfg: ModelConfig
    params: Any
    mode: str = "bf16"  # bf16 | w4a8 | bvq
    mesh: Any = None
    s_max: int = 512
    use_pallas: bool = False
    # paged decode attention path: "auto" resolves per backend (the Pallas
    # paged kernel where its TPU dialect lowers, the exact device gather
    # everywhere else); "gather" replays the exact dense math over a
    # device-side page gather (bit-identical to the dense cache path);
    # "pallas" attends in place through the page table with
    # kernels/paged_attn.py (interpret mode on CPU).
    paged_attn_impl: str = "auto"

    def _apply(self, params, tokens, cache):
        paged_kw = {}
        if cache is not None and "page_table" in cache:
            paged_kw = dict(paged_impl=resolve_paged_attn_impl(self.paged_attn_impl))
        if self.mode == "w4a8":
            return qlm.apply_quantized_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas, **paged_kw,
            )
        if self.mode == "bvq":
            return qlm.apply_bvq_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas, **paged_kw,
            )
        return lm.apply_lm(
            params, self.cfg, self.mesh, tokens, cache=cache, **paged_kw
        )


def make_interface(model: ServingModel) -> LMInterface:
    cfg, mesh, s_max = model.cfg, model.mesh, model.s_max

    def fresh_cache(batch):
        if model.mode in ("w4a8", "bvq"):
            # quantized paths use the dense attn cache layout
            c = lm.init_cache(
                dataclasses.replace(cfg),  # same shapes
                batch, s_max, tp=mesh.shape["model"] if mesh else 1,
            )
            return c
        return lm.init_cache(cfg, batch, s_max, tp=mesh.shape["model"] if mesh else 1)

    @jax.jit
    def _prefill(params, tokens, cache):
        return model._apply(params, tokens, cache)

    @jax.jit
    def _extend(params, tokens, cache):
        return model._apply(params, tokens, cache)

    def prefill(params, tokens):
        cache = fresh_cache(tokens.shape[0])
        return _prefill(params, tokens, cache)

    def extend(params, tokens, cache):
        return _extend(params, tokens, cache)

    def rewind(cache, n):
        if n < 0:
            raise ValueError(f"rewind expects n >= 0, got {n}")
        length = cache["length"]
        try:
            if int(length) - n < 0:
                raise ValueError(
                    f"over-rewind: cache length {int(length)} < rewind {n}"
                )
        except jax.errors.ConcretizationTypeError:
            pass  # traced length: fall through to the clamp below
        c = dict(cache)
        c["length"] = jnp.maximum(length - n, 0)
        return c

    return LMInterface(prefill=prefill, extend=extend, rewind=rewind)


# ---------------------------------------------------------------------------
# Shared helpers (the frozen host_gather baseline also imports these)
# ---------------------------------------------------------------------------


def _np_dtype(cfg: ModelConfig):
    return np.asarray(jnp.zeros((), cfg.jdtype)).dtype


def _wdos_costs(mcfg: ModelConfig) -> Tuple[float, float]:
    load = 12.0 * mcfg.d_model * mcfg.d_model * 1e-6  # ~per-layer weight bytes
    return load, 0.25 * load


def _empty_summary(cfg) -> dict:
    return {
        "requests": 0, "rounds": 0, "steps": 0, "emitted": 0,
        "acceptance_rate": 0.0, "target_pool": None, "draft_pool": None,
        "wdos_modeled_speedup": 1.0,
        "wdos_utilization": {},
        "par_mode": getattr(cfg, "par_mode", "off"),
        "kv_path": getattr(cfg, "kv_path", "paged"),
        "kv_copy_s": 0.0,
        "table_upload_s": 0.0,
    }


def _pool_for(
    model: ServingModel, cfg, peaks: Sequence[int],
    alloc_storage: bool = True,
):
    """Page pool sized to hold `max_batch` worst-case requests (or the
    explicit cfg.num_pages budget).  alloc_storage=False builds the pure
    allocator for the device-resident path (KV bytes live in JAX arrays)."""
    mcfg = model.cfg
    if mcfg.kv_quant:
        # the MODEL's dense-cache kv_quant knob (contiguous int8 cache) —
        # distinct from EngineConfig.kv_quant, which compresses the PAGED
        # pools and dequantizes inside the paged-attention consumers
        raise NotImplementedError("paged pools hold dense-dtype KV (kv_quant=False)")
    if model.mesh is not None:
        raise NotImplementedError("the Engine runs the single-host path (mesh=None)")
    if getattr(cfg, "pool_bytes", None) is not None:
        # byte-budget sizing: admission is then effectively on COMPRESSED
        # bytes — an int8 pool gets ~3.5x the pages (and thus resident
        # requests) of a dense pool under the same budget
        num_pages = num_pages_for_bytes(
            cfg.pool_bytes,
            n_layers=mcfg.n_layers,
            kv_heads=L.kv_store_heads(mcfg, 1),
            head_dim=mcfg.hd,
            page_size=cfg.page_size,
            dtype=_np_dtype(mcfg),
            kv_quant=getattr(cfg, "kv_quant", "none"),
        )
    elif cfg.num_pages is not None:
        num_pages = cfg.num_pages
    else:
        worst = sorted((pages_for(p, cfg.page_size) for p in peaks), reverse=True)
        num_pages = sum(worst[: cfg.max_batch])
    return PagedKVPool(
        n_layers=mcfg.n_layers,
        kv_heads=L.kv_store_heads(mcfg, 1),
        head_dim=mcfg.hd,
        num_pages=num_pages,
        page_size=cfg.page_size,
        dtype=_np_dtype(mcfg),
        alloc_storage=alloc_storage,
        kv_quant=getattr(cfg, "kv_quant", "none"),
    )


# host_gather.py (frozen baseline) keeps calling the accept rule through
# this name; the shared implementation lives in core/speculative.py now.
_greedy_accept_host = speculative_accept_greedy_host


def _make_paged_step(model: ServingModel):
    """jit of one batched paged forward: every active request is a batch row
    with its OWN page-table row and length (positions, causal masking, and
    the pool write slots are per-row).  The K/V store is carried as a device
    dict pytree (``{"k", "v"}`` dense, ``+{"k_scale", "v_scale"}`` for int8
    pools — see paged_cache.device_pool_store): the step scatters new tokens
    (and, quantized, their page scales — same dispatch, so value/scale can
    never go stale independently) in place and returns the updated store, so
    NO K/V bytes ever cross the host boundary.  The store is DONATED: the
    caller always rebinds it to the step's output, so XLA may alias the
    scatter in place instead of copying the pool."""

    @partial(jax.jit, donate_argnums=(2,))
    def step(params, tokens, store, page_table, lengths):
        # tokens (B, W) int32; store arrays (L, P+1, ps, kvh, hd|1)
        cache = {
            "lengths": lengths,
            "page_table": page_table,
            "attn": dict(store),
        }
        logits, nc = model._apply(params, tokens, cache)
        return logits, {name: nc["attn"][name] for name in store}

    return step


def _make_tree_step(model: ServingModel):
    """jit of one batched TREE-window forward (spec_mode="tree"): the window
    holds a draft tree in BFS order, ``win_pos`` gives each slot its RoPE
    depth offset, and ``tree_mask`` (B, W, W) restricts window-internal
    attention to each slot's own root-path (models/layers.forward_cache_ctx
    threads both through the paged attention consumers; the Pallas kernel
    applies the mask in place, the gather fallback through
    ``_tree_window_attention``).  Same donation/store contract as
    ``_make_paged_step``."""

    @partial(jax.jit, donate_argnums=(2,))
    def step(params, tokens, store, page_table, lengths, win_pos, tree_mask):
        cache = {
            "lengths": lengths,
            "page_table": page_table,
            "win_pos": win_pos,
            "tree_mask": tree_mask,
            "attn": dict(store),
        }
        logits, nc = model._apply(params, tokens, cache)
        return logits, {name: nc["attn"][name] for name in store}

    return step


def _make_fused_step(target: ServingModel, draft: ServingModel):
    """jit of ONE fused PAR dispatch: the target model's verify pass (width
    ``max_dl + 1``, rows selected by `v_mask`) and the draft model's
    micro-step (width 1, rows selected by `d_mask`) in a SINGLE XLA
    program.  The two subgraphs share no values, so the compiler is free to
    overlap them — the TPU analogue of the chip issuing TLM work to the
    EMAC queue while DLM work streams from RERAM.  Masked rows are diverted
    to each pool's scratch page inside the traced forward
    (models/layers.forward_cache_ctx role-mask semantics), so a drafting
    row never writes the target pool and a verifying row's target writes
    never leak into its neighbour's pages.  Widths are FIXED per engine
    (verify always max_dl + 1, causally padded), so the program compiles
    once, not per round shape."""

    @partial(jax.jit, donate_argnums=(4, 5))
    def step(t_params, d_params, v_tokens, d_tokens,
             t_store, d_store,
             t_table, t_len, d_table, d_len, v_mask, d_mask):
        t_cache = {
            "lengths": t_len,
            "page_table": t_table,
            "role_mask": v_mask,
            "attn": dict(t_store),
        }
        v_logits, t_nc = target._apply(t_params, v_tokens, t_cache)
        d_cache = {
            "lengths": d_len,
            "page_table": d_table,
            "role_mask": d_mask,
            "attn": dict(d_store),
        }
        d_logits, d_nc = draft._apply(d_params, d_tokens, d_cache)
        return (v_logits, d_logits,
                {name: t_nc["attn"][name] for name in t_store},
                {name: d_nc["attn"][name] for name in d_store})

    return step


def _make_masked_draft_step(draft: ServingModel):
    """jit of a draft-only PAR slot (no row is window-full): one draft
    micro-step with the per-row role mask, so rows retired mid-step stay
    inert without re-uploading the page table."""

    @partial(jax.jit, donate_argnums=(2,))
    def step(params, tokens, store, page_table, lengths, mask):
        cache = {
            "lengths": lengths,
            "page_table": page_table,
            "role_mask": mask,
            "attn": dict(store),
        }
        logits, nc = draft._apply(params, tokens, cache)
        return logits, {name: nc["attn"][name] for name in store}

    return step


def _make_fused_tree_step(target: ServingModel, draft: ServingModel):
    """jit of ONE fused tree-PAR dispatch: both sides run the FULL
    fixed-width tree window (``tree_budget + 1``) — the target verifies
    complete trees on rows selected by `v_mask` while the draft side
    re-feeds every active row's partial tree (for verifying rows that is
    the straggler dispatch landing the leaf KV).  Tree masks and depth
    positions ride per side; widths are fixed so the program compiles
    once."""

    @partial(jax.jit, donate_argnums=(4, 5))
    def step(t_params, d_params, v_tokens, d_tokens,
             t_store, d_store,
             t_table, t_len, d_table, d_len, v_mask, d_mask,
             t_win_pos, t_tree_mask, d_win_pos, d_tree_mask):
        t_cache = {
            "lengths": t_len,
            "page_table": t_table,
            "role_mask": v_mask,
            "win_pos": t_win_pos,
            "tree_mask": t_tree_mask,
            "attn": dict(t_store),
        }
        v_logits, t_nc = target._apply(t_params, v_tokens, t_cache)
        d_cache = {
            "lengths": d_len,
            "page_table": d_table,
            "role_mask": d_mask,
            "win_pos": d_win_pos,
            "tree_mask": d_tree_mask,
            "attn": dict(d_store),
        }
        d_logits, d_nc = draft._apply(d_params, d_tokens, d_cache)
        return (v_logits, d_logits,
                {name: t_nc["attn"][name] for name in t_store},
                {name: d_nc["attn"][name] for name in d_store})

    return step


def _make_masked_tree_draft_step(draft: ServingModel):
    """jit of a draft-only tree-PAR slot (no row is tree-full): one
    full-width tree re-feed with the per-row role mask."""

    @partial(jax.jit, donate_argnums=(2,))
    def step(params, tokens, store, page_table, lengths, mask,
             win_pos, tree_mask):
        cache = {
            "lengths": lengths,
            "page_table": page_table,
            "role_mask": mask,
            "win_pos": win_pos,
            "tree_mask": tree_mask,
            "attn": dict(store),
        }
        logits, nc = draft._apply(params, tokens, cache)
        return logits, {name: nc["attn"][name] for name in store}

    return step


@partial(jax.jit, donate_argnums=(0,))
def _scatter_prefill(store, k_dense, v_dense, pages, n, start=0):
    """Scatter a freshly prefilled request's cache rows [start, n) straight
    into its pool pages — device to device, no host round-trip.
    store: device store dict (paged_cache.device_pool_store);
    k_dense/v_dense: (L, s_max, kvh, hd); pages: (mp,) physical page ids,
    unowned slots holding the scratch page.  `n`/`start` are traced (one
    compile per model, not per prompt length): the fixed-width scatter
    covers the whole table span and routes slots outside [start, n) to the
    scratch page.  A prefix-cache hit passes start = tokens_matched so the
    shared prefix pages — whose rows are already resident — are never
    touched (rows below `start` may even map COW-protected shared pages).

    For an int8 store the dense prefix is quantized here (the same
    per-slot-per-head rule the decode steps apply in
    models/layers.paged_attention_update) and values + scales land in one
    dispatch, so a page's scale can never be stale relative to its bytes."""
    pool_k = store["k"]
    nl, p1, ps, kvh, hd = pool_k.shape
    s_max = k_dense.shape[1]
    cap = pages.shape[0] * ps  # table span; may overhang s_max by < ps
    pos = jnp.arange(cap)
    scratch = (p1 - 1) * ps + pos % ps  # harmless dup writes per layer
    flat = jnp.where(
        (pos >= start) & (pos < n), pages[pos // ps] * ps + pos % ps, scratch
    )
    src_k = k_dense[:, jnp.minimum(pos, s_max - 1)]
    src_v = v_dense[:, jnp.minimum(pos, s_max - 1)]
    if "k_scale" in store:
        qk, sk = L._kv_quantize(src_k)
        qv, sv = L._kv_quantize(src_v)
        writes = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    else:
        writes = {"k": src_k, "v": src_v}
    out = {}
    for name, src in writes.items():
        pool = store[name]
        width = pool.shape[-1]
        out[name] = (
            pool.reshape(nl, p1 * ps, kvh, width)
            .at[:, flat]
            .set(src.astype(pool.dtype))
            .reshape(pool.shape)
        )
    return out


@partial(jax.jit, donate_argnums=(0,))
def _copy_page(store, src, dst):
    """Copy one physical page (every array of the store: values and, for
    int8, scales) device-side — the copy-on-write step that privatizes a
    partially-shared prefix page before its holder's first scatter.
    `src`/`dst` are traced so the one compiled program serves every COW."""
    return {name: a.at[:, dst].set(a[:, src]) for name, a in store.items()}


@partial(jax.jit, donate_argnums=(0,))
def _compact_slots(store, src, dst):
    """Batched flat-slot copy over every array of a device store — the
    tree-verify COMPACTION step: after acceptance walks a non-leftmost
    root path, the accepted nodes' KV rows (scattered at their BFS window
    slots) are copied down to the chain positions the committed sequence
    expects, so rewind-to-committed leaves the pool bit-identical to a
    chain round that drafted the same tokens.  `src`/`dst` are fixed-width
    (padded with the scratch page's flat slots: harmless self-copies), so
    one compiled program serves every round; the gather reads the donated
    input before the scatter writes, so overlapping src/dst spans are
    safe."""
    out = {}
    for name, a in store.items():
        nl, p1, ps = a.shape[0], a.shape[1], a.shape[2]
        flat = a.reshape(nl, p1 * ps, *a.shape[3:])
        out[name] = flat.at[:, dst].set(flat[:, src]).reshape(a.shape)
    return out


def _sample_tree_level(req, cfg, logits: np.ndarray) -> None:
    """Grow one request's draft tree by ONE level from a window logits
    matrix (W, V) — row 0 is the distribution after the committed tip, row
    1+i after drafted node i.  Frontier nodes (deepest fully-grown level)
    each fan out to ``spec_branches`` children when the draft's top-1
    probability is below ``branch_threshold`` (a low-confidence position —
    the paper's adaptive parallel-speculation cue) and the per-round node
    budget still covers the fan-out; otherwise one child.  Greedy requests
    take the top-k distinct tokens (child 0 is the argmax, so the chain
    path is always a subtree and greedy tree output is token-identical to
    greedy chain); sampled requests draw i.i.d. children from the
    request's draft key stream indexed by ``tree_draws`` (the with-
    replacement draws the tree rejection rule in core/speculative.py is
    exact for) and stash the logits row for the accept rule.  Mutates the
    request's tree phase state in place; when the budget is exhausted
    before any child lands, stamps ``tree_depth`` to ``tree_dl`` so the
    tree reads as full."""
    parents = req.tree_parents
    depths = tree_depths(parents, len(parents) + 1)
    d = req.tree_depth
    if d == 0:
        frontier = [0]
    else:
        frontier = [1 + i for i in range(len(parents)) if depths[1 + i] == d]
    sp = req.sampling
    grew = False
    for slot in frontier:
        budget = cfg.tree_budget - len(req.tree_nodes)
        if budget <= 0:
            break
        row = logits[slot]
        # draft top-1 probability (softmax max) — the branch cue
        conf = 1.0 / float(
            np.exp(row.astype(np.float64) - float(row.max())).sum()
        )
        k = (
            cfg.spec_branches
            if conf < cfg.branch_threshold and budget >= cfg.spec_branches
            else 1
        )
        if sp.greedy:
            toks = topk_tokens_host(row, k)
        else:
            toks = [
                int(sample_token_host(
                    req.draft_key(req.tree_draws + i), row,
                    sp.temperature, sp.top_k, sp.top_p,
                ))
                for i in range(k)
            ]
            req.tree_draws += k
            req.tree_q[slot] = row.copy()
        for t in toks:
            req.tree_parents.append(slot - 1)
            req.tree_nodes.append(int(t))
        grew = True
    req.tree_depth = d + 1 if grew else req.tree_dl


def _tree_window_rows(req, width: int):
    """(tokens, positions, mask) window rows for one request's tree: slot 0
    re-feeds the committed tip at depth 0, slot 1+i holds drafted node i at
    its tree depth; the ancestor mask keeps padded slots self-visible so
    their (overwritten-later) softmax stays finite."""
    toks = np.zeros((width,), np.int32)
    toks[0] = req.last_tok
    n = len(req.tree_nodes)
    if n:
        toks[1: 1 + n] = req.tree_nodes
    return (
        toks,
        tree_depths(req.tree_parents, width),
        tree_ancestor_mask(req.tree_parents, width),
    )


class _TableSet:
    """Host mirror of one pool's per-slot page tables / lengths.

    Page tables only change at admission/retirement (pages are backed
    eagerly, so a request's table is stable for its whole lifetime);
    lengths change every round.  Both are O(B) int32 uploads — the point of
    the device-resident design is that these tiny tables are ALL that
    crosses the host boundary per round.  `cap_tokens` (the engine's
    max_model_len, NOT s_max) sizes the table width, which in turn bounds
    the attention span the paged forward touches."""

    def __init__(self, max_batch: int, pool: PagedKVPool, cap_tokens: int):
        self.max_pages = pages_for(cap_tokens, pool.page_size)
        self.scratch = pool.num_pages  # device arrays have one extra page
        self.table = np.full((max_batch, self.max_pages), self.scratch, np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self._table_dev = None

    def set_row(self, slot: int, seq) -> None:
        row = self.table[slot]
        row[:] = self.scratch
        row[: len(seq.pages)] = seq.pages
        self._table_dev = None

    def clear_row(self, slot: int) -> None:
        self.table[slot] = self.scratch
        self._table_dev = None

    def load(self, rows):
        """rows: iterable of (slot, PagedSequence) -> (table, lengths) dev.
        Blocks until the uploads land so the caller's timing is comparable
        to the host baseline's blocking kv_copy_s."""
        self.lengths[:] = 0
        for slot, seq in rows:
            self.lengths[slot] = seq.length
        return self.table_dev(), jax.block_until_ready(jnp.asarray(self.lengths))

    def table_dev(self):
        """The cached device page table alone (fused PAR slots build their
        per-slot lengths/masks themselves; the table row for every active
        request is lifetime-stable, so one upload serves the whole step)."""
        if self._table_dev is None:
            self._table_dev = jax.block_until_ready(jnp.asarray(self.table))
        return self._table_dev


# ---------------------------------------------------------------------------
# The stepwise Engine
# ---------------------------------------------------------------------------


class Engine:
    """Continuous-batching speculative-decoding engine over device-resident
    paged KV pools.

    Lifecycle::

        eng = Engine(target, draft, EngineConfig(max_batch=4))
        rid = eng.add_request(prompt, SamplingParams(max_tokens=32))
        while eng.has_unfinished():
            for out in eng.step():      # one batched SD round
                stream(out.new_token_ids)
        tokens = eng.output_tokens(rid)

    ``add_request`` is admissible at any time — between ``step()`` calls a
    new request joins the queue and is prefilled/scheduled on the next step
    without draining the active batch.  ``abort`` retires a request
    immediately and returns its pool pages.  ``run`` is the convenience
    drain loop the deprecated ``serve_batch`` wrapper uses.

    Greedy requests are bit-identical per request to the single-request
    dense-cache reference; sampled requests (``temperature > 0``) follow
    the lossless rejection-sampling rule with per-request key streams.
    """

    def __init__(
        self,
        target: ServingModel,
        draft: ServingModel,
        config: Optional[EngineConfig] = None,
        detokenize: Optional[Callable[[int], str]] = None,
        metrics: Optional[MetricsRegistry] = None,
        trace: Optional[Tracer] = None,
    ):
        cfg = config if config is not None else EngineConfig()
        if cfg.paged_attn_impl is not None:
            impl = resolve_paged_attn_impl(cfg.paged_attn_impl)
            target = dataclasses.replace(target, paged_attn_impl=impl)
            draft = dataclasses.replace(draft, paged_attn_impl=impl)
        self.cfg = cfg
        self.target = target
        self.draft = draft
        self.max_model_len = (
            cfg.max_model_len
            if cfg.max_model_len is not None
            else min(target.s_max, draft.s_max)
        )
        for model in (target, draft):
            if self.max_model_len > model.s_max:
                raise ValueError(
                    f"max_model_len {self.max_model_len} exceeds "
                    f"s_max={model.s_max} of {model.cfg.name}"
                )

        # host pools are pure allocators; the KV bytes live in device arrays.
        # One allocator serves every storage KIND: under kv_quant="mixed"
        # both a dense and an int8 device store back the SAME page ids, a
        # request reads/writes only the store of its resolved kind, and the
        # wrong-kind storage of its pages simply holds unread garbage — so
        # admission, page tables, and rewind bookkeeping stay kind-agnostic.
        worst = [self.max_model_len] * cfg.max_batch
        self._t_pool = _pool_for(target, cfg, worst, alloc_storage=False)
        self._d_pool = _pool_for(draft, cfg, worst, alloc_storage=False)
        self._kinds: Tuple[str, ...] = cfg.kv_kinds
        self._t_store = {
            k: device_pool_store(self._t_pool, kv_quant=k) for k in self._kinds
        }
        self._d_store = {
            k: device_pool_store(self._d_pool, kv_quant=k) for k in self._kinds
        }

        # copy-on-write prefix cache: a refcounted radix tree over prompt
        # blocks that maps cache hits as read-only shared pages in BOTH
        # pools, so the shared span's prefill is skipped entirely
        # (serving/prefix_cache.py; admission integration lives in the
        # batcher, the hit-path prefill in _prefill_into)
        self._prefix: Optional[PrefixCache] = None
        if cfg.prefix_cache:
            self._prefix = PrefixCache(
                {"target": self._t_pool, "draft": self._d_pool},
                cfg.page_size,
            )

        # observability: one shared registry — the batcher's fused/finish
        # counters, the engine's latency histograms, and the server's
        # GET /metrics all read and write the same families.  The tracer
        # defaults to the no-op NULL_TRACER; when a real one is passed the
        # engine adopts its clock so request timestamps and spans share a
        # timebase.  All instrumentation wraps dispatch boundaries the hot
        # loop already synchronizes at — no block_until_ready is added.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = trace if trace is not None else NULL_TRACER
        if self.tracer.enabled:
            self._now = self.tracer.now
        else:
            _t0 = time.perf_counter()
            self._now = lambda: time.perf_counter() - _t0
        self._init_metrics()

        # sampled device-time profiling: every profile_every_n-th round,
        # each dispatched program is bracketed with block_until_ready
        # timing (the ONLY place the engine ever adds a device sync —
        # timing never changes the math, so tokens stay bit-identical) and
        # stamped once with its compile-time cost_analysis FLOPs/bytes.
        self._profile_every = cfg.profile_every_n
        self._profile_round = False
        self._round_idx = 0
        self._prog_cost: Dict[str, dict] = {}
        self._prog_wall: Dict[str, float] = {}
        self._prog_calls: Dict[str, int] = {}
        # flight recorder: bounded ring of per-round records with anomaly
        # triggers (serving/flight_recorder.py); fed at every round-wall
        # site INCLUDING empty rounds (pool exhaustion shows up as rounds
        # that admit and run nothing)
        self.flight = FlightRecorder(
            cfg.flight_ring, metrics=self.metrics, tracer=self.tracer,
            dump_dir=cfg.flight_dump_dir,
        )

        self._batcher = ContinuousBatcher(
            cfg, self._t_pool, self._d_pool,
            t_layers=target.cfg.n_layers, d_layers=draft.cfg.n_layers,
            t_costs=_wdos_costs(target.cfg), d_costs=_wdos_costs(draft.cfg),
            metrics=self.metrics,
            prefix_cache=self._prefix,
        )
        self._t_iface, self._d_iface = make_interface(target), make_interface(draft)
        self._t_step, self._d_step = _make_paged_step(target), _make_paged_step(draft)
        if cfg.par_mode == "wdos":
            self._fused_step = _make_fused_step(target, draft)
            self._draft_slot_step = _make_masked_draft_step(draft)
        if cfg.spec_mode == "tree":
            self._t_tree_step = _make_tree_step(target)
            self._d_tree_step = _make_tree_step(draft)
            if cfg.par_mode == "wdos":
                self._fused_tree_step = _make_fused_tree_step(target, draft)
                self._draft_tree_slot_step = _make_masked_tree_draft_step(draft)
        self._t_tables = _TableSet(cfg.max_batch, self._t_pool, self.max_model_len)
        self._d_tables = _TableSet(cfg.max_batch, self._d_pool, self.max_model_len)
        self._requests: Dict[int, Request] = {}
        self._next_id = 0
        # token -> text for SamplingParams.stop matching (and the HTTP
        # server's text fields); defaults to the toy decimal renderer
        self._detokenize = (
            detokenize if detokenize is not None else default_detokenize
        )

    # -- observability -------------------------------------------------------

    def _init_metrics(self) -> None:
        """Register the engine's metric families (docs/OBSERVABILITY.md is
        the catalog).  Registration is idempotent, so sharing one registry
        across engines is safe."""
        m = self.metrics
        self._m_submitted = m.counter(
            "requests_submitted_total", "Requests accepted by add_request"
        )
        self._m_steps = m.counter("steps_total", "Engine steps executed")
        self._m_emitted = m.counter(
            "tokens_emitted_total", "Tokens delivered to consumers"
        )
        self._m_drafted = m.counter(
            "tokens_drafted_total", "Draft tokens proposed"
        )
        self._m_accepted = m.counter(
            "tokens_accepted_total", "Draft tokens accepted by verification"
        )
        self._m_table_upload = m.counter(
            "table_upload_seconds_total",
            "Host seconds uploading page tables / lengths (the only "
            "per-round host->device traffic on the paged path)",
        )
        self._m_accept_rate = m.gauge(
            "acceptance_rate", "Cumulative accepted/drafted fraction"
        )
        self._m_queue_depth = m.gauge(
            "queue_depth", "Requests waiting for admission (QUEUED)"
        )
        self._m_active = m.gauge(
            "active_requests", "Requests holding a decode slot"
        )
        self._m_pool_pages = m.gauge(
            "pool_pages", "Paged-KV pool residency", ("pool", "state")
        )
        self._m_kv_bytes = m.gauge(
            "kv_bytes_total",
            "Bytes resident in allocated paged-KV pages (per storage "
            "dtype; int8 includes its f32 per-slot scales)",
            ("pool", "dtype"),
        )
        self._m_kv_bytes_per_token = m.gauge(
            "kv_bytes_per_token",
            "K+V bytes one cached token occupies (per storage dtype)",
            ("pool", "dtype"),
        )
        self._m_ttft = m.histogram(
            "ttft_seconds", "Submit -> first delivered token",
            buckets=LATENCY_BUCKETS,
        )
        self._m_itl = m.histogram(
            "itl_seconds",
            "Gap between successive token deliveries of one request "
            "(round granularity: one observation per non-empty delta)",
            buckets=LATENCY_BUCKETS,
        )
        self._m_round_wall = m.histogram(
            "round_wall_seconds", "Wall time of one engine step",
            buckets=LATENCY_BUCKETS,
        )
        self._m_admission_wait = m.histogram(
            "admission_wait_seconds", "Submit -> admission into a decode slot",
            buckets=LATENCY_BUCKETS,
        )
        self._m_round_accept = m.histogram(
            "round_acceptance", "Per-round accepted/drafted fraction",
            buckets=RATIO_BUCKETS,
        )
        # tree-speculation families (live under spec_mode="tree";
        # registered unconditionally — and materialized at zero — so the
        # catalog and the /metrics scrape are stable on chain engines)
        self._m_tree_nodes = m.counter(
            "tree_nodes_total",
            "Draft-tree nodes proposed for verification (tree rounds)",
        )
        self._m_tree_branches = m.counter(
            "tree_branches_total",
            "Extra branches forked beyond a chain: fan-out minus one, "
            "summed over branching nodes",
        )
        self._m_tree_depth = m.histogram(
            "tree_accept_depth",
            "Depth of the accepted root path per tree round (committed "
            "draft tokens; the bonus token is not counted)",
            buckets=(0.0, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0),
        )
        self._m_tree_compactions = m.counter(
            "tree_compactions_total",
            "Device compaction dispatches relocating an accepted "
            "non-leftmost tree path's KV into chain order",
        )
        for fam in (self._m_tree_nodes, self._m_tree_branches,
                    self._m_tree_compactions):
            fam.inc(0)
        # prefix-cache families (registered unconditionally so the catalog
        # is stable; they stay at zero when EngineConfig.prefix_cache=False)
        self._m_prefix_hit_rate = m.gauge(
            "prefix_hit_rate",
            "Prefix-cache hit fraction over admission lookups",
        )
        self._m_shared_pages = m.gauge(
            "shared_pages",
            "Prefix-cache page residency: state='shared' counts pool pages "
            "mapped by more than one holder, state='cached' the pages "
            "pinned by the radix tree",
            ("pool", "state"),
        )
        self._m_tokens_saved = m.counter(
            "prefill_tokens_saved_total",
            "Prompt rows whose prefill was skipped via shared prefix pages",
        )
        self._m_prefix_cow = m.counter(
            "prefix_cow_total",
            "Copy-on-write privatizations of a partially-shared prefix page",
        )

    def _refresh_gauges(self) -> None:
        """Republish the level-style series (queue depth, active slots,
        pool residency, cumulative acceptance) — called at step boundaries
        and before a stats snapshot, never inside a dispatch."""
        self._m_queue_depth.set(self.queue_depth())
        self._m_active.set(self.num_active())
        for name, pool in (("target", self._t_pool), ("draft", self._d_pool)):
            st = pool.stats()
            g = self._m_pool_pages
            g.labels(pool=name, state="used").set(st.used_pages)
            g.labels(pool=name, state="reserved").set(st.reserved_pages)
            g.labels(pool=name, state="free").set(st.free_pages)
            used_tokens = st.used_pages * pool.page_size
            for dt, bpt in pool.bytes_per_token_by_kind().items():
                self._m_kv_bytes_per_token.labels(pool=name, dtype=dt).set(bpt)
                self._m_kv_bytes.labels(pool=name, dtype=dt).set(
                    bpt * used_tokens
                )
        drafted = self._m_drafted.value()
        if drafted:
            self._m_accept_rate.set(self._m_accepted.value() / drafted)
        if self._prefix is not None:
            self._m_prefix_hit_rate.set(self._prefix.hit_rate)
            for name, pool in (
                ("target", self._t_pool), ("draft", self._d_pool)
            ):
                g = self._m_shared_pages
                g.labels(pool=name, state="shared").set(pool.shared_page_count)
                g.labels(pool=name, state="cached").set(self._prefix.node_count)

    def stats_snapshot(self) -> dict:
        """One consistent, JSON-safe stats view, built in a single pass on
        the calling thread.  The AsyncEngine worker publishes this object
        atomically after each step, so ``/stats`` reports queue depth,
        active-vs-queued counts, and pool residency from the SAME moment
        instead of separately-raced reads."""
        self._refresh_gauges()
        t_stats, d_stats = self.pool_stats()
        b = self._batcher
        snap = {
            "queued": self.queue_depth(),
            "active": self.num_active(),
            "max_batch": self.cfg.max_batch,
            "par_mode": self.cfg.par_mode,
            "kv_quant": self.cfg.kv_quant,
            "steps": b.step_count,
            "rounds": b.rounds,
            "finished_requests": b.finished_count,
            "emitted_tokens": b.finished_emitted,
            "acceptance_rate": b.finished_accepted / max(b.finished_drafted, 1),
            "target_pool": dataclasses.asdict(t_stats),
            "draft_pool": dataclasses.asdict(d_stats),
        }
        fused = b.fused_summary()
        if fused is not None:
            snap["fused"] = fused
        if self._prefix is not None:
            snap["prefix_cache"] = self._prefix.stats()
        return snap

    # -- request lifecycle ---------------------------------------------------

    def add_request(
        self,
        prompt,
        sampling_params: Optional[SamplingParams] = None,
        sink: Optional[Callable[[int], None]] = None,
    ) -> int:
        """Submit a prompt; returns its request id.  Admissible at any time
        — the batcher prefills it on the next ``step()`` once a slot and
        pages are free, without draining the active batch."""
        sp = sampling_params if sampling_params is not None else SamplingParams()
        req = Request(
            rid=self._next_id,
            prompt=np.asarray(prompt).reshape(-1),
            max_new_tokens=sp.max_tokens,
            sink=sink,
            sampling=sp,
            detokenize=self._detokenize,
            # raises ValueError when the request pins a storage this engine
            # did not allocate (e.g. kv_quant="int8" on a "none" engine)
            kv_kind=self.cfg.resolve_kv_quant(sp.kv_quant),
        )
        peak = req.peak_cache_len(self.cfg.spec_window)
        if peak > self.max_model_len:
            raise ValueError(
                f"request peak cache length {peak} (prompt {req.prompt.shape[0]} "
                f"+ max_tokens {sp.max_tokens} + speculation window "
                f"{self.cfg.spec_window}) exceeds max_model_len={self.max_model_len}"
            )
        self._next_id += 1
        self._requests[req.rid] = req
        self._batcher.submit(req)
        req.submit_ts = self._now()
        self._m_submitted.inc()
        self.tracer.instant("engine", "submit", cat="lifecycle", rid=req.rid)
        return req.rid

    def abort(self, request_id: int) -> bool:
        """Cancel a request: a queued one is dropped, an active one retires
        immediately and its pool pages return to the free list (un-blocking
        queued admissions on the next step).  Returns False if the id is
        unknown or already finished."""
        req = self._requests.get(request_id)
        if req is None or req.state is RequestState.FINISHED:
            return False
        if req.state is RequestState.QUEUED:
            return self._batcher.cancel_queued(request_id) is not None
        slot = self._batcher.slot_of(request_id)
        assert slot is not None, "active request without a slot"
        self._t_tables.clear_row(slot)
        self._d_tables.clear_row(slot)
        self._batcher.retire(slot, reason="abort")
        return True

    def release_request(self, request_id: int) -> bool:
        """Drop a FINISHED request's bookkeeping (its ``Request`` object,
        including the output buffer).  A run-to-drain caller never needs
        this — ``output_tokens``/``request`` stay valid until released —
        but a long-lived server must release retired requests or the
        engine's request map grows without bound (the batcher's summary
        counters are aggregates and survive the release)."""
        req = self._requests.get(request_id)
        if req is None or req.state is not RequestState.FINISHED:
            return False
        del self._requests[request_id]
        return True

    def has_unfinished(self) -> bool:
        return not self._batcher.all_done()

    def queue_depth(self) -> int:
        """Requests waiting for admission (QUEUED, not yet in a batch slot)."""
        return len(self._batcher.queue)

    def num_active(self) -> int:
        """Requests currently holding a decode slot."""
        return sum(1 for r in self._batcher.slots if r is not None)

    def request(self, request_id: int) -> Request:
        return self._requests[request_id]

    def output_tokens(self, request_id: int) -> jnp.ndarray:
        req = self._requests[request_id]
        return jnp.asarray(req.out[: req.max_new_tokens], jnp.int32)

    def pool_stats(self):
        """(target PoolStats, draft PoolStats) — page residency right now."""
        return self._t_pool.stats(), self._d_pool.stats()

    # -- sampled device-time profiling ---------------------------------------

    def _program_cost(self, program: str, fn, args) -> dict:
        """One-time compile-time stamp per program name: XLA
        ``cost_analysis()`` FLOPs / bytes accessed.  MUST run before the
        program's first profiled dispatch — the step fns donate their
        stores, so lowering from live args is only safe while the caller
        still owns them.  Degrades to ``{}`` for callables without
        ``.lower`` (the host-orchestrated prefill) or backends that don't
        report cost analysis."""
        cost = self._prog_cost.get(program)
        if cost is None:
            cost = {}
            try:
                analysis = fn.lower(*args).compile().cost_analysis()
                if isinstance(analysis, (list, tuple)):
                    analysis = analysis[0] if analysis else {}
                cost = {
                    "flops": float(analysis.get("flops", 0.0)),
                    "bytes": float(analysis.get("bytes accessed", 0.0)),
                }
            except Exception:
                pass
            self._prog_cost[program] = cost
        return cost

    def _profiled(self, program: str, fn, *args):
        """Run one dispatch.  On a profiled round (``profile_every_n``-th
        step), bracket it with ``block_until_ready`` timing, accumulate
        per-program wall/calls for ``profile_summary()``, and emit a span
        on the tracer's "device" track carrying the program's compile-time
        FLOPs/bytes stamp.  Off-round cost: one bool check."""
        if not self._profile_round:
            return fn(*args)
        cost = self._program_cost(program, fn, args)
        t0 = self._now()
        out = fn(*args)
        jax.block_until_ready(out)
        t1 = self._now()
        self._prog_wall[program] = self._prog_wall.get(program, 0.0) + (t1 - t0)
        self._prog_calls[program] = self._prog_calls.get(program, 0) + 1
        self.tracer.rec("device", program, t0, t1, cat="device", **cost)
        return out

    def profile_summary(self) -> Dict[str, dict]:
        """Measured device-time attribution: per dispatched program, the
        bracketed call count, summed wall seconds, and the one-time
        cost_analysis stamp.  Empty unless ``profile_every_n > 0`` sampled
        at least one round — ``benchmarks/roofline_report.attribution``
        joins this against ``core/perfmodel.program_model``."""
        out: Dict[str, dict] = {}
        for prog, calls in self._prog_calls.items():
            cost = self._prog_cost.get(prog) or {}
            out[prog] = {
                "calls": calls,
                "wall_s": self._prog_wall.get(prog, 0.0),
                "flops": cost.get("flops", 0.0),
                "bytes": cost.get("bytes", 0.0),
            }
        return out

    # -- flight-recorder feed ------------------------------------------------

    def _flight_base(self) -> Tuple[float, float, int]:
        """Counter values at round start, so the round record carries
        per-round DELTAS (the emitted counter increments after the round
        wall is taken, so drafted/accepted/admitted are the honest
        per-round signals)."""
        return (
            self._m_drafted.value(),
            self._m_accepted.value(),
            self._batcher.admitted,
        )

    def _flight_round(self, base, t_step: float, t_end: float, rows: int,
                      mode: str) -> None:
        """Append one round record to the flight recorder (including empty
        rounds: pool exhaustion and admission stalls MANIFEST as rounds
        with queued work and zero rows)."""
        if not self.flight.enabled:
            return
        d0, a0, adm0 = base
        self.flight.record({
            "round": self._batcher.step_count,
            "mode": mode,
            "rows": rows,
            "wall_s": t_end - t_step,
            "drafted": self._m_drafted.value() - d0,
            "accepted": self._m_accepted.value() - a0,
            "admitted": self._batcher.admitted - adm0,
            "queued": self.queue_depth(),
            "active": self.num_active(),
            "free_pages": {
                "target": self._t_pool.free_pages,
                "draft": self._d_pool.free_pages,
            },
            "t": t_end,
        })

    def flight_snapshot(self, dump: bool = False) -> dict:
        """The flight recorder's JSON-safe view (``GET /debug/flight``);
        ``dump=True`` additionally captures the trace tail and writes a
        postmortem file when a dump dir is configured."""
        return self.flight.dump() if dump else self.flight.snapshot()

    # -- the stepwise round --------------------------------------------------

    def _kvq_mask(self, active):
        """(B,) bool device mask — True where the row's KV is int8 — or
        ``None`` on single-kind engines (which then dispatch exactly the
        pre-compression program: no mask, no merge, bit-identical)."""
        if len(self._kinds) == 1:
            return None
        m = np.zeros((self.cfg.max_batch,), bool)
        for slot, req in active:
            m[slot] = req.kv_kind == "int8"
        return jnp.asarray(m)

    def _dispatch(self, program, step_fn, params, tokens, stores, table,
                  lengths, kvq_dev, *extra):
        """One logical batched forward over every storage kind.

        Single-kind engines run one dispatch.  Mixed engines run the step
        once per store and merge logits row-wise by kind: a row's writes
        land only in its OWN pages of each store (the page table confines
        them), and a row only ever READS the store of its kind, so the
        wrong-kind dispatch leaves unread garbage — never corruption.
        ``extra`` forwards step-specific trailing operands (the tree
        steps' win_pos / tree_mask).  ``program`` names the dispatch for
        the sampled device-time profiler (``_profiled`` is a passthrough
        on unprofiled rounds)."""
        if kvq_dev is None:
            k0 = self._kinds[0]
            logits, stores[k0] = self._profiled(
                program, step_fn, params, tokens, stores[k0], table,
                lengths, *extra,
            )
            return logits
        outs = {}
        for k in self._kinds:
            outs[k], stores[k] = self._profiled(
                program, step_fn, params, tokens, stores[k], table,
                lengths, *extra,
            )
        return jnp.where(kvq_dev[:, None, None], outs["int8"], outs["none"])

    def _prefill_into(self, req: Request, model: ServingModel,
                      iface: LMInterface, seq, store, tables, slot,
                      role: str):
        """Prefill one request into one pool (target or draft).

        Miss path: the same jitted prefill program as the single-request
        path => bitwise identical prefix KV; the cache rows scatter
        device->device into the request's (eagerly backed, lifetime-stable)
        pages — only the store of the request's resolved kind (int8 rows
        quantize inside the scatter; the wrong-kind storage of these pages
        is never read).

        Prefix-cache hit path (req.prefix_match covers ``m`` tokens): the
        shared pages are already in the page table (mapped at admission) and
        hold exactly the KV a full prefill would have written (prefix rows
        are bitwise invariant to what follows them).  A partially-shared
        last page is copy-on-written FIRST — value and scale arrays of the
        request's store, device-side — so the shared original is never
        written; then the unshared tail [m, plen-1) runs as a dense
        ``extend`` over a cache seeded with the node mirrors' FP prefix
        (bitwise equal to full-prefill tail KV) and scatters with
        ``start=m``, leaving the shared rows untouched.  The request's
        first write lands at ``plen-1 >= m``, always in a private page, so
        speculative rewind (bounded below by committed-1) can never touch a
        shared page.

        Returns ``(store, dense_kv)`` where dense_kv = (k, v) host arrays
        covering rows [0, plen-1) for radix-tree donation, or None when the
        forward was skipped entirely (full hit — every block is cached)."""
        plen = req.prompt.shape[0]
        match = req.prefix_match
        m = match.tokens_matched if match is not None else 0
        seq.ensure_backed(seq.capacity_pages * seq.pool.page_size)
        if seq.needs_cow:
            src, dst = seq.cow_last_shared()
            store = _copy_page(store, src, dst)
            if self._prefix is not None:
                self._prefix.cow_copies += 1
            self._m_prefix_cow.inc()
        tables.set_row(slot, seq)
        if m >= plen - 1:
            # full hit: rows [0, plen-1) are all resident in shared pages
            # (the COW above privatized the write frontier); no forward runs
            return store, None
        if m > 0:
            k_pre, v_pre = match.prefix_kv(role)
            cache = lm.init_cache(
                model.cfg, 1, model.s_max,
                tp=model.mesh.shape["model"] if model.mesh else 1,
            )
            attn = dict(cache["attn"])
            attn["k"] = attn["k"].at[:, 0, :m].set(
                jnp.asarray(k_pre, attn["k"].dtype)
            )
            attn["v"] = attn["v"].at[:, 0, :m].set(
                jnp.asarray(v_pre, attn["v"].dtype)
            )
            cache = dict(cache)
            cache["attn"] = attn
            cache["length"] = jnp.asarray(m, jnp.int32)
            # pad the unshared tail to a power-of-two bucket so the extend
            # compiles once per bucket, not once per tail length (causal
            # attention: pad rows sit AFTER the tail, so tail rows are
            # bitwise unaffected; the scatter's [start, n) bound and the
            # mirror slice below both ignore the pad rows)
            tail = req.prompt[m:-1]
            width = 1 << (len(tail) - 1).bit_length()
            # steady-state hits leave tails shorter than one page (only full
            # blocks are cached); floor the bucket at page_size so they all
            # share ONE compiled extend instead of one per {1, 2, 4, ...}
            width = min(max(width, seq.pool.page_size), model.s_max - m)
            padded = np.zeros(width, np.int32)
            padded[: len(tail)] = tail
            _, cache = iface.extend(
                model.params, jnp.asarray(padded[None]), cache
            )
        else:
            _, cache = iface.prefill(
                model.params, jnp.asarray(req.prompt[None, :-1])
            )
        store = _scatter_prefill(
            store,
            cache["attn"]["k"][:, 0], cache["attn"]["v"][:, 0],
            jnp.asarray(tables.table[slot]), plen - 1, m,
        )
        seq.advance(plen - 1 - m)
        dense = None
        if self._prefix is not None:
            upto = plen - 1
            ps = seq.pool.page_size
            # the full-block walk guarantees nodes for blocks [0, m // ps);
            # when that covers every full block of the prompt, insert()
            # would be a no-op — skip the device->host KV pull entirely
            # (the steady-state hit path: only the sub-page tail ran)
            if m // ps < upto // ps:
                dense = (
                    np.asarray(cache["attn"]["k"][:, 0, :upto]),
                    np.asarray(cache["attn"]["v"][:, 0, :upto]),
                )
        return store, dense

    def _admit(self) -> None:
        """Admit whatever fits and prefill it into both pools."""
        for slot, req in self._batcher.admit():
            t_adm = self._now()
            req.admit_ts = t_adm
            if req.submit_ts is not None:
                self._m_admission_wait.observe(t_adm - req.submit_ts)
            self.tracer.instant(
                f"row{slot}", "admit", cat="lifecycle", rid=req.rid
            )
            kind = req.kv_kind
            # "prefill" brackets the whole host-orchestrated prefill (the
            # forward + device scatter); its cost stamp degrades to {} —
            # _prefill_into is not a single jitted program
            self._t_store[kind], t_kv = self._profiled(
                "prefill", self._prefill_into,
                req, self.target, self._t_iface, req.t_seq,
                self._t_store[kind], self._t_tables, slot, "target",
            )
            self._d_store[kind], d_kv = self._profiled(
                "prefill", self._prefill_into,
                req, self.draft, self._d_iface, req.d_seq,
                self._d_store[kind], self._d_tables, slot, "draft",
            )
            if self._prefix is not None:
                if req.prefix_match is not None:
                    self._m_tokens_saved.inc(req.prefix_match.tokens_matched)
                if t_kv is not None and d_kv is not None:
                    # donate the freshly prefilled FULL blocks: the tree
                    # pins the pages (pool incref) and mirrors the dense
                    # FP rows for future hits' seeded tail prefills
                    self._prefix.insert(
                        req.prompt, kind,
                        {"target": req.t_seq.pages, "draft": req.d_seq.pages},
                        {"target": t_kv, "draft": d_kv},
                        upto=req.prompt.shape[0] - 1,
                    )
            req.state = RequestState.DECODE
            self.tracer.rec(
                f"row{slot}", "prefill", t_adm, self._now(),
                cat="prefill", rid=req.rid,
            )

    def step(self) -> List[RequestOutput]:
        """Admit what fits, then run ONE engine round over every active
        request — a two-phase draft-all-then-verify-all round
        (``par_mode="off"``) or a horizon of WDOS-planned fused PAR
        dispatches (``par_mode="wdos"``).  Returns a ``RequestOutput`` per
        request that progressed, with the incrementally verified tokens.
        The two modes emit bit-identical tokens; "wdos" may commit more
        than one window per request per round.

        Under ``spec_mode="tree"`` the same two schedulers run the
        tree-speculation round instead: top-k branch drafting into a
        fixed-width window, one causally-tree-masked verify dispatch, and
        the lossless multi-branch accept walk (core/speculative.py)."""
        self._round_idx += 1
        self._profile_round = (
            self._profile_every > 0
            and self._round_idx % self._profile_every == 0
        )
        if self.cfg.spec_mode == "tree":
            if self.cfg.par_mode == "wdos":
                return self._step_fused_tree()
            return self._step_two_phase_tree()
        if self.cfg.par_mode == "wdos":
            return self._step_fused()
        return self._step_two_phase()

    def _step_two_phase(self) -> List[RequestOutput]:
        cfg = self.cfg
        t_step = self._now()
        fb = self._flight_base()
        self._admit()
        active = self._batcher.active()
        if not active:
            self._batcher.step_count += 1
            self._m_steps.inc()
            self._refresh_gauges()
            self._flight_round(fb, t_step, self._now(), 0, "two_phase")
            return []

        dls = {slot: req.controller.draft_len() for slot, req in active}
        modes = {slot: req.controller.mode for slot, req in active}
        round_dl = max(dls.values())
        any_sampled = any(not req.sampling.greedy for _, req in active)
        kvq_dev = self._kvq_mask(active)

        t0 = self._now()
        d_table, d_len0 = self._d_tables.load((s, r.d_seq) for s, r in active)
        t_table, t_len0 = self._t_tables.load((s, r.t_seq) for s, r in active)
        t_draft0 = self._now()
        self._m_table_upload.inc(t_draft0 - t0)

        # ---- draft phase: round_dl proposal steps + 1 straggler step, all
        # batched; the draft pool stays on device across the loop.  Greedy
        # batches keep the next-token argmax on device; once any active row
        # samples, each proposal hops through the host so every sampled row
        # can draw from its own (temperature/top-k, per-request-key) draft
        # distribution — greedy rows still take the argmax (np and jnp share
        # the first-max tie rule, so the round stays bit-identical for them).
        cur = np.zeros((cfg.max_batch,), np.int32)
        for slot, req in active:
            cur[slot] = req.last_tok
        cur_dev = jnp.asarray(cur)
        draft_cols: List[Any] = []
        q_cols: List[np.ndarray] = []  # per-position draft logits (sampled rounds)
        for j in range(round_dl + 1):
            logits = self._dispatch(
                "draft", self._d_step, self.draft.params, cur_dev[:, None],
                self._d_store, d_table, d_len0 + j, kvq_dev,
            )
            if j < round_dl:
                if any_sampled:
                    last = np.asarray(logits[:, -1, :])
                    q_cols.append(last)
                    nxt = np.argmax(last, axis=-1).astype(np.int32)
                    for slot, req in active:
                        sp = req.sampling
                        if not sp.greedy:
                            nxt[slot] = sample_token_host(
                                req.draft_key(j), last[slot],
                                sp.temperature, sp.top_k, sp.top_p,
                            )
                    draft_cols.append(nxt)
                    cur_dev = jnp.asarray(nxt)
                else:
                    cur_dev = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                    draft_cols.append(cur_dev)
            # else: straggler — feeds d_{round_dl-1}, completing the cache for
            # fully-accepted rows; over-written rows rewind it away below.
        if any_sampled:
            drafts = np.stack(draft_cols, axis=1)  # (B, round_dl)
        else:
            drafts = np.asarray(jnp.stack(draft_cols, axis=1))
        t_verify0 = self._now()
        self.tracer.rec(
            "engine", "draft_phase", t_draft0, t_verify0,
            cat="phase", rows=len(active), dl=round_dl,
        )

        # ---- verify phase: one batched pass scoring [last_tok, drafts...]
        window = np.zeros((cfg.max_batch, round_dl + 1), np.int32)
        window[:, 0] = cur
        window[:, 1:] = drafts
        v_logits = self._dispatch(
            "verify", self._t_step, self.target.params, jnp.asarray(window),
            self._t_store, t_table, t_len0, kvq_dev,
        )
        p_logits = np.asarray(v_logits)  # (B, round_dl+1, V)
        self.tracer.rec(
            "engine", "verify_phase", t_verify0, self._now(),
            cat="phase", rows=len(active),
        )

        # ---- per-request accept / commit: a pure length update per row —
        # the KV was written in place by the steps above, and rewind just
        # drops the tail (stale pool slots are masked, then overwritten)
        work = []
        progressed: List[Request] = []
        for slot, req in active:
            dl = dls[slot]
            sp = req.sampling
            if sp.greedy:
                new, n_acc = speculative_accept_greedy_host(
                    drafts[slot], p_logits[slot], dl
                )
            else:
                q_logits = np.stack([q_cols[j][slot] for j in range(dl)])
                new, n_acc = speculative_sample_host(
                    req.accept_key(), drafts[slot], p_logits[slot], q_logits,
                    dl, sp.temperature, sp.top_k, sp.top_p,
                )
            req.commit(new)
            req.record_round(modes[slot], dl, n_acc, len(new))
            req.rounds += 1
            req.drafted += dl
            req.accepted += n_acc
            req.controller.observe(n_acc, dl)
            self._m_drafted.inc(dl)
            self._m_accepted.inc(n_acc)
            self._m_round_accept.observe(n_acc / dl if dl else 0.0)
            if self.tracer.enabled:
                self.tracer.instant(
                    f"row{slot}", "commit", cat="commit",
                    rid=req.rid, drafted=dl, accepted=n_acc,
                )
            work.append((req, dl))
            progressed.append(req)
            # both models wrote round_dl+1 positions; keep n_acc + 1
            # (draft invariant: cache == committed[:-1], incl. straggler)
            for seq in (req.t_seq, req.d_seq):
                seq.advance(round_dl + 1)
                seq.rewind(round_dl - n_acc, release_pages=False)
        self._batcher.model_round(work)
        for slot, req in active:
            if req.done:
                self._t_tables.clear_row(slot)
                self._d_tables.clear_row(slot)
                if self.tracer.enabled:
                    self.tracer.instant(
                        f"row{slot}", "finish", cat="lifecycle",
                        rid=req.rid, reason=req.finish_reason or "length",
                    )
                self._batcher.retire(slot)
        self._batcher.step_count += 1
        self._m_steps.inc()
        t_end = self._now()
        self._m_round_wall.observe(t_end - t_step)
        self.tracer.rec(
            "engine", f"step#{self._batcher.step_count}", t_step, t_end,
            cat="step", par_mode="off", rows=len(active),
        )
        self._refresh_gauges()
        self._flight_round(fb, t_step, t_end, len(active), "two_phase")

        return [self._output_for(req, t_end) for req in progressed]

    # -- tree speculation (spec_mode="tree") ---------------------------------

    def _tree_verify_commit(self, slot, req, p_win, mode, dl, moves_t,
                            moves_d, work) -> int:
        """Accept/commit one verified tree row: walk the lossless
        multi-branch accept rule over the window logits (W, V), commit the
        accepted root path (+ the residual/bonus token), queue the KV
        compaction moves that relocate the path's BFS slots to the chain
        positions the committed sequence expects, and advance/rewind both
        sequences back to committed-1.  Returns the accepted count."""
        w = self.cfg.tree_budget + 1
        sp = req.sampling
        nodes, parents = req.tree_nodes, req.tree_parents
        if sp.greedy:
            new, path, n_acc = speculative_tree_accept_greedy_host(
                nodes, parents, p_win
            )
        else:
            q_win = np.zeros((w, p_win.shape[-1]), np.float32)
            for qslot, row in req.tree_q.items():
                q_win[qslot] = row
            new, path, n_acc = speculative_tree_sample_host(
                req.accept_key(), nodes, parents, p_win, q_win,
                sp.temperature, sp.top_k, sp.top_p,
            )
        drafted_n = len(nodes)
        req.commit(new)
        req.record_round(mode, dl, n_acc, len(new))
        req.rounds += 1
        req.drafted += drafted_n
        req.accepted += n_acc
        req.controller.observe(n_acc, dl)
        self._m_drafted.inc(drafted_n)
        self._m_accepted.inc(n_acc)
        self._m_round_accept.observe(n_acc / dl if dl else 0.0)
        self._m_tree_nodes.inc(drafted_n)
        # a chain of drafted_n nodes has drafted_n DISTINCT parents (each
        # node its own); every duplicate parent is one extra forked branch
        self._m_tree_branches.inc(drafted_n - len(set(parents)))
        self._m_tree_depth.observe(n_acc)
        if self.tracer.enabled:
            self.tracer.instant(
                f"row{slot}", "commit", cat="commit",
                rid=req.rid, drafted=drafted_n, accepted=n_acc,
            )
        work.append((req, dl))
        # the accepted path sits at BFS window slots base+1+path[i]; the
        # committed sequence needs its KV at the chain slots base+1+i.  A
        # leftmost path (always, at fan-out 1) is already in place.  RoPE
        # agrees by construction: path[i] is a depth-(i+1) node, encoded at
        # position base+1+i — exactly its destination slot.
        if path != list(range(n_acc)):
            for seq, mv in (
                (req.t_seq, moves_t[req.kv_kind]),
                (req.d_seq, moves_d[req.kv_kind]),
            ):
                base = seq.length
                src = seq.flat_slots(base + 1 + np.asarray(path, np.int64))
                dst = seq.flat_slots(
                    base + 1 + np.arange(n_acc, dtype=np.int64)
                )
                mv[0].extend(int(x) for x in src)
                mv[1].extend(int(x) for x in dst)
        # both models wrote the full W-wide window; keep n_acc + 1
        # (draft invariant: cache == committed[:-1], incl. straggler)
        for seq in (req.t_seq, req.d_seq):
            seq.advance(w)
            seq.rewind(w - 1 - n_acc, release_pages=False)
        req.clear_tree()
        return n_acc

    def _compact_pools(self, moves_t, moves_d) -> None:
        """Flush queued tree-compaction moves: one fixed-width
        ``_compact_slots`` dispatch per (pool, kind) that has any, padded
        with scratch-page self-copies so each compiles once.  Each
        dispatch counts in ``tree_compactions_total`` and the flush spans
        the engine track (a tree round otherwise hides its KV relocation
        cost in the step gap)."""
        if not any(
            src for mv in (moves_t, moves_d) for (src, _) in mv.values()
        ):
            return
        t0 = self._now()
        cap = self.cfg.max_batch * self.cfg.tree_budget
        n_dispatched = 0
        for moves, stores, pool in (
            (moves_t, self._t_store, self._t_pool),
            (moves_d, self._d_store, self._d_pool),
        ):
            scratch = pool.num_pages * pool.page_size  # the extra page's 1st slot
            for k, (src, dst) in moves.items():
                if not src:
                    continue
                s = np.full((cap,), scratch, np.int64)
                d = np.full((cap,), scratch, np.int64)
                s[: len(src)] = src
                d[: len(dst)] = dst
                stores[k] = self._profiled(
                    "compaction", _compact_slots,
                    stores[k], jnp.asarray(s), jnp.asarray(d),
                )
                self._m_tree_compactions.inc()
                n_dispatched += 1
        if self.tracer.enabled:
            self.tracer.rec(
                "engine", "compaction", t0, self._now(),
                cat="phase", dispatches=n_dispatched,
            )

    def _step_two_phase_tree(self) -> List[RequestOutput]:
        """Tree-speculation round, two-phase schedule: grow every active
        request's draft tree one LEVEL per draft dispatch — the whole
        fixed-width window re-feeds at the SAME base length each time, so
        each level's frontier attends its ancestors through the tree mask
        and pad slots hold not-yet-read garbage — then verify every
        complete tree in ONE tree-masked target dispatch and walk the
        multi-branch accept rule per row.  Dispatch count matches a chain
        round of the same depth (round_depth + 1 draft + 1 verify)."""
        cfg = self.cfg
        t_step = self._now()
        fb = self._flight_base()
        self._admit()
        active = self._batcher.active()
        if not active:
            self._batcher.step_count += 1
            self._m_steps.inc()
            self._refresh_gauges()
            self._flight_round(fb, t_step, self._now(), 0, "two_phase_tree")
            return []

        w = cfg.tree_budget + 1
        b = cfg.max_batch
        # the controller's draft length is the tree DEPTH target; the node
        # budget (tree_budget) caps how much width the fan-out rule may
        # spend along the way
        dls = {
            slot: min(req.controller.draft_len(), cfg.tree_budget)
            for slot, req in active
        }
        modes = {slot: req.controller.mode for slot, req in active}
        round_depth = max(dls.values())
        kvq_dev = self._kvq_mask(active)

        t0 = self._now()
        d_table, d_len0 = self._d_tables.load((s, r.d_seq) for s, r in active)
        t_table, t_len0 = self._t_tables.load((s, r.t_seq) for s, r in active)
        t_draft0 = self._now()
        self._m_table_upload.inc(t_draft0 - t0)

        for slot, req in active:
            req.begin_tree(dls[slot])

        diag = np.arange(w)

        def window_inputs():
            tok = np.zeros((b, w), np.int32)
            pos = np.zeros((b, w), np.int32)
            tm = np.zeros((b, w, w), np.float32)
            tm[:, diag, diag] = 1.0  # inactive rows: self-only, finite softmax
            for slot, req in active:
                tok[slot], pos[slot], tm[slot] = _tree_window_rows(req, w)
            return jnp.asarray(tok), jnp.asarray(pos), jnp.asarray(tm)

        # ---- draft phase: round_depth level-growing dispatches + 1
        # straggler feeding the complete tree (lands the leaf KV for
        # fully-accepted paths; rewind drops the rest)
        for j in range(round_depth + 1):
            tok_dev, pos_dev, tm_dev = window_inputs()
            logits = self._dispatch(
                "tree_draft", self._d_tree_step, self.draft.params, tok_dev,
                self._d_store, d_table, d_len0, kvq_dev, pos_dev, tm_dev,
            )
            if j < round_depth:
                l_np = np.asarray(logits)
                for slot, req in active:
                    if not req.tree_full:
                        _sample_tree_level(req, cfg, l_np[slot])
        t_verify0 = self._now()
        self.tracer.rec(
            "engine", "tree_draft", t_draft0, t_verify0,
            cat="phase", rows=len(active), depth=round_depth, spec="tree",
        )

        # ---- verify phase: one tree-masked batched pass over full trees
        tok_dev, pos_dev, tm_dev = window_inputs()
        v_logits = self._dispatch(
            "tree_verify", self._t_tree_step, self.target.params, tok_dev,
            self._t_store, t_table, t_len0, kvq_dev, pos_dev, tm_dev,
        )
        p_logits = np.asarray(v_logits)  # (B, W, V)
        self.tracer.rec(
            "engine", "tree_verify", t_verify0, self._now(),
            cat="phase", rows=len(active), spec="tree",
        )

        # ---- per-request accept / commit / compaction
        work: List[Tuple[Request, int]] = []
        progressed: List[Request] = []
        moves_t = {k: ([], []) for k in self._kinds}
        moves_d = {k: ([], []) for k in self._kinds}
        for slot, req in active:
            self._tree_verify_commit(
                slot, req, p_logits[slot], modes[slot], dls[slot],
                moves_t, moves_d, work,
            )
            progressed.append(req)
        self._compact_pools(moves_t, moves_d)
        self._batcher.model_round(work)
        for slot, req in active:
            if req.done:
                self._t_tables.clear_row(slot)
                self._d_tables.clear_row(slot)
                if self.tracer.enabled:
                    self.tracer.instant(
                        f"row{slot}", "finish", cat="lifecycle",
                        rid=req.rid, reason=req.finish_reason or "length",
                    )
                self._batcher.retire(slot)
        self._batcher.step_count += 1
        self._m_steps.inc()
        t_end = self._now()
        self._m_round_wall.observe(t_end - t_step)
        self.tracer.rec(
            "engine", f"step#{self._batcher.step_count}", t_step, t_end,
            cat="step", par_mode="off", rows=len(active),
        )
        self._refresh_gauges()
        self._flight_round(fb, t_step, t_end, len(active), "two_phase_tree")

        return [self._output_for(req, t_end) for req in progressed]

    def _output_for(self, req: Request,
                    now: Optional[float] = None) -> RequestOutput:
        """One streaming RequestOutput: the newly deliverable tokens since
        the last step (``Request.take_delta`` — stop-string holdback may
        defer tokens, never retract them) plus the cumulative deliverable
        completion.

        This is also the delivery point, so TTFT/ITL are accounted here: a
        non-empty delta is one delivery — the first observes TTFT (submit
        to first token), each later one the inter-delivery gap (ITL at
        round granularity)."""
        delta = req.take_delta()
        if delta:
            t = self._now() if now is None else now
            self._m_emitted.inc(len(delta))
            if req.first_emit_ts is None:
                req.first_emit_ts = t
                if req.submit_ts is not None:
                    self._m_ttft.observe(t - req.submit_ts)
            elif req.last_emit_ts is not None:
                self._m_itl.observe(t - req.last_emit_ts)
            req.last_emit_ts = t
        return RequestOutput(
            request_id=req.rid,
            prompt_token_ids=[int(t) for t in req.prompt],
            new_token_ids=delta,
            finished=req.state is RequestState.FINISHED,
            outputs=[CompletionOutput(
                index=0,
                token_ids=[int(t) for t in req.out[: req.emittable_len()]],
                finish_reason=req.finish_reason,
            )],
        )

    # -- the fused cross-request PAR round (par_mode="wdos") -----------------

    def _step_fused(self) -> List[RequestOutput]:
        """One engine round as a horizon of FUSED dispatches.

        The horizon is ``max_dl + 2`` slots — the same dispatch budget a
        two-phase round spends (``round_dl + 1`` draft micro-steps plus one
        verify pass) — but rows are no longer in lockstep: each slot the
        WDOS planner (core/scheduler.plan_mixed_slot) sends window-full
        rows to VERIFY and everyone else to DRAFT, both executed in one
        fused XLA program.  A short-window row therefore verifies, commits,
        opens its next window and keeps drafting while a long-window
        neighbour is still proposing — which is exactly how the fused mode
        drains staggered/heterogeneous workloads in fewer rounds than the
        two-phase scheduler (tests/test_par_mode.py asserts both the
        round count and bit-identical tokens).  Mid-window phase state
        carries across steps; every active row completes at least one
        verify per round (its remaining cycle is at most ``max_dl + 1``
        slots), so each round streams tokens for every active request."""
        cfg = self.cfg
        t_step = self._now()
        fb = self._flight_base()
        self._admit()
        if not self._batcher.active():
            self._batcher.step_count += 1
            self._m_steps.inc()
            self._refresh_gauges()
            self._flight_round(fb, t_step, self._now(), 0, "fused")
            return []
        wv = cfg.max_dl + 1  # fixed verify width: one compiled program
        horizon = cfg.max_dl + 2
        b = cfg.max_batch
        touched: Dict[int, Request] = {
            req.rid: req for _, req in self._batcher.active()
        }
        # kind mask over the step's initial actives covers every later slot
        # too (the active set only shrinks mid-step; retired rows' merged
        # logits are never read)
        kvq_dev = self._kvq_mask(self._batcher.active())
        work: List[Tuple[Request, int]] = []

        # page tables are lifetime-stable: one cached upload serves every
        # slot of the step (rows retired mid-step are inert via the masks)
        t0 = self._now()
        d_table = self._d_tables.table_dev()
        t_table = self._t_tables.table_dev()
        self._m_table_upload.inc(self._now() - t0)

        for _ in range(horizon):
            active = self._batcher.active()
            if not active:
                break
            by_slot = dict(active)
            for _, req in active:
                if req.pending_dl is None:
                    req.begin_window(req.controller.draft_len())
            plan = sch.plan_mixed_slot([
                sch.RowPhase(slot=s, window=r.pending_dl,
                             drafted=len(r.pending))
                for s, r in active
            ])

            # assemble the slot's per-row inputs (O(B) int32 host work)
            d_tok = np.zeros((b, 1), np.int32)
            d_len = np.zeros((b,), np.int32)
            d_mask = np.zeros((b,), bool)
            for slot in plan.draft_rows:
                req = by_slot[slot]
                d_tok[slot, 0] = req.draft_tip
                d_len[slot] = req.d_seq.length + len(req.pending)
                d_mask[slot] = True
            for slot in plan.verify_rows:
                # the window's straggler: the draft side feeds the final
                # proposal WHILE the target verifies — intra-request overlap
                # riding along in the same fused program
                req = by_slot[slot]
                d_tok[slot, 0] = int(req.pending[-1])
                d_len[slot] = req.d_seq.length + req.pending_dl
                d_mask[slot] = True

            slot_t0 = self._now()
            if plan.verify_rows:
                v_tok = np.zeros((b, wv), np.int32)
                t_len = np.zeros((b,), np.int32)
                v_mask = np.zeros((b,), bool)
                for slot in plan.verify_rows:
                    req = by_slot[slot]
                    v_tok[slot, 0] = req.last_tok
                    v_tok[slot, 1: 1 + req.pending_dl] = req.pending
                    t_len[slot] = req.t_seq.length
                    v_mask[slot] = True
                v_tok_dev, d_tok_dev = jnp.asarray(v_tok), jnp.asarray(d_tok)
                t_len_dev, d_len_dev = jnp.asarray(t_len), jnp.asarray(d_len)
                vm_dev, dm_dev = jnp.asarray(v_mask), jnp.asarray(d_mask)
                vs, ds = {}, {}
                for k in self._kinds:
                    (vs[k], ds[k], self._t_store[k],
                     self._d_store[k]) = self._profiled(
                        "fused_wdos", self._fused_step,
                        self.target.params, self.draft.params,
                        v_tok_dev, d_tok_dev,
                        self._t_store[k], self._d_store[k],
                        t_table, t_len_dev, d_table, d_len_dev,
                        vm_dev, dm_dev,
                    )
                if kvq_dev is None:
                    v_logits, d_logits = vs[self._kinds[0]], ds[self._kinds[0]]
                else:
                    sel = kvq_dev[:, None, None]
                    v_logits = jnp.where(sel, vs["int8"], vs["none"])
                    d_logits = jnp.where(sel, ds["int8"], ds["none"])
                v_np = np.asarray(v_logits)
            else:
                d_tok_dev = jnp.asarray(d_tok)
                d_len_dev, dm_dev = jnp.asarray(d_len), jnp.asarray(d_mask)
                ds = {}
                for k in self._kinds:
                    ds[k], self._d_store[k] = self._profiled(
                        "draft_slot", self._draft_slot_step,
                        self.draft.params, d_tok_dev, self._d_store[k],
                        d_table, d_len_dev, dm_dev,
                    )
                if kvq_dev is None:
                    d_logits = ds[self._kinds[0]]
                else:
                    d_logits = jnp.where(
                        kvq_dev[:, None, None], ds["int8"], ds["none"]
                    )
                v_np = None
            # only drafting rows consume draft logits; skip the (B, V)
            # device->host pull on all-verify slots
            d_np = np.asarray(d_logits[:, -1, :]) if plan.draft_rows else None
            slot_t1 = self._now()
            self._batcher.record_fused_slot(plan, slot_t1 - slot_t0, wv)
            if self.tracer.enabled:
                # one engine-track span per fused dispatch plus a span on
                # every participating row's track — the per-row staggering
                # IS the wdos schedule made visible
                kind = (
                    "fused" if plan.fused
                    else "verify_only" if plan.verify_rows
                    else "draft_only"
                )
                self.tracer.rec(
                    "engine", "fused_slot", slot_t0, slot_t1, cat="fused",
                    kind=kind, draft_rows=len(plan.draft_rows),
                    verify_rows=len(plan.verify_rows),
                )
                for slot in plan.draft_rows:
                    self.tracer.rec(
                        f"row{slot}", "draft", slot_t0, slot_t1,
                        cat="draft", rid=by_slot[slot].rid,
                    )
                for slot in plan.verify_rows:
                    self.tracer.rec(
                        f"row{slot}", "verify", slot_t0, slot_t1,
                        cat="verify", rid=by_slot[slot].rid,
                    )

            # draft rows: append the next proposal (same argmax/sampling
            # rule and the same (round, position) key indices as the
            # two-phase path, so tokens are bit-identical across modes)
            for slot in plan.draft_rows:
                req = by_slot[slot]
                sp = req.sampling
                row = d_np[slot]
                if sp.greedy:
                    nxt = int(np.argmax(row))
                else:
                    nxt = sample_token_host(
                        req.draft_key(len(req.pending)), row,
                        sp.temperature, sp.top_k, sp.top_p,
                    )
                    req.pending_q.append(row.copy())
                req.pending.append(nxt)

            # verify rows: per-row accept/commit, then advance/rewind both
            # sequences back to committed-1 (the rewind-bounds invariant)
            for slot in plan.verify_rows:
                req = by_slot[slot]
                dl = req.pending_dl
                sp = req.sampling
                mode = req.controller.mode
                drafts = np.asarray(req.pending, np.int64)
                if sp.greedy:
                    new, n_acc = speculative_accept_greedy_host(
                        drafts, v_np[slot], dl
                    )
                else:
                    new, n_acc = speculative_sample_host(
                        req.accept_key(), drafts, v_np[slot],
                        np.stack(req.pending_q), dl,
                        sp.temperature, sp.top_k, sp.top_p,
                    )
                req.commit(new)
                req.record_round(mode, dl, n_acc, len(new))
                req.rounds += 1
                req.drafted += dl
                req.accepted += n_acc
                req.controller.observe(n_acc, dl)
                self._m_drafted.inc(dl)
                self._m_accepted.inc(n_acc)
                self._m_round_accept.observe(n_acc / dl if dl else 0.0)
                if self.tracer.enabled:
                    self.tracer.instant(
                        f"row{slot}", "commit", cat="commit",
                        rid=req.rid, drafted=dl, accepted=n_acc,
                    )
                work.append((req, dl))
                # target wrote wv positions, draft dl + 1 (incl. straggler);
                # both keep exactly n_acc + 1
                req.t_seq.advance(wv)
                req.t_seq.rewind(wv - 1 - n_acc, release_pages=False)
                req.d_seq.advance(dl + 1)
                req.d_seq.rewind(dl - n_acc, release_pages=False)
                req.clear_window()
                if req.done:
                    # retire MID-STEP: the freed slot's mask bits go False
                    # for the remaining slots (its stale table rows are
                    # never dereferenced), and its pages are free for the
                    # next step's admissions
                    self._t_tables.clear_row(slot)
                    self._d_tables.clear_row(slot)
                    if self.tracer.enabled:
                        self.tracer.instant(
                            f"row{slot}", "finish", cat="lifecycle",
                            rid=req.rid,
                            reason=req.finish_reason or "length",
                        )
                    self._batcher.retire(slot)

        self._batcher.model_round(work)
        self._batcher.step_count += 1
        self._m_steps.inc()
        t_end = self._now()
        self._m_round_wall.observe(t_end - t_step)
        self.tracer.rec(
            "engine", f"step#{self._batcher.step_count}", t_step, t_end,
            cat="step", par_mode="wdos", rows=len(touched),
        )
        self._refresh_gauges()
        self._flight_round(fb, t_step, t_end, len(touched), "fused")

        return [self._output_for(req, t_end) for req in touched.values()]

    def _step_fused_tree(self) -> List[RequestOutput]:
        """One tree-speculation round as a horizon of fused dispatches
        (spec_mode="tree", par_mode="wdos"): every slot the WDOS planner
        sends tree-full rows to VERIFY — the tree-masked target window
        fused with the draft side's straggler re-feed of the same complete
        tree — while everyone else grows its tree one level from the same
        fused program's draft logits.  Phase state (the partial tree)
        carries across engine steps exactly like the chain window, and
        ``rounds`` increments only at commit, so a request's trees and
        tokens are identical to the two-phase tree scheduler's."""
        cfg = self.cfg
        t_step = self._now()
        fb = self._flight_base()
        self._admit()
        if not self._batcher.active():
            self._batcher.step_count += 1
            self._m_steps.inc()
            self._refresh_gauges()
            self._flight_round(fb, t_step, self._now(), 0, "fused_tree")
            return []
        w = cfg.tree_budget + 1  # fixed window width, BOTH sides
        horizon = min(cfg.max_dl, cfg.tree_budget) + 2
        b = cfg.max_batch
        diag = np.arange(w)
        touched: Dict[int, Request] = {
            req.rid: req for _, req in self._batcher.active()
        }
        kvq_dev = self._kvq_mask(self._batcher.active())
        work: List[Tuple[Request, int]] = []

        t0 = self._now()
        d_table = self._d_tables.table_dev()
        t_table = self._t_tables.table_dev()
        self._m_table_upload.inc(self._now() - t0)

        for _ in range(horizon):
            active = self._batcher.active()
            if not active:
                break
            by_slot = dict(active)
            for _, req in active:
                if req.tree_dl is None:
                    req.begin_tree(
                        min(req.controller.draft_len(), cfg.tree_budget)
                    )
            plan = sch.plan_mixed_slot([
                sch.RowPhase(slot=s, window=r.tree_dl, drafted=r.tree_depth)
                for s, r in active
            ])

            # every active row re-feeds its current tree on the draft side
            # at its BASE length (verify rows feed the complete tree — the
            # straggler landing the leaf KV inside the verify slot)
            d_tok = np.zeros((b, w), np.int32)
            d_pos = np.zeros((b, w), np.int32)
            d_tm = np.zeros((b, w, w), np.float32)
            d_tm[:, diag, diag] = 1.0
            d_len = np.zeros((b,), np.int32)
            d_mask = np.zeros((b,), bool)
            for slot, req in active:
                d_tok[slot], d_pos[slot], d_tm[slot] = _tree_window_rows(req, w)
                d_len[slot] = req.d_seq.length
                d_mask[slot] = True

            slot_t0 = self._now()
            if plan.verify_rows:
                v_tok = np.zeros((b, w), np.int32)
                t_pos = np.zeros((b, w), np.int32)
                t_tm = np.zeros((b, w, w), np.float32)
                t_tm[:, diag, diag] = 1.0
                t_len = np.zeros((b,), np.int32)
                v_mask = np.zeros((b,), bool)
                for slot in plan.verify_rows:
                    req = by_slot[slot]
                    v_tok[slot], t_pos[slot], t_tm[slot] = _tree_window_rows(
                        req, w
                    )
                    t_len[slot] = req.t_seq.length
                    v_mask[slot] = True
                heads = (jnp.asarray(v_tok), jnp.asarray(d_tok))
                tails = (
                    t_table, jnp.asarray(t_len), d_table, jnp.asarray(d_len),
                    jnp.asarray(v_mask), jnp.asarray(d_mask),
                    jnp.asarray(t_pos), jnp.asarray(t_tm),
                    jnp.asarray(d_pos), jnp.asarray(d_tm),
                )
                vs, ds = {}, {}
                for k in self._kinds:
                    (vs[k], ds[k], self._t_store[k],
                     self._d_store[k]) = self._profiled(
                        "fused_tree", self._fused_tree_step,
                        self.target.params, self.draft.params, *heads,
                        self._t_store[k], self._d_store[k], *tails,
                    )
                if kvq_dev is None:
                    v_logits, d_logits = vs[self._kinds[0]], ds[self._kinds[0]]
                else:
                    sel = kvq_dev[:, None, None]
                    v_logits = jnp.where(sel, vs["int8"], vs["none"])
                    d_logits = jnp.where(sel, ds["int8"], ds["none"])
                v_np = np.asarray(v_logits)
            else:
                d_tok_dev = jnp.asarray(d_tok)
                tails = (
                    d_table, jnp.asarray(d_len), jnp.asarray(d_mask),
                    jnp.asarray(d_pos), jnp.asarray(d_tm),
                )
                ds = {}
                for k in self._kinds:
                    ds[k], self._d_store[k] = self._profiled(
                        "tree_draft_slot", self._draft_tree_slot_step,
                        self.draft.params, d_tok_dev, self._d_store[k],
                        *tails,
                    )
                if kvq_dev is None:
                    d_logits = ds[self._kinds[0]]
                else:
                    d_logits = jnp.where(
                        kvq_dev[:, None, None], ds["int8"], ds["none"]
                    )
                v_np = None
            # tree growth consumes the WHOLE window's logits (one row per
            # frontier node), not just the last column
            d_np = np.asarray(d_logits) if plan.draft_rows else None
            slot_t1 = self._now()
            self._batcher.record_fused_slot(
                plan, slot_t1 - slot_t0, w, draft_width=w
            )
            if self.tracer.enabled:
                kind = (
                    "fused" if plan.fused
                    else "verify_only" if plan.verify_rows
                    else "draft_only"
                )
                self.tracer.rec(
                    "engine", "fused_slot", slot_t0, slot_t1, cat="fused",
                    kind=kind, draft_rows=len(plan.draft_rows),
                    verify_rows=len(plan.verify_rows), spec="tree",
                )
                for slot in plan.draft_rows:
                    self.tracer.rec(
                        f"row{slot}", "tree_draft", slot_t0, slot_t1,
                        cat="draft", rid=by_slot[slot].rid,
                        depth=by_slot[slot].tree_depth,
                    )
                for slot in plan.verify_rows:
                    self.tracer.rec(
                        f"row{slot}", "tree_verify", slot_t0, slot_t1,
                        cat="verify", rid=by_slot[slot].rid,
                    )

            # draft rows: one more tree level (same fan-out rule and the
            # same (round, draw-index) key stream as the two-phase path)
            for slot in plan.draft_rows:
                _sample_tree_level(by_slot[slot], cfg, d_np[slot])

            # verify rows: accept/commit + queue compaction, retire done
            moves_t = {k: ([], []) for k in self._kinds}
            moves_d = {k: ([], []) for k in self._kinds}
            for slot in plan.verify_rows:
                req = by_slot[slot]
                dl = req.tree_dl
                self._tree_verify_commit(
                    slot, req, v_np[slot], req.controller.mode, dl,
                    moves_t, moves_d, work,
                )
                if req.done:
                    self._t_tables.clear_row(slot)
                    self._d_tables.clear_row(slot)
                    if self.tracer.enabled:
                        self.tracer.instant(
                            f"row{slot}", "finish", cat="lifecycle",
                            rid=req.rid,
                            reason=req.finish_reason or "length",
                        )
                    self._batcher.retire(slot)
            # flush compaction BEFORE the next fused dispatch: a committed
            # row's next window overlaps its old BFS slots
            self._compact_pools(moves_t, moves_d)

        self._batcher.model_round(work)
        self._batcher.step_count += 1
        self._m_steps.inc()
        t_end = self._now()
        self._m_round_wall.observe(t_end - t_step)
        self.tracer.rec(
            "engine", f"step#{self._batcher.step_count}", t_step, t_end,
            cat="step", par_mode="wdos", rows=len(touched),
        )
        self._refresh_gauges()
        self._flight_round(fb, t_step, t_end, len(touched), "fused_tree")

        return [self._output_for(req, t_end) for req in touched.values()]

    # -- drain / reporting ---------------------------------------------------

    def run(
        self,
        prompts: Optional[Sequence[Any]] = None,
        sampling_params=None,
        sinks: Optional[Sequence[Optional[Callable[[int], None]]]] = None,
    ) -> Tuple[List[jnp.ndarray], dict]:
        """Convenience drain loop: optionally add `prompts` (with one shared
        or per-prompt ``SamplingParams``), then ``step()`` until nothing is
        queued or active.  Returns (outputs for the added prompts — or every
        request this engine has seen — in submission order, summary)."""
        rids = None
        if prompts is not None:
            n = len(prompts)
            if sampling_params is None:
                sps = [None] * n
            elif isinstance(sampling_params, SamplingParams):
                sps = [sampling_params] * n
            else:
                sps = list(sampling_params)
                if len(sps) != n:
                    raise ValueError(
                        f"{len(sps)} sampling_params for {n} prompts"
                    )
            rids = [
                self.add_request(p, sps[i], sink=sinks[i] if sinks else None)
                for i, p in enumerate(prompts)
            ]
        while self.has_unfinished():
            self.step()
        ids = rids if rids is not None else sorted(self._requests)
        return [self.output_tokens(r) for r in ids], self.summary()

    def summary(self) -> dict:
        s = self._batcher.summary()
        s["kv_path"] = "paged"
        s["par_mode"] = self.cfg.par_mode
        s["kv_quant"] = self.cfg.kv_quant
        s["kv_bytes_per_token"] = {
            "target": float(self._t_pool.bytes_per_token()),
            "draft": float(self._d_pool.bytes_per_token()),
        }
        s["kv_copy_s"] = 0.0  # no host K/V copies exist on this path
        s["table_upload_s"] = self._m_table_upload.value()
        if self._prefix is not None:
            s["prefix_cache"] = self._prefix.stats()
        return s


# ---------------------------------------------------------------------------
# Deprecated run-to-drain wrappers (kept bit-identical for greedy decoding)
# ---------------------------------------------------------------------------


def _seed_from_key(key) -> int:
    """Fold a jax PRNG key into a per-request integer seed (the wrappers'
    bridge from the old key-threading API to per-request key streams)."""
    try:
        data = jax.random.key_data(key)
    except (AttributeError, TypeError):
        data = key
    return int(np.asarray(data).ravel()[-1])


def serve_sd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: SDConfig,
):
    """DEPRECATED: single-request speculative decoding via the Engine.

    Greedy outputs are bit-identical to the historical ``sd_generate``
    driver.  For ``temperature > 0`` the engine's per-request key stream
    (seeded from `key`) replaces the old shared key threading, so sampled
    outputs are equally-distributed but not draw-for-draw identical."""
    warn_deprecated_once("serve_sd", "Engine.add_request(...) + Engine.step()")
    prompt_np = np.asarray(prompt).reshape(-1)
    ecfg = EngineConfig(
        max_batch=1,
        draft_len=cfg.draft_len,
        model_wdos=False,
        max_model_len=prompt_np.shape[0] + cfg.max_tokens + cfg.draft_len,
    )
    eng = Engine(target, draft, ecfg)
    sp = SamplingParams(
        temperature=max(cfg.temperature, 0.0),
        max_tokens=cfg.max_tokens,
        seed=_seed_from_key(key),
    )
    outs, _ = eng.run([prompt_np], sp)
    req = eng.request(0)
    stats = SDStats(
        emitted=jnp.asarray(req.emitted_total),
        rounds=jnp.asarray(req.rounds),
        drafted=jnp.asarray(req.drafted),
        accepted=jnp.asarray(req.accepted),
    )
    return outs[0], stats


def serve_apsd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: APSDConfig,
):
    """DEPRECATED: single-request APSD via the Engine's adaptive mode.

    The engine's per-request ``DraftController`` drives the same
    ``APSDPolicy`` mode machine (short windows while the TLM rejects, long
    while it accepts), so greedy outputs stay bit-identical (lossless);
    round stats are rebuilt from the request's round log.  The engine
    schedules PAR rounds as longer windows rather than the reference
    driver's draft-during-verify pipelining — the cross-request overlap the
    batcher's WDOS model prices replaces intra-request pipelining here (the
    full pipelined reference survives as ``core/apsd.apsd_generate``)."""
    warn_deprecated_once(
        "serve_apsd", "Engine with EngineConfig(adaptive=True)"
    )
    prompt_np = np.asarray(prompt).reshape(-1)
    ecfg = EngineConfig(
        max_batch=1,
        adaptive=True,
        short_dl=cfg.short_dl,
        long_dl=cfg.long_dl,
        model_wdos=False,
        max_model_len=prompt_np.shape[0] + cfg.max_tokens + cfg.long_dl,
    )
    eng = Engine(target, draft, ecfg)
    sp = SamplingParams(
        temperature=max(cfg.temperature, 0.0),
        max_tokens=cfg.max_tokens,
        seed=_seed_from_key(key),
    )
    outs, _ = eng.run([prompt_np], sp)
    req = eng.request(0)
    records = tuple(
        RoundRecord(mode=m, drafted=d, accepted=a, emitted=e, discarded=0)
        for m, d, a, e in req.history
    )
    stats = APSDStats(
        emitted=sum(r.emitted for r in records),
        rounds=len(records),
        drafted=sum(r.drafted for r in records),
        accepted=sum(r.accepted for r in records),
        discarded=0,
        par_rounds=sum(1 for r in records if r.mode == PAR),
        records=records,
    )
    return outs[0], stats


def serve_batch(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompts: Sequence[Any],  # each (S,) or (1, S) int32, S >= 2
    cfg: BatchConfig,
    sinks: Optional[Sequence[Optional[Callable[[int], None]]]] = None,
) -> Tuple[List[jnp.ndarray], dict]:
    """DEPRECATED: run-to-drain batch decoding; use ``Engine`` directly.

    Thin wrapper: builds an ``Engine`` sized exactly like the historical
    closed-batch runtime (pool fits the ``max_batch`` largest submitted
    requests; table width = the batch's worst-case peak), adds every prompt,
    and drains.  Greedy outputs are bit-identical per request to the
    pre-redesign loop (and to ``serve_sd``).  ``cfg.kv_path == "host"``
    still selects the frozen legacy host-gather loop
    (serving/host_gather.py) kept as the benchmark baseline."""
    warn_deprecated_once("serve_batch", "Engine.run(...)")
    if cfg.kv_path == "host":
        from repro.serving.host_gather import serve_batch_host as _host_impl

        return _host_impl(key, target, draft, prompts, cfg, sinks=sinks)
    if cfg.kv_path != "paged":
        raise ValueError(f"kv_path must be 'paged' or 'host', got {cfg.kv_path!r}")
    if cfg.temperature != 0.0:
        raise NotImplementedError(
            "the deprecated serve_batch wrapper keeps its historical "
            "greedy-only contract; pass SamplingParams(temperature=...) "
            "to Engine.add_request for sampled decoding"
        )
    del key  # greedy path is deterministic; kept for API symmetry
    if not len(prompts):
        return [], _empty_summary(cfg)
    prompts_np = [np.asarray(p).reshape(-1) for p in prompts]
    peaks = [p.shape[0] + cfg.max_tokens + cfg.max_dl for p in prompts_np]
    for model in (target, draft):
        if max(peaks) > model.s_max:
            raise ValueError(
                f"peak cache length {max(peaks)} exceeds s_max={model.s_max} "
                f"of {model.cfg.name}"
            )
    if cfg.num_pages is not None:
        num_pages = cfg.num_pages
    else:
        worst = sorted((pages_for(p, cfg.page_size) for p in peaks), reverse=True)
        num_pages = sum(worst[: cfg.max_batch])
    ecfg = EngineConfig(
        max_batch=cfg.max_batch,
        page_size=cfg.page_size,
        draft_len=cfg.draft_len,
        adaptive=cfg.adaptive,
        short_dl=cfg.short_dl,
        long_dl=cfg.long_dl,
        num_pages=num_pages,
        max_model_len=max(peaks),
        model_wdos=cfg.model_wdos,
    )
    eng = Engine(target, draft, ecfg)
    sp = SamplingParams(max_tokens=cfg.max_tokens)
    return eng.run(prompts_np, sp, sinks=sinks)


def serve_batch_host(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompts: Sequence[Any],
    cfg: BatchConfig,
    sinks: Optional[Sequence[Optional[Callable[[int], None]]]] = None,
) -> Tuple[List[jnp.ndarray], dict]:
    """DEPRECATED: the legacy host-gather loop, kept only as the benchmark
    baseline (``bench_serving --kv-path host``)."""
    warn_deprecated_once(
        "serve_batch_host", "Engine.run(...) (device-resident paged KV)"
    )
    from repro.serving.host_gather import serve_batch_host as _host_impl

    return _host_impl(key, target, draft, prompts, cfg, sinks=sinks)
