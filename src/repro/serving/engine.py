"""Serving engine: wires real zoo models into the SD / APSD drivers.

Builds `LMInterface` adapters (prefill / extend / rewind over the functional
caches) for any of: bf16 `lm.apply_lm`, W4A8 `apply_quantized_lm`, BVQ
`apply_bvq_lm` — so the full paper configuration

    TLM = W4A8+LRU target model,  DLM = BVQ draft model,  APSD controller

runs end to end on real weights.  Rewind is O(1): reset the cache length
(stale slots are overwritten and masked).  On a TPU mesh the draft and
verify dispatches overlap (the WDOS idea); on CPU they serialize but are
bit-identical.

`serve_batch` is the multi-request runtime on top of the same models: KV
lives in DEVICE-RESIDENT block-granular paged pools (serving/paged_cache.py
allocator + JAX pool arrays), a continuous batcher (serving/batcher.py)
admits/evicts requests under a page budget, and each draft/verify step runs
as ONE batched model call over every active request that scatters new
tokens straight into pool pages and attends through per-row page tables —
no per-round host gather/scatter of K/V views.  Accept/rewind is a
per-row length update with zero KV copies.  Greedy outputs are
bit-identical per request to the single-request ``serve_sd`` path —
batching and paging change scheduling and residency, never sampling.
(The pre-refactor host-gather loop survives in serving/host_gather.py as
the benchmark baseline, selected by ``BatchConfig.kv_path == "host"``.)
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apsd import APSDConfig, apsd_generate
from repro.core.speculative import LMInterface, SDConfig, sd_generate
from repro.models import layers as L
from repro.models import lm
from repro.models.common import ModelConfig
from repro.serving import quantized_lm as qlm
from repro.serving.batcher import BatchConfig, ContinuousBatcher
from repro.serving.paged_cache import PagedKVPool, device_pool_init, pages_for
from repro.serving.request import Request, RequestState

__all__ = [
    "make_interface",
    "ServingModel",
    "serve_sd",
    "serve_apsd",
    "serve_batch",
    "BatchConfig",
]


@dataclasses.dataclass
class ServingModel:
    cfg: ModelConfig
    params: Any
    mode: str = "bf16"  # bf16 | w4a8 | bvq
    mesh: Any = None
    s_max: int = 512
    use_pallas: bool = False
    # paged decode attention path: "gather" replays the exact dense math
    # over a device-side page gather (bit-identical to serve_sd); "pallas"
    # attends in place through the page table with kernels/paged_attn.py
    # (interpret mode on CPU).
    paged_attn_impl: str = "gather"

    def _apply(self, params, tokens, cache):
        paged_kw = {}
        if cache is not None and "page_table" in cache:
            paged_kw = dict(paged_impl=self.paged_attn_impl)
        if self.mode == "w4a8":
            return qlm.apply_quantized_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas, **paged_kw,
            )
        if self.mode == "bvq":
            return qlm.apply_bvq_lm(
                params, self.cfg, self.mesh, tokens, cache=cache,
                use_pallas=self.use_pallas, **paged_kw,
            )
        return lm.apply_lm(
            params, self.cfg, self.mesh, tokens, cache=cache, **paged_kw
        )


def make_interface(model: ServingModel) -> LMInterface:
    cfg, mesh, s_max = model.cfg, model.mesh, model.s_max

    def fresh_cache(batch):
        if model.mode in ("w4a8", "bvq"):
            # quantized paths use the dense attn cache layout
            c = lm.init_cache(
                dataclasses.replace(cfg),  # same shapes
                batch, s_max, tp=mesh.shape["model"] if mesh else 1,
            )
            return c
        return lm.init_cache(cfg, batch, s_max, tp=mesh.shape["model"] if mesh else 1)

    @jax.jit
    def _prefill(params, tokens, cache):
        return model._apply(params, tokens, cache)

    @jax.jit
    def _extend(params, tokens, cache):
        return model._apply(params, tokens, cache)

    def prefill(params, tokens):
        cache = fresh_cache(tokens.shape[0])
        return _prefill(params, tokens, cache)

    def extend(params, tokens, cache):
        return _extend(params, tokens, cache)

    def rewind(cache, n):
        if n < 0:
            raise ValueError(f"rewind expects n >= 0, got {n}")
        length = cache["length"]
        try:
            if int(length) - n < 0:
                raise ValueError(
                    f"over-rewind: cache length {int(length)} < rewind {n}"
                )
        except jax.errors.ConcretizationTypeError:
            pass  # traced length: fall through to the clamp below
        c = dict(cache)
        c["length"] = jnp.maximum(length - n, 0)
        return c

    return LMInterface(prefill=prefill, extend=extend, rewind=rewind)


def serve_sd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: SDConfig,
):
    return sd_generate(
        key,
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        prompt, cfg,
    )


def serve_apsd(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompt: jnp.ndarray,
    cfg: APSDConfig,
):
    return apsd_generate(
        key,
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        prompt, cfg,
    )


# ---------------------------------------------------------------------------
# Continuous-batching runtime (device-resident paged KV, zero host copies)
# ---------------------------------------------------------------------------


def _np_dtype(cfg: ModelConfig):
    return np.asarray(jnp.zeros((), cfg.jdtype)).dtype


def _wdos_costs(mcfg: ModelConfig) -> Tuple[float, float]:
    load = 12.0 * mcfg.d_model * mcfg.d_model * 1e-6  # ~per-layer weight bytes
    return load, 0.25 * load


def _empty_summary(cfg: BatchConfig) -> dict:
    return {
        "requests": 0, "rounds": 0, "steps": 0, "emitted": 0,
        "acceptance_rate": 0.0, "target_pool": None, "draft_pool": None,
        "wdos_modeled_speedup": 1.0,
        "wdos_utilization": {},
        "kv_path": cfg.kv_path,
        "kv_copy_s": 0.0,
        "table_upload_s": 0.0,
    }


def _pool_for(
    model: ServingModel, cfg: BatchConfig, peaks: Sequence[int],
    alloc_storage: bool = True,
):
    """Page pool sized to hold `max_batch` worst-case requests (or the
    explicit cfg.num_pages budget).  alloc_storage=False builds the pure
    allocator for the device-resident path (KV bytes live in JAX arrays)."""
    mcfg = model.cfg
    if mcfg.kv_quant:
        raise NotImplementedError("paged pools hold dense-dtype KV (kv_quant=False)")
    if model.mesh is not None:
        raise NotImplementedError("serve_batch runs the single-host path (mesh=None)")
    if cfg.num_pages is not None:
        num_pages = cfg.num_pages
    else:
        worst = sorted((pages_for(p, cfg.page_size) for p in peaks), reverse=True)
        num_pages = sum(worst[: cfg.max_batch])
    return PagedKVPool(
        n_layers=mcfg.n_layers,
        kv_heads=L.kv_store_heads(mcfg, 1),
        head_dim=mcfg.hd,
        num_pages=num_pages,
        page_size=cfg.page_size,
        dtype=_np_dtype(mcfg),
        alloc_storage=alloc_storage,
    )


def _greedy_accept_host(drafts: np.ndarray, p_logits: np.ndarray, dl: int):
    """Host-side mirror of ``speculative_accept_greedy`` for one request:
    accept while draft == argmax(target); emit the bonus/correction token."""
    tlm_tok = np.argmax(p_logits, axis=-1)  # (L+1,), first-max tie rule == jnp
    n_acc = 0
    while n_acc < dl and tlm_tok[n_acc] == drafts[n_acc]:
        n_acc += 1
    return [int(t) for t in drafts[:n_acc]] + [int(tlm_tok[n_acc])], n_acc


def _make_paged_step(model: ServingModel):
    """jit of one batched paged forward: every active request is a batch row
    with its OWN page-table row and length (positions, causal masking, and
    the pool write slots are per-row).  The K/V pools are carried as device
    values — the step scatters new tokens in place and returns the updated
    pools, so NO K/V bytes ever cross the host boundary.  The pool buffers
    are DONATED: the caller always rebinds them to the step's outputs, so
    XLA may alias the scatter in place instead of copying the pool."""

    @partial(jax.jit, donate_argnums=(2, 3))
    def step(params, tokens, pool_k, pool_v, page_table, lengths):
        # tokens (B, W) int32; pools (L, P+1, ps, kvh, hd); table (B, mp)
        cache = {
            "lengths": lengths,
            "page_table": page_table,
            "attn": {"k": pool_k, "v": pool_v},
        }
        logits, nc = model._apply(params, tokens, cache)
        return logits, nc["attn"]["k"], nc["attn"]["v"]

    return step


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_prefill(pool_k, pool_v, k_dense, v_dense, pages, n):
    """Scatter a freshly prefilled request's first `n` cache rows straight
    into its pool pages — device to device, no host round-trip.
    k_dense/v_dense: (L, s_max, kvh, hd); pages: (mp,) physical page ids,
    unowned slots holding the scratch page.  `n` is traced (one compile per
    model, not per prompt length): the fixed-width scatter covers the whole
    table span and routes slots >= n to the scratch page."""
    nl, p1, ps, kvh, hd = pool_k.shape
    s_max = k_dense.shape[1]
    cap = pages.shape[0] * ps  # table span; may overhang s_max by < ps
    pos = jnp.arange(cap)
    scratch = (p1 - 1) * ps + pos % ps  # harmless dup writes per layer
    flat = jnp.where(pos < n, pages[pos // ps] * ps + pos % ps, scratch)
    src = k_dense[:, jnp.minimum(pos, s_max - 1)]
    pk = pool_k.reshape(nl, p1 * ps, kvh, hd).at[:, flat].set(src)
    srcv = v_dense[:, jnp.minimum(pos, s_max - 1)]
    pv = pool_v.reshape(nl, p1 * ps, kvh, hd).at[:, flat].set(srcv)
    return pk.reshape(pool_k.shape), pv.reshape(pool_v.shape)


class _TableSet:
    """Host mirror of one pool's per-slot page tables / lengths.

    Page tables only change at admission/retirement (pages are backed
    eagerly, so a request's table is stable for its whole lifetime);
    lengths change every round.  Both are O(B) int32 uploads — the point of
    the device-resident refactor is that these tiny tables are ALL that
    crosses the host boundary per round.  `cap_tokens` (the batch's
    worst-case peak cache length, NOT s_max) sizes the table width, which
    in turn bounds the attention span the paged forward touches."""

    def __init__(self, max_batch: int, pool: PagedKVPool, cap_tokens: int):
        self.max_pages = pages_for(cap_tokens, pool.page_size)
        self.scratch = pool.num_pages  # device arrays have one extra page
        self.table = np.full((max_batch, self.max_pages), self.scratch, np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self._table_dev = None

    def set_row(self, slot: int, seq) -> None:
        row = self.table[slot]
        row[:] = self.scratch
        row[: len(seq.pages)] = seq.pages
        self._table_dev = None

    def clear_row(self, slot: int) -> None:
        self.table[slot] = self.scratch
        self._table_dev = None

    def load(self, rows):
        """rows: iterable of (slot, PagedSequence) -> (table, lengths) dev.
        Blocks until the uploads land so the caller's timing is comparable
        to the host baseline's blocking kv_copy_s."""
        self.lengths[:] = 0
        for slot, seq in rows:
            self.lengths[slot] = seq.length
        if self._table_dev is None:
            self._table_dev = jax.block_until_ready(jnp.asarray(self.table))
        return self._table_dev, jax.block_until_ready(jnp.asarray(self.lengths))


def serve_batch(
    key: jax.Array,
    target: ServingModel,
    draft: ServingModel,
    prompts: Sequence[Any],  # each (S,) or (1, S) int32, S >= 2
    cfg: BatchConfig,
    sinks: Optional[Sequence[Optional[Callable[[int], None]]]] = None,
) -> Tuple[List[jnp.ndarray], dict]:
    """Continuously-batched greedy speculative decoding over device-resident
    paged KV pools.

    Admits up to ``cfg.max_batch`` concurrent requests (more queue behind the
    page budget), runs each SD round as batched draft/verify steps over every
    active request — prefill scatters straight into pool pages, decode
    scatters/attends in place through per-row page tables, and accept/rewind
    is a per-row length update with no KV copy.  Streams tokens to
    per-request sinks.  Returns the per-request outputs (original submission
    order) and the batch summary (pool stats + the WDOS cross-request
    overlap model).

    ``cfg.kv_path == "host"`` selects the legacy host-gather loop
    (serving/host_gather.py) kept as the benchmark baseline.

    Greedy only: per-request outputs are bit-identical to ``serve_sd`` with
    the same models (asserted in tests/test_serving_batch.py).
    """
    if cfg.kv_path == "host":
        from repro.serving.host_gather import serve_batch_host

        return serve_batch_host(key, target, draft, prompts, cfg, sinks=sinks)
    if cfg.kv_path != "paged":
        raise ValueError(f"kv_path must be 'paged' or 'host', got {cfg.kv_path!r}")
    del key  # greedy path is deterministic; kept for API symmetry with serve_sd
    if cfg.temperature != 0.0:
        raise NotImplementedError("serve_batch currently supports temperature=0.0")

    requests = [
        Request(
            rid=i,
            prompt=np.asarray(p).reshape(-1),
            max_new_tokens=cfg.max_tokens,
            sink=sinks[i] if sinks else None,
        )
        for i, p in enumerate(prompts)
    ]
    if not requests:
        return [], _empty_summary(cfg)
    peaks = [r.peak_cache_len(cfg.max_dl) for r in requests]
    for model in (target, draft):
        if max(peaks) > model.s_max:
            raise ValueError(
                f"peak cache length {max(peaks)} exceeds s_max={model.s_max} "
                f"of {model.cfg.name}"
            )

    # host pools are pure allocators; the KV bytes live in device arrays
    t_pool = _pool_for(target, cfg, peaks, alloc_storage=False)
    d_pool = _pool_for(draft, cfg, peaks, alloc_storage=False)
    t_pk, t_pv = device_pool_init(t_pool)
    d_pk, d_pv = device_pool_init(d_pool)

    batcher = ContinuousBatcher(
        cfg, t_pool, d_pool,
        t_layers=target.cfg.n_layers, d_layers=draft.cfg.n_layers,
        t_costs=_wdos_costs(target.cfg), d_costs=_wdos_costs(draft.cfg),
    )
    for r in requests:
        batcher.submit(r)

    t_iface, d_iface = make_interface(target), make_interface(draft)
    t_step, d_step = _make_paged_step(target), _make_paged_step(draft)
    t_tables = _TableSet(cfg.max_batch, t_pool, max(peaks))
    d_tables = _TableSet(cfg.max_batch, d_pool, max(peaks))
    table_upload_s = 0.0  # tiny int32 table/length uploads (all that remains)

    def _prefill_into(req: Request, iface: LMInterface, params, seq,
                      pool_k, pool_v, tables, slot):
        # same jitted program as the single-request path => bitwise
        # identical prefix KV; the cache rows scatter device->device into
        # the request's (eagerly backed, lifetime-stable) pages
        plen = req.prompt.shape[0]
        _, cache = iface.prefill(params, jnp.asarray(req.prompt[None, :-1]))
        seq.ensure_backed(seq.reservation * seq.pool.page_size)
        tables.set_row(slot, seq)
        pool_k, pool_v = _scatter_prefill(
            pool_k, pool_v,
            cache["attn"]["k"][:, 0], cache["attn"]["v"][:, 0],
            jnp.asarray(tables.table[slot]), plen - 1,
        )
        seq.advance(plen - 1)
        return pool_k, pool_v

    while not batcher.all_done():
        for slot, req in batcher.admit():
            t_pk, t_pv = _prefill_into(
                req, t_iface, target.params, req.t_seq, t_pk, t_pv,
                t_tables, slot,
            )
            d_pk, d_pv = _prefill_into(
                req, d_iface, draft.params, req.d_seq, d_pk, d_pv,
                d_tables, slot,
            )
            req.state = RequestState.DECODE
        active = batcher.active()
        if not active:
            batcher.step_count += 1
            continue

        dls = {slot: req.controller.draft_len() for slot, req in active}
        round_dl = max(dls.values())

        t0 = time.perf_counter()
        d_table, d_len0 = d_tables.load((s, r.d_seq) for s, r in active)
        t_table, t_len0 = t_tables.load((s, r.t_seq) for s, r in active)
        table_upload_s += time.perf_counter() - t0

        # ---- draft phase: round_dl sampled steps + 1 straggler step, all
        # batched; the draft pool stays on device across the loop.
        cur = np.zeros((cfg.max_batch,), np.int32)
        for slot, req in active:
            cur[slot] = req.last_tok
        cur_dev = jnp.asarray(cur)
        draft_cols = []
        for j in range(round_dl + 1):
            logits, d_pk, d_pv = d_step(
                draft.params, cur_dev[:, None], d_pk, d_pv, d_table, d_len0 + j
            )
            if j < round_dl:
                cur_dev = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                draft_cols.append(cur_dev)
            # else: straggler — feeds d_{round_dl-1}, completing the cache for
            # fully-accepted rows; over-written rows rewind it away below.
        drafts = np.asarray(jnp.stack(draft_cols, axis=1))  # (B, round_dl)

        # ---- verify phase: one batched pass scoring [last_tok, drafts...]
        window = np.zeros((cfg.max_batch, round_dl + 1), np.int32)
        window[:, 0] = cur
        window[:, 1:] = drafts
        v_logits, t_pk, t_pv = t_step(
            target.params, jnp.asarray(window), t_pk, t_pv, t_table, t_len0
        )
        p_logits = np.asarray(v_logits)  # (B, round_dl+1, V)

        # ---- per-request accept / commit: a pure length update per row —
        # the KV was written in place by the steps above, and rewind just
        # drops the tail (stale pool slots are masked, then overwritten)
        work = []
        for slot, req in active:
            dl = dls[slot]
            new, n_acc = _greedy_accept_host(drafts[slot], p_logits[slot], dl)
            req.commit(new)
            req.rounds += 1
            req.drafted += dl
            req.accepted += n_acc
            req.controller.observe(n_acc, dl)
            work.append((req, dl))
            # both models wrote round_dl+1 positions; keep n_acc + 1
            # (draft invariant: cache == committed[:-1], incl. straggler)
            for seq in (req.t_seq, req.d_seq):
                seq.advance(round_dl + 1)
                seq.rewind(round_dl - n_acc, release_pages=False)
        batcher.model_round(work)
        for slot, req in active:
            if req.done:
                t_tables.clear_row(slot)
                d_tables.clear_row(slot)
                batcher.retire(slot)
        batcher.step_count += 1

    outputs = [
        jnp.asarray(r.out[: r.max_new_tokens], jnp.int32) for r in requests
    ]
    summary = batcher.summary()
    summary["kv_path"] = "paged"
    summary["kv_copy_s"] = 0.0  # no host K/V copies exist on this path
    summary["table_upload_s"] = table_upload_s
    return outputs, summary
