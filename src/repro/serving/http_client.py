"""Shared raw-socket HTTP client for the serving stack's own consumers.

``tests/test_server.py``, ``scripts/server_smoke.py``, and
``examples/serve_http.py`` each used to carry their own copy of the same
asyncio-streams HTTP/1.1 client; this module is the one implementation
they all drive ``CompletionServer`` through.  It deliberately speaks the
same minimal protocol the server does — request line + headers +
Content-Length body, ``Connection: close`` responses — with no external
dependency, so exercising it IS exercising the wire format a load
balancer sees.

The surface splits by how much of the exchange the caller wants to own:

* ``request`` / ``get_json`` — one whole request/response round trip;
* ``sse_request`` — POST a streaming completion, drain the SSE body, and
  parse it into chunk dicts (``parse_sse`` validates the framing:
  ``data:`` lines, blank-line separation, terminal ``data: [DONE]``);
* ``open_request`` + ``read_head`` + ``iter_sse`` — incremental control
  for live-streaming consumers and disconnect scenarios (open, read a
  chunk or two, hang up).
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, AsyncIterator, Dict, List, Optional, Tuple

__all__ = [
    "format_request",
    "open_request",
    "read_head",
    "request",
    "get_json",
    "parse_sse",
    "sse_request",
    "iter_sse",
]


def format_request(method: str, path: str, payload: Any = None,
                   host: str = "client") -> bytes:
    """Serialize one HTTP/1.1 request with an optional JSON body."""
    body = json.dumps(payload).encode() if payload is not None else b""
    head = (
        f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
        f"Content-Length: {len(body)}\r\n\r\n"
    ).encode()
    return head + body


async def open_request(
    port: int, method: str, path: str, payload: Any = None,
    host: str = "127.0.0.1",
) -> Tuple[asyncio.StreamReader, asyncio.StreamWriter]:
    """Connect, send the request, and hand back the raw streams — for
    callers that read incrementally (SSE consumers) or disconnect early
    (abort scenarios).  The caller owns closing the writer."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(format_request(method, path, payload))
    await writer.drain()
    return reader, writer


async def request(
    port: int, method: str, path: str, payload: Any = None,
    host: str = "127.0.0.1",
) -> Tuple[int, str, bytes]:
    """One whole round trip: returns (status, response head, body bytes)."""
    reader, writer = await open_request(port, method, path, payload, host)
    raw = await reader.read()
    writer.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    return status, head.decode("latin-1"), body


async def get_json(
    port: int, path: str, host: str = "127.0.0.1",
) -> Tuple[int, Any]:
    """GET a JSON endpoint: returns (status, decoded body)."""
    status, _head, body = await request(port, "GET", path, host=host)
    return status, json.loads(body) if body else None


async def read_head(reader: asyncio.StreamReader) -> str:
    """Consume and return the response head (through the blank line)."""
    head = await reader.readuntil(b"\r\n\r\n")
    return head.decode("latin-1")


def parse_sse(body: bytes) -> List[Optional[Dict[str, Any]]]:
    """Parse a complete SSE body into chunk dicts, validating the framing:
    every event is a single ``data: `` line, events are blank-line
    separated, and the stream ends with ``data: [DONE]`` (not included in
    the returned list).  Raises ``AssertionError`` on violations — the
    framing contract is part of what the tests and the CI smoke check."""
    events = [e for e in body.decode().split("\n\n") if e.strip()]
    assert events, "empty SSE body"
    assert events[-1] == "data: [DONE]", f"missing [DONE]: {events[-1]!r}"
    for e in events:
        assert e.startswith("data: ") and "\n" not in e, f"bad SSE event {e!r}"
    return [json.loads(e[len("data: "):]) for e in events[:-1]]


async def sse_request(
    port: int, payload: Dict[str, Any], path: str = "/v1/completions",
    host: str = "127.0.0.1",
) -> Tuple[int, str, List[Dict[str, Any]]]:
    """POST a streaming completion and drain it: returns (status, response
    head, parsed chunks).  Non-200 responses return the error body parsed
    as no chunks (the JSON error stays in the head's connection)."""
    reader, writer = await open_request(
        port, "POST", path, dict(payload, stream=True), host
    )
    raw = await reader.read()
    writer.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    if status != 200:
        return status, head.decode("latin-1"), []
    return status, head.decode("latin-1"), parse_sse(body)


async def iter_sse(
    reader: asyncio.StreamReader,
) -> AsyncIterator[Dict[str, Any]]:
    """Yield SSE chunk dicts as they arrive (after ``read_head``); stops
    at ``data: [DONE]``.  For live consumers that act per token."""
    while True:
        event = (await reader.readuntil(b"\n\n")).decode().strip()
        if event == "data: [DONE]":
            return
        yield json.loads(event[len("data: "):])
