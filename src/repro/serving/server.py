"""Streaming HTTP completion server over ``AsyncEngine`` — stdlib only.

A dependency-free front-end on raw asyncio streams (no FastAPI/uvicorn in
the container): one long-lived accept loop, one coroutine per connection,
one ``AsyncEngine.generate`` iterator per completion.  Endpoints::

    POST /v1/completions   {"prompt": [ids...], "max_tokens": 32,
                            "stream": true, "temperature": 0.8,
                            "top_k": 0, "top_p": 0.9, "seed": 1,
                            "stop": ["7 "], "wait": true}
    GET  /healthz          liveness: {"status": "ok"}
    GET  /stats            AsyncEngine.stats(): queue depth, pool residency,
                           fused PAR telemetry, throughput counters — all
                           from ONE worker-published snapshot
    GET  /metrics          Prometheus text exposition of the engine's
                           MetricsRegistry (docs/OBSERVABILITY.md catalog)

``"stream": true`` answers with Server-Sent Events: one ``data:`` chunk per
token (id + detokenized text + running index), a final chunk carrying
``finish_reason``, then ``data: [DONE]``.  Non-streaming requests block and
return the whole completion as JSON.  In both cases the tokens are
bit-identical to a synchronous ``Engine.run()`` of the same (prompt,
SamplingParams) — the server only changes delivery, never sampling.

Service semantics:

* **client disconnect → abort** — every in-flight completion watches its
  socket; EOF (or a failed write) cancels the generator, which aborts the
  request on the engine's worker thread and returns its pool pages
  immediately.
* **backpressure** — admission beyond ``AsyncEngine.max_queued`` either
  awaits capacity (default) or, with ``"wait": false``, fails fast as
  HTTP 429.
* **errors** — malformed JSON / bad params are HTTP 400 with a JSON error
  body; unknown routes 404.

The protocol layer speaks minimal HTTP/1.1: requests are parsed from the
request line + headers + Content-Length body; responses close the
connection (``Connection: close``) so streamed bodies need no chunked
framing.  That is all a load balancer or the bench harness needs, and it
keeps the hot path free of framework overhead.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, Callable, Dict, Optional, Tuple

from repro.serving.api import SamplingParams, default_detokenize
from repro.serving.async_engine import AsyncEngine, QueueFullError

__all__ = ["CompletionServer", "main"]

_MAX_BODY_BYTES = 10 * 1024 * 1024


class _HTTPError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


_STATUS_TEXT = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 429: "Too Many Requests",
    500: "Internal Server Error",
}


def _response(status: int, body: bytes, content_type: str) -> bytes:
    return (
        f"HTTP/1.1 {status} {_STATUS_TEXT.get(status, 'Unknown')}\r\n"
        f"Content-Type: {content_type}\r\n"
        f"Content-Length: {len(body)}\r\n"
        "Connection: close\r\n\r\n"
    ).encode() + body


def _json_response(status: int, obj: Any) -> bytes:
    return _response(
        status, json.dumps(obj).encode(), "application/json"
    )


async def _read_request(
    reader: asyncio.StreamReader,
) -> Tuple[str, str, Dict[str, str], bytes]:
    """Parse one HTTP/1.1 request: (method, path, headers, body).

    Oversized headers surface as ``asyncio.LimitOverrunError`` from
    ``readuntil`` (the StreamReader's 64 KiB limit) — mapped to a 400 by
    the connection handler alongside the ``_HTTPError``s raised here."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    try:
        method, path, _version = lines[0].split(" ", 2)
    except ValueError:
        raise _HTTPError(400, f"malformed request line: {lines[0]!r}")
    headers: Dict[str, str] = {}
    for line in lines[1:]:
        if not line:
            continue
        name, _, value = line.partition(":")
        headers[name.strip().lower()] = value.strip()
    raw_len = headers.get("content-length", "0") or "0"
    try:
        length = int(raw_len)
    except ValueError:
        raise _HTTPError(400, f"bad Content-Length: {raw_len!r}")
    if not 0 <= length <= _MAX_BODY_BYTES:
        raise _HTTPError(400, f"bad body length {length}")
    body = await reader.readexactly(length) if length else b""
    return method, path, headers, body


def _parse_sampling(payload: Dict[str, Any]) -> SamplingParams:
    try:
        return SamplingParams(
            temperature=float(payload.get("temperature", 0.0)),
            top_k=int(payload.get("top_k", 0)),
            top_p=float(payload.get("top_p", 1.0)),
            seed=int(payload.get("seed", 0)),
            max_tokens=int(payload.get("max_tokens", 64)),
            # SamplingParams normalizes: bare string -> 1-tuple, list -> tuple
            stop=payload.get("stop", ()),
        )
    except (TypeError, ValueError) as e:
        raise _HTTPError(400, f"bad sampling params: {e}")


class CompletionServer:
    """The HTTP front-end: routes completions into an ``AsyncEngine``.

    ``start()`` binds the listening socket (``port=0`` picks a free port,
    exposed as ``.port`` — how the tests and the smoke script run
    side-effect-free); ``serve_forever()`` blocks in the accept loop;
    ``stop()`` closes the listener and the engine (aborting any in-flight
    requests)."""

    def __init__(
        self,
        async_engine: AsyncEngine,
        detokenize: Optional[Callable[[int], str]] = None,
    ):
        self.engine = async_engine
        self._detokenize = (
            detokenize if detokenize is not None
            else getattr(async_engine.engine, "_detokenize", default_detokenize)
        )
        self._server: Optional[asyncio.AbstractServer] = None
        self.requests_served = 0
        m = async_engine.metrics
        self._m_http = m.counter(
            "http_requests_total", "HTTP requests answered, by route/status",
            ("route", "status"),
        )
        self._m_429 = m.counter(
            "http_429_total",
            "Completions rejected with 429 (backpressure fail-fast)",
        )

    # -- lifecycle -----------------------------------------------------------

    async def start(self, host: str = "127.0.0.1", port: int = 8000) -> None:
        self._server = await asyncio.start_server(self._handle, host, port)

    @property
    def port(self) -> int:
        assert self._server is not None, "server not started"
        return self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        assert self._server is not None, "server not started"
        async with self._server:
            await self._server.serve_forever()

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        await self.engine.aclose()

    # -- connection handling -------------------------------------------------

    _ROUTES = (
        "/healthz", "/stats", "/metrics", "/debug/flight", "/v1/completions",
    )

    def _count(self, route: str, status: int) -> None:
        self._m_http.labels(route=route, status=str(status)).inc()
        if status == 429:
            self._m_429.inc()

    async def _handle(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        route = "unknown"
        try:
            try:
                try:
                    method, path, _headers, body = await _read_request(reader)
                except (asyncio.IncompleteReadError, ConnectionError):
                    return  # client went away before sending a full request
                except asyncio.LimitOverrunError:
                    raise _HTTPError(400, "headers too large")
                # /debug/flight takes a ?dump=1 query; strip it for routing
                bare = path.split("?", 1)[0]
                route = bare if bare in self._ROUTES else "other"
                self.requests_served += 1
                self.engine.tracer.instant(
                    "http", "request", cat="http", method=method, route=route
                )
                if bare == "/healthz" and method == "GET":
                    writer.write(_json_response(200, {"status": "ok"}))
                    self._count(route, 200)
                elif bare == "/stats" and method == "GET":
                    stats = self.engine.stats()
                    stats["requests_served"] = self.requests_served
                    writer.write(_json_response(200, stats))
                    self._count(route, 200)
                elif bare == "/metrics" and method == "GET":
                    # count BEFORE rendering so the scrape sees itself —
                    # Prometheus convention, and it keeps the series
                    # non-empty from the very first scrape
                    self._count(route, 200)
                    writer.write(_response(
                        200, self.engine.metrics.render().encode(),
                        "text/plain; version=0.0.4",
                    ))
                elif bare == "/debug/flight" and method == "GET":
                    dump = "dump=1" in (path.split("?", 1) + [""])[1]
                    writer.write(_json_response(
                        200, self.engine.flight_snapshot(dump=dump)
                    ))
                    self._count(route, 200)
                elif bare == "/v1/completions" and method == "POST":
                    await self._completion(reader, writer, body)
                    self._count(route, 200)
                elif bare in self._ROUTES:
                    raise _HTTPError(405, f"{method} not allowed on {bare}")
                else:
                    raise _HTTPError(404, f"no route for {path}")
            except _HTTPError as e:
                writer.write(_json_response(e.status, {"error": e.message}))
                self._count(route, e.status)
            except (ConnectionError, asyncio.CancelledError):
                raise
            except Exception as e:  # engine/worker failure: a real 500
                writer.write(_json_response(
                    500, {"error": f"{type(e).__name__}: {e}"}
                ))
                self._count(route, 500)
            await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    def _parse_completion(self, body: bytes):
        try:
            payload = json.loads(body or b"{}")
        except json.JSONDecodeError as e:
            raise _HTTPError(400, f"bad JSON body: {e}")
        if not isinstance(payload, dict):
            raise _HTTPError(400, "body must be a JSON object")
        prompt = payload.get("prompt")
        if (
            not isinstance(prompt, list) or len(prompt) < 2
            or not all(isinstance(t, int) for t in prompt)
        ):
            raise _HTTPError(
                400, "prompt must be a list of >= 2 token ids (ints)"
            )
        return prompt, _parse_sampling(payload), payload

    async def _completion(
        self,
        reader: asyncio.StreamReader,
        writer: asyncio.StreamWriter,
        body: bytes,
    ) -> None:
        prompt, sp, payload = self._parse_completion(body)
        wait = bool(payload.get("wait", True))
        stream = bool(payload.get("stream", False))
        try:
            agen = self.engine.generate(prompt, sp, wait=wait)
            if stream:
                await self._stream_sse(reader, writer, agen, prompt)
            else:
                await self._respond_whole(reader, writer, agen, prompt)
        except QueueFullError as e:
            raise _HTTPError(429, str(e))
        except ValueError as e:  # add_request validation (e.g. max_model_len)
            raise _HTTPError(400, str(e))

    # -- delivery ------------------------------------------------------------

    async def _watch_disconnect(self, reader: asyncio.StreamReader):
        """Resolves when the client hangs up (EOF on the request socket —
        completion requests send nothing after the body, so any EOF means
        the peer is gone).  Stray non-EOF bytes are drained and ignored.

        Deliberate trade-off: a client that half-closes its write side
        after the request (rare for SSE consumers) is treated as gone and
        its request aborted — the protocol here is one request per
        connection with the read side held open, and failing to abort on
        real disconnects would leak decode work, which is the worse
        error for a saturated accelerator."""
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                return

    _SSE_HEAD = (
        b"HTTP/1.1 200 OK\r\n"
        b"Content-Type: text/event-stream\r\n"
        b"Cache-Control: no-cache\r\n"
        b"Connection: close\r\n\r\n"
    )

    async def _stream_sse(self, reader, writer, agen, prompt) -> None:
        """SSE delivery.  The response head is written only once the FIRST
        output arrives: ``generate`` is a lazy async generator, so admission
        rejections (QueueFullError / validation) surface at the first
        ``__anext__`` and must still become proper 429/400 responses."""
        watcher = asyncio.ensure_future(self._watch_disconnect(reader))
        gen = agen.__aiter__()
        head_sent = False
        index = 0
        rid = None
        tracer = self.engine.tracer
        try:
            while True:
                nxt = asyncio.ensure_future(gen.__anext__())
                done, _ = await asyncio.wait(
                    {nxt, watcher}, return_when=asyncio.FIRST_COMPLETED
                )
                if nxt not in done:  # client disconnected mid-stream
                    nxt.cancel()
                    await asyncio.gather(nxt, return_exceptions=True)
                    tracer.instant("http", "disconnect", cat="http", rid=rid)
                    await gen.aclose()  # -> Engine.abort, pages freed
                    return
                try:
                    out = nxt.result()
                except StopAsyncIteration:
                    break
                except (ConnectionError, asyncio.CancelledError):
                    raise
                except Exception as e:
                    if head_sent:
                        # the SSE body is already underway: a second HTTP
                        # response would corrupt the stream — just drop
                        # the connection (the finally's aclose aborts)
                        return
                    if isinstance(e, QueueFullError):
                        raise _HTTPError(429, str(e))
                    if isinstance(e, ValueError):
                        raise _HTTPError(400, str(e))
                    raise _HTTPError(500, f"{type(e).__name__}: {e}")
                if not head_sent:
                    writer.write(self._SSE_HEAD)
                    head_sent = True
                rid = out.request_id
                finish_reason = out.outputs[0].finish_reason
                for i, tok in enumerate(out.new_token_ids):
                    is_final = (
                        out.finished and i == len(out.new_token_ids) - 1
                    )
                    chunk = {
                        "id": out.request_id,
                        "object": "completion.chunk",
                        "index": index,
                        "token": int(tok),
                        "text": self._detokenize(int(tok)),
                        "finish_reason": finish_reason if is_final else None,
                    }
                    writer.write(
                        b"data: " + json.dumps(chunk).encode() + b"\n\n"
                    )
                    index += 1
                if out.finished and not out.new_token_ids:
                    # stop-truncation can finish a request with an empty
                    # delta; the client still needs the finish_reason
                    writer.write(
                        b"data: " + json.dumps({
                            "id": out.request_id,
                            "object": "completion.chunk",
                            "index": index, "token": None, "text": "",
                            "finish_reason": finish_reason,
                        }).encode() + b"\n\n"
                    )
                await writer.drain()
            if not head_sent:
                writer.write(self._SSE_HEAD)
            writer.write(b"data: [DONE]\n\n")
            await writer.drain()
            tracer.instant(
                "http", "completion", cat="http", rid=rid, tokens=index
            )
        except (ConnectionError, OSError):
            pass  # failed write: the finally's aclose aborts the request
        finally:
            watcher.cancel()
            await asyncio.gather(watcher, return_exceptions=True)
            await gen.aclose()

    async def _respond_whole(self, reader, writer, agen, prompt) -> None:
        watcher = asyncio.ensure_future(self._watch_disconnect(reader))
        collect = asyncio.ensure_future(self._collect(agen))
        tracer = self.engine.tracer
        try:
            done, _ = await asyncio.wait(
                {collect, watcher}, return_when=asyncio.FIRST_COMPLETED
            )
            if collect not in done:  # disconnected while we were decoding
                collect.cancel()  # cancels generate() -> abort
                await asyncio.gather(collect, return_exceptions=True)
                tracer.instant("http", "disconnect", cat="http", rid=None)
                return
            rid, token_ids, finish_reason = collect.result()
            tracer.instant(
                "http", "completion", cat="http", rid=rid,
                tokens=len(token_ids),
            )
            writer.write(_json_response(200, {
                "id": rid,
                "object": "completion",
                "token_ids": token_ids,
                "text": "".join(self._detokenize(t) for t in token_ids),
                "finish_reason": finish_reason,
                "usage": {
                    "prompt_tokens": len(prompt),
                    "completion_tokens": len(token_ids),
                },
            }))
            await writer.drain()
        finally:
            watcher.cancel()
            await asyncio.gather(watcher, return_exceptions=True)

    @staticmethod
    async def _collect(agen):
        rid, token_ids, finish_reason = None, [], None
        async for out in agen:
            rid = out.request_id
            token_ids = [int(t) for t in out.token_ids]
            finish_reason = out.outputs[0].finish_reason
        return rid, token_ids, finish_reason


# ---------------------------------------------------------------------------
# CLI: serve the smoke-scale toy pair
# ---------------------------------------------------------------------------


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="serve the smoke-scale TLM/DLM pair over HTTP"
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-queued", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--par-mode", choices=["off", "wdos"], default="off")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests "
                         "(radix tree, copy-on-write; tokens stay "
                         "bit-identical to sharing off)")
    ap.add_argument("--tokenizer", default=None, metavar="VOCAB_JSON",
                    help="BPE vocab file (BPETokenizer.save) used to "
                         "detokenize streamed tokens; 'builtin' trains the "
                         "self-contained default vocab; omitted -> decimal "
                         "token ids")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Chrome-trace/Perfetto JSON timeline of "
                         "the whole serving session to PATH on shutdown")
    ap.add_argument("--trace-jsonl", default=None, metavar="PATH",
                    help="stream structured span/instant events to PATH as "
                         "JSONL while serving")
    args = ap.parse_args(argv)

    from repro.launch.serve import build_pair
    from repro.serving.engine import Engine
    from repro.serving.api import EngineConfig
    from repro.serving.tracing import Tracer

    tracer = None
    if args.trace_out or args.trace_jsonl:
        tracer = Tracer(jsonl_path=args.trace_jsonl)

    detokenize = None
    if args.tokenizer is not None:
        from repro.serving.tokenizer import BPETokenizer

        tok = (
            BPETokenizer.trained() if args.tokenizer == "builtin"
            else BPETokenizer.load(args.tokenizer)
        )
        detokenize = tok.piece

    print(f"building TLM/DLM pair (quantize={not args.no_quant}) ...")
    target, draft = build_pair(seed=0, s_max=256, quantize=not args.no_quant)
    engine = Engine(target, draft, EngineConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        par_mode=args.par_mode, prefix_cache=args.prefix_cache,
    ), trace=tracer, detokenize=detokenize)

    async def _run():
        server = CompletionServer(
            AsyncEngine(engine, max_queued=args.max_queued)
        )
        await server.start(args.host, args.port)
        print(f"listening on http://{args.host}:{server.port}  "
              "(POST /v1/completions, GET /healthz, GET /stats, "
              "GET /metrics)")
        try:
            await server.serve_forever()
        finally:
            await server.stop()

    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        pass
    finally:
        if tracer is not None:
            if args.trace_out:
                tracer.export(args.trace_out)
                print(f"trace written to {args.trace_out} "
                      "(load in https://ui.perfetto.dev)")
            tracer.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
