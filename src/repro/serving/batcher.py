"""Continuous-batching scheduler over the paged KV pools.

Admission/eviction works like vLLM's conservative policy: a QUEUED request is
admitted into a free batch slot only when BOTH pools (target + draft) can
reserve its worst-case page count (prompt + max_new_tokens + a full
draft/verify window), so an admitted request can never OOM mid-flight; a
FINISHED request releases its pages immediately, which un-blocks the queue —
the batch composition changes continuously, no global barrier.

Each decode round the batcher also builds a WDOS instruction DAG
(`core/scheduler.py`'s ``Queue``/``Instr``) for the work it just dispatched:
per request, DLM drafting is a RERAM-fed layer pipeline per draft token and
TLM verification an EMAC-fed pipeline depending on that request's last draft
— *different requests share no edges*, so the 4-queue out-of-order scheduler
overlaps request A's verify (EMAC+COMPUTE) with request B's drafting
(RERAM+COMPUTE).  That is the paper's Fig. 31.1.5 mechanism lifted from
intra-request (APSD PAR mode) to cross-request scheduling; the modeled
speedup vs. the in-order baseline is reported in the batch summary.

With ``par_mode="wdos"`` the overlap is no longer only priced — the engine
EXECUTES the mixed phase plans (core/scheduler.plan_mixed_slot) as fused
dispatches, and this module additionally accumulates the *measured*
fused-slot telemetry (``FusedTelemetry``: slot counts, per-role row
occupancy, wall seconds by slot kind, and the discrete-event pricing of the
exact slots that ran).  ``bench_serving.py`` reports the analytic model and
the measurement side by side so the model stays validated against reality.

Invariants this module owns: a request is admitted only when BOTH pools can
reserve its worst case (so an active request can never OOM mid-flight);
admission is head-of-line FIFO (a too-big head blocks the queue rather than
being overtaken); pages release at retirement, never mid-flight; and every
(slot, request) binding is stable from admission to retirement — the page
tables the engine uploads stay valid for the request's whole lifetime.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core import scheduler as sch
from repro.core.scheduler import MixedSlotPlan, Queue
from repro.serving.paged_cache import PagedKVPool, pages_for
from repro.serving.request import DraftController, Request, RequestState

__all__ = [
    "BatchConfig",
    "ContinuousBatcher",
    "WDOSModelStats",
    "FusedTelemetry",
]


@dataclasses.dataclass(frozen=True)
class BatchConfig:
    """Knobs for the DEPRECATED ``serve_batch`` wrapper.

    New code should drive ``serving.Engine`` with ``api.EngineConfig``
    (engine-wide knobs) + per-request ``api.SamplingParams`` — this type
    survives only so the legacy run-to-drain wrappers keep their exact
    signature.  ``ContinuousBatcher`` itself accepts either config (it only
    reads the scheduling fields both share)."""

    max_batch: int = 8  # concurrent DECODE slots (vmapped model batch)
    page_size: int = 16  # tokens per KV page
    max_tokens: int = 64  # per-request generation budget
    draft_len: int = 3  # fixed draft window (adaptive=False)
    adaptive: bool = False  # per-request APSD draft-length adaptation
    short_dl: int = 2
    long_dl: int = 6
    temperature: float = 0.0  # only greedy (0.0) is supported today
    num_pages: Optional[int] = None  # page budget per pool (None: fit max_batch)
    model_wdos: bool = True  # build the per-round WDOS DAG (stats)
    # "paged": device-resident pools, zero host K/V copies (the real path);
    # "host": legacy gather/scatter loop (serving/host_gather.py baseline)
    kv_path: str = "paged"

    @property
    def max_dl(self) -> int:
        return self.long_dl if self.adaptive else self.draft_len


@dataclasses.dataclass
class WDOSModelStats:
    """Accumulated discrete-event model of the dispatched rounds."""

    wdos_makespan: float = 0.0
    inorder_makespan: float = 0.0
    busy: Dict[Queue, float] = dataclasses.field(
        default_factory=lambda: {q: 0.0 for q in Queue}
    )

    @property
    def modeled_speedup(self) -> float:
        return self.inorder_makespan / self.wdos_makespan if self.wdos_makespan else 1.0

    def utilization(self, q: Queue) -> float:
        return self.busy[q] / self.wdos_makespan if self.wdos_makespan else 0.0


@dataclasses.dataclass
class FusedTelemetry:
    """Measured + modeled record of the fused PAR slots actually executed.

    ``slots`` counts every dispatched slot; ``fused_slots`` those where
    different requests' draft and verify work co-resided in one program
    (the cross-request overlap itself); ``draft_row_slots`` /
    ``verify_row_slots`` sum per-slot role occupancy.  Wall seconds are
    split by which program the slot dispatched — the draft-only micro-step
    vs the draft+verify fused program (``verify_wall_s`` counts every slot
    with a verify pass, whether or not a neighbour drafted alongside, so
    it is deliberately a superset of the ``fused_slots`` numerator) — so
    the bench can compare the measured serialized cost on this backend
    against what the WDOS pricing (accumulated into
    ``modeled_*_makespan`` from the very plans that ran) says decoupled
    queues would overlap."""

    slots: int = 0
    fused_slots: int = 0
    draft_row_slots: int = 0
    verify_row_slots: int = 0
    draft_only_wall_s: float = 0.0
    verify_wall_s: float = 0.0
    modeled_wdos_makespan: float = 0.0
    modeled_inorder_makespan: float = 0.0

    @property
    def occupancy(self) -> float:
        """Fraction of slots with true cross-request draft/verify overlap."""
        return self.fused_slots / self.slots if self.slots else 0.0

    @property
    def mean_rows_per_slot(self) -> float:
        busy = self.draft_row_slots + self.verify_row_slots
        return busy / self.slots if self.slots else 0.0

    @property
    def modeled_overlap_speedup(self) -> float:
        """What the 4-queue WDOS would save over in-order issue on the
        slots that actually ran (1.0 when nothing has been recorded)."""
        if not self.modeled_wdos_makespan:
            return 1.0
        return self.modeled_inorder_makespan / self.modeled_wdos_makespan

    def as_dict(self) -> Dict[str, float]:
        return {
            "slots": self.slots,
            "fused_slots": self.fused_slots,
            "occupancy": self.occupancy,
            "draft_row_slots": self.draft_row_slots,
            "verify_row_slots": self.verify_row_slots,
            "mean_rows_per_slot": self.mean_rows_per_slot,
            "draft_only_wall_s": self.draft_only_wall_s,
            "verify_wall_s": self.verify_wall_s,
            "modeled_overlap_speedup": self.modeled_overlap_speedup,
        }


class ContinuousBatcher:
    """Slot/queue bookkeeping + page-budget admission + WDOS round model."""

    def __init__(
        self,
        cfg,  # BatchConfig or api.EngineConfig (shared scheduling fields)
        t_pool: PagedKVPool,
        d_pool: PagedKVPool,
        t_layers: int,
        d_layers: int,
        t_costs: Tuple[float, float],  # (per-layer load, per-layer compute)
        d_costs: Tuple[float, float],
    ):
        self.cfg = cfg
        self.t_pool = t_pool
        self.d_pool = d_pool
        self.t_layers = t_layers
        self.d_layers = d_layers
        self.t_costs = t_costs
        self.d_costs = d_costs
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        self.step_count = 0
        self.rounds = 0
        self.admitted = 0
        # aggregate counters over retired requests — deliberately NOT a
        # list of Request objects: a long-lived server retires requests
        # forever, so per-request state must be droppable (Engine.
        # release_request) without losing the summary
        self.finished_count = 0
        self.finished_emitted = 0
        self.finished_drafted = 0
        self.finished_accepted = 0
        self.wdos = WDOSModelStats()
        self.fused = FusedTelemetry()

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.cfg.adaptive:
            req.controller = DraftController(self.cfg.short_dl, self.cfg.long_dl)
        else:
            req.controller = DraftController(self.cfg.draft_len, self.cfg.draft_len)
        self.queue.append(req)

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots FIFO while both pools can take the worst case.
        Returns the newly admitted (slot, request) pairs (they need prefill)."""
        out: List[Tuple[int, Request]] = []
        for slot in range(self.cfg.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            peak = req.peak_cache_len(self.cfg.max_dl)
            t_seq = self.t_pool.allocate_sequence(peak)
            if t_seq is None:
                break  # head-of-line: keep FIFO order, wait for pages
            d_seq = self.d_pool.allocate_sequence(peak)
            if d_seq is None:
                t_seq.release()
                break
            self.queue.popleft()
            req.t_seq, req.d_seq = t_seq, d_seq
            req.state = RequestState.PREFILL
            req.admitted_step = self.step_count
            self.slots[slot] = req
            self.admitted += 1
            out.append((slot, req))
        return out

    def active(self) -> List[Tuple[int, Request]]:
        return [
            (i, r)
            for i, r in enumerate(self.slots)
            if r is not None and r.state is RequestState.DECODE
        ]

    def _tally_finished(self, req: Request) -> None:
        self.finished_count += 1
        self.finished_emitted += len(req.out)
        self.finished_drafted += req.drafted
        self.finished_accepted += req.accepted

    def retire(self, slot: int, reason: str = "length") -> None:
        req = self.slots[slot]
        assert req is not None
        req.finish(self.step_count, reason=reason)
        self._tally_finished(req)
        self.slots[slot] = None

    def cancel_queued(self, rid: int) -> Optional[Request]:
        """Drop a not-yet-admitted request from the queue (Engine.abort).
        Returns the request (finished with reason "abort") or None.

        Scans a snapshot, not the live deque: the async front-end calls
        this on its worker thread while ``submit`` may append from the
        event-loop thread, and direct deque iteration raises on concurrent
        mutation.  ``list(deque)`` and ``deque.remove`` are single C-level
        operations (atomic under the GIL), so the snapshot-then-remove
        pair is safe; a request cannot leave the queue between the two
        except through this thread's own admit/cancel calls."""
        for req in list(self.queue):
            if req.rid == rid:
                self.queue.remove(req)
                req.finish(self.step_count, reason="abort")
                self._tally_finished(req)
                return req
        return None

    def slot_of(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                return i
        return None

    def all_done(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)

    # -- WDOS discrete-event model of one dispatched round ------------------

    def model_round(self, work: Sequence[Tuple[Request, int]]) -> None:
        """Price the round just executed: per request, `dl` chained DLM
        draft pipelines (RERAM loads) then one TLM verify pipeline (EMAC
        loads) depending on the request's final draft compute."""
        self.rounds += 1
        if not self.cfg.model_wdos or not work:
            return
        b = sch.new_builder()
        d_load, d_comp = self.d_costs
        t_load, t_comp = self.t_costs
        for req, dl in work:
            prev: Tuple[int, ...] = ()
            for j in range(dl):
                _, last = sch.layer_pipeline_instrs(
                    b, self.d_layers, Queue.RERAM, d_load, d_comp,
                    entry_deps=prev, tag=f"r{req.rid}.draft{j}",
                )
                prev = (last,)
            _, _ = sch.layer_pipeline_instrs(
                b, self.t_layers, Queue.EMAC, t_load, t_comp * (dl + 1),
                entry_deps=prev, tag=f"r{req.rid}.verify",
            )
        s = sch.wdos_schedule(b.instrs)
        base = sch.inorder_schedule(b.instrs)
        self.wdos.wdos_makespan += s.makespan
        self.wdos.inorder_makespan += base.makespan
        for q in Queue:
            self.wdos.busy[q] += s.busy[q]

    # -- fused PAR slot telemetry (par_mode="wdos") --------------------------

    def record_fused_slot(
        self, plan: MixedSlotPlan, wall_s: float, verify_width: int
    ) -> None:
        """Account one executed fused slot: measured wall time by slot kind
        plus the discrete-event pricing of exactly this plan (so the model
        and the measurement always describe the same schedule)."""
        self.fused.slots += 1
        self.fused.draft_row_slots += len(plan.draft_rows)
        self.fused.verify_row_slots += len(plan.verify_rows)
        if plan.fused:
            self.fused.fused_slots += 1
        if plan.verify_rows:
            self.fused.verify_wall_s += wall_s
        else:
            self.fused.draft_only_wall_s += wall_s
        if not self.cfg.model_wdos:
            return
        b = sch.new_builder()
        sch.mixed_slot_instrs(
            b, plan, self.t_layers, self.d_layers,
            self.t_costs, self.d_costs, verify_width,
        )
        if not b.instrs:
            return
        s = sch.wdos_schedule(b.instrs)
        base = sch.inorder_schedule(b.instrs)
        self.fused.modeled_wdos_makespan += s.makespan
        self.fused.modeled_inorder_makespan += base.makespan

    # -- reporting ----------------------------------------------------------

    def summary(self) -> Dict[str, object]:
        out = {
            "requests": self.finished_count,
            "rounds": self.rounds,
            "steps": self.step_count,
            "emitted": self.finished_emitted,
            "acceptance_rate": (
                self.finished_accepted / max(self.finished_drafted, 1)
            ),
            "target_pool": self.t_pool.stats(),
            "draft_pool": self.d_pool.stats(),
            "wdos_modeled_speedup": self.wdos.modeled_speedup,
            "wdos_utilization": {q.name: self.wdos.utilization(q) for q in Queue},
        }
        if self.fused.slots:
            out["fused"] = self.fused.as_dict()
        return out
