"""Continuous-batching scheduler over the paged KV pools.

Admission/eviction works like vLLM's conservative policy: a QUEUED request is
admitted into a free batch slot only when BOTH pools (target + draft) can
reserve its worst-case page count (prompt + max_new_tokens + a full
draft/verify window), so an admitted request can never OOM mid-flight; a
FINISHED request releases its pages immediately, which un-blocks the queue —
the batch composition changes continuously, no global barrier.

Each decode round the batcher also builds a WDOS instruction DAG
(`core/scheduler.py`'s ``Queue``/``Instr``) for the work it just dispatched:
per request, DLM drafting is a RERAM-fed layer pipeline per draft token and
TLM verification an EMAC-fed pipeline depending on that request's last draft
— *different requests share no edges*, so the 4-queue out-of-order scheduler
overlaps request A's verify (EMAC+COMPUTE) with request B's drafting
(RERAM+COMPUTE).  That is the paper's Fig. 31.1.5 mechanism lifted from
intra-request (APSD PAR mode) to cross-request scheduling; the modeled
speedup vs. the in-order baseline is reported in the batch summary.

With ``par_mode="wdos"`` the overlap is no longer only priced — the engine
EXECUTES the mixed phase plans (core/scheduler.plan_mixed_slot) as fused
dispatches, and this module accounts the *measured* fused-slot telemetry
into the shared ``MetricsRegistry`` (serving/observability.py): slot counts
by kind, per-role row occupancy, wall seconds by dispatched program, and
the discrete-event pricing of the exact slots that ran.  ``fused_summary()``
derives the classic report (occupancy, mean rows/slot, modeled overlap
speedup) from those counters, so ``bench_serving.py`` and the server's
``GET /metrics`` read the very same numbers.

Invariants this module owns: a request is admitted only when BOTH pools can
reserve its worst case (so an active request can never OOM mid-flight);
admission is head-of-line FIFO (a too-big head blocks the queue rather than
being overtaken); pages release at retirement, never mid-flight; and every
(slot, request) binding is stable from admission to retirement — the page
tables the engine uploads stay valid for the request's whole lifetime.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core import scheduler as sch
from repro.core.scheduler import MixedSlotPlan, Queue
from repro.serving.observability import MetricsRegistry
from repro.serving.paged_cache import PagedKVPool, pages_for
from repro.serving.request import DraftController, Request, RequestState

__all__ = [
    "BatchConfig",
    "ContinuousBatcher",
    "WDOSModelStats",
]


@dataclasses.dataclass(frozen=True)
class BatchConfig:
    """Knobs for the DEPRECATED ``serve_batch`` wrapper.

    New code should drive ``serving.Engine`` with ``api.EngineConfig``
    (engine-wide knobs) + per-request ``api.SamplingParams`` — this type
    survives only so the legacy run-to-drain wrappers keep their exact
    signature.  ``ContinuousBatcher`` itself accepts either config (it only
    reads the scheduling fields both share)."""

    max_batch: int = 8  # concurrent DECODE slots (vmapped model batch)
    page_size: int = 16  # tokens per KV page
    max_tokens: int = 64  # per-request generation budget
    draft_len: int = 3  # fixed draft window (adaptive=False)
    adaptive: bool = False  # per-request APSD draft-length adaptation
    short_dl: int = 2
    long_dl: int = 6
    temperature: float = 0.0  # only greedy (0.0) is supported today
    num_pages: Optional[int] = None  # page budget per pool (None: fit max_batch)
    model_wdos: bool = True  # build the per-round WDOS DAG (stats)
    # "paged": device-resident pools, zero host K/V copies (the real path);
    # "host": legacy gather/scatter loop (serving/host_gather.py baseline)
    kv_path: str = "paged"

    @property
    def max_dl(self) -> int:
        return self.long_dl if self.adaptive else self.draft_len


@dataclasses.dataclass
class WDOSModelStats:
    """Accumulated discrete-event model of the dispatched rounds."""

    wdos_makespan: float = 0.0
    inorder_makespan: float = 0.0
    busy: Dict[Queue, float] = dataclasses.field(
        default_factory=lambda: {q: 0.0 for q in Queue}
    )

    @property
    def modeled_speedup(self) -> float:
        return self.inorder_makespan / self.wdos_makespan if self.wdos_makespan else 1.0

    def utilization(self, q: Queue) -> float:
        return self.busy[q] / self.wdos_makespan if self.wdos_makespan else 0.0


class ContinuousBatcher:
    """Slot/queue bookkeeping + page-budget admission + WDOS round model."""

    def __init__(
        self,
        cfg,  # BatchConfig or api.EngineConfig (shared scheduling fields)
        t_pool: PagedKVPool,
        d_pool: PagedKVPool,
        t_layers: int,
        d_layers: int,
        t_costs: Tuple[float, float],  # (per-layer load, per-layer compute)
        d_costs: Tuple[float, float],
        metrics: Optional[MetricsRegistry] = None,
        prefix_cache=None,  # Optional[PrefixCache]: shared-prefix admission
    ):
        self.cfg = cfg
        self.t_pool = t_pool
        self.d_pool = d_pool
        self.prefix_cache = prefix_cache
        self.t_layers = t_layers
        self.d_layers = d_layers
        self.t_costs = t_costs
        self.d_costs = d_costs
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        self.step_count = 0
        self.rounds = 0
        self.admitted = 0
        # aggregate counters over retired requests — deliberately NOT a
        # list of Request objects: a long-lived server retires requests
        # forever, so per-request state must be droppable (Engine.
        # release_request) without losing the summary
        self.finished_count = 0
        self.finished_emitted = 0
        self.finished_drafted = 0
        self.finished_accepted = 0
        self.wdos = WDOSModelStats()
        # fused-PAR slot accounting lives in the shared registry (the same
        # series GET /metrics exports); fused_summary() derives the classic
        # report from these counters.
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._m_rounds = self.metrics.counter(
            "rounds_total", "Decode rounds dispatched"
        )
        self._m_finished = self.metrics.counter(
            "requests_finished_total",
            "Requests retired, by finish reason", ("reason",),
        )
        self._m_fused_slots = self.metrics.counter(
            "fused_slots_total",
            "Fused-PAR slots dispatched: kind=fused has cross-request "
            "draft+verify co-residency, verify_only / draft_only do not",
            ("kind",),
        )
        self._m_fused_rows = self.metrics.counter(
            "fused_rows_total",
            "Batch rows occupied across fused-PAR slots, by role",
            ("role",),
        )
        self._m_fused_wall = self.metrics.counter(
            "fused_wall_seconds_total",
            "Measured wall seconds by dispatched program: program=verify "
            "is any slot with a verify pass (fused or not), draft_only the "
            "pure draft micro-step",
            ("program",),
        )
        self._m_wdos_modeled = self.metrics.counter(
            "wdos_modeled_seconds_total",
            "Discrete-event makespan of the executed slots under each "
            "schedule (wdos 4-queue vs in-order issue)",
            ("schedule",),
        )

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.cfg.adaptive:
            req.controller = DraftController(self.cfg.short_dl, self.cfg.long_dl)
        else:
            req.controller = DraftController(self.cfg.draft_len, self.cfg.draft_len)
        self.queue.append(req)

    def _allocate_pair(self, peak: int, match):
        """One attempt at both pools' worst-case reservations, discounted
        by the prefix match's fully shared pages; (None, None) on failure
        with nothing leaked."""
        if match is not None:
            m = match.tokens_matched
            t_seq = self.t_pool.allocate_sequence(
                peak, shared_pages=match.shared_pages("target"), shared_tokens=m
            )
            if t_seq is None:
                return None, None
            d_seq = self.d_pool.allocate_sequence(
                peak, shared_pages=match.shared_pages("draft"), shared_tokens=m
            )
        else:
            t_seq = self.t_pool.allocate_sequence(peak)
            if t_seq is None:
                return None, None
            d_seq = self.d_pool.allocate_sequence(peak)
        if d_seq is None:
            t_seq.release()
            return None, None
        return t_seq, d_seq

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots FIFO while both pools can take the worst case.
        Returns the newly admitted (slot, request) pairs (they need prefill).

        With a prefix cache: the head request's longest cached prefix
        discounts its reservation (fully shared pages cost nothing), and
        under pool pressure admission evicts LRU zero-ref cached subtrees
        one at a time until the reservation fits or nothing evictable is
        left (then head-of-line stall, exactly as before)."""
        out: List[Tuple[int, Request]] = []
        for slot in range(self.cfg.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            # spec_window == max_dl for chain speculation; tree speculation
            # reserves tree_budget + 1 window positions instead (the fan-out
            # tree's sibling branches all land in the reserved tail, so a
            # mid-round abort frees them with the ordinary release path)
            peak = req.peak_cache_len(
                getattr(self.cfg, "spec_window", self.cfg.max_dl)
            )
            match = (
                self.prefix_cache.match(req.prompt, req.kv_kind)
                if self.prefix_cache is not None
                else None
            )
            while True:
                t_seq, d_seq = self._allocate_pair(peak, match)
                if t_seq is not None:
                    break
                if self.prefix_cache is None or self.prefix_cache.evict_one() == 0:
                    break  # head-of-line: keep FIFO order, wait for pages
                # eviction may have freed a node on the matched path (zero
                # node refs until acquire) — re-resolve against the tree as
                # it now stands before retrying the allocation
                match = self.prefix_cache.match(req.prompt, req.kv_kind)
            if t_seq is None:
                break
            if match is not None:
                self.prefix_cache.acquire(match)
                req.prefix_match = match
            self.queue.popleft()
            req.t_seq, req.d_seq = t_seq, d_seq
            req.state = RequestState.PREFILL
            req.admitted_step = self.step_count
            self.slots[slot] = req
            self.admitted += 1
            out.append((slot, req))
        return out

    def active(self) -> List[Tuple[int, Request]]:
        return [
            (i, r)
            for i, r in enumerate(self.slots)
            if r is not None and r.state is RequestState.DECODE
        ]

    def _tally_finished(self, req: Request) -> None:
        self.finished_count += 1
        self.finished_emitted += len(req.out)
        self.finished_drafted += req.drafted
        self.finished_accepted += req.accepted
        self._m_finished.labels(reason=req.finish_reason or "length").inc()

    def retire(self, slot: int, reason: str = "length") -> None:
        req = self.slots[slot]
        assert req is not None
        req.finish(self.step_count, reason=reason)
        # unpin the radix path AFTER the sequences released their page
        # references — finish/abort must never free a page another row
        # maps, and the pool's per-page refcount guarantees it
        if req.prefix_match is not None:
            self.prefix_cache.release(req.prefix_match)
            req.prefix_match = None
        self._tally_finished(req)
        self.slots[slot] = None

    def cancel_queued(self, rid: int) -> Optional[Request]:
        """Drop a not-yet-admitted request from the queue (Engine.abort).
        Returns the request (finished with reason "abort") or None.

        Scans a snapshot, not the live deque: the async front-end calls
        this on its worker thread while ``submit`` may append from the
        event-loop thread, and direct deque iteration raises on concurrent
        mutation.  ``list(deque)`` and ``deque.remove`` are single C-level
        operations (atomic under the GIL), so the snapshot-then-remove
        pair is safe; a request cannot leave the queue between the two
        except through this thread's own admit/cancel calls."""
        for req in list(self.queue):
            if req.rid == rid:
                self.queue.remove(req)
                req.finish(self.step_count, reason="abort")
                self._tally_finished(req)
                return req
        return None

    def slot_of(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                return i
        return None

    def all_done(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)

    # -- WDOS discrete-event model of one dispatched round ------------------

    def model_round(self, work: Sequence[Tuple[Request, int]]) -> None:
        """Price the round just executed: per request, `dl` chained DLM
        draft pipelines (RERAM loads) then one TLM verify pipeline (EMAC
        loads) depending on the request's final draft compute."""
        self.rounds += 1
        self._m_rounds.inc()
        if not self.cfg.model_wdos or not work:
            return
        b = sch.new_builder()
        d_load, d_comp = self.d_costs
        t_load, t_comp = self.t_costs
        for req, dl in work:
            prev: Tuple[int, ...] = ()
            for j in range(dl):
                _, last = sch.layer_pipeline_instrs(
                    b, self.d_layers, Queue.RERAM, d_load, d_comp,
                    entry_deps=prev, tag=f"r{req.rid}.draft{j}",
                )
                prev = (last,)
            _, _ = sch.layer_pipeline_instrs(
                b, self.t_layers, Queue.EMAC, t_load, t_comp * (dl + 1),
                entry_deps=prev, tag=f"r{req.rid}.verify",
            )
        s = sch.wdos_schedule(b.instrs)
        base = sch.inorder_schedule(b.instrs)
        self.wdos.wdos_makespan += s.makespan
        self.wdos.inorder_makespan += base.makespan
        for q in Queue:
            self.wdos.busy[q] += s.busy[q]

    # -- fused PAR slot telemetry (par_mode="wdos") --------------------------

    def record_fused_slot(
        self, plan: MixedSlotPlan, wall_s: float, verify_width: int,
        draft_width: int = 1,
    ) -> None:
        """Account one executed fused slot: measured wall time by slot kind
        plus the discrete-event pricing of exactly this plan (so the model
        and the measurement always describe the same schedule)."""
        kind = (
            "fused" if plan.fused
            else "verify_only" if plan.verify_rows
            else "draft_only"
        )
        self._m_fused_slots.labels(kind=kind).inc()
        if plan.draft_rows:
            self._m_fused_rows.labels(role="draft").inc(len(plan.draft_rows))
        if plan.verify_rows:
            self._m_fused_rows.labels(role="verify").inc(len(plan.verify_rows))
        # wall split is by dispatched PROGRAM, not by fused-ness: any slot
        # with a verify pass ran the draft+verify fused program
        program = "verify" if plan.verify_rows else "draft_only"
        self._m_fused_wall.labels(program=program).inc(wall_s)
        if not self.cfg.model_wdos:
            return
        b = sch.new_builder()
        sch.mixed_slot_instrs(
            b, plan, self.t_layers, self.d_layers,
            self.t_costs, self.d_costs, verify_width,
            draft_width=draft_width,
        )
        if not b.instrs:
            return
        s = sch.wdos_schedule(b.instrs)
        base = sch.inorder_schedule(b.instrs)
        self._m_wdos_modeled.labels(schedule="wdos").inc(s.makespan)
        self._m_wdos_modeled.labels(schedule="inorder").inc(base.makespan)

    # -- reporting ----------------------------------------------------------

    def fused_summary(self) -> Optional[Dict[str, float]]:
        """The classic fused-PAR report, derived from the registry counters
        (None until a fused slot has run).  Key set is the stable interface
        ``bench_serving`` and the CI trajectory files consume — identical
        to the retired FusedTelemetry.as_dict()."""
        slots_fam = self._m_fused_slots
        slots = slots_fam.total()
        if not slots:
            return None
        fused = slots_fam.value(kind="fused")
        d_rows = self._m_fused_rows.value(role="draft")
        v_rows = self._m_fused_rows.value(role="verify")
        modeled_wdos = self._m_wdos_modeled.value(schedule="wdos")
        modeled_inorder = self._m_wdos_modeled.value(schedule="inorder")
        return {
            "slots": int(slots),
            "fused_slots": int(fused),
            "occupancy": fused / slots,
            "draft_row_slots": int(d_rows),
            "verify_row_slots": int(v_rows),
            "mean_rows_per_slot": (d_rows + v_rows) / slots,
            "draft_only_wall_s": self._m_fused_wall.value(program="draft_only"),
            "verify_wall_s": self._m_fused_wall.value(program="verify"),
            "modeled_overlap_speedup": (
                modeled_inorder / modeled_wdos if modeled_wdos else 1.0
            ),
        }

    def summary(self) -> Dict[str, object]:
        out = {
            "requests": self.finished_count,
            "rounds": self.rounds,
            "steps": self.step_count,
            "emitted": self.finished_emitted,
            "acceptance_rate": (
                self.finished_accepted / max(self.finished_drafted, 1)
            ),
            "target_pool": self.t_pool.stats(),
            "draft_pool": self.d_pool.stats(),
            "wdos_modeled_speedup": self.wdos.modeled_speedup,
            "wdos_utilization": {q.name: self.wdos.utilization(q) for q in Queue},
        }
        fused = self.fused_summary()
        if fused is not None:
            out["fused"] = fused
        return out
