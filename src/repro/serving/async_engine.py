"""Async serving front-end: one asyncio generator per request over the
stepwise ``Engine``.

``AsyncEngine`` is the layer that turns the engine into a *service*: the
blocking ``Engine.step()`` loop runs on a dedicated background thread, and
each client coroutine consumes its own request through::

    aeng = AsyncEngine(engine, max_queued=32)
    async for out in aeng.generate(prompt, SamplingParams(max_tokens=32)):
        send(out.new_token_ids)          # RequestOutput, incremental

Three service behaviours the synchronous Engine cannot offer by itself:

* **Per-request streams under live arrival** — requests are submitted from
  any number of coroutines at any time; the worker thread keeps stepping
  whatever is active, and each committed ``RequestOutput`` is routed to its
  request's stream.  Tokens stay BIT-IDENTICAL to a solo ``Engine.run()``
  of the same (prompt, SamplingParams): the engine's per-request key
  streams and schedule-invariant commit rules guarantee that arrival
  interleaving changes only *when* work runs, never *what* it computes.
* **Cancellation → abort** — when a consumer's task is cancelled (or the
  generator is closed early, e.g. an HTTP client disconnects), the
  request's ``Engine.abort()`` runs on the worker thread and its pool
  pages return to the free list immediately, un-blocking queued admissions
  on the next step.
* **Backpressure** — a bounded admission gate: at most ``max_queued``
  requests may sit in the engine's QUEUED state.  Over-limit submits
  either await capacity (``wait=True``, the default) or fail fast with
  ``QueueFullError`` (``wait=False`` — the server maps this to HTTP 429).
  The permit releases when the request leaves QUEUED (admitted into a
  batch slot, or aborted while waiting), so the gate bounds *waiting*
  work, not concurrency.

Threading contract (single-writer): ``Engine.add_request`` touches only
host-side queues and is called from the event-loop thread; ``step()`` and
``abort()`` (which touch device pools and page tables) run exclusively on
the worker thread — aborts are routed to it as commands.  Outputs cross
back via ``loop.call_soon_threadsafe``, so stream consumers never see a
torn update.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import threading
from typing import Any, AsyncIterator, Deque, Dict, List, Optional

from repro.serving.api import RequestOutput, SamplingParams
from repro.serving.engine import Engine
from repro.serving.request import RequestState

__all__ = ["AsyncEngine", "QueueFullError"]


class QueueFullError(RuntimeError):
    """Raised by ``generate(wait=False)`` when the admission queue is at
    ``max_queued`` — the fail-fast half of the backpressure contract."""


_ABORTED = object()  # stream sentinel: request aborted, no final output
_CLOSED = object()  # stream sentinel: engine shut down


@dataclasses.dataclass
class _Stream:
    """Loop-side mailbox for one request's outputs."""

    queue: asyncio.Queue
    finished: bool = False


class AsyncEngine:
    """Async wrapper around ``Engine``: background step loop + per-request
    async iterators + bounded-admission backpressure.

    The wrapped engine must be used exclusively through this object once
    the worker starts.  Use as an async context manager (or call
    ``aclose()``) so the worker thread is joined deterministically::

        async with AsyncEngine(engine) as aeng:
            outs = [o async for o in aeng.generate(prompt, sp)]
    """

    def __init__(self, engine: Engine, *, max_queued: int = 16,
                 idle_poll_s: float = 0.02):
        if max_queued < 1:
            raise ValueError(f"max_queued must be >= 1, got {max_queued}")
        self.engine = engine
        self.max_queued = max_queued
        self._idle_poll_s = idle_poll_s
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._worker_error: Optional[BaseException] = None
        # loop-thread state
        self._streams: Dict[int, _Stream] = {}
        self._pending = 0  # submitted-but-not-yet-admitted (QUEUED) count
        self._waiters: Deque[asyncio.Future] = collections.deque()
        # worker-shared state (guarded by _lock)
        self._lock = threading.Lock()
        self._cmds: Deque[tuple] = collections.deque()
        self._awaiting_admission: set = set()
        self._wake = threading.Event()
        # published stats snapshot: built on whichever thread owns the
        # engine at the time (here, before the worker exists; afterwards
        # the worker republishes after each step) and swapped in with ONE
        # attribute assignment — atomic under the GIL, so stats() always
        # reads a complete, same-moment view
        self._snapshot: dict = engine.stats_snapshot()

    # -- lifecycle -----------------------------------------------------------

    def _ensure_started(self) -> None:
        if self._worker_error is not None:
            raise RuntimeError("AsyncEngine worker died") from self._worker_error
        if self._stopping:
            raise RuntimeError("AsyncEngine is closed")
        loop = asyncio.get_running_loop()
        if self._loop is None:
            self._loop = loop
            self._thread = threading.Thread(
                target=self._worker, name="async-engine-step", daemon=True
            )
            self._thread.start()
        elif self._loop is not loop:
            raise RuntimeError("AsyncEngine is bound to a different event loop")

    async def __aenter__(self) -> "AsyncEngine":
        self._ensure_started()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Abort every open request, stop the worker, join the thread."""
        if self._thread is None:
            self._stopping = True
            return
        for rid, stream in list(self._streams.items()):
            if not stream.finished:
                self._enqueue_cmd(("abort", rid))
        self._stopping = True
        self._wake.set()
        await asyncio.get_running_loop().run_in_executor(
            None, self._thread.join
        )

    # -- backpressure gate ---------------------------------------------------

    async def _acquire_slot(self, wait: bool) -> None:
        if self._pending < self.max_queued and not self._waiters:
            self._pending += 1
            return
        if not wait:
            raise QueueFullError(
                f"admission queue full ({self._pending}/{self.max_queued} "
                "queued requests)"
            )
        fut = self._loop.create_future()
        self._waiters.append(fut)
        try:
            await fut  # resolved by _release_slot with the permit pre-taken
        except asyncio.CancelledError:
            # NB: cancelling the awaiting task also cancels `fut`, so
            # fut.done() alone cannot distinguish "granted" from
            # "cancelled while waiting" — only a RESULT means the permit
            # was handed over (and must be returned).
            if fut.cancelled() or not fut.done():
                try:
                    self._waiters.remove(fut)  # never granted: withdraw
                except ValueError:
                    pass
            else:  # granted concurrently with the cancel
                self._release_slot()
            raise

    def _release_slot(self) -> None:
        """Loop-thread: a request left QUEUED — hand its permit onward."""
        self._pending -= 1
        while self._waiters:
            fut = self._waiters.popleft()
            if not fut.done():
                self._pending += 1
                fut.set_result(None)
                return

    def queue_depth(self) -> int:
        """Requests currently holding an admission permit (QUEUED)."""
        return self._pending

    # -- submission / consumption --------------------------------------------

    async def generate(
        self,
        prompt: Any,
        sampling_params: Optional[SamplingParams] = None,
        *,
        wait: bool = True,
    ) -> AsyncIterator[RequestOutput]:
        """Submit a prompt and stream its ``RequestOutput``s as rounds
        commit tokens.  The final output has ``finished=True``; its
        cumulative ``token_ids`` are bit-identical to a synchronous
        ``Engine.run()`` of the same (prompt, SamplingParams).

        Backpressure: when ``max_queued`` requests are already waiting for
        admission, ``wait=True`` suspends until a permit frees while
        ``wait=False`` raises ``QueueFullError`` immediately.

        Cancelling the consuming task (or closing the generator early)
        aborts the request on the worker thread: its pool pages are freed
        immediately and the stream ends."""
        self._ensure_started()
        await self._acquire_slot(wait)
        # re-check AFTER the (possibly long) permit wait: aclose() may have
        # stopped the worker meanwhile, and a submit landing after its exit
        # would hang on a stream nothing will ever feed.  Everything from
        # here to the stream registration below is synchronous on the loop
        # thread, so aclose() cannot interleave — a later aclose() sees the
        # registered stream and aborts it.
        if self._stopping or self._worker_error is not None:
            self._release_slot()
            self._ensure_started()  # raises the closed/died error
        try:
            # loop-thread submit: add_request only touches host-side queues
            # (the worker's step() pops from the same thread-safe deque)
            rid = self.engine.add_request(prompt, sampling_params)
        except Exception:
            self._release_slot()
            raise
        stream = _Stream(queue=asyncio.Queue())
        self._streams[rid] = stream
        with self._lock:
            self._awaiting_admission.add(rid)
        self._wake.set()
        try:
            while True:
                item = await stream.queue.get()
                if item is _ABORTED or item is _CLOSED:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
                if item.finished:
                    return
        finally:
            if not stream.finished:
                # consumer bailed (cancelled / early close / error): free
                # the request's pages right away
                stream.finished = True
                self._enqueue_cmd(("abort", rid))
            self._streams.pop(rid, None)
            # the stream is done either way: drop the engine-side Request
            # bookkeeping once the worker has retired it (a long-lived
            # server would otherwise accumulate every request ever served)
            self._enqueue_cmd(("release", rid))

    async def abort(self, request_id: int) -> None:
        """Abort a request by id (the disconnect path when the consumer
        cannot cancel the generator itself)."""
        self._ensure_started()
        self._enqueue_cmd(("abort", request_id))

    # -- stats ---------------------------------------------------------------

    def stats(self) -> dict:
        """JSON-safe service stats: queue/backpressure depth, slot and page
        residency, throughput counters, and the fused PAR telemetry when
        par_mode="wdos".

        Engine-side numbers come from ONE published snapshot
        (``Engine.stats_snapshot`` built on the worker thread after each
        step), so queue depth, active count, and pool residency describe
        the same moment — no separately-raced reads of a stepping engine.
        Only the loop-owned backpressure fields are added here."""
        out = dict(self._snapshot)
        out["pending_admission"] = self._pending
        out["max_queued"] = self.max_queued
        return out

    @property
    def metrics(self):
        """The engine's ``MetricsRegistry`` (what GET /metrics renders)."""
        return self.engine.metrics

    @property
    def tracer(self):
        """The engine's span tracer (NULL_TRACER unless one was passed)."""
        return self.engine.tracer

    def flight_snapshot(self, dump: bool = False) -> dict:
        """The engine's flight-recorder snapshot (GET /debug/flight).
        Safe to call from the loop thread while the worker steps: the
        recorder serializes reads against the worker's record() with its
        own lock, so the view is internally consistent."""
        return self.engine.flight_snapshot(dump=dump)

    # -- worker thread -------------------------------------------------------

    def _enqueue_cmd(self, cmd: tuple) -> None:
        with self._lock:
            self._cmds.append(cmd)
        self._wake.set()

    def _post(self, rid: int, item) -> None:
        """Loop-thread callback: route one item into its request's stream."""
        stream = self._streams.get(rid)
        if stream is None or stream.finished:
            return
        if item is _ABORTED or item is _CLOSED or isinstance(item, BaseException):
            stream.finished = True
        elif getattr(item, "finished", False):
            stream.finished = True
        stream.queue.put_nowait(item)

    def _worker(self) -> None:
        eng = self.engine
        loop = self._loop
        try:
            while True:
                with self._lock:
                    cmds = list(self._cmds)
                    self._cmds.clear()
                self._wake.clear()
                releases: List[int] = []
                posts: List[tuple] = []
                for cmd in cmds:
                    if cmd[0] == "abort":
                        rid = cmd[1]
                        if eng.abort(rid):
                            posts.append((rid, _ABORTED))
                    elif cmd[0] == "release":
                        releases.append(cmd[1])
                has_work = eng.has_unfinished()
                if has_work:
                    for out in eng.step():
                        posts.append((out.request_id, out))
                # always: an abort can release a QUEUED request's permit
                # even when no step ran
                self._check_admissions()
                # releases LAST: the permit bookkeeping above must still
                # see the Request before its record drops
                for rid in releases:
                    eng.release_request(rid)
                if has_work or cmds:
                    # republish the stats snapshot: single attribute
                    # assignment (atomic under the GIL), so a concurrent
                    # stats() sees either the old or the new complete view.
                    # Published BEFORE the outputs below so that by the
                    # time a consumer observes its stream finish/abort,
                    # stats() already reflects that state (freed pages,
                    # decremented active count)
                    self._snapshot = eng.stats_snapshot()
                for rid, item in posts:
                    loop.call_soon_threadsafe(self._post, rid, item)
                if not has_work:
                    if self._stopping:
                        break
                    # idle: sleep until a submit/abort/stop wakes us
                    self._wake.wait(timeout=self._idle_poll_s)
            loop.call_soon_threadsafe(self._close_streams)
        except BaseException as e:  # engine bug: fail every open stream
            self._worker_error = e
            loop.call_soon_threadsafe(self._close_streams, e)

    def _check_admissions(self) -> None:
        """Worker: release backpressure permits for requests that left
        QUEUED this step (admitted to a slot, or aborted while waiting)."""
        with self._lock:
            awaiting = list(self._awaiting_admission)
        released: List[int] = []
        for rid in awaiting:
            req = self.engine._requests.get(rid)
            # a missing record means the request already finished AND was
            # released — its permit must come back too
            if req is None or req.state is not RequestState.QUEUED:
                released.append(rid)
        if released:
            with self._lock:
                self._awaiting_admission.difference_update(released)
            for _ in released:
                self._loop.call_soon_threadsafe(self._release_slot)

    def _close_streams(self, error: Optional[BaseException] = None) -> None:
        for rid, stream in list(self._streams.items()):
            if not stream.finished:
                self._post(rid, error if error is not None else _CLOSED)
