"""Block-granular paged KV-cache pool (the vLLM idea, sized for SD serving).

One pool per model holds EVERY concurrent request's KV in fixed-size pages
(`page_size` tokens x all layers x kv heads x head dim); a request owns a
page table (ordered page list) + a token length.  This is what turns the
single-request serving path into a multi-tenant runtime:

* admission is a reservation against the free list (worst-case pages for
  prompt + max_new_tokens + draft window), so a request admitted by the
  batcher can never OOM mid-flight;
* speculative rewind is O(1): decrement the length and push whole pages that
  fell past the new high-water mark back onto the free list — the exact
  paged analogue of the dense cache's "reset the length" trick;
* release returns pages AND the unused tail of the reservation, so finished
  requests immediately make room for queued ones (continuous batching).

Two storage modes:

* ``alloc_storage=True`` (legacy / benchmark baseline): host-side numpy
  arrays (layer-stacked, ``(n_layers, num_pages, page_size, kv_heads,
  head_dim)``); a consumer gathers a request's pages into a dense view and
  scatters written spans back (``PagedSequence.append``/``gather_into``).
* ``alloc_storage=False`` (device-resident serving): this object is pure
  allocator/bookkeeper — KV bytes live in JAX device arrays built by
  ``device_pool_init`` and are written in place by the model forward
  (``models/layers.paged_attention_update``), so no per-round host copies
  exist.  Sequences then use ``ensure_backed``/``advance``/``rewind(...,
  release_pages=False)`` so their page tables stay stable while the data
  stays on device.

The Pallas ``kernels/paged_attn.py`` kernel attends *in place* through the
page table (no gather) — same page layout either way.

Invariants (what the engine's hot loop is allowed to assume):

* **Page-table lifetime stability** — in device-resident mode a sequence's
  pages are reserved at admission AND backed eagerly (``ensure_backed``),
  so ``pages`` never changes between admission and release: the engine
  uploads each request's table row once and reuses it for every dispatch
  of the request's lifetime, including whole fused-PAR steps.
* **Rewind bounds** — ``rewind(n)`` requires ``0 <= n <= length`` (both
  validated); with ``release_pages=False`` it is a pure O(1) length update
  that never touches pages or data.  Callers may transiently ``advance``
  up to the reservation's capacity (a draft/verify window past the
  committed prefix) before rewinding back — the admission-time reservation
  (prompt + max_new_tokens + max draft window) is exactly the high-water
  bound that makes this safe.
* **Stale slots are write-before-read** — data past ``length`` is garbage
  by contract; every consumer masks by length and every new write lands at
  ``length``-relative positions, so rewound windows are overwritten before
  they could ever be attended.
* **Scratch page** — the device arrays carry one extra page (index
  ``num_pages``) the allocator never hands out; inactive or role-masked
  batch rows write there (duplicate writes are harmless because nothing
  reads it).
* **Scale freshness (``kv_quant="int8"``)** — a quantized pool stores K/V
  as int8 plus a per-slot-per-head float32 scale, laid out page-granular
  exactly like the data (``(..., page, slot, kv_head, 1)``), so a page's
  scales travel with the page through the table.  A scale entry must never
  outlive the value it was computed for: every write path stores value and
  scale together (host ``append`` quantizes both in one call; the device
  scatter writes both in one dispatch), and ``rewind``/``release`` zero
  the scale entries of dropped positions so a reused page can never
  dequantize with a stale scale.
* **Shared read-only prefix pages (prefix cache)** — a page may be mapped
  by several sequences at once: ``allocate_sequence(shared_pages=...,
  shared_tokens=...)`` maps an existing prefix (refcounting each page)
  ahead of a discounted reservation, and ``_give_page`` only frees a page
  when its last reference drops — releasing one mapper can never free a
  page another row (or the radix tree) still maps.  A sequence never
  WRITES a shared page: full shared pages sit entirely below the prefix
  (writes start at ``length >= shared_tokens``), and the one page a write
  could land in — a partially-shared last block — is copy-on-write
  swapped for a private page first (``needs_cow``/``cow_last_shared``;
  the replacement is funded by the reservation, which never discounts the
  partial page).  Speculative rewind therefore stays confined to private
  pages by construction, and ``rewind`` additionally refuses to pop a
  shared page.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "PagedKVPool",
    "PagedSequence",
    "PoolStats",
    "bytes_per_token_for",
    "device_pool_init",
    "device_pool_store",
    "kv_quantize_np",
    "num_pages_for_bytes",
]

# "mixed" is allocator/stats-only: one page allocator backs BOTH a dense
# and an int8 device store (the engine picks a store per request), so host
# storage cannot be allocated in that mode and every page is accounted at
# the sum of both kinds' bytes.
KV_QUANT_MODES = ("none", "int8", "mixed")
_SCALE_BYTES = 4  # float32 per-slot-per-head scale


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)  # ceil div


def kv_quantize_np(span: np.ndarray):
    """Symmetric per-token-per-head int8 quantization (host mirror of
    ``models/layers._kv_quantize``): span (..., hd) -> (int8 values,
    float32 scales (..., 1))."""
    s = np.maximum(np.abs(span).max(axis=-1, keepdims=True), 1e-8) / 127.0
    s = s.astype(np.float32)
    q = np.clip(np.rint(span / s), -127, 127).astype(np.int8)
    return q, s


def bytes_per_token_for(
    n_layers: int,
    kv_heads: int,
    head_dim: int,
    dtype=np.float32,
    kv_quant: str = "none",
) -> Dict[str, int]:
    """K+V bytes one cached token occupies under each storage kind a pool of
    this geometry allocates.  This is derived from the ACTUAL device-store
    layout (``device_pool_store``): dense pages are ``2 * n_layers *
    kv_heads * head_dim`` elements of the model dtype; int8 pages store the
    same element count as int8 PLUS one float32 scale per (slot, kv head)
    per K and per V — the per-page scale arrays are first-class residency,
    not bookkeeping, so every byte gauge denominated in this unit includes
    them.  ``"mixed"`` pools back every page with BOTH storages and report
    both kinds."""
    if kv_quant not in KV_QUANT_MODES:
        raise ValueError(
            f"kv_quant must be one of {KV_QUANT_MODES}, got {kv_quant!r}"
        )
    base = 2 * n_layers * kv_heads  # K and V, every layer, every kv head
    dense = base * head_dim * np.dtype(dtype).itemsize
    quant = base * (head_dim * 1 + _SCALE_BYTES)  # int8 values + f32 scale
    if kv_quant == "none":
        return {np.dtype(dtype).name: dense}
    if kv_quant == "int8":
        return {"int8": quant}
    return {np.dtype(dtype).name: dense, "int8": quant}


def num_pages_for_bytes(
    byte_budget: int,
    n_layers: int,
    kv_heads: int,
    head_dim: int,
    page_size: int,
    dtype=np.float32,
    kv_quant: str = "none",
) -> int:
    """Pages a byte budget buys under a storage kind — the admission-side
    inverse of ``bytes_per_token_for``.  Feeding COMPRESSED bytes (not raw
    page counts) into pool sizing is what lets an int8 pool admit ~3.5x the
    resident requests of an fp32 pool at the same byte budget: the page
    count scales with the true bytes/page of the storage kind."""
    per_page = sum(
        bytes_per_token_for(n_layers, kv_heads, head_dim, dtype, kv_quant)
        .values()
    ) * page_size
    if byte_budget < per_page:
        raise ValueError(
            f"pool byte budget {byte_budget} is below one page "
            f"({per_page} bytes at page_size={page_size}, kv_quant={kv_quant!r})"
        )
    return byte_budget // per_page


@dataclasses.dataclass
class PoolStats:
    num_pages: int
    page_size: int
    used_pages: int
    reserved_pages: int  # reservation not yet backed by allocated pages
    free_pages: int  # physically free (some may be spoken for)
    available_pages: int  # free minus outstanding reservations
    high_water_pages: int
    kv_quant: str = "none"
    bytes_per_token: float = 0.0  # K+V bytes (incl. scales) per cached token
    kv_bytes_total: int = 0  # bytes resident in allocated pages right now
    # bytes resident per storage kind — page-granular, derived from the
    # device-store layout, so int8/mixed totals include the per-page f32
    # scale arrays (kv_bytes_total is exactly the sum of these)
    kv_bytes_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)
    shared_pages: int = 0  # pages mapped by more than one holder (ref > 1)

    @property
    def utilization(self) -> float:
        return self.used_pages / self.num_pages if self.num_pages else 0.0


class PagedKVPool:
    """Fixed-size page pool with a free-list allocator and reservations."""

    def __init__(
        self,
        n_layers: int,
        kv_heads: int,
        head_dim: int,
        num_pages: int,
        page_size: int,
        dtype=np.float32,
        alloc_storage: bool = True,
        kv_quant: str = "none",
    ):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        if kv_quant not in KV_QUANT_MODES:
            raise ValueError(
                f"kv_quant must be one of {KV_QUANT_MODES}, got {kv_quant!r}"
            )
        self.n_layers = n_layers
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.num_pages = num_pages
        self.page_size = page_size
        self.dtype = dtype
        self.kv_quant = kv_quant
        self.k_scale = None
        self.v_scale = None
        if alloc_storage:
            if kv_quant == "mixed":
                raise NotImplementedError(
                    "kv_quant='mixed' pools are allocator-only (the engine "
                    "keeps one device store per kind); host-mode storage "
                    "must pick 'none' or 'int8'"
                )
            shape = (n_layers, num_pages, page_size, kv_heads, head_dim)
            store_dt = np.int8 if kv_quant == "int8" else dtype
            self.k = np.zeros(shape, store_dt)
            self.v = np.zeros(shape, store_dt)
            if kv_quant == "int8":
                sshape = shape[:-1] + (1,)
                self.k_scale = np.zeros(sshape, np.float32)
                self.v_scale = np.zeros(sshape, np.float32)
        else:  # pure allocator: KV bytes live in a device pool
            self.k = None
            self.v = None
        # LIFO free list: recently released pages are reused first (warm)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._allocated: set = set()
        self._reserved_unbacked = 0
        self.high_water = 0
        # page refcounts for SHARED pages only (allocated pages default to
        # ref 1); a page frees when its last reference drops
        self._ref: Dict[int, int] = {}

    # -- accounting ---------------------------------------------------------

    @property
    def used_pages(self) -> int:
        return len(self._allocated)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available_pages(self) -> int:
        """Pages neither allocated nor promised to an admitted request."""
        return len(self._free) - self._reserved_unbacked

    def can_reserve(self, n_pages: int) -> bool:
        return n_pages <= self.available_pages

    def bytes_per_token_by_kind(self) -> Dict[str, int]:
        """K+V bytes one cached token occupies, split by storage kind
        (label value is the storage dtype name: the model dtype for dense
        pages, ``"int8"`` for compressed pages incl. their f32 scale).
        Dense/int8 pools have one entry; ``"mixed"`` pools back every page
        with BOTH storages and report both."""
        return bytes_per_token_for(
            self.n_layers, self.kv_heads, self.head_dim,
            self.dtype, self.kv_quant,
        )

    def bytes_per_token(self) -> int:
        """K+V bytes one cached token occupies, including scale overhead for
        quantized pools — the dtype-aware unit `kv_bytes_total` and the
        bench's residency A/B are denominated in.  (``"mixed"`` pools sum
        both storages: every page is backed dense AND int8.)"""
        return sum(self.bytes_per_token_by_kind().values())

    def bytes_per_page(self) -> int:
        return self.bytes_per_token() * self.page_size

    def stats(self) -> PoolStats:
        used_tokens = self.used_pages * self.page_size
        by_kind = {
            kind: bpt * used_tokens
            for kind, bpt in self.bytes_per_token_by_kind().items()
        }
        return PoolStats(
            num_pages=self.num_pages,
            page_size=self.page_size,
            used_pages=self.used_pages,
            reserved_pages=self._reserved_unbacked,
            free_pages=self.free_pages,
            available_pages=self.available_pages,
            high_water_pages=self.high_water,
            kv_quant=self.kv_quant,
            bytes_per_token=float(self.bytes_per_token()),
            kv_bytes_total=sum(by_kind.values()),
            kv_bytes_by_kind=by_kind,
            shared_pages=self.shared_page_count,
        )

    # -- shared-page refcounting (prefix cache) -------------------------------

    @property
    def shared_page_count(self) -> int:
        """Pages currently held by more than one reference (mapped by
        several sequences and/or pinned by the prefix-cache radix tree)."""
        return len(self._ref)

    def page_ref(self, page: int) -> int:
        """Reference count of `page` (0 when free, 1 for a sole owner)."""
        if page not in self._allocated:
            return 0
        return self._ref.get(page, 1)

    def incref_page(self, page: int) -> None:
        """Add a reference to an ALLOCATED page (map it into another
        sequence, or pin it in the prefix-cache tree).  Every reference is
        returned through ``_give_page``, which frees only the last one."""
        if page not in self._allocated:
            raise RuntimeError(f"incref of unallocated page {page}")
        self._ref[page] = self._ref.get(page, 1) + 1

    # -- sequence lifecycle -------------------------------------------------

    def allocate_sequence(
        self,
        max_tokens: int,
        shared_pages: Optional[Sequence[int]] = None,
        shared_tokens: int = 0,
    ) -> Optional["PagedSequence"]:
        """Reserve worst-case capacity for one request; None if it won't fit.

        `max_tokens` is the cache high-water mark (prompt + generation +
        draft/verify window), not just the prompt length.

        ``shared_pages``/``shared_tokens`` map an existing read-only prefix
        (prefix cache hit): the listed pages — covering exactly
        ``shared_tokens`` positions — are refcounted and become the front of
        the new sequence's page table, and the reservation is discounted by
        the number of FULLY shared pages.  A partially-shared last page is
        deliberately NOT discounted: its reservation slot funds the private
        copy ``cow_last_shared`` swaps in before the sequence's first write
        into that block."""
        capacity = pages_for(max_tokens, self.page_size)
        if capacity > self.num_pages:
            raise ValueError(
                f"request needs {capacity} pages > pool capacity "
                f"{self.num_pages}"
            )
        shared = list(shared_pages) if shared_pages else []
        if shared:
            if not 0 < shared_tokens <= max_tokens:
                raise ValueError(
                    f"shared_tokens {shared_tokens} out of (0, {max_tokens}]"
                )
            if pages_for(shared_tokens, self.page_size) != len(shared):
                raise ValueError(
                    f"{len(shared)} shared pages cover "
                    f"{pages_for(shared_tokens, self.page_size)} blocks, not "
                    f"shared_tokens={shared_tokens}"
                )
        elif shared_tokens:
            raise ValueError("shared_tokens without shared_pages")
        full_shared = shared_tokens // self.page_size
        need = capacity - full_shared
        if not self.can_reserve(need):
            return None
        for page in shared:
            self.incref_page(page)
        self._reserved_unbacked += need
        return PagedSequence(
            self, reservation=need,
            shared_pages=shared, shared_tokens=shared_tokens,
            capacity_pages=capacity,
        )

    # -- internal page ops (called by PagedSequence) ------------------------

    def _take_page(self) -> int:
        page = self._free.pop()
        self._allocated.add(page)
        self._reserved_unbacked -= 1
        self.high_water = max(self.high_water, self.used_pages)
        return page

    def _give_page(self, page: int, *, back_to_reservation: bool) -> None:
        if page not in self._allocated:
            raise RuntimeError(f"double-free of page {page}")
        ref = self._ref.get(page, 1)
        if ref > 1:
            # another sequence (or the prefix tree) still maps this page:
            # drop one reference, keep the page allocated.  A shared page
            # was never part of this holder's reservation, so it cannot
            # return to one.
            if back_to_reservation:
                raise RuntimeError(
                    f"shared page {page} cannot return to a reservation"
                )
            if ref == 2:
                del self._ref[page]
            else:
                self._ref[page] = ref - 1
            return
        self._allocated.remove(page)
        self._free.append(page)
        if back_to_reservation:
            self._reserved_unbacked += 1


class PagedSequence:
    """One request's page table + length over a shared PagedKVPool.

    A sequence may start life with a read-only SHARED PREFIX (prefix cache
    hit): ``pages[:n_shared]`` are refcounted pages owned jointly with other
    sequences and/or the prefix tree, covering ``shared_tokens`` committed
    positions, and ``length`` starts at ``shared_tokens``.  Shared pages are
    never written; when ``shared_tokens`` ends mid-page the holder must call
    ``cow_last_shared()`` before its first write (``append``/``advance``
    enforce this).  Rewind never reaches below ``shared_tokens``, so the
    speculative-rewind contract only ever touches private pages."""

    def __init__(
        self,
        pool: PagedKVPool,
        reservation: int,
        shared_pages: Sequence[int] = (),
        shared_tokens: int = 0,
        capacity_pages: Optional[int] = None,
    ):
        self.pool = pool
        self.pages: List[int] = list(shared_pages)
        self.length = shared_tokens
        self.reservation = reservation
        self.n_shared = len(self.pages)
        self.shared_tokens = shared_tokens
        self.capacity_pages = (
            capacity_pages if capacity_pages is not None else reservation
        )
        self.released = False

    # -- index helpers ------------------------------------------------------

    def _flat_index(self, start: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(page ids, in-page slots) for token positions [start, start+n)."""
        pos = np.arange(start, start + n)
        page_idx = pos // self.pool.page_size
        return np.asarray(self.pages, np.int64)[page_idx], pos % self.pool.page_size

    def flat_slots(self, positions) -> np.ndarray:
        """Flat pool-slot index (page * page_size + in-page slot) of each
        absolute token position — the device-store row a position occupies
        once pool arrays are viewed as (n_layers, P * page_size, ...).

        Positions must be backed (< len(pages) * page_size).  This is the
        public indexing the engine's tree-path compaction uses to copy
        accepted-branch KV into canonical chain order on device."""
        assert not self.released, "flat_slots on a released sequence"
        pos = np.asarray(positions, np.int64)
        ps = self.pool.page_size
        assert pos.size == 0 or (
            pos.min() >= 0 and pos.max() < len(self.pages) * ps
        ), (positions, len(self.pages))
        pages = np.asarray(self.pages, np.int64)[pos // ps]
        return pages * ps + pos % ps

    def _ensure_capacity(self, n_tokens: int) -> None:
        need = pages_for(n_tokens, self.pool.page_size)
        while len(self.pages) < need:
            if len(self.pages) >= self.capacity_pages:
                raise RuntimeError(
                    f"sequence exceeded its reservation-backed capacity of "
                    f"{self.capacity_pages} pages"
                )
            self.pages.append(self.pool._take_page())

    # -- shared-prefix / copy-on-write ---------------------------------------

    @property
    def owned_pages(self) -> int:
        """Pages this sequence owns privately (excludes the shared prefix)."""
        return len(self.pages) - self.n_shared

    @property
    def needs_cow(self) -> bool:
        """True while the write frontier sits inside a shared page: the
        prefix ends mid-block, so the first write would scatter into a page
        other holders read.  ``cow_last_shared()`` clears it."""
        return self.n_shared > 0 and self.length < self.n_shared * self.pool.page_size

    def cow_last_shared(self) -> Tuple[int, int]:
        """Swap the partially-shared last prefix page for a private copy.

        Funded by this sequence's reservation — allocation deliberately does
        not discount the partial block.  On host-storage pools the page
        contents (values AND scales) are copied here; storage-less pools
        return ``(src, dst)`` so the device-resident caller can mirror the
        copy in its jax stores before the next table upload.  The source
        page loses one reference."""
        assert not self.released, "cow on a released sequence"
        if not self.needs_cow:
            raise RuntimeError("cow_last_shared: no partially-shared page")
        if self.owned_pages >= self.reservation:
            raise RuntimeError("cow_last_shared: reservation exhausted")
        src = self.pages[self.n_shared - 1]
        dst = self.pool._take_page()
        if self.pool.k is not None:
            self.pool.k[:, dst] = self.pool.k[:, src]
            self.pool.v[:, dst] = self.pool.v[:, src]
            if self.pool.k_scale is not None:
                self.pool.k_scale[:, dst] = self.pool.k_scale[:, src]
                self.pool.v_scale[:, dst] = self.pool.v_scale[:, src]
        self.pages[self.n_shared - 1] = dst
        self.n_shared -= 1
        self.pool._give_page(src, back_to_reservation=False)
        return src, dst

    # -- data path ----------------------------------------------------------

    def append(self, k_span: np.ndarray, v_span: np.ndarray) -> None:
        """Write KV for token span [length, length+L) and advance length.

        k_span/v_span: (n_layers, L, kv_heads, head_dim)."""
        assert not self.released, "append on a released sequence"
        if self.pool.k is None:
            raise RuntimeError(
                "host append on a storage-less pool (device-resident mode); "
                "use advance() — data is written by the model forward"
            )
        l = k_span.shape[1]
        if l == 0:
            return
        if self.needs_cow:
            raise RuntimeError(
                "append into a partially-shared page; call cow_last_shared() first"
            )
        self._ensure_capacity(self.length + l)
        pg, slot = self._flat_index(self.length, l)
        if self.pool.kv_quant == "int8":
            kq, ks = kv_quantize_np(np.asarray(k_span, np.float32))
            vq, vs = kv_quantize_np(np.asarray(v_span, np.float32))
            # value and scale land together — a slot is never readable with
            # a scale from a previous tenant of the page
            self.pool.k[:, pg, slot] = kq
            self.pool.v[:, pg, slot] = vq
            self.pool.k_scale[:, pg, slot] = ks
            self.pool.v_scale[:, pg, slot] = vs
        else:
            self.pool.k[:, pg, slot] = k_span
            self.pool.v[:, pg, slot] = v_span
        self.length += l

    # -- device-resident bookkeeping (no host data path) --------------------

    def ensure_backed(self, n_tokens: int) -> None:
        """Eagerly back pages for `n_tokens` capacity (device-resident mode:
        backing everything at admission keeps the page table stable for the
        request's whole lifetime, so it uploads once, not per round).
        Admission already reserved the worst case, so this cannot fail for
        n_tokens within the reservation."""
        assert not self.released, "ensure_backed on a released sequence"
        self._ensure_capacity(n_tokens)

    def advance(self, n: int) -> None:
        """Advance length by n WITHOUT touching data — the device pool was
        already written in place by the model forward's paged scatter."""
        assert not self.released, "advance on a released sequence"
        if n < 0:
            raise ValueError(f"advance expects n >= 0, got {n}")
        if n > 0 and self.needs_cow:
            raise RuntimeError(
                "advance into a partially-shared page; call cow_last_shared() "
                "first (the device scatter would have written a shared page)"
            )
        self._ensure_capacity(self.length + n)
        self.length += n

    def gather_into(self, k_dst: np.ndarray, v_dst: np.ndarray) -> None:
        """Materialize the dense per-request view: dst (n_layers, S_pad, kvh,
        hd) receives the pages' contents at their token positions.  Slots
        beyond `length` are left as-is — every consumer masks by length."""
        assert not self.released
        if self.pool.k is None:
            raise RuntimeError(
                "host gather on a storage-less pool (device-resident mode)"
            )
        assert self.length <= k_dst.shape[1], (self.length, k_dst.shape)
        n = len(self.pages)
        if n == 0:
            return
        ps = self.pool.page_size
        pg = np.asarray(self.pages, np.int64)
        # the last page's tail may overhang a dst that is not a multiple of
        # page_size — clamp the copy (only junk slots past `length` drop)
        m = min(n * ps, k_dst.shape[1])
        span = self.pool.k[:, pg].reshape(self.pool.n_layers, n * ps, *k_dst.shape[2:])
        span_v = self.pool.v[:, pg].reshape(self.pool.n_layers, n * ps, *v_dst.shape[2:])
        if self.pool.kv_quant == "int8":
            sshape = (self.pool.n_layers, n * ps, self.pool.kv_heads, 1)
            ks = self.pool.k_scale[:, pg].reshape(sshape)
            vs = self.pool.v_scale[:, pg].reshape(sshape)
            k_dst[:, :m] = (span[:, :m].astype(np.float32) * ks[:, :m]).astype(
                k_dst.dtype
            )
            v_dst[:, :m] = (span_v[:, :m].astype(np.float32) * vs[:, :m]).astype(
                v_dst.dtype
            )
        else:
            k_dst[:, :m] = span[:, :m]
            v_dst[:, :m] = span_v[:, :m]

    def rewind(self, n: int, *, release_pages: bool = True) -> None:
        """Drop the last n tokens in O(pages dropped): adjust the length and
        return whole pages past the new high-water mark to the free list
        (into this sequence's reservation, so it may regrow).

        release_pages=False keeps every backed page (device-resident mode:
        the table must stay stable and the pages are reserved anyway), making
        speculative rewind a pure O(1) length update — mirroring the
        engine's `rewind` contract including its n >= 0 / over-rewind
        validation.

        On a quantized pool BOTH forms additionally zero the dropped
        positions' scale entries (the partially-rewound tail of the retained
        last page included): data past ``length`` is garbage by contract,
        but a scale is *metadata* — left stale it could pair with a later
        tenant's int8 values if a write path ever split value and scale.
        Zeroing makes the failure mode loud (dequantizes to 0) instead of
        silently plausible."""
        assert not self.released, "rewind on a released sequence"
        if n < 0:
            raise ValueError(f"rewind expects n >= 0, got {n}")
        if n > self.length:
            raise ValueError(f"over-rewind: length {self.length} < rewind {n}")
        if self.length - n < self.shared_tokens:
            raise ValueError(
                f"rewind below the shared prefix: {self.length - n} < "
                f"{self.shared_tokens} committed shared tokens"
            )
        old_length = self.length
        self.length -= n
        self._invalidate_scales(self.length, old_length)
        if not release_pages:
            return
        keep = max(pages_for(self.length, self.pool.page_size), self.n_shared)
        while len(self.pages) > keep:
            self.pool._give_page(self.pages.pop(), back_to_reservation=True)

    def _invalidate_scales(self, start: int, stop: int) -> None:
        """Zero host-side scale entries for token positions [start, stop)
        (clamped to backed pages) — no-op for unquantized or storage-less
        pools (the device scatter writes value+scale in one dispatch, so
        device pools have no stale-scale window to close)."""
        if self.pool.k_scale is None:
            return
        # never scribble on pages other holders still read: skip the shared
        # prefix and any privately-listed page the prefix tree pinned after
        # this sequence donated it (pool ref > 1)
        start = max(start, self.n_shared * self.pool.page_size)
        stop = min(stop, len(self.pages) * self.pool.page_size)
        if stop <= start:
            return
        pg, slot = self._flat_index(start, stop - start)
        sole = np.asarray([self.pool.page_ref(int(p)) <= 1 for p in pg])
        pg, slot = pg[sole], slot[sole]
        if len(pg) == 0:
            return
        self.pool.k_scale[:, pg, slot] = 0.0
        self.pool.v_scale[:, pg, slot] = 0.0

    def release(self) -> None:
        """Return every page reference and the unused reservation to the
        pool.  Shared pages (prefix hits, or private pages later donated to
        the prefix tree) only lose a reference here; a page is freed at its
        last reference, so releasing one row can never free a page another
        row still maps."""
        if self.released:
            raise RuntimeError("double release of PagedSequence")
        self._invalidate_scales(0, len(self.pages) * self.pool.page_size)
        owned = self.owned_pages
        for page in self.pages:
            self.pool._give_page(page, back_to_reservation=False)
        self.pool._reserved_unbacked -= self.reservation - owned
        self.pages = []
        self.length = 0
        self.n_shared = 0
        self.released = True


# ---------------------------------------------------------------------------
# Device-resident pool storage (functional, jit-compatible)
# ---------------------------------------------------------------------------


def device_pool_init(pool: PagedKVPool, dtype=None):
    """JAX-array KV storage for `pool`: ``(k, v)`` each of shape
    ``(n_layers, num_pages + 1, page_size, kv_heads, head_dim)``.

    One extra SCRATCH page (index ``pool.num_pages``, never handed out by
    the allocator) absorbs writes from inactive batch rows, whose page
    tables point every slot at it — their garbage lands where no request
    reads.  The arrays are pure values: the model forward scatters new
    tokens in (``models/layers.paged_attention_update``) and returns the
    updated pool; speculative rewind never touches them (stale slots are
    masked by length, then overwritten in place on the next append — the
    paged analogue of the dense cache's reset-the-length trick)."""
    import jax.numpy as jnp  # deferred: allocator stays importable sans jax

    dtype = dtype if dtype is not None else pool.dtype
    shape = (
        pool.n_layers,
        pool.num_pages + 1,
        pool.page_size,
        pool.kv_heads,
        pool.head_dim,
    )
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def device_pool_store(
    pool: PagedKVPool, dtype=None, kv_quant: Optional[str] = None
) -> Dict[str, "object"]:
    """Device storage for `pool` as a dict pytree the engine threads through
    its jitted steps: ``{"k", "v"}`` for dense pools, plus per-slot-per-head
    float32 ``{"k_scale", "v_scale"}`` arrays (``(..., kv_heads, 1)``) when
    the storage kind is ``"int8"`` — the pages stay int8 at rest and every
    consumer dequantizes at the point of use.  Scales carry the same scratch
    page as the data (index ``num_pages``).

    ``kv_quant`` overrides the pool's own mode per store — a ``"mixed"``
    pool (one allocator, two storages) builds one store per kind."""
    import jax.numpy as jnp  # deferred: allocator stays importable sans jax

    kind = kv_quant if kv_quant is not None else pool.kv_quant
    if kind == "mixed":
        raise ValueError(
            "a device store holds ONE storage kind; build one per kind "
            "with kv_quant='none' / 'int8'"
        )
    if kind == "int8":
        k, v = device_pool_init(pool, dtype=jnp.int8)
        sshape = k.shape[:-1] + (1,)
        return {
            "k": k,
            "v": v,
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    k, v = device_pool_init(pool, dtype=dtype)
    return {"k": k, "v": v}
