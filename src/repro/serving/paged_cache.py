"""Block-granular paged KV-cache pool (the vLLM idea, sized for SD serving).

One pool per model holds EVERY concurrent request's KV in fixed-size pages
(`page_size` tokens x all layers x kv heads x head dim); a request owns a
page table (ordered page list) + a token length.  This is what turns the
single-request serving path into a multi-tenant runtime:

* admission is a reservation against the free list (worst-case pages for
  prompt + max_new_tokens + draft window), so a request admitted by the
  batcher can never OOM mid-flight;
* speculative rewind is O(1): decrement the length and push whole pages that
  fell past the new high-water mark back onto the free list — the exact
  paged analogue of the dense cache's "reset the length" trick;
* release returns pages AND the unused tail of the reservation, so finished
  requests immediately make room for queued ones (continuous batching).

Two storage modes:

* ``alloc_storage=True`` (legacy / benchmark baseline): host-side numpy
  arrays (layer-stacked, ``(n_layers, num_pages, page_size, kv_heads,
  head_dim)``); a consumer gathers a request's pages into a dense view and
  scatters written spans back (``PagedSequence.append``/``gather_into``).
* ``alloc_storage=False`` (device-resident serving): this object is pure
  allocator/bookkeeper — KV bytes live in JAX device arrays built by
  ``device_pool_init`` and are written in place by the model forward
  (``models/layers.paged_attention_update``), so no per-round host copies
  exist.  Sequences then use ``ensure_backed``/``advance``/``rewind(...,
  release_pages=False)`` so their page tables stay stable while the data
  stays on device.

The Pallas ``kernels/paged_attn.py`` kernel attends *in place* through the
page table (no gather) — same page layout either way.

Invariants (what the engine's hot loop is allowed to assume):

* **Page-table lifetime stability** — in device-resident mode a sequence's
  pages are reserved at admission AND backed eagerly (``ensure_backed``),
  so ``pages`` never changes between admission and release: the engine
  uploads each request's table row once and reuses it for every dispatch
  of the request's lifetime, including whole fused-PAR steps.
* **Rewind bounds** — ``rewind(n)`` requires ``0 <= n <= length`` (both
  validated); with ``release_pages=False`` it is a pure O(1) length update
  that never touches pages or data.  Callers may transiently ``advance``
  up to the reservation's capacity (a draft/verify window past the
  committed prefix) before rewinding back — the admission-time reservation
  (prompt + max_new_tokens + max draft window) is exactly the high-water
  bound that makes this safe.
* **Stale slots are write-before-read** — data past ``length`` is garbage
  by contract; every consumer masks by length and every new write lands at
  ``length``-relative positions, so rewound windows are overwritten before
  they could ever be attended.
* **Scratch page** — the device arrays carry one extra page (index
  ``num_pages``) the allocator never hands out; inactive or role-masked
  batch rows write there (duplicate writes are harmless because nothing
  reads it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "PagedKVPool",
    "PagedSequence",
    "PoolStats",
    "device_pool_init",
]


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)  # ceil div


@dataclasses.dataclass
class PoolStats:
    num_pages: int
    page_size: int
    used_pages: int
    reserved_pages: int  # reservation not yet backed by allocated pages
    free_pages: int  # physically free (some may be spoken for)
    available_pages: int  # free minus outstanding reservations
    high_water_pages: int

    @property
    def utilization(self) -> float:
        return self.used_pages / self.num_pages if self.num_pages else 0.0


class PagedKVPool:
    """Fixed-size page pool with a free-list allocator and reservations."""

    def __init__(
        self,
        n_layers: int,
        kv_heads: int,
        head_dim: int,
        num_pages: int,
        page_size: int,
        dtype=np.float32,
        alloc_storage: bool = True,
    ):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.n_layers = n_layers
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.num_pages = num_pages
        self.page_size = page_size
        self.dtype = dtype
        if alloc_storage:
            shape = (n_layers, num_pages, page_size, kv_heads, head_dim)
            self.k = np.zeros(shape, dtype)
            self.v = np.zeros(shape, dtype)
        else:  # pure allocator: KV bytes live in a device pool
            self.k = None
            self.v = None
        # LIFO free list: recently released pages are reused first (warm)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._allocated: set = set()
        self._reserved_unbacked = 0
        self.high_water = 0

    # -- accounting ---------------------------------------------------------

    @property
    def used_pages(self) -> int:
        return len(self._allocated)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available_pages(self) -> int:
        """Pages neither allocated nor promised to an admitted request."""
        return len(self._free) - self._reserved_unbacked

    def can_reserve(self, n_pages: int) -> bool:
        return n_pages <= self.available_pages

    def stats(self) -> PoolStats:
        return PoolStats(
            num_pages=self.num_pages,
            page_size=self.page_size,
            used_pages=self.used_pages,
            reserved_pages=self._reserved_unbacked,
            free_pages=self.free_pages,
            available_pages=self.available_pages,
            high_water_pages=self.high_water,
        )

    # -- sequence lifecycle -------------------------------------------------

    def allocate_sequence(self, max_tokens: int) -> Optional["PagedSequence"]:
        """Reserve worst-case capacity for one request; None if it won't fit.

        `max_tokens` is the cache high-water mark (prompt + generation +
        draft/verify window), not just the prompt length."""
        need = pages_for(max_tokens, self.page_size)
        if need > self.num_pages:
            raise ValueError(
                f"request needs {need} pages > pool capacity {self.num_pages}"
            )
        if not self.can_reserve(need):
            return None
        self._reserved_unbacked += need
        return PagedSequence(self, reservation=need)

    # -- internal page ops (called by PagedSequence) ------------------------

    def _take_page(self) -> int:
        page = self._free.pop()
        self._allocated.add(page)
        self._reserved_unbacked -= 1
        self.high_water = max(self.high_water, self.used_pages)
        return page

    def _give_page(self, page: int, *, back_to_reservation: bool) -> None:
        if page not in self._allocated:
            raise RuntimeError(f"double-free of page {page}")
        self._allocated.remove(page)
        self._free.append(page)
        if back_to_reservation:
            self._reserved_unbacked += 1


class PagedSequence:
    """One request's page table + length over a shared PagedKVPool."""

    def __init__(self, pool: PagedKVPool, reservation: int):
        self.pool = pool
        self.pages: List[int] = []
        self.length = 0
        self.reservation = reservation
        self.released = False

    # -- index helpers ------------------------------------------------------

    def _flat_index(self, start: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(page ids, in-page slots) for token positions [start, start+n)."""
        pos = np.arange(start, start + n)
        page_idx = pos // self.pool.page_size
        return np.asarray(self.pages, np.int64)[page_idx], pos % self.pool.page_size

    def _ensure_capacity(self, n_tokens: int) -> None:
        need = pages_for(n_tokens, self.pool.page_size)
        while len(self.pages) < need:
            if len(self.pages) >= self.reservation:
                raise RuntimeError(
                    f"sequence exceeded its reservation of {self.reservation} pages"
                )
            self.pages.append(self.pool._take_page())

    # -- data path ----------------------------------------------------------

    def append(self, k_span: np.ndarray, v_span: np.ndarray) -> None:
        """Write KV for token span [length, length+L) and advance length.

        k_span/v_span: (n_layers, L, kv_heads, head_dim)."""
        assert not self.released, "append on a released sequence"
        if self.pool.k is None:
            raise RuntimeError(
                "host append on a storage-less pool (device-resident mode); "
                "use advance() — data is written by the model forward"
            )
        l = k_span.shape[1]
        if l == 0:
            return
        self._ensure_capacity(self.length + l)
        pg, slot = self._flat_index(self.length, l)
        self.pool.k[:, pg, slot] = k_span
        self.pool.v[:, pg, slot] = v_span
        self.length += l

    # -- device-resident bookkeeping (no host data path) --------------------

    def ensure_backed(self, n_tokens: int) -> None:
        """Eagerly back pages for `n_tokens` capacity (device-resident mode:
        backing everything at admission keeps the page table stable for the
        request's whole lifetime, so it uploads once, not per round).
        Admission already reserved the worst case, so this cannot fail for
        n_tokens within the reservation."""
        assert not self.released, "ensure_backed on a released sequence"
        self._ensure_capacity(n_tokens)

    def advance(self, n: int) -> None:
        """Advance length by n WITHOUT touching data — the device pool was
        already written in place by the model forward's paged scatter."""
        assert not self.released, "advance on a released sequence"
        if n < 0:
            raise ValueError(f"advance expects n >= 0, got {n}")
        self._ensure_capacity(self.length + n)
        self.length += n

    def gather_into(self, k_dst: np.ndarray, v_dst: np.ndarray) -> None:
        """Materialize the dense per-request view: dst (n_layers, S_pad, kvh,
        hd) receives the pages' contents at their token positions.  Slots
        beyond `length` are left as-is — every consumer masks by length."""
        assert not self.released
        if self.pool.k is None:
            raise RuntimeError(
                "host gather on a storage-less pool (device-resident mode)"
            )
        assert self.length <= k_dst.shape[1], (self.length, k_dst.shape)
        n = len(self.pages)
        if n == 0:
            return
        ps = self.pool.page_size
        pg = np.asarray(self.pages, np.int64)
        # the last page's tail may overhang a dst that is not a multiple of
        # page_size — clamp the copy (only junk slots past `length` drop)
        m = min(n * ps, k_dst.shape[1])
        span = self.pool.k[:, pg].reshape(self.pool.n_layers, n * ps, *k_dst.shape[2:])
        k_dst[:, :m] = span[:, :m]
        span_v = self.pool.v[:, pg].reshape(self.pool.n_layers, n * ps, *v_dst.shape[2:])
        v_dst[:, :m] = span_v[:, :m]

    def rewind(self, n: int, *, release_pages: bool = True) -> None:
        """Drop the last n tokens in O(pages dropped): adjust the length and
        return whole pages past the new high-water mark to the free list
        (into this sequence's reservation, so it may regrow).

        release_pages=False keeps every backed page (device-resident mode:
        the table must stay stable and the pages are reserved anyway), making
        speculative rewind a pure O(1) length update — mirroring the
        engine's `rewind` contract including its n >= 0 / over-rewind
        validation."""
        assert not self.released, "rewind on a released sequence"
        if n < 0:
            raise ValueError(f"rewind expects n >= 0, got {n}")
        if n > self.length:
            raise ValueError(f"over-rewind: length {self.length} < rewind {n}")
        self.length -= n
        if not release_pages:
            return
        keep = pages_for(self.length, self.pool.page_size)
        while len(self.pages) > keep:
            self.pool._give_page(self.pages.pop(), back_to_reservation=True)

    def release(self) -> None:
        """Return every page and the unused reservation to the pool."""
        if self.released:
            raise RuntimeError("double release of PagedSequence")
        for page in self.pages:
            self.pool._give_page(page, back_to_reservation=False)
        self.pool._reserved_unbacked -= self.reservation - len(self.pages)
        self.pages = []
        self.length = 0
        self.released = True


# ---------------------------------------------------------------------------
# Device-resident pool storage (functional, jit-compatible)
# ---------------------------------------------------------------------------


def device_pool_init(pool: PagedKVPool, dtype=None):
    """JAX-array KV storage for `pool`: ``(k, v)`` each of shape
    ``(n_layers, num_pages + 1, page_size, kv_heads, head_dim)``.

    One extra SCRATCH page (index ``pool.num_pages``, never handed out by
    the allocator) absorbs writes from inactive batch rows, whose page
    tables point every slot at it — their garbage lands where no request
    reads.  The arrays are pure values: the model forward scatters new
    tokens in (``models/layers.paged_attention_update``) and returns the
    updated pool; speculative rewind never touches them (stale slots are
    masked by length, then overwritten in place on the next append — the
    paged analogue of the dense cache's reset-the-length trick)."""
    import jax.numpy as jnp  # deferred: allocator stays importable sans jax

    dtype = dtype if dtype is not None else pool.dtype
    shape = (
        pool.n_layers,
        pool.num_pages + 1,
        pool.page_size,
        pool.kv_heads,
        pool.head_dim,
    )
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
