"""LEGACY host-gather serving loop — the benchmark baseline the paged
device-resident path replaced.

This is the pre-refactor ``serve_batch`` decode loop: the paged pools live
in host numpy, and every SD round each request's full dense KV view is
gathered pool -> host -> device before the vmapped step, then the written
span is copied back host-side (``np.asarray`` of the full K/V buffers).
That per-round O(S_max x B) host traffic is exactly the data-movement tax
the paper's ReRAM-on-logic stacking argues against; it is kept ONLY so
``benchmarks/bench_serving.py --kv-path host`` can measure the win of the
device-resident path (``serving/engine.py``), which keeps KV on device and
scatters/attends in place through the page table.

Outputs are bit-identical to the stepwise ``Engine``'s paged path (and to
the single-request reference drivers) for greedy decoding — same jitted
per-row programs, different data residency.  This loop predates the Engine
API and stays run-to-drain + greedy-only by design; it is reached through
the deprecated ``serve_batch`` wrapper with ``cfg.kv_path == "host"`` or
directly by ``benchmarks/bench_serving.py``.

This module is a deliberately FROZEN copy of the pre-refactor loop: it
shares only the engine's leaf helpers (pool sizing, accept rule, summary
shape) and keeps its own round loop verbatim, so future changes to the
live paged engine cannot silently alter the baseline being measured
against.  Parity with the paged path is asserted in
tests/test_serving_paged.py.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.batcher import BatchConfig, ContinuousBatcher
from repro.serving.paged_cache import PagedKVPool
from repro.serving.request import Request, RequestState

__all__ = ["serve_batch_host"]


def _make_batched_step(model):
    """jit(vmap) of one cache-extending forward: every active request is a
    batch row with its OWN cache length (positions, masking, and the KV
    write offset are per-row).  Returns full updated dense K/V views so the
    engine scatters only the written span back into the page pool."""

    @jax.jit
    def step(params, tokens, k, v, lengths):
        # tokens (B, L) int32; k/v (B, n_layers, 1, S_pad, kvh, hd); lengths (B,)
        def one(tok, kk, vv, ln):
            cache = {"length": ln, "attn": {"k": kk, "v": vv}}
            logits, nc = model._apply(params, tok[None, :], cache)
            return logits[0], nc["attn"]["k"], nc["attn"]["v"]

        return jax.vmap(one)(tokens, k, v, lengths)

    return step


class _PoolGather:
    """Reusable pinned host buffers for pool -> dense batched cache views."""

    def __init__(self, max_batch: int, pool: PagedKVPool, s_pad: int, dtype):
        shape = (max_batch, pool.n_layers, 1, s_pad, pool.kv_heads, pool.head_dim)
        self.k = np.zeros(shape, dtype)
        self.v = np.zeros(shape, dtype)
        self.lengths = np.zeros((max_batch,), np.int32)

    def load(self, rows):
        """rows: iterable of (slot index, PagedSequence)."""
        self.lengths[:] = 0
        for i, seq in rows:
            seq.gather_into(self.k[i, :, 0], self.v[i, :, 0])
            self.lengths[i] = seq.length
        return jnp.asarray(self.k), jnp.asarray(self.v), jnp.asarray(self.lengths)


def serve_batch_host(
    key: jax.Array,
    target,
    draft,
    prompts: Sequence[Any],
    cfg: BatchConfig,
    sinks: Optional[Sequence[Optional[Callable[[int], None]]]] = None,
) -> Tuple[List[jnp.ndarray], dict]:
    """The legacy host-gather loop (see module docstring).  Called through
    ``engine.serve_batch(..., cfg)`` with ``cfg.kv_path == "host"``."""
    from repro.core.speculative import LMInterface
    from repro.serving import engine as E

    del key
    if cfg.temperature != 0.0:
        raise NotImplementedError("serve_batch currently supports temperature=0.0")

    requests = [
        Request(
            rid=i,
            prompt=np.asarray(p).reshape(-1),
            max_new_tokens=cfg.max_tokens,
            sink=sinks[i] if sinks else None,
        )
        for i, p in enumerate(prompts)
    ]
    if not requests:
        return [], E._empty_summary(cfg)
    peaks = [r.peak_cache_len(cfg.max_dl) for r in requests]
    for model in (target, draft):
        if max(peaks) > model.s_max:
            raise ValueError(
                f"peak cache length {max(peaks)} exceeds s_max={model.s_max} "
                f"of {model.cfg.name}"
            )

    t_pool = E._pool_for(target, cfg, peaks)
    d_pool = E._pool_for(draft, cfg, peaks)
    batcher = ContinuousBatcher(
        cfg, t_pool, d_pool,
        t_layers=target.cfg.n_layers, d_layers=draft.cfg.n_layers,
        t_costs=E._wdos_costs(target.cfg), d_costs=E._wdos_costs(draft.cfg),
    )
    for r in requests:
        batcher.submit(r)

    t_iface, d_iface = E.make_interface(target), E.make_interface(draft)
    t_step, d_step = _make_batched_step(target), _make_batched_step(draft)
    t_gather = _PoolGather(
        cfg.max_batch, t_pool, target.s_max, E._np_dtype(target.cfg)
    )
    d_gather = _PoolGather(
        cfg.max_batch, d_pool, draft.s_max, E._np_dtype(draft.cfg)
    )
    kv_copy_s = 0.0  # cumulative host<->device K/V copy time (the tax)

    def _prefill_into(req: Request, iface: LMInterface, params, seq):
        # same jitted program as the single-request path => bitwise identical
        nonlocal kv_copy_s
        plen = req.prompt.shape[0]
        _, cache = iface.prefill(params, jnp.asarray(req.prompt[None, :-1]))
        t0 = time.perf_counter()
        k = np.asarray(cache["attn"]["k"])[:, 0]  # (n_layers, s_max, kvh, hd)
        v = np.asarray(cache["attn"]["v"])[:, 0]
        seq.append(k[:, : plen - 1], v[:, : plen - 1])
        kv_copy_s += time.perf_counter() - t0

    while not batcher.all_done():
        for _, req in batcher.admit():
            _prefill_into(req, t_iface, target.params, req.t_seq)
            _prefill_into(req, d_iface, draft.params, req.d_seq)
            req.state = RequestState.DECODE
        active = batcher.active()
        if not active:
            batcher.step_count += 1
            continue

        dls = {slot: req.controller.draft_len() for slot, req in active}
        round_dl = max(dls.values())

        # ---- draft phase: round_dl sampled steps + 1 straggler step, all
        # vmapped; the dense draft cache stays on device across the loop.
        t0 = time.perf_counter()
        dk, dv, d_len0 = d_gather.load((s, r.d_seq) for s, r in active)
        kv_copy_s += time.perf_counter() - t0
        cur = np.zeros((cfg.max_batch,), np.int32)
        for slot, req in active:
            cur[slot] = req.last_tok
        cur_dev = jnp.asarray(cur)
        draft_cols = []
        for j in range(round_dl + 1):
            logits, dk, dv = d_step(
                draft.params, cur_dev[:, None], dk, dv, d_len0 + j
            )
            if j < round_dl:
                cur_dev = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                draft_cols.append(cur_dev)
            # else: straggler — feeds d_{round_dl-1}, completing the cache for
            # fully-accepted rows; over-written rows rewind it away below.
        drafts = np.asarray(jnp.stack(draft_cols, axis=1))  # (B, round_dl)

        # ---- verify phase: one vmapped pass scoring [last_tok, drafts...]
        t0 = time.perf_counter()
        tk, tv, t_len0 = t_gather.load((s, r.t_seq) for s, r in active)
        kv_copy_s += time.perf_counter() - t0
        window = np.zeros((cfg.max_batch, round_dl + 1), np.int32)
        window[:, 0] = cur
        window[:, 1:] = drafts
        v_logits, tk, tv = t_step(
            target.params, jnp.asarray(window), tk, tv, t_len0
        )
        p_logits = np.asarray(v_logits)  # (B, round_dl+1, V)
        t0 = time.perf_counter()
        dk_host, dv_host = np.asarray(dk), np.asarray(dv)
        tk_host, tv_host = np.asarray(tk), np.asarray(tv)
        kv_copy_s += time.perf_counter() - t0

        # ---- per-request accept / commit / page maintenance
        work = []
        for slot, req in active:
            dl = dls[slot]
            new, n_acc = E._greedy_accept_host(drafts[slot], p_logits[slot], dl)
            req.commit(new)
            req.rounds += 1
            req.drafted += dl
            req.accepted += n_acc
            req.controller.observe(n_acc, dl)
            work.append((req, dl))
            # target wrote round_dl+1 positions at t_len0; keep n_acc + 1
            t0 = time.perf_counter()
            tpos = int(t_len0[slot])
            req.t_seq.append(
                tk_host[slot, :, 0, tpos : tpos + round_dl + 1],
                tv_host[slot, :, 0, tpos : tpos + round_dl + 1],
            )
            req.t_seq.rewind(round_dl - n_acc)
            # draft wrote round_dl+1 positions at d_len0 (incl. straggler);
            # the invariant cache == committed[:-1] keeps n_acc + 1 of them
            dpos = int(d_len0[slot])
            req.d_seq.append(
                dk_host[slot, :, 0, dpos : dpos + round_dl + 1],
                dv_host[slot, :, 0, dpos : dpos + round_dl + 1],
            )
            req.d_seq.rewind(round_dl - n_acc)
            kv_copy_s += time.perf_counter() - t0
        batcher.model_round(work)
        for slot, req in active:
            if req.done:
                batcher.retire(slot)
        batcher.step_count += 1

    outputs = [
        jnp.asarray(r.out[: r.max_new_tokens], jnp.int32) for r in requests
    ]
    summary = batcher.summary()
    summary["kv_path"] = "host"
    summary["kv_copy_s"] = kv_copy_s
    summary["table_upload_s"] = 0.0  # same schema as the paged path
    return outputs, summary
