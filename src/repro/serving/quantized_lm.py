"""W4A8 target-LM and BVQ draft-LM serving paths — the paper's technique as
a first-class feature (dense/GQA family: the TLM/DLM pairs are LLaMA-style).

QuaRot-style computational invariance with the LRU rotation:
  * RMSNorm scales fold into the following projections (g := 1); RMSNorm
    without scale commutes with any orthogonal R (||xR|| == ||x||).
  * The residual stream is rotated once, offline: embed <- embed @ R1,
    every in-projection W <- R1^T W, every out-projection W <- W @ R1,
    head <- R1^T head.  R1 = plan_rotation(d_model) — exactly orthogonal
    for every LRU scheme, so with bits=None this is EXACT (tested).
  * The down_proj input (the paper's worked example: LLaMA d_ff = 2^k * m)
    is rotated ONLINE by R2 = plan_rotation(d_ff) via the Pallas FWHT
    kernel, with R2^T folded into w_down offline.
  * All linears then quantize to INT4 weights / dynamic INT8 activations
    (kernels/w4a8_matmul.py).

The BVQ draft path compresses every linear into block codebooks + indices
(kernels/bvq_matmul.py) — the RS-PNM dataflow.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import bvq as bvq_mod
from repro.core import quantization as q
from repro.core import rotation as rot
from repro.kernels import ops
from repro.models import layers as L
from repro.models.common import Family, ModelConfig
from repro.models.lm import batch_axes_for

Params = Dict[str, Any]

__all__ = [
    "quantize_dense_lm",
    "apply_quantized_lm",
    "bvq_compress_lm",
    "apply_bvq_lm",
    "quantized_param_specs",
    "abstract_quantized",
]


# ---------------------------------------------------------------------------
# Offline transformation (rotation folding + quantization)
# ---------------------------------------------------------------------------


def _fold_norm_into(w: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Absorb an RMSNorm scale into the input side of a linear weight."""
    return w * g.reshape((-1,) + (1,) * (w.ndim - 1)).astype(w.dtype)


def _rot_in(w: jnp.ndarray, plan) -> jnp.ndarray:
    """W <- R^T W along the input (first) axis, any trailing shape."""
    shape = w.shape
    w2 = w.reshape(shape[0], -1)
    w2 = rot.rotate_weight_in(w2.astype(jnp.float32), plan)
    return w2.reshape(shape)


def _rot_out(w: jnp.ndarray, plan) -> jnp.ndarray:
    """W <- W R along the output (last) axis."""
    shape = w.shape
    w2 = w.reshape(-1, shape[-1]).astype(jnp.float32)
    w2 = rot.local_rotate(w2, plan)
    return w2.reshape(shape)


def _quant_pack(w: jnp.ndarray, bits: Optional[int]):
    """(K, N) -> packed int4 + scales, or passthrough when bits is None."""
    if bits is None:
        return {"w": w.astype(jnp.float32)}
    wq, sw = q.quantize_weight_int(w.astype(jnp.float32), bits=bits, axis=0)
    return {"packed": q.pack_int4(wq, axis=0), "sw": sw.reshape(1, -1)}


def quantize_dense_lm(
    params: Params, cfg: ModelConfig, bits: Optional[int] = 4, rotate: bool = True
) -> Params:
    """Transform bf16 dense-LM params into the W4A8 serving form.

    bits=None keeps float weights (validates rotation-folding exactness);
    rotate=False skips the LRU rotations (the no-rotation ablation the
    paper's perplexity table compares against)."""
    assert cfg.family in (Family.DENSE, Family.VLM), "W4A8 path: dense family"
    r1 = rot.plan_rotation(cfg.d_model) if rotate else None
    r2 = rot.plan_rotation(cfg.d_ff) if rotate else None
    d, h, kv, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, cfg.d_ff

    def fold_layer(lp: Params) -> Params:
        wq_ = _fold_norm_into(lp["attn"]["wq"], lp["ln1"]["g"]).reshape(d, h * hd)
        wk_ = _fold_norm_into(lp["attn"]["wk"], lp["ln1"]["g"]).reshape(d, kv * hd)
        wv_ = _fold_norm_into(lp["attn"]["wv"], lp["ln1"]["g"]).reshape(d, kv * hd)
        wo_ = lp["attn"]["wo"].reshape(h * hd, d)
        wg_ = _fold_norm_into(lp["mlp"]["w_gate"], lp["ln2"]["g"])
        wu_ = _fold_norm_into(lp["mlp"]["w_up"], lp["ln2"]["g"])
        wd_ = lp["mlp"]["w_down"]
        if rotate:
            wq_, wk_, wv_ = (_rot_in(w, r1) for w in (wq_, wk_, wv_))
            wg_, wu_ = _rot_in(wg_, r1), _rot_in(wu_, r1)
            wo_ = _rot_out(wo_, r1)
            wd_ = _rot_out(wd_, r1)
            wd_ = _rot_in(wd_, r2)  # online R2 rotates the d_ff activation
        return {
            "wq": _quant_pack(wq_, bits),
            "wk": _quant_pack(wk_, bits),
            "wv": _quant_pack(wv_, bits),
            "wo": _quant_pack(wo_, bits),
            "w_gate": _quant_pack(wg_, bits),
            "w_up": _quant_pack(wu_, bits),
            "w_down": _quant_pack(wd_, bits),
            "qk_extra": {
                k: lp["attn"][k] for k in ("q_norm", "k_norm") if k in lp["attn"]
            },
        }

    layers = jax.vmap(fold_layer)(params["layers"])
    embed = params["embed"]["tok"].astype(jnp.float32)
    head = _fold_norm_into(
        params["embed"]["head"], params["final_norm"]["g"]
    ).astype(jnp.float32)
    if rotate:
        embed = rot.local_rotate(embed, r1)  # (V, d): rotate output side
        head = _rot_in(head, r1)
    return {
        "embed": embed.astype(cfg.jdtype),
        "head": _quant_pack(head, bits),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# Quantized forward (decode/prefill/extend with cache)
# ---------------------------------------------------------------------------


def _qlinear(x: jnp.ndarray, qw: Params, use_pallas: bool) -> jnp.ndarray:
    if "w" in qw:  # float passthrough (bits=None)
        return x @ qw["w"].astype(x.dtype)
    return ops.w4a8_linear(x, qw["packed"], qw["sw"], use_pallas=use_pallas)


def _paged_attn(q_, k_, v_, kvs_, lengths, pctx):
    """One layer's paged attention: scatter the span into the pool slice,
    attend through the page table, return (att, new pool slices).  Pool
    slices carrying ``k_scale`` are compressed (int8 + per-slot scales) —
    the update quantizes on scatter and dequantizes at the consumer."""
    table, impl, tree_mask = pctx
    pc = L.PagedCache(
        k=kvs_["k"], v=kvs_["v"], page_table=table, length=lengths, impl=impl,
        k_scale=kvs_.get("k_scale"), v_scale=kvs_.get("v_scale"),
        tree_mask=tree_mask,
    )
    att, new_pools = L.paged_attention_update(q_, k_, v_, pc)
    return att, new_pools


def _norm_only(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_quantized_lm(
    qparams: Params,
    cfg: ModelConfig,
    mesh,
    tokens: jnp.ndarray,
    cache: Optional[Params] = None,
    rotate: bool = True,
    use_pallas: bool = False,
    last_logit_only: bool = False,
    paged_impl: str = "gather",
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """W4A8 serving forward (dense family).  Mirrors lm.apply_lm's dense
    path with quantized linears; scan over layers.  A cache carrying
    ``page_table`` is the device-resident paged pool (per-row lengths)."""
    tp = mesh.shape["model"] if mesh is not None else 1
    b, s = tokens.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    store = L.kv_store_heads(cfg, tp)
    r2 = rot.plan_rotation(cfg.d_ff) if rotate else None
    paged = cache is not None and "page_table" in cache
    offset, positions, pctx = L.forward_cache_ctx(cache, b, s, paged_impl)
    x = qparams["embed"][tokens].astype(cfg.jdtype)
    if mesh is not None:
        from repro.models.lm import batch_axes_for
        ba = batch_axes_for(mesh, b)
        x = jax.lax.with_sharding_constraint(x, P(ba, None, None))
    new_cache = dict(cache) if cache is not None else None

    def body(carry, xs):
        xc = carry
        p, kvs_ = xs
        z = _norm_only(xc)
        q_ = _qlinear(z, p["wq"], use_pallas).reshape(b, s, h, hd)
        k_ = _qlinear(z, p["wk"], use_pallas).reshape(b, s, kv, hd)
        v_ = _qlinear(z, p["wv"], use_pallas).reshape(b, s, kv, hd)
        if cfg.qk_norm:
            q_ = L._qk_head_norm(q_, p["qk_extra"]["q_norm"])
            k_ = L._qk_head_norm(k_, p["qk_extra"]["k_norm"])
        q_ = L.rope(q_, positions, cfg.rope_theta)
        k_ = L.rope(k_, positions, cfg.rope_theta)
        k_ = L._repeat_kv(k_, store)
        v_ = L._repeat_kv(v_, store)
        if pctx is not None:
            att, ys = _paged_attn(q_, k_, v_, kvs_, offset, pctx)
        elif kvs_ is not None and "k_scale" in kvs_:
            kq, ksc = L._kv_quantize(k_)
            vq, vsc = L._kv_quantize(v_)
            ck = jax.lax.dynamic_update_slice_in_dim(kvs_["k"], kq, offset, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kvs_["v"], vq, offset, axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(kvs_["k_scale"], ksc, offset, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(kvs_["v_scale"], vsc, offset, axis=1)
            if s == 1:
                att = L._decode_attention(q_, ck, cv, offset + 1,
                                          k_scale=cks, v_scale=cvs)
            else:
                att = L.flash_attention(
                    q_, L._kv_dequant(ck, cks, xc.dtype),
                    L._kv_dequant(cv, cvs, xc.dtype),
                    causal=True, q_offset=offset,
                )
            ys = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        elif kvs_ is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(kvs_["k"], k_, offset, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kvs_["v"], v_, offset, axis=1)
            if s == 1:
                att = L._decode_attention(q_, ck, cv, offset + 1)
            else:
                att = L.flash_attention(q_, ck, cv, causal=True, q_offset=offset)
            ys = {"k": ck, "v": cv}
        else:
            att = L.flash_attention(q_, k_, v_, causal=True)
            ys = None
        att = att.reshape(b, s, h * hd)
        xc = xc + _qlinear(att, p["wo"], use_pallas)
        z2 = _norm_only(xc)
        g_ = _qlinear(z2, p["w_gate"], use_pallas)
        u_ = _qlinear(z2, p["w_up"], use_pallas)
        hid = jax.nn.silu(g_.astype(jnp.float32)).astype(xc.dtype) * u_
        if rotate:  # the LRU's online stage (Pallas FWHT kernel)
            hid = ops.lru_rotate(hid, r2, use_pallas=use_pallas)
        xc = xc + _qlinear(hid, p["w_down"], use_pallas)
        return xc, ys

    if cache is not None:
        x, kv_out = jax.lax.scan(body, x, (qparams["layers"], cache["attn"]))
        new_cache["attn"] = kv_out
        new_cache["lengths" if paged else "length"] = offset + s
    else:
        x, _ = jax.lax.scan(lambda c, p: body(c, (p, None)), x, qparams["layers"])
    x = _norm_only(x)
    if last_logit_only:
        x = x[:, -1:, :]
    logits = _qlinear(x, qparams["head"], use_pallas)
    return logits, new_cache


# ---------------------------------------------------------------------------
# BVQ draft-LM path (RS-PNM dataflow)
# ---------------------------------------------------------------------------


def bvq_compress_lm(
    params: Params, cfg: ModelConfig, bcfg: bvq_mod.BVQConfig, key: jax.Array
) -> Params:
    """Compress every linear of a dense LM into BVQ codebooks + indices."""
    assert cfg.family in (Family.DENSE, Family.VLM)
    d, h, kv, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, cfg.d_ff

    def one(w, k):
        return bvq_mod.bvq_compress(w.astype(jnp.float32), bcfg, k)

    def fold_layer(lp: Params, k) -> Params:
        ks = jax.random.split(k, 7)
        return {
            "ln1": lp["ln1"],
            "ln2": lp["ln2"],
            "wq": one(lp["attn"]["wq"].reshape(d, h * hd), ks[0]),
            "wk": one(lp["attn"]["wk"].reshape(d, kv * hd), ks[1]),
            "wv": one(lp["attn"]["wv"].reshape(d, kv * hd), ks[2]),
            "wo": one(lp["attn"]["wo"].reshape(h * hd, d), ks[3]),
            "w_gate": one(lp["mlp"]["w_gate"], ks[4]),
            "w_up": one(lp["mlp"]["w_up"], ks[5]),
            "w_down": one(lp["mlp"]["w_down"], ks[6]),
            "qk_extra": {
                kk: lp["attn"][kk] for kk in ("q_norm", "k_norm") if kk in lp["attn"]
            },
        }

    keys = jax.random.split(key, cfg.n_layers)
    layers = jax.vmap(fold_layer)(params["layers"], keys)
    return {
        "embed": params["embed"]["tok"],
        "head": params["embed"]["head"],
        "final_norm": params["final_norm"],
        "layers": layers,
    }


def apply_bvq_lm(
    qparams: Params,
    cfg: ModelConfig,
    mesh,
    tokens: jnp.ndarray,
    cache: Optional[Params] = None,
    use_pallas: bool = False,
    last_logit_only: bool = False,
    paged_impl: str = "gather",
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """BVQ draft-LM forward: weights decoded from codebooks on the fly.
    A cache carrying ``page_table`` is the device-resident paged pool."""
    tp = mesh.shape["model"] if mesh is not None else 1
    b, s = tokens.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    store = L.kv_store_heads(cfg, tp)
    paged = cache is not None and "page_table" in cache
    offset, positions, pctx = L.forward_cache_ctx(cache, b, s, paged_impl)
    x = qparams["embed"][tokens].astype(cfg.jdtype)
    new_cache = dict(cache) if cache is not None else None

    def lin(xin, bw):
        return ops.bvq_linear(xin, bw, use_pallas=use_pallas)

    def body(carry, xs):
        xc = carry
        p, kvs_ = xs
        z = L.rmsnorm(p["ln1"], xc)
        q_ = lin(z, p["wq"]).reshape(b, s, h, hd).astype(xc.dtype)
        k_ = lin(z, p["wk"]).reshape(b, s, kv, hd).astype(xc.dtype)
        v_ = lin(z, p["wv"]).reshape(b, s, kv, hd).astype(xc.dtype)
        if cfg.qk_norm:
            q_ = L._qk_head_norm(q_, p["qk_extra"]["q_norm"])
            k_ = L._qk_head_norm(k_, p["qk_extra"]["k_norm"])
        q_ = L.rope(q_, positions, cfg.rope_theta)
        k_ = L.rope(k_, positions, cfg.rope_theta)
        k_ = L._repeat_kv(k_, store)
        v_ = L._repeat_kv(v_, store)
        if pctx is not None:
            att, ys = _paged_attn(q_, k_, v_, kvs_, offset, pctx)
        elif kvs_ is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(kvs_["k"], k_, offset, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kvs_["v"], v_, offset, axis=1)
            if s == 1:
                att = L._decode_attention(q_, ck, cv, offset + 1)
            else:
                att = L.flash_attention(q_, ck, cv, causal=True, q_offset=offset)
            ys = {"k": ck, "v": cv}
        else:
            att = L.flash_attention(q_, k_, v_, causal=True)
            ys = None
        att = att.reshape(b, s, h * hd)
        xc = xc + lin(att, p["wo"]).astype(xc.dtype)
        z2 = L.rmsnorm(p["ln2"], xc)
        g_ = lin(z2, p["w_gate"])
        u_ = lin(z2, p["w_up"]).astype(xc.dtype)
        hid = jax.nn.silu(g_.astype(jnp.float32)).astype(xc.dtype) * u_
        xc = xc + lin(hid, p["w_down"]).astype(xc.dtype)
        return xc, ys

    if cache is not None:
        x, kv_out = jax.lax.scan(body, x, (qparams["layers"], cache["attn"]))
        new_cache["attn"] = kv_out
        new_cache["lengths" if paged else "length"] = offset + s
    else:
        x, _ = jax.lax.scan(lambda c, p: body(c, (p, None)), x, qparams["layers"])
    x = L.rmsnorm(qparams["final_norm"], x)
    if last_logit_only:
        x = x[:, -1:, :]
    logits = x @ qparams["head"].astype(x.dtype)
    return logits, new_cache

# ---------------------------------------------------------------------------
# Sharding specs + abstract params (for the quantized-decode dry-run cells)
# ---------------------------------------------------------------------------


def quantized_param_specs(cfg: ModelConfig) -> Params:
    fs = "data" if cfg.fsdp else None

    def lin_in():  # packed (K/2, N): N = TP columns
        return {"packed": P(fs, "model"), "sw": P(None, "model")}

    def lin_out():  # packed (K/2, N): K = TP rows (heads / d_ff)
        return {"packed": P("model", fs), "sw": P(None, None)}

    layer = {
        "wq": lin_in(), "wk": lin_in(), "wv": lin_in(),
        "wo": lin_out(),
        "w_gate": lin_in(), "w_up": lin_in(),
        "w_down": lin_out(),
        "qk_extra": (
            {"q_norm": P(None), "k_norm": P(None)} if cfg.qk_norm else {}
        ),
    }
    stacked = jax.tree.map(
        lambda sp: P(*((None,) + tuple(sp))), layer,
        is_leaf=lambda sp: isinstance(sp, P),
    )
    return {
        "embed": P("model", fs),
        "head": {"packed": P(fs, "model"), "sw": P(None, "model")},
        "layers": stacked,
    }


def abstract_quantized(cfg: ModelConfig, tp: int):
    """ShapeDtypeStruct tree of the W4A8 params (no allocation)."""
    from repro.models.lm import init_lm

    def build(key):
        p, _ = init_lm(key, cfg, tp)
        return quantize_dense_lm(p, cfg, bits=4, rotate=True)

    return jax.eval_shape(build, jax.random.PRNGKey(0)), quantized_param_specs(cfg)

# ---------------------------------------------------------------------------
# W4A8 MoE serving path (beyond-paper: the technique applied to experts)
# ---------------------------------------------------------------------------


def _quant_pack_experts(w: jnp.ndarray):
    """(E, K, F) -> int4-packed along K + per-(expert, out) scales."""
    wq, sw = q.quantize_weight_int(w.astype(jnp.float32), bits=4, axis=1)
    return {"packed": q.pack_int4(wq, axis=1), "sw": sw}  # (E,K/2,F), (E,1,F)


def quantize_moe_lm(params: Params, cfg: ModelConfig) -> Params:
    """W4A8 transform for the MoE family: attention + expert FFNs packed
    int4; router stays f32 (tiny, accuracy-critical).  No rotation folding
    (MoE residual rotation interacts with the router input; the LRU online
    stage is unnecessary for byte reduction, which is what decode needs)."""
    assert cfg.family is Family.MOE
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd

    def fold_layer(lp: Params) -> Params:
        return {
            "ln1": lp["ln1"],
            "ln2": lp["ln2"],
            "wq": _quant_pack(lp["attn"]["wq"].reshape(d, h * hd), 4),
            "wk": _quant_pack(lp["attn"]["wk"].reshape(d, kv * hd), 4),
            "wv": _quant_pack(lp["attn"]["wv"].reshape(d, kv * hd), 4),
            "wo": _quant_pack(lp["attn"]["wo"].reshape(h * hd, d), 4),
            "router": lp["moe"]["router"],
            "w_gate": _quant_pack_experts(lp["moe"]["w_gate"]),
            "w_up": _quant_pack_experts(lp["moe"]["w_up"]),
            "w_down": _quant_pack_experts(lp["moe"]["w_down"]),
        }

    layers = jax.vmap(fold_layer)(params["layers"])
    return {
        "embed": params["embed"]["tok"],
        "head": _quant_pack(params["embed"]["head"].astype(jnp.float32), 4),
        "final_norm": params["final_norm"],
        "layers": layers,
    }


def quantized_moe_param_specs(cfg: ModelConfig) -> Params:
    fs = "data" if cfg.fsdp else None

    def lin_in():
        return {"packed": P(fs, "model"), "sw": P(None, "model")}

    def lin_out():
        return {"packed": P("model", fs), "sw": P(None, None)}

    def experts():
        return {"packed": P("model", fs, None), "sw": P("model", None, None)}

    layer = {
        "ln1": {"g": P(None)}, "ln2": {"g": P(None)},
        "wq": lin_in(), "wk": lin_in(), "wv": lin_in(), "wo": lin_out(),
        "router": P(None, None),
        "w_gate": experts(), "w_up": experts(), "w_down": experts(),
    }
    stacked = jax.tree.map(
        lambda sp: P(*((None,) + tuple(sp))), layer,
        is_leaf=lambda sp: isinstance(sp, P),
    )
    return {
        "embed": P("model", fs),
        "head": {"packed": P(fs, "model"), "sw": P(None, "model")},
        "final_norm": {"g": P(None)},
        "layers": stacked,
    }


def abstract_quantized_moe(cfg: ModelConfig, tp: int):
    from repro.models.lm import init_lm

    def build(key):
        p, _ = init_lm(key, cfg, tp)
        return quantize_moe_lm(p, cfg)

    return jax.eval_shape(build, jax.random.PRNGKey(0)), quantized_moe_param_specs(cfg)


def _moe_a2a_quant(layer: Params, x: jnp.ndarray, cfg: ModelConfig, mesh,
                   seq_sharded: bool) -> jnp.ndarray:
    """GShard a2a with int4-packed expert weights: tokens quantize to INT8
    per-token, expert GEMMs accumulate INT32, dequant fuses into the gated
    combine — the TFTE dataflow applied to experts."""
    from repro.models.layers import moe_ff_split, pick_batch_axes, _topk_gates

    tp = mesh.shape["model"]
    e = cfg.n_experts
    split = moe_ff_split(cfg, tp)
    e_loc = max(e // tp, 1)
    batch_axes = pick_batch_axes(mesh, x.shape[0])

    def local(x_loc, router, wg_p, wg_s, wu_p, wu_s, wd_p, wd_s):
        b_loc, s_loc, d = x_loc.shape
        t = x_loc.reshape(-1, d)
        n_tok = t.shape[0]
        cap = max(int(cfg.capacity_factor * n_tok * cfg.top_k / e), 4)
        logits = t.astype(jnp.float32) @ router
        gates, ids = _topk_gates(logits, cfg.top_k)
        flat_ids = ids.reshape(-1)
        flat_gates = gates.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(n_tok), cfg.top_k)
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        slot = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)
        keep = (slot >= 0) & (slot < cap)
        slot_c = jnp.clip(slot, 0, cap - 1)
        buf = jnp.zeros((e, cap, d), x_loc.dtype)
        buf = buf.at[flat_ids, slot_c].add(
            jnp.where(keep[:, None], t[flat_tok], 0.0).astype(x_loc.dtype)
        )
        if split > 1:
            buf = jnp.repeat(buf, split, axis=0)
        buf = buf.reshape(tp, e_loc, cap, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        recv = recv.reshape(e_loc, tp * cap, d)
        # INT8 tokens x INT4 experts, INT32 accumulation
        xq, sx = q.quantize_act_int8(recv)  # (e_loc, C, d) int8, (e_loc,C,1)
        wg = q.unpack_int4(wg_p, axis=1).astype(jnp.int32)  # (e_loc, d, f)
        wu = q.unpack_int4(wu_p, axis=1).astype(jnp.int32)
        g_acc = jnp.einsum("ecd,edf->ecf", xq.astype(jnp.int32), wg,
                           preferred_element_type=jnp.int32)
        u_acc = jnp.einsum("ecd,edf->ecf", xq.astype(jnp.int32), wu,
                           preferred_element_type=jnp.int32)
        g_out = g_acc.astype(jnp.float32) * sx * wg_s
        u_out = u_acc.astype(jnp.float32) * sx * wu_s
        hmid = jax.nn.silu(g_out) * u_out  # (e_loc, C, f) f32
        hq, sh = q.quantize_act_int8(hmid)
        wd = q.unpack_int4(wd_p, axis=1).astype(jnp.int32)  # (e_loc, f, d)
        y_acc = jnp.einsum("ecf,efd->ecd", hq.astype(jnp.int32), wd,
                           preferred_element_type=jnp.int32)
        y = (y_acc.astype(jnp.float32) * sh * wd_s).astype(x_loc.dtype)
        y = y.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0)
        back = back.reshape(e, split, cap, d).sum(axis=1)
        picked = back[flat_ids, slot_c]
        picked = jnp.where(keep[:, None], picked, 0.0)
        contrib = picked.astype(jnp.float32) * flat_gates[:, None]
        out = jnp.zeros((n_tok, d), jnp.float32).at[flat_tok].add(contrib)
        return out.astype(x_loc.dtype).reshape(b_loc, s_loc, d)

    from jax.experimental.shard_map import shard_map

    tok_spec = (
        P(batch_axes, "model", None) if seq_sharded else P(batch_axes, None, None)
    )
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=tok_spec,
        check_rep=False,
    )
    return fn(x, layer["router"],
              layer["w_gate"]["packed"], layer["w_gate"]["sw"],
              layer["w_up"]["packed"], layer["w_up"]["sw"],
              layer["w_down"]["packed"], layer["w_down"]["sw"])


def apply_quantized_moe_lm(
    qparams: Params,
    cfg: ModelConfig,
    mesh,
    tokens: jnp.ndarray,
    cache: Optional[Params] = None,
    use_pallas: bool = False,
    last_logit_only: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """W4A8 MoE decode/prefill: quantized attention + quantized a2a experts."""
    tp = mesh.shape["model"] if mesh is not None else 1
    b, s = tokens.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    store = L.kv_store_heads(cfg, tp)
    offset = cache["length"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(offset + jnp.arange(s)[None, :], (b, s))
    x = qparams["embed"][tokens].astype(cfg.jdtype)
    if mesh is not None:
        from repro.models.lm import batch_axes_for
        ba = batch_axes_for(mesh, b)
        x = jax.lax.with_sharding_constraint(x, P(ba, None, None))
    new_cache = dict(cache) if cache is not None else None

    def body(carry, xs):
        xc = carry
        p, kvs_ = xs
        z = L.rmsnorm(p["ln1"], xc)
        q_ = _qlinear(z, p["wq"], use_pallas).reshape(b, s, h, hd).astype(xc.dtype)
        k_ = _qlinear(z, p["wk"], use_pallas).reshape(b, s, kv, hd).astype(xc.dtype)
        v_ = _qlinear(z, p["wv"], use_pallas).reshape(b, s, kv, hd).astype(xc.dtype)
        q_ = L.rope(q_, positions, cfg.rope_theta)
        k_ = L.rope(k_, positions, cfg.rope_theta)
        k_ = L._repeat_kv(k_, store)
        v_ = L._repeat_kv(v_, store)
        if kvs_ is not None and "k_scale" in kvs_:
            kq, ksc = L._kv_quantize(k_)
            vq, vsc = L._kv_quantize(v_)
            ck = jax.lax.dynamic_update_slice_in_dim(kvs_["k"], kq, offset, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kvs_["v"], vq, offset, axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(kvs_["k_scale"], ksc, offset, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(kvs_["v_scale"], vsc, offset, axis=1)
            att = L._decode_attention(q_, ck, cv, offset + 1, k_scale=cks, v_scale=cvs) if s == 1 else L.flash_attention(q_, L._kv_dequant(ck, cks, xc.dtype), L._kv_dequant(cv, cvs, xc.dtype), causal=True, q_offset=offset)
            ys = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        elif kvs_ is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(kvs_["k"], k_, offset, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kvs_["v"], v_, offset, axis=1)
            att = L._decode_attention(q_, ck, cv, offset + 1) if s == 1 else L.flash_attention(q_, ck, cv, causal=True, q_offset=offset)
            ys = {"k": ck, "v": cv}
        else:
            att = L.flash_attention(q_, k_, v_, causal=True)
            ys = None
        att = att.reshape(b, s, h * hd)
        xc = xc + _qlinear(att, p["wo"], use_pallas).astype(xc.dtype)
        z2 = L.rmsnorm(p["ln2"], xc)
        if mesh is not None:
            f = _moe_a2a_quant(p, z2, cfg, mesh, seq_sharded=False)
        else:
            # single-device reference: dequantize experts, dense dispatch
            e = cfg.n_experts
            wg = (q.unpack_int4(p["w_gate"]["packed"], axis=1).astype(jnp.float32)
                  * p["w_gate"]["sw"])
            wu = (q.unpack_int4(p["w_up"]["packed"], axis=1).astype(jnp.float32)
                  * p["w_up"]["sw"])
            wd = (q.unpack_int4(p["w_down"]["packed"], axis=1).astype(jnp.float32)
                  * p["w_down"]["sw"])
            from repro.models.layers import moe_apply_dense
            f = moe_apply_dense(
                {"router": p["router"], "w_gate": wg.astype(xc.dtype),
                 "w_up": wu.astype(xc.dtype), "w_down": wd.astype(xc.dtype)},
                z2, cfg,
            )
        xc = xc + f.astype(xc.dtype)
        return xc, ys

    if cache is not None:
        x, kv_out = jax.lax.scan(body, x, (qparams["layers"], cache["attn"]))
        new_cache["attn"] = kv_out
        new_cache["length"] = offset + s
    else:
        x, _ = jax.lax.scan(lambda c, pp: body(c, (pp, None)), x, qparams["layers"])
    x = L.rmsnorm(qparams["final_norm"], x)
    if last_logit_only:
        x = x[:, -1:, :]
    logits = _qlinear(x, qparams["head"], use_pallas)
    return logits, new_cache
