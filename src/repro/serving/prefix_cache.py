"""Copy-on-write prefix cache: a refcounted radix tree over prompt tokens.

Production traffic is millions of users hitting a handful of system
prompts, so the dominant prefill cost is redundant: every request
recomputes KV for the same prefix.  This module caches prompt prefixes at
PAGE-BLOCK granularity — one radix-tree node per ``page_size``-token block
— and maps cache hits straight into new requests' page tables as
READ-ONLY SHARED PAGES (``PagedSequence`` shared-prefix support in
``paged_cache.py``), so the shared span's prefill is skipped entirely.

Structure and contracts:

* **One tree per KV storage kind.**  In a ``kv_quant="mixed"`` engine an
  int8 request's pages hold garbage in the dense store arrays (and vice
  versa), so pages are only shareable between requests of the same kind.
* **A node = one full block**, keyed by the block's token ids.  It holds
  one page id per pool role (``target``/``draft``) — pinned with a pool
  reference the tree owns until eviction — plus a host FP *mirror* of the
  block's dense KV per role.  The mirror is what makes sharing
  bit-identical under quantized storage: the engine seeds a dense cache
  with the FP prefix and runs the tail prefill as an ``extend``, which
  produces exactly the KV a full prefill would have (the quantized page
  bytes were themselves produced from this same dense KV).
* **Partial matches** (the prompt diverges mid-block, or the cached block
  covers more than ``plen - 1`` tokens) map the final page partially;
  the holding sequence must copy-on-write it before its first scatter
  (``PagedSequence.cow_last_shared``), so the shared original is never
  written.
* **Refcounts at two levels.**  ``_Node.ref`` counts live *requests*
  currently matched through the node (acquire/release from the batcher);
  ``PagedKVPool`` refcounts the *pages* (tree pin + every mapping
  sequence).  Donating requests do NOT hold node refs — evicting a node
  whose donor still runs merely drops the tree's page reference.
* **Eviction is LRU over zero-ref leaves** whose pages would actually
  free (pool refcount 1, i.e. only the tree holds them), driven by the
  batcher's admission retry loop under pool pressure.

Everything here is host-side bookkeeping: no jax imports, O(blocks) dict
walks per admission, nothing on the per-token path.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .paged_cache import PagedKVPool

__all__ = ["PrefixCache", "PrefixMatch"]


@dataclasses.dataclass
class _Node:
    """One full prompt block: its tokens, one pinned page per pool role,
    and a host FP mirror of the block's dense KV per role."""

    key: bytes
    tokens: np.ndarray  # int32 (page_size,)
    pages: Dict[str, int]
    mirrors: Dict[str, Tuple[np.ndarray, np.ndarray]]  # role -> (k, v)
    parent: Optional["_Node"]
    children: Dict[bytes, "_Node"] = dataclasses.field(default_factory=dict)
    ref: int = 0  # live requests matched through this node
    tick: int = 0  # LRU clock (monotone counter, not wall time)


class PrefixMatch:
    """The longest cached prefix for one prompt: the node path, how many
    tokens it covers (capped at ``plen - 1``), and accessors for the pages
    to map and the dense-KV seed for the tail prefill."""

    def __init__(self, kind: str, nodes: List[_Node], tokens_matched: int):
        self.kind = kind
        self.nodes = nodes
        self.tokens_matched = tokens_matched

    @property
    def partial(self) -> bool:
        """True when the final page is only partially covered — the mapping
        sequence will copy-on-write it before its first write."""
        ps = len(self.nodes[0].tokens)
        return self.tokens_matched % ps != 0

    def shared_pages(self, role: str) -> List[int]:
        return [n.pages[role] for n in self.nodes]

    def prefix_kv(self, role: str) -> Tuple[np.ndarray, np.ndarray]:
        """Dense (k, v) for the matched prefix, shape (L, m, kvh, hd) —
        the seed for running the unshared tail as a dense ``extend``."""
        m = self.tokens_matched
        k = np.concatenate([n.mirrors[role][0] for n in self.nodes], axis=1)
        v = np.concatenate([n.mirrors[role][1] for n in self.nodes], axis=1)
        return k[:, :m], v[:, :m]


def _lcp(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the longest common prefix of two int token arrays."""
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class PrefixCache:
    """Radix tree of prompt blocks -> shared pages, one tree per KV kind.

    ``pools`` maps pool roles (``"target"``/``"draft"``) to the
    ``PagedKVPool`` whose pages the corresponding role's nodes pin; every
    node carries one page per role so a hit discounts BOTH pools'
    prefills."""

    def __init__(self, pools: Dict[str, PagedKVPool], page_size: int):
        self.pools = dict(pools)
        self.page_size = page_size
        self._roots: Dict[str, _Node] = {}
        self._clock = itertools.count(1)
        # counters for /metrics and the bench harness
        self.lookups = 0
        self.hits = 0
        self.tokens_saved = 0
        self.node_count = 0
        self.evictions = 0
        self.cow_copies = 0

    # -- lookup ---------------------------------------------------------------

    def _root(self, kind: str) -> _Node:
        root = self._roots.get(kind)
        if root is None:
            root = _Node(
                key=b"", tokens=np.zeros(0, np.int32), pages={}, mirrors={},
                parent=None,
            )
            self._roots[kind] = root
        return root

    def match(self, prompt, kind: str) -> Optional[PrefixMatch]:
        """Longest cached prefix of ``prompt`` under ``kind``'s tree, or
        None.  The match is capped at ``len(prompt) - 1`` tokens: the last
        prompt token must be (re)fed to produce first-decode logits, so its
        KV row is always private."""
        self.lookups += 1
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        limit = len(prompt) - 1
        if limit < 1:
            return None
        node, nodes, m = self._root(kind), [], 0
        while m + ps <= limit:
            child = node.children.get(prompt[m : m + ps].tobytes())
            if child is None:
                break
            nodes.append(child)
            node = child
            m += ps
        # divergence (or the limit) lies mid-block: take the child with the
        # longest common prefix over the remaining tokens — its page will be
        # mapped partially and copy-on-written by the holder
        want = prompt[m:limit]
        best, best_r = None, 0
        for child in node.children.values():
            r = _lcp(child.tokens, want)
            if r > best_r:
                best, best_r = child, r
        if best is not None:
            nodes.append(best)
            m += best_r
        if m == 0:
            return None
        self.hits += 1
        return PrefixMatch(kind, nodes, m)

    # -- request refs -----------------------------------------------------------

    def acquire(self, match: PrefixMatch) -> None:
        """A matched request was admitted: pin its node path against
        eviction for the request's lifetime.  ``tokens_saved`` counts here
        (admission), not at lookup — a stalled request may be re-matched
        several times before a slot frees."""
        tick = next(self._clock)
        for node in match.nodes:
            node.ref += 1
            node.tick = tick
        self.tokens_saved += match.tokens_matched

    def release(self, match: PrefixMatch) -> None:
        """The matched request retired (finish OR abort): unpin its path.
        Page references are dropped separately by ``PagedSequence.release``;
        the tree's own page pins stay until eviction."""
        tick = next(self._clock)
        for node in match.nodes:
            if node.ref <= 0:
                raise RuntimeError("prefix-cache release without acquire")
            node.ref -= 1
            node.tick = tick

    # -- insertion ----------------------------------------------------------------

    def insert(
        self,
        prompt,
        kind: str,
        page_lists: Dict[str, List[int]],
        kv: Dict[str, Tuple[np.ndarray, np.ndarray]],
        upto: int,
    ) -> int:
        """Donate a freshly prefilled request's blocks to the tree.

        ``page_lists[role]`` is the donor sequence's page table,
        ``kv[role]`` its dense (k, v) covering at least ``upto`` rows, and
        ``upto`` the number of committed prefill rows (``plen - 1``).  Only
        FULL blocks are inserted — a partial tail block would be written by
        the donor's own decode.  Already-present blocks are skipped; new
        nodes pin the donor's pages with a pool reference (the donor keeps
        its own — last reference frees).  Returns nodes inserted."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        node, inserted = self._root(kind), 0
        tick = next(self._clock)
        for i in range(upto // ps):
            block = prompt[i * ps : (i + 1) * ps]
            key = block.tobytes()
            child = node.children.get(key)
            if child is None:
                pages = {role: page_lists[role][i] for role in self.pools}
                for role, page in pages.items():
                    self.pools[role].incref_page(page)
                mirrors = {
                    role: (
                        np.array(kv[role][0][:, i * ps : (i + 1) * ps]),
                        np.array(kv[role][1][:, i * ps : (i + 1) * ps]),
                    )
                    for role in self.pools
                }
                child = _Node(
                    key=key, tokens=block.copy(), pages=pages,
                    mirrors=mirrors, parent=node,
                )
                node.children[key] = child
                self.node_count += 1
                inserted += 1
            child.tick = tick
            node = child
        return inserted

    # -- eviction -------------------------------------------------------------

    def evict_one(self) -> int:
        """Free the least-recently-used evictable leaf; returns pages freed
        (0 when nothing is evictable).  Evictable = no children, no live
        request refs, and every page's pool refcount is 1 (only the tree
        holds it — evicting anything else frees no memory)."""
        best: Optional[_Node] = None
        stack = [
            child for root in self._roots.values()
            for child in root.children.values()
        ]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node.children or node.ref > 0:
                continue
            if any(
                self.pools[role].page_ref(page) != 1
                for role, page in node.pages.items()
            ):
                continue
            if best is None or node.tick < best.tick:
                best = node
        if best is None:
            return 0
        for role, page in best.pages.items():
            self.pools[role]._give_page(page, back_to_reservation=False)
        assert best.parent is not None
        del best.parent.children[best.key]
        self.node_count -= 1
        self.evictions += 1
        return len(best.pages)

    def evict_pages(self, want: int) -> int:
        """Evict until ``want`` pages were freed or nothing evictable is
        left; returns pages actually freed."""
        freed = 0
        while freed < want:
            got = self.evict_one()
            if got == 0:
                break
            freed += got
        return freed

    # -- introspection --------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def cached_pages(self) -> int:
        """Pages currently pinned by the tree (per role sum)."""
        return self.node_count * len(self.pools)

    def stats(self) -> Dict[str, float]:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hit_rate,
            "tokens_saved": self.tokens_saved,
            "nodes": self.node_count,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
        }
