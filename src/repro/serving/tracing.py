"""Span tracer: per-request timelines exportable as Chrome-trace JSON
(loadable in Perfetto / ``chrome://tracing``) and as structured JSONL.

The point of tracing here is to make the WDOS schedule *visible*: with
``par_mode="wdos"`` different requests draft and verify out of phase
inside shared fused dispatches, and the only honest way to check (or
debug) that staggering is a timeline with one track per batch row.  The
engine emits spans at dispatch boundaries only — the tracer never calls
``block_until_ready`` and never touches device values, so it cannot add
host syncs to the decode loop or perturb bit-identity (the parity suites
run unchanged with tracing enabled; tests/test_observability.py).

Span hierarchy the engine emits (docs/OBSERVABILITY.md draws it):

    engine track:   step#k [par_mode] > draft_phase / verify_phase (off)
                                      > fused_slot (wdos)
    row<i> track:   admit > prefill > {draft | verify}* > commit > finish
    http track:     request / disconnect / completion instants (server)

Every span/instant carries the request id in ``args`` where one applies,
so a request's life is greppable across tracks — and the same events
stream to a JSONL file (one JSON object per line) when the tracer is
built with ``jsonl_path=...``, which is the machine-tailable log a
serving deployment wants.

Off by default: the engine holds ``NULL_TRACER`` unless one is passed
(``Engine(..., trace=Tracer())``), and every ``NULL_TRACER`` method is a
constant-time no-op — the disabled fast path is one attribute check per
instrumentation site.

Export: ``to_chrome_trace()`` returns the Chrome Trace Event JSON dict
(``{"traceEvents": [...]}``, complete/``"X"`` events with microsecond
timestamps plus ``thread_name`` metadata per track); ``export(path)``
writes it.  Load it in https://ui.perfetto.dev or ``chrome://tracing``.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "validate_chrome_trace"]


class Tracer:
    """Collects spans/instants on named tracks; thread-safe.

    Timestamps are seconds relative to tracer construction (one shared
    ``time.perf_counter`` origin), converted to integer microseconds at
    export.  ``rec()`` takes explicit boundaries so callers can reuse a
    wall-clock reading they already took for telemetry — zero extra clock
    reads on instrumented paths that already time themselves."""

    enabled = True

    def __init__(self, jsonl_path: Optional[str] = None):
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._jsonl = open(jsonl_path, "w") if jsonl_path else None

    # -- clock ----------------------------------------------------------------

    def now(self) -> float:
        """Seconds since tracer start (the span timebase)."""
        return time.perf_counter() - self._t0

    # -- recording ------------------------------------------------------------

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)
            if self._jsonl is not None:
                self._jsonl.write(json.dumps(ev) + "\n")

    def rec(self, track: str, name: str, t0: float, t1: float,
            cat: str = "", **args) -> None:
        """One complete span [t0, t1] (tracer-relative seconds) on `track`."""
        self._emit({
            "ph": "X", "track": track, "name": name, "cat": cat,
            "ts": t0, "dur": max(t1 - t0, 0.0), "args": args,
        })

    def instant(self, track: str, name: str, cat: str = "", **args) -> None:
        self._emit({
            "ph": "i", "track": track, "name": name, "cat": cat,
            "ts": self.now(), "args": args,
        })

    @contextmanager
    def span(self, track: str, name: str, cat: str = "", **args):
        t0 = self.now()
        try:
            yield
        finally:
            self.rec(track, name, t0, self.now(), cat, **args)

    # -- export ---------------------------------------------------------------

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome_trace(self) -> dict:
        """Chrome Trace Event format: one pid, one tid per track (in
        first-seen order), ``thread_name`` metadata so Perfetto labels the
        tracks, microsecond integer timestamps."""
        tids: Dict[str, int] = {}
        out: List[dict] = []
        for ev in self.events():
            track = ev["track"]
            tid = tids.get(track)
            if tid is None:
                tid = tids[track] = len(tids)
                out.append({
                    "ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                    "args": {"name": track},
                })
            ce = {
                "ph": ev["ph"], "name": ev["name"], "cat": ev["cat"] or "serving",
                "pid": 0, "tid": tid, "ts": round(ev["ts"] * 1e6),
                "args": ev["args"],
            }
            if ev["ph"] == "X":
                ce["dur"] = max(round(ev["dur"] * 1e6), 1)
            else:
                ce["s"] = "t"  # thread-scoped instant
            out.append(ce)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def close(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None


class NullTracer(Tracer):
    """The disabled fast path: every method is a constant-time no-op.
    Shared as ``NULL_TRACER`` — the engine's default when no tracer is
    passed, so instrumentation sites need no ``if`` guards."""

    enabled = False

    def __init__(self):  # no clock read, no lock, no buffers
        pass

    def now(self) -> float:
        return 0.0

    def rec(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    @contextmanager
    def span(self, *a, **kw):
        yield

    def events(self) -> List[dict]:
        return []

    def to_chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        raise RuntimeError("cannot export a NullTracer (tracing is off)")

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()


def validate_chrome_trace(obj: dict) -> List[str]:
    """Schema check for an exported trace: returns a list of problems
    (empty = valid).  Used by the trace-export tests and the CI smoke so a
    regression can never silently produce a file Perfetto rejects.

    Beyond the per-event field checks, two track-level rules:

    * every non-metadata event's tid must be introduced by a
      ``thread_name`` metadata event (Perfetto renders unnamed tids as
      anonymous tracks — always a tracer bug here, since ``Tracer``
      emits the M record at first use of a track);
    * spans on a ``device*`` track must not overlap: the device executes
      one bracketed dispatch at a time (``block_until_ready`` between
      programs), so overlap means broken attribution.  Host tracks nest
      spans (step ⊃ phase) and are exempt.  A 1 µs slack absorbs the
      microsecond rounding + min-duration clamp of ``to_chrome_trace``.
    """
    problems: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be a dict with 'traceEvents'"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    track_by_tid: Dict[int, str] = {}
    device_spans: Dict[int, List[Tuple[float, float, int]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not a dict")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E"):
            problems.append(f"event {i}: bad ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        if ph == "M":
            if ev.get("name") == "thread_name":
                track = (ev.get("args") or {}).get("name")
                if isinstance(track, str) and "tid" in ev:
                    track_by_tid[ev["tid"]] = track
            continue
        tid = ev.get("tid")
        if tid is not None and tid not in track_by_tid:
            problems.append(
                f"event {i}: tid {tid} has no thread_name metadata"
            )
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        dur = ev.get("dur")
        if ph == "X" and not isinstance(dur, (int, float)):
            problems.append(f"event {i}: complete event missing dur")
        elif ph == "X" and str(track_by_tid.get(tid, "")).startswith("device"):
            device_spans.setdefault(tid, []).append((ts, ts + dur, i))
    for tid, spans in device_spans.items():
        spans.sort()
        for (_, prev_end, prev_i), (ts, _, i) in zip(spans, spans[1:]):
            if prev_end > ts + 1:  # 1 us slack for rounding/min-dur clamp
                problems.append(
                    f"device track tid {tid}: span at event {prev_i} "
                    f"overlaps span at event {i} "
                    f"(end {prev_end} > start {ts})"
                )
    return problems
