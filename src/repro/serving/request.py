"""Request lifecycle for the continuous-batching runtime.

A request moves QUEUED -> PREFILL -> DECODE -> FINISHED.  While in DECODE it
owns one PagedSequence per model (target + draft) and carries its own APSD
mode state: the paper's adaptive controller picks the draft length *per
request per round* (short window while the TLM is rejecting, long window
while it accepts everything — `core/apsd.APSDPolicy`), so easy and hard
requests in the same batch draft different amounts.  Tokens stream to an
optional per-request sink as soon as they commit.

Every request also carries its own ``SamplingParams`` and — for
``temperature > 0`` — its own PRNG key stream: keys are derived from the
request's seed and indexed by (stream, round, position), never drawn from a
shared counter, so a request's sampled tokens are identical no matter which
batch composition the engine happens to schedule it into.

``SamplingParams.stop`` is enforced here at commit time: each committed
token's detokenized text extends the request's generated-text stream, the
stream is scanned for the earliest new stop match, and on a hit the output
truncates at the token boundary before the match (the stop string itself
is excluded) with ``finish_reason="stop"``.  The cache bookkeeping is
untouched — the engine's advance/rewind depends only on the round's
acceptance count — so a stopped request retires and frees its pages
through the exact same path as a length-finished one.

Under fused cross-request PAR execution (``EngineConfig(par_mode="wdos")``)
a request additionally carries its PHASE state: the draft window currently
in flight (``begin_window`` / ``pending`` / ``window_full``).  Phase state
persists ACROSS engine steps — a request may end a step mid-draft and
resume proposing where it left off while a neighbouring row verifies — and
is what lets the WDOS planner schedule rows out of order.  Invariants: the
window's proposals are exactly the tokens whose draft-model KV has been
scattered at positions ``d_seq.length + [0, len(pending))``; a window is
verified only when full; ``rounds`` (the key-stream round index) increments
only at commit, so draft/accept keys are identical whether the engine runs
two-phase or fused rounds.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

from repro.core.apsd import NONPAR, PAR, APSDPolicy
from repro.serving.api import SamplingParams
from repro.serving.paged_cache import PagedSequence

__all__ = ["RequestState", "DraftController", "Request"]

# per-request PRNG stream ids (folded into the seed key first)
_DRAFT_STREAM = 0  # draft-token sampling, indexed by (round, position)
_ACCEPT_STREAM = 1  # rejection-sampling accept/residual, indexed by round


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass
class DraftController:
    """Per-request adaptive draft length (the APSD mode state machine).

    Fixed-DL SD is the degenerate case short_dl == long_dl.  The PAR/NONPAR
    transition reuses ``APSDPolicy.next_mode``; in the batched runtime "PAR"
    buys a longer draft window (the cross-request overlap itself is what the
    batcher's WDOS model prices — see serving/batcher.py).
    """

    short_dl: int
    long_dl: int
    mode: int = NONPAR

    def draft_len(self) -> int:
        return self.long_dl if self.mode == PAR else self.short_dl

    def observe(self, n_accepted: int, window: int) -> None:
        all_acc = n_accepted == window
        self.mode = APSDPolicy.next_mode(self.mode, all_acc, True)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32, S >= 2
    max_new_tokens: int
    sink: Optional[Callable[[int], None]] = None  # streaming token callback
    sampling: Optional[SamplingParams] = None  # None => greedy defaults
    # token -> text, used only when sampling.stop is non-empty (the engine
    # injects its detokenizer at add_request)
    detokenize: Optional[Callable[[int], str]] = None
    # resolved KV storage kind ("none" | "int8") — the engine resolves the
    # request's SamplingParams.kv_quant against EngineConfig.kv_quant at
    # add_request and stamps the result here; it selects which device store
    # the request's pages are read from for its whole lifetime
    kv_kind: str = "none"

    state: RequestState = RequestState.QUEUED
    out: List[int] = dataclasses.field(default_factory=list)
    last_tok: int = 0  # tip of the committed sequence (re-fed each round)
    t_seq: Optional[PagedSequence] = None  # target-model KV pages
    d_seq: Optional[PagedSequence] = None  # draft-model KV pages
    controller: Optional[DraftController] = None
    finish_reason: Optional[str] = None  # "length" | "abort" once FINISHED
    # prefix-cache hit this request was admitted with (PrefixMatch), held
    # until retire so the batcher can unpin the matched radix-tree path;
    # None when the cache is off or the lookup missed
    prefix_match: Optional[Any] = None

    # stats
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    emitted_total: int = 0  # committed tokens incl. the final round's overshoot
    admitted_step: int = -1
    finished_step: int = -1
    # observability timestamps (tracer-relative seconds), stamped by the
    # engine at lifecycle boundaries — the request itself never reads a
    # clock, so Request stays schedule- and instrumentation-agnostic.
    # submit/admit feed the admission-wait histogram; first/last emit feed
    # TTFT and inter-token-latency.
    submit_ts: Optional[float] = None
    admit_ts: Optional[float] = None
    first_emit_ts: Optional[float] = None
    last_emit_ts: Optional[float] = None
    # (mode, drafted, accepted, emitted) per round — the APSD round log the
    # serve_apsd compatibility wrapper rebuilds its stats from
    history: List[Tuple[int, int, int, int]] = dataclasses.field(
        default_factory=list
    )

    # -- fused-PAR phase state (par_mode="wdos"): the draft window in flight.
    # pending_dl is None between windows; pending holds the proposals made so
    # far (their draft KV sits at d_seq.length + [0, len(pending))); pending_q
    # mirrors pending with the draft logits sampled rows need for the
    # rejection rule.  Survives across engine steps (mid-draft carry-over).
    pending_dl: Optional[int] = None
    pending: List[int] = dataclasses.field(default_factory=list)
    pending_q: List[np.ndarray] = dataclasses.field(default_factory=list)

    # -- tree-speculation phase state (spec_mode="tree"): the draft TREE in
    # flight.  tree_dl is the round's target depth (None between rounds);
    # tree_nodes[i] / tree_parents[i] are the drafted token and parent NODE
    # index (-1 = root) in drafting (BFS) order — window slot 1+i; tree_depth
    # is the deepest fully-grown level; tree_draws counts sampled child
    # draws this round (the draft_key position index, so a request's tree is
    # identical no matter the batch composition); tree_q maps a window slot
    # to the draft logits row its children were sampled from (sampled
    # requests only — the tree rejection rule needs q at every branch
    # point).  Survives across fused engine steps like the chain window.
    tree_dl: Optional[int] = None
    tree_nodes: List[int] = dataclasses.field(default_factory=list)
    tree_parents: List[int] = dataclasses.field(default_factory=list)
    tree_depth: int = 0
    tree_draws: int = 0
    tree_q: dict = dataclasses.field(default_factory=dict)

    # -- stop-sequence state (sampling.stop non-empty): the detokenized
    # generated text plus each output token's cumulative text end offset,
    # so a match maps back to a token-boundary truncation point.  The two
    # watermarks implement the HOLDBACK rule: a token whose text could
    # still become the start of a stop match is not delivered (sink or
    # RequestOutput delta) until later text proves it safe — so a stop
    # string spanning a round boundary never retracts a delivered token.
    stop_hit: bool = False
    _gen_text: str = ""
    _text_ends: List[int] = dataclasses.field(default_factory=list)
    _stream_mark: int = 0  # sink watermark (stop path only)
    _delta_mark: int = 0  # RequestOutput-delta watermark (engine)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.shape[0] < 2:
            raise ValueError("prompt must have >= 2 tokens (SD invariant)")
        self.last_tok = int(self.prompt[-1])
        if self.sampling is None:
            self.sampling = SamplingParams(max_tokens=self.max_new_tokens)
        self._base_key = None  # lazy: greedy requests never build a key

    # -- sampling key streams ------------------------------------------------

    def _key(self) -> jax.Array:
        if self._base_key is None:
            self._base_key = jax.random.PRNGKey(self.sampling.seed)
        return self._base_key

    def draft_key(self, position: int) -> jax.Array:
        """Key for sampling the draft token at `position` of the current
        round (``self.rounds`` — incremented only after the round commits)."""
        k = jax.random.fold_in(self._key(), _DRAFT_STREAM)
        return jax.random.fold_in(jax.random.fold_in(k, self.rounds), position)

    def accept_key(self) -> jax.Array:
        """Key for the current round's rejection-sampling accept/residual."""
        k = jax.random.fold_in(self._key(), _ACCEPT_STREAM)
        return jax.random.fold_in(k, self.rounds)

    # -- fused-PAR window phase ----------------------------------------------

    def begin_window(self, dl: int) -> None:
        """Open a fresh draft window of `dl` proposals (fused-PAR mode)."""
        if dl < 1:
            raise ValueError(f"draft window must be >= 1, got {dl}")
        self.pending_dl = dl
        self.pending = []
        self.pending_q = []

    def clear_window(self) -> None:
        self.pending_dl = None
        self.pending = []
        self.pending_q = []

    @property
    def window_full(self) -> bool:
        """Ready to verify: every proposal of the open window is drafted."""
        return self.pending_dl is not None and len(self.pending) >= self.pending_dl

    @property
    def draft_tip(self) -> int:
        """Token the next draft micro-step consumes: the last proposal of
        the open window, or the committed tip when the window is empty."""
        return int(self.pending[-1]) if self.pending else self.last_tok

    # -- tree-speculation phase (spec_mode="tree") ---------------------------

    def begin_tree(self, dl: int) -> None:
        """Open a fresh draft tree targeting depth `dl`."""
        if dl < 1:
            raise ValueError(f"tree depth must be >= 1, got {dl}")
        self.tree_dl = dl
        self.tree_nodes = []
        self.tree_parents = []
        self.tree_depth = 0
        self.tree_draws = 0
        self.tree_q = {}

    def clear_tree(self) -> None:
        self.tree_dl = None
        self.tree_nodes = []
        self.tree_parents = []
        self.tree_depth = 0
        self.tree_draws = 0
        self.tree_q = {}

    @property
    def tree_full(self) -> bool:
        """Ready to verify: the tree reached its target depth (or exhausted
        its node budget early, in which case the grower stamps tree_depth
        forward to tree_dl)."""
        return self.tree_dl is not None and self.tree_depth >= self.tree_dl

    # -- lifecycle -----------------------------------------------------------

    @property
    def committed_len(self) -> int:
        """Prompt + generated tokens (the model-visible sequence)."""
        return self.prompt.shape[0] + len(self.out)

    @property
    def done(self) -> bool:
        return self.stop_hit or len(self.out) >= self.max_new_tokens

    def peak_cache_len(self, max_dl: int) -> int:
        """Worst-case cache length: committed-1 positions plus a full
        draft/verify window (+1 for the verify bonus / draft straggler)."""
        return self.prompt.shape[0] + self.max_new_tokens + max_dl

    def commit(self, tokens: List[int]) -> None:
        """Append verified tokens; stream them (up to the budget); update the
        tip.  A round may overshoot max_new_tokens — the overshoot is kept
        for cache bookkeeping and trimmed at finish, like ``sd_generate``.

        When ``sampling.stop`` is set, each token's detokenized text extends
        the request's generated-text stream and the stream is scanned for
        the earliest stop match; on a hit the output is truncated at the
        token boundary BEFORE the match (the stop string is excluded),
        ``finish_reason`` becomes "stop", and the engine retires the request
        at the end of the round — the cache advance/rewind bookkeeping is
        untouched (it depends only on the round's acceptance count), so the
        pages free through the normal retirement path."""
        if self.sampling is not None and self.sampling.stop:
            self._commit_with_stop(tokens)
            return
        keep = max(0, self.max_new_tokens - len(self.out))
        if self.sink is not None:
            for t in tokens[:keep]:
                self.sink(int(t))
        self.out.extend(tokens)
        self.emitted_total += len(tokens)
        if tokens:
            self.last_tok = int(tokens[-1])

    def _commit_with_stop(self, tokens: List[int]) -> None:
        detok = self.detokenize
        if detok is None:
            from repro.serving.api import default_detokenize as detok
        stops = self.sampling.stop
        self.emitted_total += len(tokens)
        if tokens:
            # the committed-window tip, pre-truncation: cache bookkeeping
            # (advance/rewind in the engine) sees the same tip it always did
            self.last_tok = int(tokens[-1])
        for t in tokens:
            if self.stop_hit:
                break
            if len(self.out) >= self.max_new_tokens:
                # overshoot past the budget: kept for cache bookkeeping
                # only (trimmed at finish) — it is NOT part of the
                # delivered completion, so it must not extend the text
                # stream nor fire a stop the user would never have seen
                self.out.append(int(t))
                continue
            tail_start = len(self._gen_text)
            self.out.append(int(t))
            self._gen_text += detok(int(t))
            self._text_ends.append(len(self._gen_text))
            # a NEW match must end inside this token's text: scanning from
            # tail_start - (max stop len - 1) covers matches that began in
            # earlier tokens without re-finding old text
            start = None
            for s in stops:
                lo = max(0, tail_start - len(s) + 1)
                m = self._gen_text.find(s, lo)
                if m >= 0 and (start is None or m < start):
                    start = m
            if start is not None:
                # keep tokens whose text ends at or before the match start
                n_keep = bisect.bisect_right(self._text_ends, start)
                self.out = self.out[:n_keep]
                self.stop_hit = True
                self.finish_reason = "stop"
        # stream only what is SAFE: survived truncation, fits the budget,
        # and cannot still become part of a future cross-round stop match
        if self.sink is not None:
            hi = self.emittable_len()
            for t in self.out[self._stream_mark: hi]:
                self.sink(int(t))
            self._stream_mark = max(self._stream_mark, hi)

    def _held_tail_chars(self) -> int:
        """Chars at the end of the generated text that are a proper prefix
        of some stop string — i.e. could still become the beginning of a
        match once more tokens arrive (the holdback window)."""
        best = 0
        text = self._gen_text
        for s in self.sampling.stop:
            for l in range(min(len(s) - 1, len(text)), best, -1):
                if text.endswith(s[:l]):
                    best = l
                    break
        return best

    def emittable_len(self) -> int:
        """Output tokens safe to DELIVER right now (sink / RequestOutput):
        everything committed up to the budget, minus — while stop matching
        is still live — the held tail whose text could yet become part of
        a match.  Once the request resolves (stop hit, or the budget is
        reached so no further match can truncate delivered tokens) the
        holdback flushes.  For requests without stop strings this is
        simply min(len(out), max_new_tokens) — the historical slice."""
        n = min(len(self.out), self.max_new_tokens)
        if not self.sampling.stop or self.stop_hit or n >= self.max_new_tokens:
            return n
        held = self._held_tail_chars()
        if not held:
            return n
        safe_char = len(self._gen_text) - held
        return min(n, bisect.bisect_right(self._text_ends, safe_char))

    def take_delta(self) -> List[int]:
        """Newly deliverable tokens since the last call — what the engine
        puts in ``RequestOutput.new_token_ids``.  Monotone: held-back
        tokens are only ever delivered late, never retracted, so the
        concatenation of deltas always equals the final output."""
        hi = self.emittable_len()
        lo = min(self._delta_mark, hi)
        self._delta_mark = hi
        return [int(t) for t in self.out[lo:hi]]

    def record_round(self, mode: int, drafted: int, accepted: int,
                     emitted: int) -> None:
        self.history.append((mode, drafted, accepted, emitted))

    def finish(self, step: int, reason: str = "length") -> None:
        self.state = RequestState.FINISHED
        self.clear_window()
        self.clear_tree()
        if self.finish_reason is None:
            self.finish_reason = reason
        self.finished_step = step
        self.out = self.out[: self.max_new_tokens]
        self._gen_text = ""  # stop-matching buffers are dead weight now
        self._text_ends = []
        for seq in (self.t_seq, self.d_seq):
            if seq is not None and not seq.released:
                seq.release()
        self.t_seq = self.d_seq = None

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)
