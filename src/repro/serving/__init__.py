"""Serving runtime: the stepwise continuous-batching ``Engine`` over
device-resident paged KV pools, plus the deprecated run-to-drain shims.

The public surface::

    from repro.serving import Engine, EngineConfig, SamplingParams

    eng = Engine(target, draft, EngineConfig(max_batch=4))
    rid = eng.add_request(prompt, SamplingParams(max_tokens=32))
    while eng.has_unfinished():
        for out in eng.step():          # one WDOS-scheduled SD round
            stream(out.new_token_ids)   # RequestOutput, incremental

``EngineConfig(par_mode="wdos")`` switches the rounds from two-phase
(draft-all-then-verify-all) to FUSED cross-request PAR: the WDOS phase
planner co-schedules one request's verify with its neighbours' draft
micro-steps in single fused dispatches — bit-identical tokens, fewer
rounds on heterogeneous workloads.  docs/SERVING.md is the API reference;
docs/ARCHITECTURE.md maps the stack.

The ASYNC front-end turns the engine into a service::

    async with AsyncEngine(eng, max_queued=32) as aeng:
        async for out in aeng.generate(prompt, sp):   # one iterator per
            send(out.new_token_ids)                   # request, tokens
                                                      # bit-identical to
                                                      # Engine.run()

``async_engine.AsyncEngine`` runs the step loop on a worker thread with
per-request streams, cancellation -> ``Engine.abort`` (pages freed
immediately), and a bounded admission queue (``QueueFullError`` on
fail-fast overflow); ``server.CompletionServer`` serves it over HTTP
(``POST /v1/completions`` with SSE streaming, ``/healthz``, ``/stats``,
``/metrics``) on stdlib asyncio streams — no framework dependency.

OBSERVABILITY (docs/OBSERVABILITY.md is the reference): every engine
carries a ``MetricsRegistry`` (``observability.py`` — zero-dependency
counters/gauges/histograms, Prometheus-text ``render()``) that the
batcher, the async front-end, the HTTP server, and the benchmarks all
share; pass ``Engine(..., trace=Tracer())`` to additionally record
per-request span timelines exportable as Chrome-trace/Perfetto JSON
(``tracing.py``).  Instrumentation is off-by-default-cheap and never
adds host syncs — bit-identity is unaffected with tracing enabled.
``EngineConfig.profile_every_n`` samples device-time attribution (each
dispatch program bracketed + cost-stamped onto a "device" trace track),
and every engine carries a ``flight_recorder.FlightRecorder`` — a
bounded ring of per-round records with anomaly postmortems, served at
``GET /debug/flight``.

Internals (engine-owned, import from their modules if you must):
  paged_cache.PagedKVPool  — block-granular KV pages, free list, reservations
  request.Request          — lifecycle + per-request sampling key streams
  batcher.ContinuousBatcher— page-budget admission + WDOS round model
  host_gather              — frozen legacy gather/scatter loop (bench baseline)

Deprecated shims (each warns once): ``serve_sd``, ``serve_apsd``,
``serve_batch``, ``serve_batch_host`` — thin wrappers over ``Engine``,
bit-identical for greedy decoding.
"""
from repro.serving.api import (
    CompletionOutput,
    EngineConfig,
    RequestOutput,
    SamplingParams,
    default_detokenize,
    resolve_paged_attn_impl,
)
from repro.serving.async_engine import AsyncEngine, QueueFullError
from repro.serving.flight_recorder import ANOMALY_KINDS, FlightRecorder
from repro.serving.engine import (
    BatchConfig,
    Engine,
    ServingModel,
    make_interface,
    serve_apsd,
    serve_batch,
    serve_batch_host,
    serve_sd,
)
from repro.serving.observability import (
    LATENCY_BUCKETS,
    RATIO_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.serving.server import CompletionServer
from repro.serving.tracing import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    validate_chrome_trace,
)

__all__ = [
    # the Engine API
    "Engine",
    "EngineConfig",
    "SamplingParams",
    "RequestOutput",
    "CompletionOutput",
    "ServingModel",
    "make_interface",
    "resolve_paged_attn_impl",
    "default_detokenize",
    # the async front-end
    "AsyncEngine",
    "QueueFullError",
    "CompletionServer",
    # observability: metrics registry + span tracer
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS",
    "RATIO_BUCKETS",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "validate_chrome_trace",
    "FlightRecorder",
    "ANOMALY_KINDS",
    # deprecated run-to-drain shims (+ their config type)
    "serve_sd",
    "serve_apsd",
    "serve_batch",
    "serve_batch_host",
    "BatchConfig",
]
