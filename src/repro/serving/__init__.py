"""Serving runtime: single-request SD/APSD drivers plus the continuous-
batching multi-request engine (paged KV pools + WDOS-modeled scheduler).

Layers, bottom-up:
  paged_cache.PagedKVPool  — block-granular KV pages, free list, reservations
  request.Request          — QUEUED/PREFILL/DECODE/FINISHED + APSD mode state
  batcher.ContinuousBatcher— page-budget admission + WDOS round model
  engine.serve_batch       — vmapped draft/verify steps over active requests
"""
from repro.serving.batcher import BatchConfig, ContinuousBatcher
from repro.serving.engine import (
    ServingModel,
    make_interface,
    serve_apsd,
    serve_batch,
    serve_sd,
)
from repro.serving.paged_cache import PagedKVPool, PagedSequence
from repro.serving.request import DraftController, Request, RequestState

__all__ = [
    "BatchConfig",
    "ContinuousBatcher",
    "ServingModel",
    "make_interface",
    "serve_apsd",
    "serve_batch",
    "serve_sd",
    "PagedKVPool",
    "PagedSequence",
    "DraftController",
    "Request",
    "RequestState",
]
