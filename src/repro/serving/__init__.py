"""Serving runtime: single-request SD/APSD drivers plus the continuous-
batching multi-request engine (device-resident paged KV pools +
WDOS-modeled scheduler).

Layers, bottom-up:
  paged_cache.PagedKVPool  — block-granular KV pages, free list, reservations
                             (host allocator; KV bytes in device arrays via
                             device_pool_init)
  request.Request          — QUEUED/PREFILL/DECODE/FINISHED + APSD mode state
  batcher.ContinuousBatcher— page-budget admission + WDOS round model
  engine.serve_batch       — batched draft/verify steps scattering/attending
                             in place through per-row page tables
  host_gather.serve_batch_host — legacy gather/scatter loop (bench baseline)
"""
from repro.serving.batcher import BatchConfig, ContinuousBatcher
from repro.serving.engine import (
    ServingModel,
    make_interface,
    serve_apsd,
    serve_batch,
    serve_sd,
)
from repro.serving.paged_cache import PagedKVPool, PagedSequence, device_pool_init
from repro.serving.request import DraftController, Request, RequestState

__all__ = [
    "BatchConfig",
    "ContinuousBatcher",
    "ServingModel",
    "make_interface",
    "serve_apsd",
    "serve_batch",
    "serve_sd",
    "PagedKVPool",
    "PagedSequence",
    "device_pool_init",
    "DraftController",
    "Request",
    "RequestState",
]
