"""Engine flight recorder: a bounded ring of per-round records with
anomaly triggers and JSON postmortems.

The observability layer (PR 6) answers "what is the engine doing *now*"
(gauges) and "what has it done *in total*" (counters).  What it cannot
answer is "what happened in the thirty rounds *before* things went
wrong" — the question every production incident starts with.  This
module keeps that answer resident: every engine round appends one small
host-side record (plan shape, acceptance deltas, pool/queue gauges,
round wall) to a ``deque(maxlen=capacity)``, and four anomaly detectors
watch the stream:

* ``slow_round`` — the round wall exceeded ``slow_factor`` x the rolling
  median of the ring (armed only after ``warmup`` rounds so compile
  stalls don't trip it);
* ``acceptance_collapse`` — the windowed accept rate over the last
  ``accept_window`` drafting rounds fell below ``accept_floor`` (the
  draft model has stopped predicting the target — speculation is now
  pure overhead);
* ``pool_exhausted`` — requests are queued while either KV pool has zero
  free pages (admission is blocked on capacity, not policy);
* ``admission_stall`` — ``stall_rounds`` consecutive rounds saw queued
  work but zero admissions (head-of-line livelock: the queue head's
  worst case never fits).

Each detector fires ONCE per episode (on the False→True transition;
re-arms when the condition clears), increments ``anomalies_total{kind}``
in the shared registry, and captures a postmortem: the full ring, the
triggering record, and the tail of the tracer's event buffer.  Postmortems
stay in a small in-memory deque (served at ``GET /debug/flight``) and are
additionally written to ``dump_dir`` as JSON files when one is configured.

Cost model: recording is O(1) appends of a ~15-key dict per round plus
one ``statistics.median`` over at most ``capacity`` floats — no device
syncs, no tracing requirement, and it never touches sampling math, so
tokens stay bit-identical with the recorder on (the same contract as
PR 6's tracer; tests/test_observability.py).  All public methods take an
internal lock, so the server thread may snapshot while the engine thread
records.
"""
from __future__ import annotations

import json
import os
import statistics
import threading
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["FlightRecorder", "ANOMALY_KINDS"]

ANOMALY_KINDS = (
    "slow_round",
    "acceptance_collapse",
    "pool_exhausted",
    "admission_stall",
)


class FlightRecorder:
    """Bounded per-round ring buffer + anomaly triggers + postmortems.

    ``record()`` is called by the engine once per round (including empty
    rounds — pool exhaustion *manifests* as empty rounds); ``snapshot()``
    and ``dump()`` may be called from any thread."""

    def __init__(
        self,
        capacity: int = 256,
        *,
        metrics=None,  # Optional[MetricsRegistry]
        tracer=None,  # Optional[Tracer] — tail of its events in postmortems
        dump_dir: Optional[str] = None,
        slow_factor: float = 4.0,
        warmup: int = 16,
        accept_floor: float = 0.1,
        accept_window: int = 8,
        stall_rounds: int = 16,
        trace_tail: int = 64,
        max_postmortems: int = 4,
    ):
        self.enabled = capacity > 0
        self.capacity = capacity
        self.tracer = tracer
        self.dump_dir = dump_dir
        self.slow_factor = slow_factor
        self.warmup = warmup
        self.accept_floor = accept_floor
        self.accept_window = accept_window
        self.stall_rounds = stall_rounds
        self.trace_tail = trace_tail
        self._lock = threading.Lock()
        self._ring: Deque[dict] = deque(maxlen=max(capacity, 1))
        self._postmortems: Deque[dict] = deque(maxlen=max_postmortems)
        self._rounds = 0
        self._stall_run = 0  # consecutive queued-but-nothing-admitted rounds
        self._active: set = set()  # anomaly kinds currently in-episode
        self._counts: Dict[str, int] = {k: 0 for k in ANOMALY_KINDS}
        self._m_anomalies = None
        if metrics is not None:
            self._m_anomalies = metrics.counter(
                "anomalies_total",
                "Flight-recorder anomaly episodes, by trigger kind",
                ("kind",),
            )
            for kind in ANOMALY_KINDS:  # materialize all series at 0
                self._m_anomalies.labels(kind=kind).inc(0)

    # -- recording -----------------------------------------------------------

    def record(self, rec: dict) -> List[str]:
        """Append one round record; detect anomalies against the PRIOR
        ring state; return the kinds that fired this round (empty for a
        healthy round).  ``rec`` must carry: ``wall_s``, ``drafted``,
        ``accepted``, ``admitted`` (all per-round deltas), ``queued``,
        ``active``, and ``free_pages`` ({"target": n, "draft": n})."""
        if not self.enabled:
            return []
        with self._lock:
            fired = self._detect(rec)
            rec = dict(rec)
            rec["seq"] = self._rounds
            if fired:
                rec["anomalies"] = fired
            self._ring.append(rec)
            self._rounds += 1
            for kind in fired:
                self._counts[kind] += 1
                self._postmortems.append(self._postmortem(kind, rec))
        # metrics/disk outside the lock: counter families have their own
        # lock, and a slow disk write must not block a /debug/flight read
        for kind in fired:
            if self._m_anomalies is not None:
                self._m_anomalies.labels(kind=kind).inc()
            if self.dump_dir:
                self._write_dump(kind, rec)
        return fired

    def _detect(self, rec: dict) -> List[str]:
        """Evaluate all triggers vs the ring as it stood BEFORE this
        record; episode semantics — a kind fires only on its False→True
        transition and re-arms when its condition clears."""
        now: Dict[str, bool] = {}

        walls = [r["wall_s"] for r in self._ring if r.get("wall_s", 0) > 0]
        now["slow_round"] = bool(
            len(walls) >= self.warmup
            and rec.get("wall_s", 0.0)
            > self.slow_factor * statistics.median(walls)
        )

        recent = list(self._ring)[-(self.accept_window - 1):] + [rec]
        drafted = sum(r.get("drafted", 0) for r in recent)
        accepted = sum(r.get("accepted", 0) for r in recent)
        now["acceptance_collapse"] = bool(
            self._rounds + 1 >= self.warmup
            and drafted > 0
            and len(recent) >= self.accept_window
            and accepted / drafted < self.accept_floor
        )

        free = rec.get("free_pages", {})
        now["pool_exhausted"] = bool(
            rec.get("queued", 0) > 0
            and (free.get("target", 1) == 0 or free.get("draft", 1) == 0)
        )

        if rec.get("queued", 0) > 0 and rec.get("admitted", 0) == 0:
            self._stall_run += 1
        else:
            self._stall_run = 0
        now["admission_stall"] = self._stall_run >= self.stall_rounds

        fired = []
        for kind in ANOMALY_KINDS:
            if now[kind] and kind not in self._active:
                fired.append(kind)
        # re-arm cleared kinds; keep in-episode kinds latched
        self._active = {k for k in ANOMALY_KINDS if now[k]}
        return fired

    # -- postmortems ---------------------------------------------------------

    def _postmortem(self, kind: str, rec: dict) -> dict:
        tail: List[dict] = []
        if self.tracer is not None and getattr(self.tracer, "enabled", False):
            tail = self.tracer.events()[-self.trace_tail:]
        return {
            "kind": kind,
            "fired_at_round": rec["seq"],
            "record": rec,
            "ring": list(self._ring),
            "trace_tail": tail,
        }

    def _write_dump(self, kind: str, rec: dict) -> None:
        try:
            os.makedirs(self.dump_dir, exist_ok=True)
            path = os.path.join(
                self.dump_dir, f"flight_{kind}_r{rec['seq']}.json"
            )
            with self._lock:
                pm = next(
                    (p for p in reversed(self._postmortems)
                     if p["kind"] == kind), None
                )
            with open(path, "w") as f:
                json.dump(pm, f)
        except OSError:
            pass  # a full disk must never take the engine down

    # -- read side -----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-safe view: config, anomaly counts, the ring, and retained
        postmortems.  What ``GET /debug/flight`` serves."""
        with self._lock:
            return {
                "enabled": self.enabled,
                "capacity": self.capacity,
                "rounds_recorded": self._rounds,
                "anomalies": dict(self._counts),
                "active_episodes": sorted(self._active),
                "ring": list(self._ring),
                "postmortems": list(self._postmortems),
            }

    def dump(self, path: Optional[str] = None, reason: str = "manual") -> dict:
        """On-demand postmortem (``GET /debug/flight?dump=1`` or an
        operator signal): snapshot + trace tail, optionally written to
        ``path`` (or an auto-named file in ``dump_dir``)."""
        snap = self.snapshot()
        snap["reason"] = reason
        if self.tracer is not None and getattr(self.tracer, "enabled", False):
            snap["trace_tail"] = self.tracer.events()[-self.trace_tail:]
        if path is None and self.dump_dir:
            path = os.path.join(
                self.dump_dir, f"flight_{reason}_r{snap['rounds_recorded']}.json"
            )
        if path:
            try:
                os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                with open(path, "w") as f:
                    json.dump(snap, f)
                snap["dumped_to"] = path
            except OSError:
                pass
        return snap
