"""Zero-dependency metrics registry: counters, gauges, histograms, and a
Prometheus-text exposition — the single source of truth for every number
the serving stack reports.

The paper's headline claims are all *rates* (14.08–135.69 token/s,
4.46–7.17x over vanilla SD, acceptance-driven adaptive windows), so the
serving stack needs one instrumentation layer that the engine, the async
front-end, the HTTP server, and the benchmarks all read from — instead of
the ad-hoc per-module dicts they used to carry.  ``MetricsRegistry`` is
that layer:

* **Counter** — monotone accumulator (``inc`` rejects negative deltas);
* **Gauge** — last-write-wins level (queue depth, pool residency);
* **Histogram** — fixed cumulative buckets + sum/count (TTFT, ITL,
  round wall time, per-round acceptance fraction).  Buckets are fixed at
  registration so ``observe`` is O(buckets) with no allocation.

Families are label-aware (``family.labels(pool="target")``) with children
created on first use; re-registering a name returns the existing family
(idempotent) and raises on a type mismatch.  All mutation goes through one
registry lock — the engine worker thread observes while the HTTP loop
thread scrapes, and increments are read-modify-write, so lock-free "+="
would lose updates.  The instrumented paths run at *round* granularity
(not per token, never inside a traced computation), so the lock is never
contended on the hot path.

Off-by-default-cheap: a registry built with ``enabled=False`` hands every
caller a shared no-op child — ``inc``/``set``/``observe`` return
immediately, values stay zero, and ``render()`` emits only headers.  The
Engine's default registry is enabled (the cost is a handful of guarded
float adds per round); the *tracer* (serving/tracing.py), which allocates
per event, is the component that defaults off.

Exposition: ``registry.render()`` returns the Prometheus text format
(``text/plain; version=0.0.4``) the server's ``GET /metrics`` serves;
``registry.snapshot()`` returns the same data JSON-safe for benchmark
files.  Nothing here imports jax — the module is pure host bookkeeping
and can never perturb bit-identity.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "LATENCY_BUCKETS",
    "RATIO_BUCKETS",
]

# Default buckets for second-valued latencies (TTFT / ITL / round wall):
# sub-ms through tens of seconds, the span CPU smoke and real TPU serving
# both land in.
LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)
# Buckets for [0, 1]-valued fractions (per-round acceptance rate).
RATIO_BUCKETS: Tuple[float, ...] = (
    0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0,
)


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats via repr."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    inner = ",".join(
        f'{n}="{_escape(str(v))}"' for n, v in zip(names, values)
    )
    return "{" + inner + "}"


class _Child:
    """One labeled series.  Mutators must run under the registry lock
    (the family wrappers take it); reads of a single float are atomic."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class _HistChild:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets  # cumulative at render time, raw here
        self.sum = 0.0
        self.count = 0


class _NoopChild:
    """Shared sink for disabled registries: every mutator is a no-op."""

    __slots__ = ()
    value = 0.0
    sum = 0.0
    count = 0

    def inc(self, v: float = 1.0) -> None:
        pass

    def dec(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NOOP = _NoopChild()


class _Family:
    """Base: a named metric with optional labels and per-label children."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 labelnames: Sequence[str]):
        self._registry = registry
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], object] = {}

    def _new_child(self):
        raise NotImplementedError

    def labels(self, **kv):
        """The child series for this label assignment (created on first
        use).  A label-less family IS its own single child."""
        if self._registry.enabled is False:
            return _NOOP
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got "
                f"{tuple(kv)}"
            )
        key = tuple(str(kv[n]) for n in self.labelnames)
        with self._registry._lock:
            child = self._children.get(key)
            if child is None:
                child = self._new_child()
                self._children[key] = child
        return _Bound(self._registry, child)

    def _default(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} has labels {self.labelnames}; use .labels()"
            )
        return self.labels()

    # -- convenience for label-less families --------------------------------

    def inc(self, v: float = 1.0) -> None:
        self._default().inc(v)

    def dec(self, v: float = 1.0) -> None:
        self._default().dec(v)

    def set(self, v: float) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    # -- reads ---------------------------------------------------------------

    def value(self, **kv) -> float:
        """Current value of one series (0.0 if never touched)."""
        key = tuple(str(kv.get(n, "")) for n in self.labelnames)
        child = self._children.get(key)
        return 0.0 if child is None else float(child.value)

    def series(self) -> Dict[Tuple[str, ...], float]:
        """{label-values: value} over every child (histograms: count)."""
        out = {}
        for key, child in self._children.items():
            out[key] = float(getattr(child, "value", getattr(child, "count", 0.0)))
        return out

    def total(self) -> float:
        return sum(self.series().values())


class _Bound:
    """A child bound to its registry lock: the mutator surface handed out
    by ``labels()``."""

    __slots__ = ("_registry", "_child")

    def __init__(self, registry: "MetricsRegistry", child):
        self._registry = registry
        self._child = child

    @property
    def value(self) -> float:
        return float(getattr(self._child, "value", 0.0))

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters are monotone; inc({v}) is negative")
        with self._registry._lock:
            self._child.value += v

    def _inc_any(self, v: float) -> None:
        with self._registry._lock:
            self._child.value += v

    def dec(self, v: float = 1.0) -> None:
        self._inc_any(-v)

    def set(self, v: float) -> None:
        with self._registry._lock:
            self._child.value = float(v)

    def observe(self, v: float) -> None:
        child = self._child
        with self._registry._lock:
            child.sum += v
            child.count += 1
            for i, ub in enumerate(self._registry._buckets_of(child)):
                if v <= ub:
                    child.counts[i] += 1
                    break


class Counter(_Family):
    kind = "counter"

    def _new_child(self):
        return _Child()

    def dec(self, v: float = 1.0) -> None:  # pragma: no cover - guard
        raise ValueError("counters are monotone; use a Gauge")


class Gauge(_Family):
    kind = "gauge"

    def _new_child(self):
        return _Child()

    def inc(self, v: float = 1.0) -> None:
        # gauges may move both ways; route around the monotone guard
        if self._registry.enabled is False:
            return
        self._default()._inc_any(v)


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, registry, name, help, labelnames,
                 buckets: Sequence[float]):
        super().__init__(registry, name, help, labelnames)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket")
        if bs[-1] != math.inf:
            bs = bs + (math.inf,)
        self.buckets = bs

    def _new_child(self):
        child = _HistChild(len(self.buckets))
        self._registry._hist_buckets[id(child)] = self.buckets
        return child

    def value(self, **kv) -> float:
        """For histograms: the observation COUNT of one series."""
        key = tuple(str(kv.get(n, "")) for n in self.labelnames)
        child = self._children.get(key)
        return 0.0 if child is None else float(child.count)

    def sum_value(self, **kv) -> float:
        key = tuple(str(kv.get(n, "")) for n in self.labelnames)
        child = self._children.get(key)
        return 0.0 if child is None else float(child.sum)


class MetricsRegistry:
    """Named metric families + Prometheus-text / JSON exposition.

    Thread-safe: one lock guards child creation, every mutation, and the
    render snapshot.  Registration is idempotent by name (same kind —
    and, for histograms, same buckets — returns the existing family)."""

    def __init__(self, enabled: bool = True, namespace: str = "serving"):
        self.enabled = enabled
        self.namespace = namespace
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}
        # child -> buckets lookup for Histogram._Bound.observe
        self._hist_buckets: Dict[int, Tuple[float, ...]] = {}

    def _buckets_of(self, child) -> Tuple[float, ...]:
        return self._hist_buckets[id(child)]

    def _full(self, name: str) -> str:
        return f"{self.namespace}_{name}" if self.namespace else name

    def _register(self, cls, name: str, help: str, labelnames, **kw):
        full = self._full(name)
        with self._lock:
            fam = self._families.get(full)
            if fam is not None:
                if not isinstance(fam, cls):
                    raise ValueError(
                        f"{full} already registered as {fam.kind}"
                    )
                if cls is Histogram and kw.get("buckets") is not None:
                    bs = tuple(sorted(float(b) for b in kw["buckets"]))
                    if bs[-1] != math.inf:
                        bs = bs + (math.inf,)
                    if bs != fam.buckets:
                        raise ValueError(f"{full}: bucket mismatch")
                return fam
            fam = cls(self, full, help, labelnames, **kw)
            self._families[full] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(self._full(name))

    def value(self, name: str, **labels) -> float:
        fam = self.get(name)
        return 0.0 if fam is None else fam.value(**labels)

    # -- exposition ----------------------------------------------------------

    def render(self) -> str:
        """Prometheus text format (``text/plain; version=0.0.4``)."""
        lines: List[str] = []
        with self._lock:
            families = list(self._families.values())
            for fam in families:
                lines.append(f"# HELP {fam.name} {fam.help}")
                lines.append(f"# TYPE {fam.name} {fam.kind}")
                children = sorted(fam._children.items())
                for key, child in children:
                    if isinstance(fam, Histogram):
                        cum = 0
                        for i, ub in enumerate(fam.buckets):
                            cum += child.counts[i]
                            ls = _label_str(
                                fam.labelnames + ("le",), key + (_fmt(ub),)
                            )
                            lines.append(f"{fam.name}_bucket{ls} {cum}")
                        ls = _label_str(fam.labelnames, key)
                        lines.append(f"{fam.name}_sum{ls} {_fmt(child.sum)}")
                        lines.append(f"{fam.name}_count{ls} {child.count}")
                    else:
                        ls = _label_str(fam.labelnames, key)
                        lines.append(f"{fam.name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-safe dump: {family: {"type", "help", "series": {label-repr:
        value-or-histogram}}} — what the benchmarks merge into their
        trajectory files so they report the same numbers ``/metrics``
        serves."""
        out: dict = {}
        with self._lock:
            for fam in self._families.values():
                series = {}
                for key, child in sorted(fam._children.items()):
                    label = ",".join(
                        f"{n}={v}" for n, v in zip(fam.labelnames, key)
                    )
                    if isinstance(fam, Histogram):
                        series[label] = {
                            "count": child.count,
                            "sum": child.sum,
                            "buckets": {
                                _fmt(ub): c
                                for ub, c in zip(fam.buckets, child.counts)
                            },
                        }
                    else:
                        series[label] = child.value
                out[fam.name] = {
                    "type": fam.kind, "help": fam.help, "series": series,
                }
        return out

    def series_names(self) -> Iterable[str]:
        """Every family name currently registered (for smoke assertions)."""
        return list(self._families)
