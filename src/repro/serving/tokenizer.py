"""A small self-contained BPE tokenizer for the serving stack.

The engine's detokenizer seam (``Engine(detokenize=...)``) has so far been
fed the toy decimal renderer (``default_detokenize`` -> ``"{id} "``), which
means stop strings and streamed text never looked like real traffic.  This
module provides a real — if tiny — char-level BPE:

* **Pieces are valid ``str``** (char-level, not byte-level), so streamed
  text is always the concatenation of whole pieces and the request-side
  stop-string/holdback machinery operates on exactly the text a user sees.
  Multi-byte characters ("é", "—", "日") are single symbols, exercising the
  holdback path with pieces longer than one UTF-8 byte.
* **Deterministic training** on a corpus string: count adjacent symbol
  pairs, merge the most frequent (ties broken lexicographically), repeat
  until the target vocab size.  No randomness, no external deps.
* **JSON vocab files** (``save``/``load``) so the server and bench load
  the same vocabulary; ``trained()`` returns the embedded-corpus default.
* **Decimal fallback**: ``piece(id)`` renders out-of-vocab ids the way
  ``default_detokenize`` would, so a model emitting ids past the trained
  vocab still streams *something* and never crashes the detokenizer.

The default vocab is capped at 512 entries to match the smoke models'
``vocab=512`` — every id the tokenizer emits is a valid model token.
"""

from __future__ import annotations

import json
import string
from typing import Dict, Iterable, List, Sequence, Tuple

__all__ = ["BPETokenizer", "DEFAULT_CORPUS", "DEFAULT_VOCAB_SIZE"]

DEFAULT_VOCAB_SIZE = 512

# Small mixed corpus: enough English to learn useful merges, plus accented
# and CJK characters so multi-byte pieces exist in the default vocab.
DEFAULT_CORPUS = (
    "You are a helpful assistant. Answer the question concisely and "
    "truthfully. If you are unsure, say so. "
    "The quick brown fox jumps over the lazy dog. "
    "the model serves the request and the request streams the response "
    "to the user while the server batches the decode step. "
    "paged attention maps token positions to pages in the pool. "
    "speculative decoding drafts tokens and verifies them in parallel. "
    "prefix caching shares the system prompt across users. "
    "résumé café naïve touché — em dash, ellipsis… "
    "日本語のテキスト, 中文文本. "
    "0123456789 () [] {} <> != == -> the end.\n"
)


class BPETokenizer:
    """Char-level BPE: ``pieces`` (id -> string), ``merges`` (ranked pairs).

    ``encode`` is exact greedy BPE (always apply the lowest-rank merge
    present), which reproduces the training segmentation; ``decode`` is
    plain concatenation — the property the stop-string machinery relies
    on."""

    def __init__(self, pieces: Sequence[str], merges: Sequence[Tuple[str, str]]):
        self.pieces: List[str] = list(pieces)
        self.merges: List[Tuple[str, str]] = [tuple(m) for m in merges]
        self._id: Dict[str, int] = {p: i for i, p in enumerate(self.pieces)}
        if len(self._id) != len(self.pieces):
            raise ValueError("duplicate pieces in vocab")
        self._rank: Dict[Tuple[str, str], int] = {
            m: r for r, m in enumerate(self.merges)
        }

    # -- training -------------------------------------------------------------

    @classmethod
    def train(cls, corpus: str, vocab_size: int = DEFAULT_VOCAB_SIZE) -> "BPETokenizer":
        # base alphabet: corpus chars plus all printable ASCII, so encode()
        # never chokes on ordinary text the training corpus happened to miss
        symbols = sorted(set(corpus) | set(string.printable))
        if len(symbols) >= vocab_size:
            raise ValueError(
                f"corpus alphabet ({len(symbols)}) already >= vocab_size"
            )
        pieces = list(symbols)
        merges: List[Tuple[str, str]] = []
        seq = list(corpus)
        # cap piece length: without it a repeated corpus degenerately
        # merges into whole sentences, leaving a useless vocab
        max_piece = 12
        while len(pieces) < vocab_size:
            counts: Dict[Tuple[str, str], int] = {}
            for a, b in zip(seq, seq[1:]):
                if len(a) + len(b) <= max_piece:
                    counts[(a, b)] = counts.get((a, b), 0) + 1
            if not counts:
                break
            # most frequent pair; ties broken lexicographically for
            # determinism across python versions
            best = min(counts.items(), key=lambda kv: (-kv[1], kv[0]))[0]
            if counts[best] < 2:
                break
            merges.append(best)
            pieces.append(best[0] + best[1])
            merged, i = [], 0
            while i < len(seq):
                if i + 1 < len(seq) and (seq[i], seq[i + 1]) == best:
                    merged.append(seq[i] + seq[i + 1])
                    i += 2
                else:
                    merged.append(seq[i])
                    i += 1
            seq = merged
        return cls(pieces, merges)

    _DEFAULT: "BPETokenizer" = None

    @classmethod
    def trained(cls) -> "BPETokenizer":
        """The default tokenizer (embedded corpus, vocab 512), cached.
        The corpus is repeated so pair counts stay >= 2 deep into training
        and the merge table actually approaches the vocab cap."""
        if cls._DEFAULT is None:
            cls._DEFAULT = cls.train(DEFAULT_CORPUS * 4, DEFAULT_VOCAB_SIZE)
        return cls._DEFAULT

    # -- persistence ------------------------------------------------------------

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(
                {"pieces": self.pieces, "merges": [list(m) for m in self.merges]},
                f, ensure_ascii=False,
            )

    @classmethod
    def load(cls, path: str) -> "BPETokenizer":
        with open(path, encoding="utf-8") as f:
            blob = json.load(f)
        return cls(blob["pieces"], [tuple(m) for m in blob["merges"]])

    # -- encode / decode ----------------------------------------------------------

    @property
    def vocab_size(self) -> int:
        return len(self.pieces)

    def encode(self, text: str) -> List[int]:
        if not text:
            return []
        seq = list(text)
        unknown = [c for c in seq if c not in self._id]
        if unknown:
            raise ValueError(
                f"characters not in tokenizer alphabet: {sorted(set(unknown))!r}"
            )
        while len(seq) > 1:
            ranked = [
                (self._rank[p], i)
                for i, p in enumerate(zip(seq, seq[1:]))
                if p in self._rank
            ]
            if not ranked:
                break
            rank = min(ranked)[0]
            merged, i = [], 0
            while i < len(seq):
                if (
                    i + 1 < len(seq)
                    and self._rank.get((seq[i], seq[i + 1])) == rank
                ):
                    merged.append(seq[i] + seq[i + 1])
                    i += 2
                else:
                    merged.append(seq[i])
                    i += 1
            seq = merged
        return [self._id[p] for p in seq]

    def piece(self, token_id: int) -> str:
        """Detokenize one id — the ``Engine(detokenize=...)`` callable.
        Ids outside the vocab fall back to the toy decimal rendering, so a
        model sampling past the trained vocab still streams text."""
        if 0 <= token_id < len(self.pieces):
            return self.pieces[token_id]
        return f"{token_id} "

    def decode(self, ids: Iterable[int]) -> str:
        return "".join(self.piece(i) for i in ids)
