"""Public serving API types: the stepwise ``Engine`` surface.

The serving entry points used to be closed-batch functions (``serve_sd``,
``serve_batch``, ...) that took a fixed prompt list and ran to drain.  The
``Engine`` (serving/engine.py) replaces them with the continuous surface the
paper's out-of-order WDOS scheduler actually wants — work arrives and
retires at any time:

    eng = Engine(target, draft, EngineConfig(max_batch=4))
    rid = eng.add_request(prompt, SamplingParams(max_tokens=32))
    while eng.has_unfinished():
        for out in eng.step():          # one WDOS-scheduled SD round
            consume(out.new_token_ids)  # streams as tokens verify

This module holds the request/response types shared by every path:

* ``SamplingParams`` — frozen per-request decode knobs.  ``temperature > 0``
  selects lossless speculative *rejection sampling* with a per-request PRNG
  key stream (seeded by ``seed``), so a request's sampled tokens are
  deterministic regardless of which batch composition it happens to run in.
* ``RequestOutput`` / ``CompletionOutput`` — the single streaming result
  type: each ``Engine.step()`` emits one ``RequestOutput`` per request that
  made progress, carrying the incremental ``new_token_ids`` plus the
  cumulative completion and finish reason.
* ``EngineConfig`` — engine-wide scheduling/residency knobs (the per-request
  knobs moved into ``SamplingParams``).

``resolve_paged_attn_impl`` centralizes the backend auto-selection: the
Pallas paged-attention kernel is the default on TPU (the backend its
dialect lowers on), the bit-exact device gather everywhere else.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Tuple, Union

__all__ = [
    "SamplingParams",
    "CompletionOutput",
    "RequestOutput",
    "EngineConfig",
    "resolve_paged_attn_impl",
    "default_detokenize",
]


def default_detokenize(token_id: int) -> str:
    """The repo's toy LMs decode over an untextured integer vocab, so the
    default "detokenizer" renders each token as its decimal id plus a
    trailing space (``[5, 17] -> "5 17 "``).  Stop-string matching
    (``SamplingParams.stop``) and the HTTP server's ``text`` fields run on
    this stream; pass a real detokenizer to ``Engine``/``AsyncEngine`` when
    serving a real vocabulary."""
    return f"{token_id} "

_PAGED_ATTN_IMPLS = ("gather", "pallas")
# backends where the paged kernel LOWERS: kernels/paged_attn.py is written
# against the TPU Pallas dialect (pltpu.PrefetchScalarGridSpec / VMEM
# scratch) and only interprets elsewhere, so auto-selection must not hand
# it to GPU — the gather path is the correct default there until a
# Triton-dialect port lands
_PALLAS_BACKENDS = ("tpu",)


def resolve_paged_attn_impl(
    impl: Optional[str] = "auto", backend: Optional[str] = None
) -> str:
    """Resolve a paged-attention impl choice to ``"gather"`` or ``"pallas"``.

    ``impl`` of ``None``/``"auto"`` auto-selects by accelerator backend:
    ``"pallas"`` where the kernel compiles (TPU), ``"gather"`` (the
    bit-exact dense replay) everywhere else.  An explicit
    ``"gather"``/``"pallas"`` always wins.  ``backend`` overrides
    ``jax.default_backend()`` (tests)."""
    if impl in _PAGED_ATTN_IMPLS:
        return impl
    if impl not in (None, "auto"):
        raise ValueError(
            f"paged_attn_impl must be one of {_PAGED_ATTN_IMPLS + ('auto',)}, "
            f"got {impl!r}"
        )
    if backend is None:
        import jax

        backend = jax.default_backend()
    return "pallas" if backend in _PALLAS_BACKENDS else "gather"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode parameters (frozen — safe to share across requests).

    ``temperature == 0`` is greedy decoding: deterministic, bit-identical to
    the single-request reference drivers.  ``temperature > 0`` runs lossless
    speculative rejection sampling: the draft proposes from its own
    (temperature/top-k/top-p filtered) distribution and the target accepts
    with the Leviathan rule, so emitted tokens are distributed exactly as
    autoregressive sampling from the target — including nucleus (``top_p``)
    truncation, which filters BOTH distributions identically so the rule
    stays lossless.  All randomness derives from a per-request key stream
    seeded by ``seed`` and indexed by (round, position), never from shared
    state — the same (prompt, params) pair yields the same tokens at batch 1
    and batch N.

    ``stop`` holds stop strings matched against the request's detokenized
    output stream (the engine's ``detokenize`` callable renders tokens to
    text): generation ends with ``finish_reason="stop"`` at the first match,
    and the final output is truncated so the stop string itself is excluded
    (tokens whose text overlaps the match are dropped)."""

    temperature: float = 0.0
    top_k: int = 0  # 0: no truncation; k > 0: sample from the top-k logits
    top_p: float = 1.0  # nucleus mass; 1.0: no truncation
    seed: int = 0
    max_tokens: int = 64
    stop: Tuple[str, ...] = ()  # stop strings over the detokenized stream
    # per-request KV storage opt-in: None defers to the engine's
    # EngineConfig.kv_quant default; "none" pins full-precision pages;
    # "int8" opts into compressed pages (relaxed determinism — see
    # docs/SERVING.md).  An explicit value that the engine mode cannot
    # honour is rejected at add_request time.
    kv_quant: Optional[str] = None

    def __post_init__(self):
        if self.kv_quant not in (None, "none", "int8"):
            raise ValueError(
                f"kv_quant must be None, 'none' or 'int8', got {self.kv_quant!r}"
            )
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens <= 0:
            raise ValueError(f"max_tokens must be > 0, got {self.max_tokens}")
        stop: Union[str, Tuple[str, ...]] = self.stop
        if isinstance(stop, str):
            stop = (stop,)
        stop = tuple(stop)
        for s in stop:
            if not isinstance(s, str) or not s:
                raise ValueError(f"stop entries must be non-empty strings, got {s!r}")
        object.__setattr__(self, "stop", stop)

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclasses.dataclass
class CompletionOutput:
    """One completion of a request (the engine produces exactly one)."""

    index: int
    token_ids: List[int]  # cumulative generated tokens, trimmed to the budget
    finish_reason: Optional[str] = None  # None | "length" | "stop" | "abort"

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None


@dataclasses.dataclass
class RequestOutput:
    """Streaming per-request result emitted by ``Engine.step()``.

    ``new_token_ids`` holds only the tokens verified *this* step (what a
    server would flush to the client); ``outputs[0].token_ids`` is the
    cumulative completion so far."""

    request_id: int
    prompt_token_ids: List[int]
    new_token_ids: List[int]
    finished: bool
    outputs: List[CompletionOutput]

    @property
    def token_ids(self) -> List[int]:
        return self.outputs[0].token_ids


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-wide knobs: scheduling, paging, and attention residency.

    Per-request knobs (``max_tokens``, ``temperature``) live in
    ``SamplingParams``; this config is fixed for the engine's lifetime."""

    max_batch: int = 8  # concurrent DECODE slots (batched model rows)
    page_size: int = 16  # tokens per KV page
    draft_len: int = 3  # fixed draft window (adaptive=False)
    adaptive: bool = False  # per-request APSD draft-length adaptation
    short_dl: int = 2
    long_dl: int = 6
    num_pages: Optional[int] = None  # page budget per pool (None: fit
    # max_batch worst-case requests of max_model_len tokens)
    max_model_len: Optional[int] = None  # peak cache length a request may
    # reach (prompt + max_tokens + draft window).  None defaults to the
    # models' s_max — the honest bound when future requests are unknown,
    # but it sizes the page tables (and the "gather" impl's attention
    # span) to the worst case; set it to your real peak when known, as
    # the deprecated serve_batch wrapper does with its closed prompt list.
    model_wdos: bool = True  # build the per-round WDOS DAG (stats)
    # paged decode-attention impl: "gather" | "pallas" | None ("auto").
    # None resolves per ServingModel (each model's own paged_attn_impl,
    # itself "auto" by default => pallas where it lowers [TPU], gather
    # elsewhere); an explicit value here overrides both models.
    paged_attn_impl: Optional[str] = None
    # cross-request PAR execution:
    #   "off"  — two-phase rounds: every active row drafts in lockstep,
    #            then one batched verify pass scores everyone (the
    #            pre-PAR behaviour, kept bit-identical);
    #   "wdos" — fused rounds: each engine step runs a horizon of FUSED
    #            dispatches in which the WDOS phase planner
    #            (core/scheduler.plan_mixed_slot) picks, per slot, which
    #            rows run a draft micro-step and which verify their full
    #            window — request A verifies while request B drafts in
    #            ONE XLA program, so rows cycle out of phase and a
    #            fast-accepting row commits multiple windows per step.
    # Greedy AND sampled outputs are bit-identical across the two modes
    # (per-row math and key streams are unchanged; only the grouping of
    # work into dispatches differs) — tests/test_par_mode.py.
    par_mode: str = "off"
    # paged-KV storage precision:
    #   "none"  — full-precision pools (the model's cache dtype); every
    #             request bit-identical to the pre-compression engine;
    #   "int8"  — ALL requests store K/V as int8 pages with per-slot
    #             per-kv-head f32 scales (~3.7x fewer pool bytes/token for
    #             f32 models; scales ride their own page-indexed pools).
    #             Dequantization happens inside the attention consumers
    #             (kernels/paged_attn.py epilogue / the device gather), so
    #             pages stay compressed at rest and in flight;
    #   "mixed" — both storages are allocated and each request picks via
    #             SamplingParams.kv_quant (default "none"): fp and int8
    #             rows batch together in the same engine step.
    # A request's explicit SamplingParams.kv_quant must be compatible:
    # "none"/"int8" engines reject requests pinning the other storage.
    kv_quant: str = "none"
    # Copy-on-write prefix cache (serving/prefix_cache.py): cache prompt
    # prefixes at page granularity in a refcounted radix tree and map hits
    # as read-only shared pages, skipping their prefill.  Tokens are
    # bit-identical to prefix_cache=False for every (impl, par_mode,
    # kv_quant) combination — tests/test_prefix_cache.py.
    prefix_cache: bool = False
    # Pool sizing by BYTE budget instead of page count: when set, each
    # pool gets `num_pages_for_bytes(pool_bytes, ...)` pages under its own
    # storage kind, so compressed (int8) pools admit ~3.5x the resident
    # requests of dense pools at the SAME budget.  Mutually exclusive with
    # num_pages.
    pool_bytes: Optional[int] = None
    # Speculation topology:
    #   "chain" — single-branch drafting (one candidate continuation per
    #             round; the historical APSD behaviour, bit-identical);
    #   "tree"  — TREE drafting: a frontier node fans out to
    #             ``spec_branches`` top-k candidate children whenever its
    #             draft top-1 probability falls below ``branch_threshold``
    #             (and the ``tree_budget`` node budget allows), and the
    #             target verifies the WHOLE tree in one ancestor-masked
    #             dispatch.  Accepted tokens stay distribution-exact
    #             (lossless tree rejection sampling,
    #             core/speculative.speculative_tree_sample_host); expected
    #             accepted tokens/round rises precisely on low-acceptance
    #             requests.
    spec_mode: str = "chain"
    spec_branches: int = 2  # fan-out at a branching position (tree mode)
    tree_budget: int = 8  # max drafted nodes per tree round (tree mode)
    # branch when the draft's top-1 probability < branch_threshold: 0.0
    # never branches (a chain-shaped tree), 1.0 branches at every frontier
    # position the node budget allows
    branch_threshold: float = 0.6
    # Sampled device-time profiling: every Nth engine round, each dispatched
    # program (prefill / draft / verify / fused_wdos / tree variants /
    # compaction) is bracketed with block_until_ready timing, stamped once
    # with XLA cost_analysis() FLOPs/bytes at compile time, and emitted as a
    # span on the tracer's "device" track.  0 disables (the default); timing
    # never changes the math, so tokens stay bit-identical with profiling on
    # (tests/test_observability.py).  Unprofiled rounds pay one int compare.
    profile_every_n: int = 0
    # Flight recorder (serving/flight_recorder.py): bounded ring of
    # per-round records with anomaly triggers (slow round, acceptance
    # collapse, pool exhaustion, admission stall).  flight_ring=0 disables
    # recording entirely; flight_dump_dir writes postmortem JSON files
    # there when an anomaly fires (None: postmortems stay in memory,
    # readable at GET /debug/flight).
    flight_ring: int = 256
    flight_dump_dir: Optional[str] = None

    def __post_init__(self):
        if self.par_mode not in ("off", "wdos"):
            raise ValueError(
                f"par_mode must be 'off' or 'wdos', got {self.par_mode!r}"
            )
        if self.spec_mode not in ("chain", "tree"):
            raise ValueError(
                f"spec_mode must be 'chain' or 'tree', got {self.spec_mode!r}"
            )
        if self.spec_mode == "tree":
            if self.spec_branches < 2:
                raise ValueError(
                    f"spec_branches must be >= 2, got {self.spec_branches}"
                )
            if self.tree_budget < 1:
                raise ValueError(
                    f"tree_budget must be >= 1, got {self.tree_budget}"
                )
            if not (0.0 <= self.branch_threshold <= 1.0):
                raise ValueError(
                    f"branch_threshold must be in [0, 1], got "
                    f"{self.branch_threshold}"
                )
        if self.kv_quant not in ("none", "int8", "mixed"):
            raise ValueError(
                f"kv_quant must be 'none', 'int8' or 'mixed', got "
                f"{self.kv_quant!r}"
            )
        if self.pool_bytes is not None:
            if self.num_pages is not None:
                raise ValueError("set num_pages or pool_bytes, not both")
            if self.pool_bytes <= 0:
                raise ValueError(f"pool_bytes must be > 0, got {self.pool_bytes}")
        if self.profile_every_n < 0:
            raise ValueError(
                f"profile_every_n must be >= 0, got {self.profile_every_n}"
            )
        if self.flight_ring < 0:
            raise ValueError(
                f"flight_ring must be >= 0, got {self.flight_ring}"
            )

    @property
    def max_dl(self) -> int:
        return self.long_dl if self.adaptive else self.draft_len

    @property
    def spec_window(self) -> int:
        """Worst-case speculative tokens resident in a request's cache at
        once — what admission must reserve beyond prompt + max_tokens.  A
        chain round writes at most ``max_dl`` uncommitted drafts; a tree
        round writes the whole padded window (``tree_budget`` nodes)."""
        return self.tree_budget if self.spec_mode == "tree" else self.max_dl

    @property
    def kv_kinds(self) -> Tuple[str, ...]:
        """The KV storage kinds this engine allocates pools for."""
        return ("none", "int8") if self.kv_quant == "mixed" else (self.kv_quant,)

    def resolve_kv_quant(self, requested: Optional[str]) -> str:
        """Resolve a request's ``SamplingParams.kv_quant`` against the engine
        mode: ``None`` takes the engine default ("none" under "mixed"); an
        explicit choice must name a storage the engine allocated."""
        if requested is None:
            return "none" if self.kv_quant == "mixed" else self.kv_quant
        if requested not in self.kv_kinds:
            raise ValueError(
                f"request kv_quant={requested!r} is incompatible with engine "
                f"kv_quant={self.kv_quant!r} (allocated kinds: {self.kv_kinds})"
            )
        return requested


# ---------------------------------------------------------------------------
# Deprecation bookkeeping for the legacy serve_* wrappers
# ---------------------------------------------------------------------------

_DEPRECATION_EMITTED: set = set()


def warn_deprecated_once(name: str, replacement: str) -> None:
    """Emit a DeprecationWarning for `name` at most once per process, so a
    server's log is not flooded by per-request wrapper calls.  (Whether the
    one emission is *displayed* still follows the active warning filters,
    as with any ``warnings.warn``.)"""
    if name in _DEPRECATION_EMITTED:
        return
    _DEPRECATION_EMITTED.add(name)
    warnings.warn(
        f"{name}() is deprecated; use {replacement}",
        DeprecationWarning,
        stacklevel=3,
    )
