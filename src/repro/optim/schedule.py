"""LR schedules (pure functions of the step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "linear_warmup_cosine"]


def cosine_schedule(step, total_steps: int, min_frac: float = 0.1):
    t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    return min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def linear_warmup_cosine(step, warmup: int, total_steps: int, min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(warmup, 1), 1.0)
    return warm * cosine_schedule(step, total_steps, min_frac)
