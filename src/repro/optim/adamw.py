"""AdamW with ZeRO-1 sharded moments and configurable moment dtype.

Moments inherit each parameter's PartitionSpec plus an optional extra
sharding over the 'data' axis (ZeRO-1) on the largest dim when the spec
leaves it free.  The giants (llama3-405b) run bf16 moments (DESIGN.md §6);
everything else fp32.  Global-norm clipping is fused into the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "optimizer_specs"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def optimizer_specs(
    param_specs,
    abstract_params=None,
    zero1_axis: Optional[str] = "data",
    axis_size: int = 1,
):
    """Moment specs mirror parameter specs.  When ``zero1_axis`` is set, each
    moment additionally shards its largest free-and-divisible dim over that
    axis (ZeRO-1).  ``abstract_params`` supplies shapes for the divisibility
    check; without it no extra sharding is added."""

    def one(spec: P, shape) -> P:
        parts = list(spec)
        if zero1_axis and shape is not None:
            # pad spec to rank
            parts = parts + [None] * (len(shape) - len(parts))
            free = [
                (shape[i], i)
                for i in range(len(shape))
                if parts[i] is None and shape[i] % max(axis_size, 1) == 0 and shape[i] >= axis_size
            ]
            if free:
                _, idx = max(free)
                parts[idx] = zero1_axis
        return P(*parts)

    if abstract_params is None:
        mom = jax.tree.map(
            lambda s: one(s, None), param_specs, is_leaf=lambda s: isinstance(s, P)
        )
    else:
        flat_s, tdef = jax.tree.flatten(
            param_specs, is_leaf=lambda s: isinstance(s, P)
        )
        flat_p = tdef.flatten_up_to(abstract_params)
        mom = tdef.unflatten(
            [one(s, p.shape) for s, p in zip(flat_s, flat_p)]
        )
    return {"mu": mom, "nu": mom, "count": P()}


def adamw_update(
    params,
    grads,
    state,
    cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
):
    """-> (new_params, new_state, metrics)."""
    # global-norm clip (f32 accumulation)
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**cf
    bc2 = 1.0 - cfg.b2**cf
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm}
