"""Gradient compression for cross-pod traffic: per-tensor INT8 with error
feedback (the residual of each quantization round folds into the next).

At 512+ chips the cross-pod data-parallel all-reduce is the scarce
collective; INT8 gradients cut those bytes 4x vs f32 (2x vs bf16) at the
cost of one extra buffer.  Error feedback keeps the *accumulated* quantizer
bias at zero, which is what preserves convergence (1-bit Adam lineage).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ErrorFeedbackState", "compress_grads_int8", "decompress_grads_int8"]


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching grads


def ef_init(grads_like) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress_grads_int8(
    grads, ef: ErrorFeedbackState
) -> Tuple[Any, Any, ErrorFeedbackState]:
    """-> (int8 tree, f32 scale tree, new error-feedback state)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qs = tdef.unflatten([o[0] for o in outs])
    scales = tdef.unflatten([o[1] for o in outs])
    new_ef = ErrorFeedbackState(residual=tdef.unflatten([o[2] for o in outs]))
    return qs, scales, new_ef


def decompress_grads_int8(qs, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, qs, scales
    )
