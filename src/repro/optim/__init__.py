from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    optimizer_specs,
)
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_grads_int8,
    decompress_grads_int8,
    ef_init,
    ErrorFeedbackState,
)
