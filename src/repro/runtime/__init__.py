from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticTrainer,
    FaultToleranceConfig,
    HeartbeatMonitor,
    StragglerMitigator,
)
