"""Fault-tolerant training runtime: heartbeats, checkpoint-restart,
elastic re-meshing, straggler mitigation.

At 1000+ nodes the design invariants are:

  * every piece of training state is (a) a pure function of (seed, step) —
    the data pipeline — or (b) in the checkpoint — params/optimizer;
  * the checkpoint restores onto ANY mesh shape (store.py reshards), so a
    failed node shrinks the fleet instead of stopping it;
  * stragglers are detected from step-time statistics (p50-relative) and
    mitigated by re-meshing away the slow host or, for the serving path,
    shrinking the draft window (APSD's own feedback does this natively).

The ``ElasticTrainer`` here drives those pieces with an injectable failure
source so the whole recovery path is unit-testable on CPU: tests kill a
"node" mid-run and assert training resumes from the last checkpoint on a
smaller mesh with identical loss trajectory up to the failure point.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.store import AsyncCheckpointer, latest_step, load_checkpoint

__all__ = [
    "FaultToleranceConfig",
    "HeartbeatMonitor",
    "StragglerMitigator",
    "ElasticTrainer",
]


@dataclasses.dataclass(frozen=True)
class FaultToleranceConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 2.0  # p50 multiplier that flags a host
    straggler_window: int = 16
    max_restarts: int = 8


class HeartbeatMonitor:
    """Tracks per-host liveness from timestamped heartbeats."""

    def __init__(self, hosts: List[int], timeout_s: float, clock=time.monotonic):
        self._clock = clock
        self.timeout = timeout_s
        self._last: Dict[int, float] = {h: clock() for h in hosts}

    def beat(self, host: int):
        self._last[host] = self._clock()

    def dead_hosts(self) -> List[int]:
        now = self._clock()
        return [h for h, t in self._last.items() if now - t > self.timeout]

    def remove(self, host: int):
        self._last.pop(host, None)


class StragglerMitigator:
    """Flags hosts whose step time exceeds ``factor`` x fleet median."""

    def __init__(self, hosts: List[int], factor: float, window: int):
        self.factor = factor
        self._times: Dict[int, deque] = {h: deque(maxlen=window) for h in hosts}

    def record(self, host: int, step_time: float):
        if host in self._times:
            self._times[host].append(step_time)

    def remove(self, host: int):
        self._times.pop(host, None)

    def stragglers(self) -> List[int]:
        means = {
            h: float(np.mean(t)) for h, t in self._times.items() if len(t) >= 4
        }
        if len(means) < 2:
            return []
        med = float(np.median(list(means.values())))
        return [h for h, m in means.items() if m > self.factor * med]


class ElasticTrainer:
    """Checkpoint-restart + elastic re-mesh driver.

    Parameters
    ----------
    build_fn(n_hosts, restore) -> (state, step_fn): constructs the mesh-
        dependent training state; ``restore`` is (step, tree) or None.
        ``step_fn(state, step) -> (state, metrics)`` runs one step.
    state_to_tree / tree_to_state: checkpointable view of the state.
    failure_source() -> Optional[int]: host id that died this tick (tests
        inject here; production wires the HeartbeatMonitor).
    """

    def __init__(
        self,
        cfg: FaultToleranceConfig,
        n_hosts: int,
        build_fn: Callable[..., Tuple[Any, Callable]],
        state_to_tree: Callable[[Any], Any],
        failure_source: Optional[Callable[[], Optional[int]]] = None,
        min_hosts: int = 1,
    ):
        self.cfg = cfg
        self.n_hosts = n_hosts
        self.min_hosts = min_hosts
        self.build_fn = build_fn
        self.state_to_tree = state_to_tree
        self.failure_source = failure_source or (lambda: None)
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir)
        self.restarts = 0
        self.history: List[dict] = []

    def _restore_tuple(self):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return None
        step, tree, extra = load_checkpoint(self.cfg.ckpt_dir, step)
        return step, tree, extra

    def run(self, total_steps: int) -> List[dict]:
        step = 0
        state, step_fn = self.build_fn(self.n_hosts, self._restore_tuple())
        restored = self._restore_tuple()
        if restored is not None:
            step = restored[0] + 1
        while step < total_steps:
            dead = self.failure_source()
            if dead is not None:
                # --- node failure: shrink fleet, restore, rebuild mesh
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted")
                self.n_hosts = max(self.n_hosts - 1, self.min_hosts)
                self.ckpt.wait()
                restored = self._restore_tuple()
                state, step_fn = self.build_fn(self.n_hosts, restored)
                step = (restored[0] + 1) if restored is not None else 0
                self.history.append({"event": "restart", "step": step,
                                     "n_hosts": self.n_hosts})
                continue
            state, metrics = step_fn(state, step)
            metrics = dict(metrics)
            metrics.update({"event": "step", "step": step, "n_hosts": self.n_hosts})
            self.history.append(metrics)
            if step % self.cfg.ckpt_every == 0 or step == total_steps - 1:
                self.ckpt.save(step, self.state_to_tree(state), {"step": step})
            step += 1
        self.ckpt.wait()
        return self.history
