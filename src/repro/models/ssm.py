"""Mamba2 (SSD — state-space duality) blocks: chunked training scan,
O(1)-state decode step  [arXiv:2405.21060].

The SSD parametrization: per head h, state x_t evolves as
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t (x) u_t
    y_t = C_t . S_t + D_h * u_t
with B_t, C_t shared across head groups (``ssm_groups``, GQA-like).  Training
uses the chunked dual form: quadratic attention-like intra-chunk term plus a
chunk-level recurrence — sub-quadratic in sequence length, which is why the
``long_500k`` shape runs for SSM/hybrid archs only.

TP: heads shard over 'model' (d_inner = heads * headdim; all assigned SSM
configs have heads % 16 == 0); the state dim N stays local per head.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_init

Params = Dict[str, Any]

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "init_ssm_cache", "ssd_chunked"]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def mamba_init(key: jax.Array, cfg: ModelConfig) -> Tuple[Params, Params]:
    d = cfg.d_model
    din = cfg.d_inner
    h = cfg.ssm_heads
    g = cfg.ssm_groups
    n = cfg.ssm_state
    conv_ch = din + 2 * g * n
    dt = cfg.jdtype
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    params = {
        # in_proj -> [z (din), x (din), B (g*n), C (g*n), dt (h)]
        "w_in": jax.random.normal(ks[0], (d, 2 * din + 2 * g * n + h), dt) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), dt) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "w_out": jax.random.normal(ks[2], (din, d), dt) / math.sqrt(din),
    }
    norm_p, _ = rmsnorm_init(din, dt)
    params["norm"] = norm_p
    fs = "data" if cfg.fsdp else None
    specs = {
        "w_in": P(fs, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P("model"),
        "D": P("model"),
        "dt_bias": P("model"),
        "w_out": P("model", fs),
        "norm": {"g": P("model")},
    }
    return params, specs


def _split_in(proj: jnp.ndarray, cfg: ModelConfig):
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din : 2 * din + 2 * g * n]
    dt = proj[..., 2 * din + 2 * g * n :]
    return z, xbc, dt


def _causal_conv_with_history(
    combined: jnp.ndarray, s: int, w: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Depthwise causal conv: ``combined`` (B, W-1+S, C) already carries the
    left history; returns the last ``s`` conv outputs (B, S, C)."""
    width = w.shape[0]
    out = sum(
        combined[:, i : i + s, :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(combined.dtype)


# ---------------------------------------------------------------------------
# SSD chunked scan (training / prefill)
# ---------------------------------------------------------------------------


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., q) -> (..., q, q) lower-triangular segment sums:
    out[.., i, j] = sum_{j < k <= i} a[.., k] (0 on diagonal, -inf above)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, Pdim) — already dt-scaled inputs u * dt
    da: jnp.ndarray,  # (B, S, H) log-decay dt * A  (negative)
    b_mat: jnp.ndarray,  # (B, S, H, N) B expanded to heads
    c_mat: jnp.ndarray,  # (B, S, H, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, Pdim, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xr = x.reshape(bsz, nc, q, h, p)
    dar = da.reshape(bsz, nc, q, h).astype(jnp.float32)
    br = b_mat.reshape(bsz, nc, q, h, n)
    cr = c_mat.reshape(bsz, nc, q, h, n)

    # intra-chunk (diagonal) term: attention-like with decay kernel L
    ell = jnp.exp(_segsum(dar.transpose(0, 1, 3, 2)))  # (b, nc, h, q, q)
    scores = jnp.einsum("bclhn,bcshn->bchls", cr, br)  # (b,nc,h,q,q)
    y_diag = jnp.einsum(
        "bchls,bchls,bcshp->bclhp",
        scores,
        ell.astype(scores.dtype),
        xr,
    )

    # chunk states: contribution of each chunk to the running state
    da_cum = jnp.cumsum(dar, axis=2)  # (b,nc,q,h)
    da_total = da_cum[:, :, -1, :]  # (b,nc,h)
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cum)  # (b,nc,q,h)
    states = jnp.einsum(
        "bcshn,bcsh,bcshp->bchpn", br, decay_to_end.astype(br.dtype), xr
    )  # (b,nc,h,p,n)

    # inter-chunk recurrence over nc
    def step(carry, inp):
        st_prev = carry  # (b,h,p,n) f32
        st_c, da_tot = inp  # (b,h,p,n), (b,h)
        new = st_c.astype(jnp.float32) + jnp.exp(da_tot)[:, :, None, None] * st_prev
        return new, st_prev

    st0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    states_t = jnp.moveaxis(states, 1, 0)  # (nc, b, h, p, n)
    da_tot_t = jnp.moveaxis(da_total, 1, 0)  # (nc, b, h)
    final_state, prev_states = jax.lax.scan(step, st0, (states_t, da_tot_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,h,p,n) state BEFORE chunk

    # off-diagonal term: prior state read out through decay
    state_decay = jnp.exp(da_cum)  # (b,nc,q,h)
    y_off = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp",
        cr,
        prev_states.astype(cr.dtype),
        state_decay.astype(cr.dtype),
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _expand_groups(m: jnp.ndarray, heads: int) -> jnp.ndarray:
    """(B, S, G, N) -> (B, S, H, N) by repeating each group H/G times."""
    g = m.shape[2]
    if g == heads:
        return m
    return jnp.repeat(m, heads // g, axis=2)


def mamba_apply(
    params: Params,
    xin: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    init_state: Optional[jnp.ndarray] = None,
    conv_state: Optional[jnp.ndarray] = None,  # (B, W-1, C) cached tail
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence SSD. Returns (y (B,S,d), final ssm state, conv tail).

    ``conv_state`` carries the previous window's last W-1 conv inputs so
    extend calls (SD verify windows) are exact; zeros == fresh sequence."""
    bsz, s, _ = xin.shape
    h, p, n, g = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    proj = xin @ params["w_in"]
    z, xbc, dt_raw = _split_in(proj, cfg)
    width = cfg.ssm_conv
    if conv_state is None:
        conv_state = jnp.zeros((bsz, width - 1, xbc.shape[-1]), xbc.dtype)
    combined = jnp.concatenate([conv_state, xbc], axis=1)  # (B, W-1+S, C)
    conv_tail = combined[:, -(width - 1) :, :]  # next window's conv state
    xbc = _causal_conv_with_history(combined, s, params["conv_w"], params["conv_b"])
    xpart = xbc[..., : cfg.d_inner].reshape(bsz, s, h, p)
    b_mat = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., cfg.d_inner + g * n :].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )  # (B,S,H)
    a = -jnp.exp(params["A_log"])  # (H,) negative
    da = dt * a  # log decay
    x_scaled = xpart * dt[..., None].astype(xpart.dtype)
    b_h, c_h = _expand_groups(b_mat, h), _expand_groups(c_mat, h)
    # pad S to a chunk multiple: zero inputs contribute nothing to states
    # and zero log-decay (exp(0)=1) leaves the recurrence untouched — exact.
    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        x_scaled = jnp.pad(x_scaled, padw)
        b_h = jnp.pad(b_h, padw)
        c_h = jnp.pad(c_h, padw)
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    y, final_state = ssd_chunked(x_scaled, da, b_h, c_h, q, init_state)
    if pad:
        y = y[:, :s]
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xpart
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    return y @ params["w_out"], final_state, conv_tail


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    h, p, n, g = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    conv_ch = cfg.d_inner + 2 * g * n
    return {
        "state": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba_decode(
    params: Params,
    xin: jnp.ndarray,  # (B, 1, d)
    cfg: ModelConfig,
    cache: Dict[str, jnp.ndarray],
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token recurrent step: O(1) state update (no KV growth)."""
    bsz = xin.shape[0]
    h, p, n, g = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    proj = xin @ params["w_in"]  # (B,1,...)
    z, xbc_new, dt_raw = _split_in(proj, cfg)
    # conv over [cached tail, new]: take the newest output column
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, W, C)
    w = params["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xin.dtype)  # (B, C)
    xpart = xbc[..., : cfg.d_inner].reshape(bsz, h, p)
    b_mat = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(bsz, g, n)
    c_mat = xbc[..., cfg.d_inner + g * n :].reshape(bsz, g, n)
    b_h = jnp.repeat(b_mat, h // g, axis=1)  # (B,H,N)
    c_h = jnp.repeat(c_mat, h // g, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a)  # (B,H)
    dbx = jnp.einsum(
        "bhp,bhn->bhpn", (xpart * dt[..., None].astype(xpart.dtype)).astype(jnp.float32), b_h.astype(jnp.float32)
    )
    state = cache["state"] * decay[:, :, None, None] + dbx
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h.astype(jnp.float32)).astype(xin.dtype)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xpart
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    new_cache = {"state": state, "conv": window[:, 1:, :]}
    return y @ params["w_out"], new_cache
