"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment — ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, d).  The encoder is bidirectional
attention + GELU MLP with a learned positional table; the decoder is causal
self-attention (cached) + cross-attention onto the encoder states + GELU
MLP.  Whisper is MHA (n_kv == n_heads == 20); 20 % 16 != 0, so attention
runs data-parallel with replicated attention weights while the FFN stays
TP-sharded (DESIGN.md §Arch-applicability).

Decode shapes exercise the DECODER (one new token against a self-KV cache
of seq_len plus cross-attention onto 1500 frames).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.common import ModelConfig
from repro.models.lm import _sp_constrain, batch_axes_for

Params = Dict[str, Any]

__all__ = [
    "init_whisper",
    "apply_whisper",
    "init_whisper_cache",
    "whisper_cache_specs",
    "whisper_loss_fn",
]


def _enc_layer_init(key, cfg: ModelConfig, tp: int):
    k1, k2 = jax.random.split(key)
    ap, asp = L.attention_init(k1, cfg, tp)
    n1, n1s = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    n2, n2s = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    mp, msp = L.mlp_init(k2, cfg)
    return (
        {"ln1": n1, "attn": ap, "ln2": n2, "mlp": mp},
        {"ln1": n1s, "attn": asp, "ln2": n2s, "mlp": msp},
    )


def _dec_layer_init(key, cfg: ModelConfig, tp: int):
    k1, k2, k3 = jax.random.split(key, 3)
    sp_, ssp = L.attention_init(k1, cfg, tp)
    cp, csp = L.attention_init(k2, cfg, tp)
    n1, n1s = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    n2, n2s = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    n3, n3s = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    mp, msp = L.mlp_init(k3, cfg)
    return (
        {"ln1": n1, "self": sp_, "ln2": n2, "cross": cp, "ln3": n3, "mlp": mp},
        {"ln1": n1s, "self": ssp, "ln2": n2s, "cross": csp, "ln3": n3s, "mlp": msp},
    )


def _stack(fn, key, n, cfg, tp):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k, cfg, tp)[0])(keys)
    _, s1 = fn(keys[0], cfg, tp)
    specs = jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), s1, is_leaf=lambda s: isinstance(s, P)
    )
    return params, specs


def init_whisper(key: jax.Array, cfg: ModelConfig, tp: int = 1) -> Tuple[Params, Params]:
    ke, kd, kt, kp, kf1, kf2 = jax.random.split(key, 6)
    dt = cfg.jdtype
    enc_p, enc_s = _stack(_enc_layer_init, ke, cfg.n_encoder_layers, cfg, tp)
    dec_p, dec_s = _stack(_dec_layer_init, kd, cfg.n_layers, cfg, tp)
    emb_p, emb_s = L.embed_init(kt, cfg)
    n1, n1s = L.rmsnorm_init(cfg.d_model, dt)
    n2, n2s = L.rmsnorm_init(cfg.d_model, dt)
    params = {
        "embed": emb_p,
        "enc_pos": jax.random.normal(kp, (cfg.n_audio_frames, cfg.d_model), dt) * 0.01,
        "encoder": enc_p,
        "decoder": dec_p,
        "enc_norm": n1,
        "dec_norm": n2,
    }
    specs = {
        "embed": emb_s,
        "enc_pos": P(None, None),
        "encoder": enc_s,
        "decoder": dec_s,
        "enc_norm": n1s,
        "dec_norm": n2s,
    }
    return params, specs


def encode(params: Params, cfg: ModelConfig, mesh, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, n_frames, d) stubbed conv output -> encoder states."""
    tp = mesh.shape["model"] if mesh is not None else 1
    x = frames.astype(cfg.jdtype) + params["enc_pos"][None]
    x = _sp_constrain(x, cfg, mesh)

    def body(carry, p):
        xc = carry
        h, _ = L.attention_apply(
            p["attn"], L.rmsnorm(p["ln1"], xc), cfg, tp, causal=False, use_rope=False
        )
        xc = _sp_constrain(xc + h, cfg, mesh)
        f = L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], xc), cfg)
        xc = _sp_constrain(xc + f, cfg, mesh)
        return xc, None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, params["encoder"])
    else:
        for i in range(cfg.n_encoder_layers):
            x, _ = fn(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return L.rmsnorm(params["enc_norm"], x)


def _cross_kv(p: Params, cfg: ModelConfig, tp: int, enc: jnp.ndarray):
    k = jnp.einsum("btd,dhk->bthk", enc, p["cross"]["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc, p["cross"]["wv"])
    store = L.kv_store_heads(cfg, tp)
    return L._repeat_kv(k, store), L._repeat_kv(v, store)


def init_whisper_cache(cfg: ModelConfig, batch: int, s_max: int, tp: int = 1, dtype=None):
    dtype = dtype or cfg.jdtype
    kvs = L.kv_store_heads(cfg, tp)
    shape = (cfg.n_layers, batch, s_max, kvs, cfg.hd)
    xshape = (cfg.n_layers, batch, cfg.n_audio_frames, kvs, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "xk": jnp.zeros(xshape, dtype),
        "xv": jnp.zeros(xshape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def whisper_cache_specs(cfg: ModelConfig, tp: int, batch_axes):
    hspec = "model" if L.attn_tp_enabled(cfg, tp) else None
    sp = P(None, batch_axes, None, hspec, None)
    return {"k": sp, "v": sp, "xk": sp, "xv": sp, "length": P()}


def apply_whisper(
    params: Params,
    cfg: ModelConfig,
    mesh,
    tokens: jnp.ndarray,  # (B, S)
    frames: Optional[jnp.ndarray] = None,  # (B, n_frames, d); None when cached
    cache: Optional[Params] = None,
    last_logit_only: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    tp = mesh.shape["model"] if mesh is not None else 1
    b, s = tokens.shape
    offset = cache["length"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(offset + jnp.arange(s)[None, :], (b, s))
    x = params["embed"]["tok"][tokens].astype(cfg.jdtype)
    x = _sp_constrain(x, cfg, mesh)

    enc = encode(params, cfg, mesh, frames) if frames is not None else None
    new_cache = dict(cache) if cache is not None else None

    def body(carry, xs):
        xc = carry
        p, kv = xs
        # self-attention (cached when serving)
        sc = None
        if kv is not None:
            sc = L.Cache(k=kv["k"], v=kv["v"], length=offset)
        h, nc = L.attention_apply(
            p["self"], L.rmsnorm(p["ln1"], xc), cfg, tp, cache=sc, positions=positions
        )
        xc = _sp_constrain(xc + h, cfg, mesh)
        # cross-attention onto encoder states
        if kv is not None and enc is None:
            xk, xv = kv["xk"], kv["xv"]
        else:
            xk, xv = _cross_kv(p, cfg, tp, enc)
        h2, _ = L.attention_apply(
            p["cross"], L.rmsnorm(p["ln2"], xc), cfg, tp,
            kv_override=(xk, xv), positions=positions, use_rope=False,
        )
        xc = _sp_constrain(xc + h2, cfg, mesh)
        f = L.mlp_apply(p["mlp"], L.rmsnorm(p["ln3"], xc), cfg)
        xc = _sp_constrain(xc + f, cfg, mesh)
        ys = None
        if kv is not None:
            ys = {"k": nc.k, "v": nc.v, "xk": xk, "xv": xv}
        return xc, ys

    remat = cfg.remat and cache is None
    fn = jax.checkpoint(body) if remat else body

    def loop(bodyfn, carry, xs_tree, n):
        if cfg.scan_layers:
            return jax.lax.scan(bodyfn, carry, xs_tree)
        ys = []
        for i in range(n):
            sl = jax.tree.map(lambda a: a[i], xs_tree)
            carry, y = bodyfn(carry, sl)
            ys.append(y)
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys and ys[0] is not None else None
        return carry, ys

    if cache is not None:
        xs = (
            params["decoder"],
            {"k": cache["k"], "v": cache["v"], "xk": cache["xk"], "xv": cache["xv"]},
        )
        x, outs = loop(fn, x, xs, cfg.n_layers)
        new_cache.update(outs)
        new_cache["length"] = offset + s
    else:
        x, _ = loop(lambda c, p: fn(c, (p, None)), x, params["decoder"], cfg.n_layers)
    x = L.rmsnorm(params["dec_norm"], x)
    if last_logit_only:
        x = x[:, -1:, :]
    logits = x @ params["embed"]["head"].astype(cfg.jdtype)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e9)
    return logits, new_cache


def whisper_loss_fn(params, cfg, mesh, tokens, frames):
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits, _ = apply_whisper(params, cfg, mesh, inp, frames=frames)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
