"""Unified decoder-only LM covering the dense / MoE / SSM / hybrid / VLM
families, with scan-over-layers (bounded HLO for 126-layer models), fully
functional KV/SSM caches, and sharding annotations for the
(pod) x data x model production mesh.

Entry points
------------
init_lm(key, cfg, tp)                         -> (params, specs)
apply_lm(params, cfg, mesh, tokens, ...)      -> (logits, cache)
init_cache(cfg, batch, s_max, tp, dtype)      -> cache pytree (+ specs)
loss_fn(params, cfg, mesh, tokens, targets)   -> scalar xent

Distribution notes
------------------
* batch shards over ('pod','data'); the residual stream's sequence dim
  shards over 'model' between blocks (Megatron-SP) when cfg.seq_shard and
  S > 1 — XLA inserts the gather/scatter pairs around attention/FFN.
* q heads shard over 'model' when divisible (see layers.attn_tp_enabled);
  KV is replicated to `kv_store_heads` virtual heads so the cache shards
  evenly with zero extra attention collectives.
* MoE layers run the GShard all-to-all path inside shard_map.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.common import Family, ModelConfig

Params = Dict[str, Any]

__all__ = [
    "init_lm",
    "apply_lm",
    "init_cache",
    "cache_specs",
    "loss_fn",
    "batch_axes_for",
    "param_count",
]


def _unrolled_pairs(body, carry, xs_tree):
    n = jax.tree.leaves(xs_tree)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], xs_tree)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def batch_axes_for(mesh, batch=None) -> Any:
    if mesh is None:
        return "data"
    if batch is None:
        return ("pod", "data") if "pod" in mesh.shape else "data"
    from repro.models.layers import pick_batch_axes

    return pick_batch_axes(mesh, batch)


def _tp_of(mesh) -> int:
    return mesh.shape["model"] if mesh is not None else 1


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(key: jax.Array, cfg: ModelConfig, tp: int, block: str):
    """One block's (params, specs). block: dense | moe | ssm."""
    dt = cfg.jdtype
    if block == "ssm":
        k1, k2 = jax.random.split(key)
        mp, ms = S.mamba_init(k1, cfg)
        np_, ns = L.rmsnorm_init(cfg.d_model, dt)
        return {"ln": np_, "mamba": mp}, {"ln": ns, "mamba": ms}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ap, asp = L.attention_init(k1, cfg, tp)
    n1, n1s = L.rmsnorm_init(cfg.d_model, dt)
    n2, n2s = L.rmsnorm_init(cfg.d_model, dt)
    if block == "moe":
        fp, fsp = L.moe_init(k2, cfg, tp)
        return (
            {"ln1": n1, "attn": ap, "ln2": n2, "moe": fp},
            {"ln1": n1s, "attn": asp, "ln2": n2s, "moe": fsp},
        )
    fp, fsp = L.mlp_init(k2, cfg)
    return (
        {"ln1": n1, "attn": ap, "ln2": n2, "mlp": fp},
        {"ln1": n1s, "attn": asp, "ln2": n2s, "mlp": fsp},
    )


def _stacked_layers(key: jax.Array, cfg: ModelConfig, tp: int, block: str, n: int):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: _layer_init(k, cfg, tp, block)[0])(keys)
    _, specs1 = _layer_init(keys[0], cfg, tp, block)
    # stacked: prepend None (layer axis unsharded) to every leaf spec
    specs = jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), specs1,
        is_leaf=lambda s: isinstance(s, P),
    )
    return params, specs


def hybrid_groups(cfg: ModelConfig) -> Tuple[int, int]:
    """(full groups of attn_every mamba blocks + shared attn, remainder)."""
    every = cfg.attn_every
    return cfg.n_layers // every, cfg.n_layers % every


def init_lm(key: jax.Array, cfg: ModelConfig, tp: int = 1) -> Tuple[Params, Params]:
    ke, kl, kf, ks = jax.random.split(key, 4)
    emb_p, emb_s = L.embed_init(ke, cfg)
    fin_p, fin_s = L.rmsnorm_init(cfg.d_model, cfg.jdtype)
    params: Params = {"embed": emb_p, "final_norm": fin_p}
    specs: Params = {"embed": emb_s, "final_norm": fin_s}
    fam = cfg.family
    if fam in (Family.DENSE, Family.VLM):
        params["layers"], specs["layers"] = _stacked_layers(
            kl, cfg, tp, "dense", cfg.n_layers
        )
    elif fam is Family.MOE:
        params["layers"], specs["layers"] = _stacked_layers(
            kl, cfg, tp, "moe", cfg.n_layers
        )
    elif fam is Family.SSM:
        params["layers"], specs["layers"] = _stacked_layers(
            kl, cfg, tp, "ssm", cfg.n_layers
        )
    elif fam is Family.HYBRID:
        ng, rem = hybrid_groups(cfg)
        grouped, gspecs = _stacked_layers(kl, cfg, tp, "ssm", ng * cfg.attn_every)
        # reshape leading axis (ng * every, ...) -> (ng, every, ...)
        params["layers"] = jax.tree.map(
            lambda x: x.reshape(ng, cfg.attn_every, *x.shape[1:]), grouped
        )
        specs["layers"] = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), gspecs,
            is_leaf=lambda s: isinstance(s, P),
        )
        if rem:
            params["tail"], specs["tail"] = _stacked_layers(kf, cfg, tp, "ssm", rem)
        # ONE shared attention block (zamba2), reused at every application
        sp, ss = _layer_init(ks, cfg, tp, "dense")
        params["shared_attn"] = sp
        specs["shared_attn"] = ss
    else:
        raise ValueError(f"init_lm does not handle family {fam}")
    return params, specs


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _attn_cache(cfg, n_layers, batch, s_max, tp, dtype):
    kvs = L.kv_store_heads(cfg, tp)
    shape = (n_layers, batch, s_max, kvs, cfg.hd)
    if cfg.kv_quant:
        sshape = (n_layers, batch, s_max, kvs, 1)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attn_cache_spec(cfg, tp, batch_axes):
    hspec = "model" if L.attn_tp_enabled(cfg, tp) else None
    sp = P(None, batch_axes, None, hspec, None)
    out = {"k": sp, "v": sp}
    if cfg.kv_quant:
        out["k_scale"] = sp
        out["v_scale"] = sp
    return out


def init_cache(
    cfg: ModelConfig, batch: int, s_max: int, tp: int = 1, dtype=None
) -> Params:
    dtype = dtype or cfg.jdtype
    fam = cfg.family
    cache: Params = {"length": jnp.zeros((), jnp.int32)}
    if fam in (Family.DENSE, Family.VLM, Family.MOE):
        cache["attn"] = _attn_cache(cfg, cfg.n_layers, batch, s_max, tp, dtype)
    elif fam is Family.SSM:
        base = S.init_ssm_cache(cfg, batch, dtype)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), base
        )
    elif fam is Family.HYBRID:
        ng, rem = hybrid_groups(cfg)
        base = S.init_ssm_cache(cfg, batch, dtype)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((ng, cfg.attn_every) + x.shape, x.dtype), base
        )
        if rem:
            cache["ssm_tail"] = jax.tree.map(
                lambda x: jnp.zeros((rem,) + x.shape, x.dtype), base
            )
        cache["attn"] = _attn_cache(cfg, ng, batch, s_max, tp, dtype)
    return cache


def cache_specs(cfg: ModelConfig, tp: int, batch_axes) -> Params:
    fam = cfg.family
    specs: Params = {"length": P()}
    if fam in (Family.DENSE, Family.VLM, Family.MOE):
        specs["attn"] = _attn_cache_spec(cfg, tp, batch_axes)
    elif fam is Family.SSM:
        specs["ssm"] = {
            "state": P(None, batch_axes, "model", None, None),
            "conv": P(None, batch_axes, None, "model"),
        }
    elif fam is Family.HYBRID:
        _, rem = hybrid_groups(cfg)
        specs["ssm"] = {
            "state": P(None, None, batch_axes, "model", None, None),
            "conv": P(None, None, batch_axes, None, "model"),
        }
        if rem:
            specs["ssm_tail"] = {
                "state": P(None, batch_axes, "model", None, None),
                "conv": P(None, batch_axes, None, "model"),
            }
        specs["attn"] = _attn_cache_spec(cfg, tp, batch_axes)
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _sp_constrain(x: jnp.ndarray, cfg: ModelConfig, mesh) -> jnp.ndarray:
    """Residual-stream sharding between blocks (Megatron-SP)."""
    if mesh is None:
        return x
    ba = batch_axes_for(mesh, x.shape[0])
    tp = _tp_of(mesh)
    if cfg.seq_shard and x.shape[1] % max(tp, 1) == 0 and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, P(ba, "model", None))
    return jax.lax.with_sharding_constraint(x, P(ba, None, None))


def _dense_block(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    mesh,
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
    length: Optional[jnp.ndarray],
    positions: jnp.ndarray,
    is_moe: bool,
    paged: Optional[Tuple] = None,  # (page_table, impl, tree_mask) pool ctx
):
    """Pre-norm attn + FFN. kv = (k_slice, v_slice) cache buffers or None.

    With `paged` (a ``(page_table, impl, tree_mask)`` triple), kv holds one
    layer's slice of the device-resident paged pool and `length` is the
    per-row (B,) length vector; attention scatters and attends through the
    page table instead of the dense buffers."""
    tp = _tp_of(mesh)
    cache = None
    if paged is not None:
        page_table, impl, tree_mask = paged
        cache = L.PagedCache(k=kv[0], v=kv[1], page_table=page_table,
                             length=length, impl=impl,
                             k_scale=kv[2] if len(kv) > 2 else None,
                             v_scale=kv[3] if len(kv) > 2 else None,
                             tree_mask=tree_mask)
    elif kv is not None:
        cache = L.Cache(k=kv[0], v=kv[1], length=length,
                        k_scale=kv[2] if len(kv) > 2 else None,
                        v_scale=kv[3] if len(kv) > 2 else None)
    h, new_cache = L.attention_apply(
        p["attn"], L.rmsnorm(p["ln1"], x), cfg, tp, cache=cache, positions=positions
    )
    x = x + h if cfg.sp_once_per_block else _sp_constrain(x + h, cfg, mesh)
    z = L.rmsnorm(p["ln2"], x)
    if is_moe:
        sp = cfg.seq_shard and z.shape[1] % max(tp, 1) == 0 and z.shape[1] > 1
        f = _moe_call(p["moe"], z, cfg, mesh, sp)
    else:
        f = L.mlp_apply(p["mlp"], z, cfg)
    x = _sp_constrain(x + f, cfg, mesh)
    if new_cache is None:
        out_kv = None
    elif isinstance(new_cache, L.PagedCache):
        if new_cache.k_scale is not None:  # compressed pool: scales ride along
            out_kv = (new_cache.k, new_cache.v,
                      new_cache.k_scale, new_cache.v_scale)
        else:
            out_kv = (new_cache.k, new_cache.v)
    elif new_cache.k_scale is not None:
        out_kv = (new_cache.k, new_cache.v, new_cache.k_scale, new_cache.v_scale)
    else:
        out_kv = (new_cache.k, new_cache.v)
    return x, out_kv


def _moe_call(p, z, cfg, mesh, sp):
    if cfg.moe_impl == "a2a" and mesh is not None:
        # sp: tokens sharded over (batch, seq); else batch only (decode)
        return L.moe_apply_a2a(p, z, cfg, mesh, seq_sharded=sp)
    return L.moe_apply_dense(p, z, cfg)


def _ssm_block(p, x, cfg, mesh, state, decode: bool):
    """Pre-norm mamba2 block. state = per-layer ssm cache dict or None."""
    z = L.rmsnorm(p["ln"], x)
    if decode:
        y, new_state = S.mamba_decode(p["mamba"], z, cfg, state)
    else:
        init = state["state"] if state is not None else None
        conv_st = state["conv"] if state is not None else None
        y, fstate, conv_tail = S.mamba_apply(p["mamba"], z, cfg, init, conv_st)
        new_state = {"state": fstate, "conv": conv_tail} if state is not None else None
    x = _sp_constrain(x + y, cfg, mesh)
    return x, new_state


def apply_lm(
    params: Params,
    cfg: ModelConfig,
    mesh,
    tokens: jnp.ndarray,  # (B, S) int32
    cache: Optional[Params] = None,
    vision_embeds: Optional[jnp.ndarray] = None,  # (B, T_img, d) for VLM
    last_logit_only: bool = False,
    paged_impl: str = "gather",  # paged caches: "gather" | "pallas"
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s = tokens.shape
    fam = cfg.family
    x = params["embed"]["tok"][tokens].astype(cfg.jdtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(cfg.jdtype), x], axis=1)
        s = x.shape[1]
    # a paged cache carries the shared device pool + per-row page tables /
    # lengths instead of per-request dense buffers + one scalar length
    paged = cache is not None and "page_table" in cache
    if paged and fam not in (Family.DENSE, Family.VLM, Family.MOE):
        raise NotImplementedError(f"paged KV cache: family {fam}")
    offset, positions, paged_ctx = L.forward_cache_ctx(cache, b, s, paged_impl)
    x = _sp_constrain(x, cfg, mesh)
    decode = cache is not None and s == 1

    remat = cfg.remat and cache is None

    def maybe_remat(fn):
        if not remat:
            return fn
        if cfg.remat_policy == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        return jax.checkpoint(fn)

    def layer_loop(body, x, xs_tree, n):
        """scan-over-layers or an unrolled python loop (cfg.scan_layers=False,
        used by the dry-run's depth-calibration lowers)."""
        if cfg.scan_layers:
            return jax.lax.scan(maybe_remat(body), x, xs_tree)
        wrapped = maybe_remat(body)
        ys = []
        for i in range(n):
            sl = jax.tree.map(lambda a: a[i], xs_tree)
            x, y = wrapped(x, sl)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return x, ys

    new_cache = dict(cache) if cache is not None else None

    if fam in (Family.DENSE, Family.VLM, Family.MOE):
        is_moe = fam is Family.MOE

        def body(carry, xs):
            xc = carry
            p, kv = xs
            if kv is None:
                kvp = None
            elif "k_scale" in kv:
                kvp = (kv["k"], kv["v"], kv["k_scale"], kv["v_scale"])
            else:
                kvp = (kv["k"], kv["v"])
            xc, out_kv = _dense_block(
                p, xc, cfg, mesh, kvp, offset, positions, is_moe,
                paged=paged_ctx,
            )
            if out_kv is None:
                ys = None
            elif len(out_kv) == 4:
                ys = {"k": out_kv[0], "v": out_kv[1],
                      "k_scale": out_kv[2], "v_scale": out_kv[3]}
            else:
                ys = {"k": out_kv[0], "v": out_kv[1]}
            return xc, ys

        if cache is not None:
            xs = (params["layers"], cache["attn"])
            x, kv_out = layer_loop(body, x, xs, cfg.n_layers)
            new_cache["attn"] = kv_out
        else:
            x, _ = layer_loop(
                lambda c, p: body(c, (p, None)), x, params["layers"], cfg.n_layers
            )
    elif fam is Family.SSM:

        def body(carry, xs):
            xc = carry
            p, st = xs
            xc, new_st = _ssm_block(p, xc, cfg, mesh, st, decode)
            return xc, new_st

        if cache is not None:
            x, st_out = layer_loop(
                body, x, (params["layers"], cache["ssm"]), cfg.n_layers
            )
            new_cache["ssm"] = st_out
        else:
            x, _ = layer_loop(
                lambda c, p: body(c, (p, None)), x, params["layers"], cfg.n_layers
            )
    elif fam is Family.HYBRID:
        ng, rem = hybrid_groups(cfg)
        shared = params["shared_attn"]

        def group_body(carry, xs):
            xc = carry
            gp, gst, kv = xs  # (every, ...) mamba stack, ssm states, attn kv

            def inner(c, ixs):
                ip, ist = ixs
                c, nst = _ssm_block(ip, c, cfg, mesh, ist, decode)
                return c, nst

            if cfg.scan_layers:
                xc, new_states = jax.lax.scan(inner, xc, (gp, gst))
            else:
                xc, new_states = _unrolled_pairs(inner, xc, (gp, gst))
            kvp = (kv["k"], kv["v"]) if kv is not None else None
            xc, out_kv = _dense_block(
                shared, xc, cfg, mesh, kvp, offset, positions, False
            )
            ys = {
                "ssm": new_states,
                "kv": {"k": out_kv[0], "v": out_kv[1]} if out_kv else None,
            }
            return xc, ys

        ng_trips = ng
        if cache is not None:
            xs = (params["layers"], cache["ssm"], cache["attn"])
            x, outs = layer_loop(group_body, x, xs, ng_trips)
            new_cache["ssm"] = outs["ssm"]
            new_cache["attn"] = outs["kv"]
        else:
            def group_nc(c, gp):
                def inner(cc, ip):
                    cc, _ = _ssm_block(ip, cc, cfg, mesh, None, False)
                    return cc, None

                c, _ = jax.lax.scan(inner, c, gp) if cfg.scan_layers else _unrolled_pairs(inner, c, gp)
                c, _ = _dense_block(shared, c, cfg, mesh, None, offset, positions, False)
                return c, None

            x, _ = layer_loop(group_nc, x, params["layers"], ng_trips)
        if rem:
            def tail_body(carry, xs):
                p, st = xs
                c, nst = _ssm_block(p, carry, cfg, mesh, st, decode)
                return c, nst

            if cache is not None:
                x, st_out = layer_loop(
                    tail_body, x, (params["tail"], cache["ssm_tail"]), rem
                )
                new_cache["ssm_tail"] = st_out
            else:
                x, _ = layer_loop(
                    lambda c, p: (tail_body(c, (p, None))[0], None),
                    x, params["tail"], rem,
                )
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["final_norm"], x)
    if last_logit_only:
        x = x[:, -1:, :]
    logits = x @ params["embed"]["head"].astype(cfg.jdtype)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e9)
    if new_cache is not None:
        new_cache["lengths" if paged else "length"] = offset + s
    return logits, new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def loss_fn(
    params: Params,
    cfg: ModelConfig,
    mesh,
    tokens: jnp.ndarray,  # (B, S+1) int32 — input/target shifted views
    vision_embeds: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits, _ = apply_lm(params, cfg, mesh, inp, vision_embeds=vision_embeds)
    if vision_embeds is not None:
        logits = logits[:, vision_embeds.shape[1] :, :]  # score text positions
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
