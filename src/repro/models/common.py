"""Model/shape configuration shared by every architecture in the zoo."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["Family", "ModelConfig", "ShapeConfig", "SHAPES"]


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    VLM = "vlm"
    AUDIO = "audio"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # --- attention details
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False  # qwen3
    rope_theta: float = 10000.0
    # --- FFN
    act: str = "swiglu"  # swiglu | squared_relu | gelu
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "a2a"  # a2a (shard_map EP) | dense (smoke tests)
    # --- SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0  # hybrid: one shared attn block every N mamba blocks
    # --- enc-dec (whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500
    # --- vlm
    n_vision_tokens: int = 0
    # --- numerics / serving
    pad_vocab_to: int = 1  # pad embedding tables so vocab % tp == 0
    kv_quant: bool = False  # INT8 KV cache (per-token-per-head scales)
    dtype: str = "bfloat16"
    quant_mode: str = "none"  # none | w4a8 (TLM) | bvq (DLM)
    # --- distribution
    fsdp: bool = False  # shard weights over the data axis too (ZeRO-3 style)
    seq_shard: bool = True  # Megatron-SP: shard the residual sequence dim
    sp_once_per_block: bool = False  # constrain only at block end (fewer AG/RS)
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save dot outputs, cheaper bwd)
    optim_dtype: str = "float32"  # adam moments dtype (bf16 for the giants)
    grad_constraint: bool = False  # pin grads to param sharding (AR -> RS)
    grad_barrier: bool = False  # stop f32-convert hoisting above grad reduce
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def vocab_padded(self) -> int:
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.family is Family.AUDIO


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
