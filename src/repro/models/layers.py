"""Layer library: norms, rotary, GQA attention (flash + decode paths),
MLP variants, MoE (dense reference + all-to-all expert parallel).

Conventions
-----------
* params are plain dict pytrees; every ``init_*`` returns ``(params, specs)``
  where ``specs`` mirrors params with ``PartitionSpec`` leaves.
* linear weights are (in, out); attention projections keep an explicit
  (heads, head_dim) split so head sharding is a named axis.
* TP ("model" axis) shards: q heads, FFN inner dim, expert dim, vocab.
  GQA with n_kv < TP replicates kv heads to ``n_kv_store = n_kv * rep``
  "virtual" heads (rep = tp // gcd(n_kv, tp)) so the KV cache shards evenly
  and attention needs NO cross-shard collectives (vLLM-style).
* archs whose head count does not divide TP (internvl 14H, whisper 20H) run
  attention data-parallel only: weights replicated over "model", FFN still
  TP-sharded (documented in DESIGN.md §Arch-applicability).
* fsdp=True additionally shards the non-TP weight axis over "data"
  (ZeRO-3); XLA inserts the all-gathers.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig

__all__ = [
    "rmsnorm_init",
    "rmsnorm",
    "rope",
    "attn_tp_enabled",
    "attention_init",
    "attention_apply",
    "mlp_init",
    "mlp_apply",
    "moe_init",
    "moe_apply",
    "embed_init",
    "Cache",
    "PagedCache",
    "paged_attention_update",
    "forward_cache_ctx",
]

Params = Dict[str, Any]


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def pick_batch_axes(mesh, batch: int):
    # Largest prefix of ('pod','data') whose size product divides `batch`;
    # long-context decode (batch 1) replicates over the data axis.
    axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    chosen = []
    prod = 1
    for a in axes:
        if batch % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    if not chosen:
        return None
    return chosen[0] if len(chosen) == 1 else tuple(chosen)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Tuple[Params, Params]:
    return {"g": jnp.ones((d,), dtype)}, {"g": P(None)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embedding
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cache:
    """Functional KV cache: fixed buffers + explicit length.

    With INT8 KV quantization (cfg.kv_quant) the buffers are int8 and
    ``k_scale``/``v_scale`` hold per-token-per-head absmax scales — the
    paper-aligned A8 cache that halves decode HBM traffic."""

    k: jnp.ndarray  # (B, S_max, n_kv_store, hd)
    v: jnp.ndarray
    length: jnp.ndarray  # () int32
    k_scale: Optional[jnp.ndarray] = None  # (B, S_max, n_kv_store, 1) f32
    v_scale: Optional[jnp.ndarray] = None


@dataclasses.dataclass
class PagedCache:
    """One layer's view of the device-resident paged KV pool.

    Unlike ``Cache`` (per-request dense buffers), the pool is SHARED across
    the whole batch: each request owns the pages its ``page_table`` row
    names, and ``length`` is per row.  Built inside the traced forward from
    the paged cache pytree plus static serving config — not itself a pytree.

    ``impl`` picks the attention path:
      * "gather" — gather pages into a dense per-request view ON DEVICE
        (width = the table span, max_pages * page_size) and run the exact
        dense decode/flash math (bit-identical floats to the
        single-request path; the default serving path);
      * "pallas" — attend in place through the page table with
        ``kernels/paged_attn.paged_decode_attention_pallas`` (interpret mode
        on CPU), zero gather materialization.

    Rows excluded from a fused PAR dispatch arrive here already diverted:
    ``forward_cache_ctx`` applies the per-row role mask upstream by
    rewriting the masked rows' table entries to the scratch page and their
    lengths to 0, so this type never needs to know about roles.

    With ``k_scale``/``v_scale`` set the pool is COMPRESSED: k/v hold int8
    and the scale pools (same page layout, trailing dim 1) hold the
    per-slot-per-head dequant factors.  New tokens quantize on scatter
    (value + scale written in the same dispatch) and both impls dequantize
    at the consumer — the Pallas kernel inside its page loop, the gather
    path right after the gather — so pages stay int8 at rest.
    """

    k: jnp.ndarray  # (P(+scratch), page_size, kvh, hd)
    v: jnp.ndarray
    page_table: jnp.ndarray  # (B, max_pages) int32
    length: jnp.ndarray  # (B,) int32 — tokens already written per request
    impl: str = "gather"  # "gather" | "pallas"
    k_scale: Optional[jnp.ndarray] = None  # (P(+scratch), page_size, kvh, 1)
    v_scale: Optional[jnp.ndarray] = None
    # (B, S, S) intra-window visibility (speculation-tree ancestor mask);
    # None keeps the causal window semantics bit-exact (chain mode)
    tree_mask: Optional[jnp.ndarray] = None


def forward_cache_ctx(cache, b: int, s: int, paged_impl: str):
    """Shared forward preamble for every model path (bf16 / W4A8 / BVQ):
    ``(offset, positions (B, S), paged_ctx)`` for any cache form.

    A cache carrying ``page_table`` is the device-resident paged pool
    (``{"lengths" (B,), "page_table" (B, mp), "attn": {"k": (L, P, ps,
    kvh, hd), ...}}``): offset is the per-row length vector and paged_ctx
    the ``(page_table, impl, tree_mask)`` triple the per-layer attention
    needs (``tree_mask``/``win_pos`` cache keys are the speculation-tree
    extras — see ``PagedCache``).  A dense cache (or None) yields the
    scalar offset and ``paged_ctx = None``.

    Role-mask semantics (fused cross-request PAR dispatches): an optional
    ``"role_mask"`` (B,) bool entry selects which rows PARTICIPATE in this
    forward.  Masked-out rows are routed entirely to the pool's scratch
    page (their page-table row is replaced by the scratch id and their
    length by 0), so their KV writes land where no request reads and their
    attention output is garbage the caller ignores.  This is what lets the
    serving engine run the draft model and the target model over the SAME
    batch in ONE fused program — each row's role mask decides which of the
    two forwards actually touches its pages — without any row ever
    polluting the pool of a model it is not using this slot."""
    if cache is not None and "page_table" in cache:
        offset = cache["lengths"]  # (B,)
        table = cache["page_table"]
        mask = cache.get("role_mask")
        if mask is not None:
            # pool device arrays carry one trailing scratch page the
            # allocator never hands out — divert masked rows' table + length
            # there so their scatter/attend is inert (dup writes harmless)
            scratch = cache["attn"]["k"].shape[1] - 1
            offset = jnp.where(mask, offset, 0)
            table = jnp.where(mask[:, None], table, scratch)
        win_pos = cache.get("win_pos")  # (B, S) tree depths, optional
        if win_pos is None:
            positions = jnp.broadcast_to(
                offset[:, None] + jnp.arange(s)[None, :], (b, s)
            )
        else:
            # speculation tree: slot order in the window is BFS (stable pool
            # slots), but RoPE positions follow tree DEPTH — node i sits at
            # absolute position offset + depth(i)
            positions = offset[:, None] + win_pos
        return offset, positions, (table, paged_impl, cache.get("tree_mask"))
    offset = cache["length"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(offset + jnp.arange(s)[None, :], (b, s))
    return offset, positions, None


def paged_attention_update(
    q: jnp.ndarray,  # (B, S, H, hd) — post-rope queries
    k_new: jnp.ndarray,  # (B, S, kvh_store, hd) — post-rope, post-repeat
    v_new: jnp.ndarray,
    pc: PagedCache,
) -> Tuple[jnp.ndarray, dict]:
    """Scatter the S new tokens into their pool pages, then attend over the
    valid per-request prefix (+ the causally-masked window when S > 1).

    Returns ``(out (B, S, H, hd), new_pools)`` where ``new_pools`` is the
    updated storage dict — ``{"k", "v"}`` plus ``{"k_scale", "v_scale"}``
    for compressed pools.  The scatter is one flat ``.at[].set`` per pool
    array — rows write disjoint pages by construction (inactive rows all
    target the scratch page, where duplicate writes are harmless); for
    compressed pools the span quantizes first and values + scales land in
    the SAME dispatch, so a readable slot always carries its own scale."""
    b, s, h, hd = q.shape
    n_pages, ps, kvh, _ = pc.k.shape
    mp = pc.page_table.shape[1]
    quantized = pc.k_scale is not None
    pos = pc.length[:, None] + jnp.arange(s)[None, :]  # (B, S) absolute slots
    page = jnp.take_along_axis(
        pc.page_table, jnp.minimum(pos // ps, mp - 1), axis=1
    )  # (B, S) physical page per token
    # positions past the table span (an engine sizing bug — admission
    # reserves peak+window, so it cannot happen from serve_batch) divert to
    # the pool's last page (the engine's scratch) rather than silently
    # overwriting the request's own committed KV in its last page
    page = jnp.where(pos >= mp * ps, n_pages - 1, page)
    flat = (page * ps + pos % ps).reshape(-1)  # (B*S,) into (P*ps, kvh, hd)

    def scatter(pool, span):
        width = pool.shape[-1]
        return (
            pool.reshape(n_pages * ps, kvh, width)
            .at[flat]
            .set(span.astype(pool.dtype).reshape(b * s, kvh, width))
            .reshape(pool.shape)
        )

    if quantized:
        kq, ksc = _kv_quantize(k_new)
        vq, vsc = _kv_quantize(v_new)
        new_k = scatter(pc.k, kq)
        new_v = scatter(pc.v, vq)
        new_ks = scatter(pc.k_scale, ksc)
        new_vs = scatter(pc.v_scale, vsc)
        new_pools = {"k": new_k, "v": new_v, "k_scale": new_ks, "v_scale": new_vs}
    else:
        new_k = scatter(pc.k, k_new)
        new_v = scatter(pc.v, v_new)
        new_ks = new_vs = None
        new_pools = {"k": new_k, "v": new_v}
    new_len = pc.length + s  # valid tokens incl. this span, per row
    if pc.impl == "pallas":
        from repro.kernels.paged_attn import paged_decode_attention_pallas

        g = h // kvh
        q5 = q.reshape(b, s, kvh, g, hd)  # H is (kv-head, group)-major
        out = paged_decode_attention_pallas(
            q5, new_k, new_v, pc.page_table, new_len,
            k_scale=new_ks, v_scale=new_vs, tree_mask=pc.tree_mask,
        )
        return out.reshape(b, s, h, hd).astype(q.dtype), new_pools
    if pc.impl != "gather":
        raise ValueError(f"unknown paged attention impl {pc.impl!r}")
    # device-side gather to the table-span width (>= every valid length by
    # the allocator's reservation invariant), then the identical dense
    # math — bit-identical to the host-dense path: masked columns
    # contribute exact zeros, so the width difference never shows
    kd = new_k[pc.page_table.reshape(-1)].reshape(b, mp * ps, kvh, hd)
    vd = new_v[pc.page_table.reshape(-1)].reshape(b, mp * ps, kvh, hd)
    if quantized:
        # explicit f32 dequant, then the UNCHANGED fp attention math — this
        # is what keeps the gather path numerically equivalent (same dots,
        # small f32 tolerance) to the kernel's in-page dequant epilogue
        ksd = new_ks[pc.page_table.reshape(-1)].reshape(b, mp * ps, kvh, 1)
        vsd = new_vs[pc.page_table.reshape(-1)].reshape(b, mp * ps, kvh, 1)
        kd = (kd.astype(jnp.float32) * ksd).astype(q.dtype)
        vd = (vd.astype(jnp.float32) * vsd).astype(q.dtype)
    if pc.tree_mask is not None:
        out = _tree_window_attention(q, kd, vd, new_len, pc.tree_mask)
    elif s == 1:
        out = _decode_attention(q, kd, vd, new_len)
    else:
        out = flash_attention(q, kd, vd, causal=True, q_offset=pc.length)
    return out, new_pools


def _tree_window_attention(
    q: jnp.ndarray,  # (B, W, H, hd) — the full speculation-tree window
    kd: jnp.ndarray,  # (B, T, kvh, hd) dense gathered (dequantized) K
    vd: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) valid tokens INCLUDING the window
    tree_mask: jnp.ndarray,  # (B, W, W) intra-window visibility
) -> jnp.ndarray:
    """Gather-path tree attention: window query w sees the committed prefix
    (positions < lengths - W) plus window slot j iff ``tree_mask[b, w, j]``.
    Same masked-softmax math as the causal gather path, generalized mask —
    the dense mirror of the Pallas kernel's tree branch."""
    b, w, h, hd = q.shape
    t, kvh = kd.shape[1], kd.shape[2]
    g = h // kvh
    q5 = q.reshape(b, w, kvh, g, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum(
        "bwkgh,btkh->bwkgt", q5 * scale, kd.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    rel = jnp.arange(t)[None, :] - (lengths[:, None] - w)  # (B, T)
    in_window = (rel >= 0) & (rel < w)
    idx = jnp.broadcast_to(jnp.clip(rel, 0, w - 1)[:, None, :], (b, w, t))
    win_vis = jnp.take_along_axis(tree_mask.astype(bool), idx, axis=2)
    prefix = jnp.arange(t)[None, None, :] < (lengths[:, None, None] - w)
    valid = prefix | (in_window[:, None, :] & win_vis)  # (B, W, T)
    scores = jnp.where(valid[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bwkgt,btkh->bwkgh", p, vd.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, w, h, hd).astype(q.dtype)


def _kv_quantize(k: jnp.ndarray):
    """(B,S,H,hd) -> (int8 values, (B,S,H,1) f32 scales)."""
    s = jnp.maximum(
        jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1, keepdims=True), 1e-8
    ) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _kv_dequant(q: jnp.ndarray, s, dtype) -> jnp.ndarray:
    if s is None:
        return q.astype(dtype)
    return (q.astype(jnp.float32) * s).astype(dtype)


def attn_tp_enabled(cfg: ModelConfig, tp: int) -> bool:
    return cfg.n_heads % tp == 0 and tp > 1


def kv_store_heads(cfg: ModelConfig, tp: int) -> int:
    if not attn_tp_enabled(cfg, tp):
        return cfg.n_kv
    rep = tp // _gcd(cfg.n_kv, tp)
    return cfg.n_kv * rep


def attention_init(
    key: jax.Array, cfg: ModelConfig, tp: int, cross: bool = False
) -> Tuple[Params, Params]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    dt = cfg.jdtype
    params = {
        "wq": jax.random.normal(k1, (d, h, hd), dt) * s,
        "wk": jax.random.normal(k2, (d, kv, hd), dt) * s,
        "wv": jax.random.normal(k3, (d, kv, hd), dt) * s,
        "wo": jax.random.normal(k4, (h, hd, d), dt) * (s / math.sqrt(h / 1.0)),
    }
    tp_on = attn_tp_enabled(cfg, tp)
    hspec = "model" if tp_on else None
    fs = "data" if cfg.fsdp else None
    specs = {
        "wq": P(fs, hspec, None),
        "wk": P(fs, None, None),  # kv heads may not divide tp; see kv repeat
        "wv": P(fs, None, None),
        "wo": P(hspec, None, fs),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), dt)
        params["k_norm"] = jnp.ones((hd,), dt)
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def _qk_head_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


def _repeat_kv(k: jnp.ndarray, store: int) -> jnp.ndarray:
    """(B, S, n_kv, hd) -> (B, S, store, hd), repeating heads contiguously so
    virtual head v serves q-heads [v * H/store : (v+1) * H/store)."""
    b, s, kv, hd = k.shape
    if store == kv:
        return k
    rep = store // kv
    return jnp.repeat(k, rep, axis=2)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, Hkv_store, hd)
    v: jnp.ndarray,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,  # absolute position of q[0]: () or (B,)
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Streaming-softmax attention, lax.scan over KV chunks (bounds memory
    at ~Sq x kv_chunk scores per step — the 32k cells need this).  Widths
    that don't split evenly fall back to the largest divisor <= the target
    chunk count; a prime Skv > kv_chunk therefore runs unchunked — callers
    with such widths (none of the shipped paths: caches, paged spans, and
    training lengths are all highly composite) should pad K/V instead."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    # dots run in the model dtype (bf16 on TPU -> MXU rate, half the bytes);
    # softmax statistics and the accumulator stay f32 (standard flash)
    dot_dt = q.dtype
    qf = (q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) * scale).astype(dot_dt)
    # largest chunk count <= skv/kv_chunk that divides skv evenly — keeps
    # the score-tensor memory bound for widths (e.g. paged spans sized in
    # pages, not powers of two) that a fixed chunk count cannot split
    n_chunks = max(skv // kv_chunk, 1)
    while skv % n_chunks:
        n_chunks -= 1
    kc = k.reshape(b, n_chunks, skv // n_chunks, hkv, hd).astype(dot_dt)
    vc = v.reshape(b, n_chunks, skv // n_chunks, hkv, hd).astype(dot_dt)
    # scalar offset -> (1, Sq) broadcast row; per-request (B,) -> (B, Sq)
    q_pos = jnp.arange(sq)[None, :] + jnp.reshape(jnp.asarray(q_offset), (-1, 1))

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, c_idx = inputs  # (B, C, hkv, hd) x2, ()
        ck = kb.shape[1]
        scores = jnp.einsum(
            "bqkgh,bckh->bkgqc", qf, kb, preferred_element_type=jnp.float32
        )  # (B,hkv,g,Sq,C) f32
        if causal:
            kv_pos = c_idx * ck + jnp.arange(ck)
            mask = q_pos[:, :, None] >= kv_pos[None, None, :]  # (B|1, Sq, C)
            scores = jnp.where(mask[:, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bkgqc,bckh->bkgqh", p.astype(dot_dt), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)  # (n_chunks, B, C, hkv, hd)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc_t, vc_t, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def _decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    cache_k: jnp.ndarray,  # (B, S_max, hkv, hd) — model dtype or int8
    cache_v: jnp.ndarray,
    length: jnp.ndarray,  # () or (B,) — valid prefix INCLUDING the new token
    k_scale=None,  # (B, S_max, hkv, 1) f32 when the cache is int8
    v_scale=None,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    s_max, hkv = cache_k.shape[1], cache_k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    # low-precision dots with f32 accumulation avoid materializing a 4-byte
    # copy of the (huge) cache operand; f32 models keep f32 math (tests)
    dot_dt = (
        jnp.bfloat16
        if (k_scale is not None or cache_k.dtype == jnp.bfloat16)
        else jnp.float32
    )
    qf = (q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) * scale).astype(dot_dt)
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", qf, cache_k.astype(dot_dt),
        preferred_element_type=jnp.float32,
    )  # (B,hkv,g,1,S) f32
    if k_scale is not None:
        # per-token scales factor OUT of the contraction (exact)
        ks = jnp.moveaxis(k_scale[..., 0], 1, -1)[:, :, None, None, :]
        scores = scores * ks
    valid = jnp.arange(s_max)[None, :] < jnp.reshape(length, (-1, 1))  # (B|1, S)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        vs = jnp.moveaxis(v_scale[..., 0], 1, -1)[:, :, None, None, :]
        p = p * vs  # fold the per-token V scale into the weights (exact)
    out = jnp.einsum(
        "bkgqs,bskh->bkgqh", p.astype(dot_dt),
        cache_v.astype(dot_dt), preferred_element_type=jnp.float32,
    )
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention_apply(
    params: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    tp: int,
    cache: Optional[Cache] = None,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    b, s, d = x.shape
    store = kv_store_heads(cfg, tp)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qk_norm:
        q = _qk_head_norm(q, params["q_norm"])
    if kv_override is not None:
        k, v = kv_override  # already (B, T, store, hd)
        new_cache = cache
        if use_rope and positions is not None:
            q = rope(q, positions, cfg.rope_theta)
        out = flash_attention(q, k, v, causal=False)
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if cfg.qk_norm:
            k = _qk_head_norm(k, params["k_norm"])
        if positions is None:
            if isinstance(cache, PagedCache):
                positions = cache.length[:, None] + jnp.arange(s)[None, :]
            else:
                positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        k = _repeat_kv(k, store)
        v = _repeat_kv(v, store)
        if isinstance(cache, PagedCache):
            # device-resident paged pool: scatter the new span into its
            # pages and attend through the page table (per-row lengths)
            out, np_ = paged_attention_update(q, k, v, cache)
            y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
            return y, dataclasses.replace(
                cache, k=np_["k"], v=np_["v"],
                k_scale=np_.get("k_scale"), v_scale=np_.get("v_scale"),
                length=cache.length + s,
            )
        quant = cache is not None and cache.k_scale is not None
        if cache is None:
            out = flash_attention(q, k, v, causal=causal)
            new_cache = None
        elif s == 1:
            # decode: append then attend over the valid prefix
            if quant:
                kq, ksc = _kv_quantize(k)
                vq, vsc = _kv_quantize(v)
                ck = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, cache.length, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, cache.length, axis=1)
                cks = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ksc, cache.length, axis=1)
                cvs = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vsc, cache.length, axis=1)
                new_len = cache.length + 1
                out = _decode_attention(q, ck, cv, new_len, k_scale=cks, v_scale=cvs)
                new_cache = Cache(k=ck, v=cv, length=new_len, k_scale=cks, v_scale=cvs)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, cache.length, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, cache.length, axis=1)
                new_len = cache.length + 1
                out = _decode_attention(q, ck, cv, new_len)
                new_cache = Cache(k=ck, v=cv, length=new_len)
        else:
            # prefill/extend into the cache, then flash over the FULL buffer:
            # the causal mask (q_pos = offset + i vs absolute kv positions)
            # attends the cached prefix and masks unwritten tail slots.
            if quant:
                kq, ksc = _kv_quantize(k)
                vq, vsc = _kv_quantize(v)
                ck = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, cache.length, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, cache.length, axis=1)
                cks = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ksc, cache.length, axis=1)
                cvs = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vsc, cache.length, axis=1)
                kf = _kv_dequant(ck, cks, x.dtype)
                vf = _kv_dequant(cv, cvs, x.dtype)
                out = flash_attention(q, kf, vf, causal=True, q_offset=cache.length)
                new_cache = Cache(k=ck, v=cv, length=cache.length + s,
                                  k_scale=cks, v_scale=cvs)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, cache.length, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, cache.length, axis=1)
                out = flash_attention(q, ck, cv, causal=True, q_offset=cache.length)
                new_cache = Cache(k=ck, v=cv, length=cache.length + s)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key: jax.Array, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.jdtype
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    fs = "data" if cfg.fsdp else None
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        params = {
            "w_gate": jax.random.normal(k1, (d, f), dt) * s_in,
            "w_up": jax.random.normal(k2, (d, f), dt) * s_in,
            "w_down": jax.random.normal(k3, (f, d), dt) * s_out,
        }
        specs = {
            "w_gate": P(fs, "model"),
            "w_up": P(fs, "model"),
            "w_down": P("model", fs),
        }
    else:
        k1, k2 = jax.random.split(key)
        params = {
            "w_up": jax.random.normal(k1, (d, f), dt) * s_in,
            "w_down": jax.random.normal(k2, (f, d), dt) * s_out,
        }
        specs = {"w_up": P(fs, "model"), "w_down": P("model", fs)}
    return params, specs


def mlp_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.act == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif cfg.act == "squared_relu":
        u = x @ params["w_up"]
        r = jax.nn.relu(u.astype(jnp.float32))
        h = (r * r).astype(x.dtype)
    else:  # gelu
        u = x @ params["w_up"]
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# MoE — dense reference + GShard-style all-to-all expert parallelism
# ---------------------------------------------------------------------------


def moe_ff_split(cfg: ModelConfig, tp: int) -> int:
    """When n_experts < tp, each expert's FFN columns split across
    tp // n_experts shards so the (expert x slice) grid covers the model
    axis exactly (grok-1: 8 experts x 2 slices on tp=16)."""
    e = cfg.n_experts
    if tp <= e:
        assert e % tp == 0, (e, tp)
        return 1
    assert tp % e == 0, (e, tp)
    return tp // e


def moe_init(key: jax.Array, cfg: ModelConfig, tp: int = 1) -> Tuple[Params, Params]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    split = moe_ff_split(cfg, tp)
    fs_ = f // split
    dt = cfg.jdtype
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    fs = "data" if cfg.fsdp else None
    # storage: (e * split, d, f / split) — total element count == e * d * f
    params = {
        "router": jax.random.normal(kr, (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k1, (e * split, d, fs_), dt) * s_in,
        "w_up": jax.random.normal(k2, (e * split, d, fs_), dt) * s_in,
        "w_down": jax.random.normal(k3, (e * split, fs_, d), dt) * s_out,
    }
    specs = {
        "router": P(None, None),
        "w_gate": P("model", fs, None),
        "w_up": P("model", fs, None),
        "w_down": P("model", None, fs),
    }
    return params, specs


def _topk_gates(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (gate values (T, k) normalized, expert ids (T, k))."""
    vals, ids = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(vals, axis=-1)
    return gates, ids


def moe_apply_dense(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Reference: every token through every expert, gated combine.
    Exact math, x E/k compute — smoke tests and tiny configs only.
    Handles the (e * split, d, f / split) storage layout."""
    b, s, d = x.shape
    e = cfg.n_experts
    es, _, fs_ = params["w_gate"].shape
    split = es // e
    t = x.reshape(-1, d)
    logits = (t.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    gates, ids = _topk_gates(logits, cfg.top_k)
    combine = jnp.zeros((t.shape[0], e), jnp.float32)
    combine = jax.vmap(lambda c, i, g: c.at[i].add(g))(combine, ids, gates)
    # (e*split, d, f/split) -> (e, d, f)
    wg = params["w_gate"].reshape(e, split, d, fs_).transpose(0, 2, 1, 3).reshape(e, d, split * fs_)
    wu = params["w_up"].reshape(e, split, d, fs_).transpose(0, 2, 1, 3).reshape(e, d, split * fs_)
    wd = params["w_down"].reshape(e, split, fs_, d).reshape(e, split * fs_, d)
    g_out = jnp.einsum("td,edf->tef", t, wg)
    u_out = jnp.einsum("td,edf->tef", t, wu)
    h = jax.nn.silu(g_out.astype(jnp.float32)).astype(x.dtype) * u_out
    y = jnp.einsum("tef,efd->ted", h, wd)
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), combine)
    return out.astype(x.dtype).reshape(b, s, d)


def moe_apply_a2a(
    params: Params,
    x: jnp.ndarray,  # (B, S, d) — sharded (data, model) over (B, S)
    cfg: ModelConfig,
    mesh,
    seq_sharded: bool = True,
) -> jnp.ndarray:
    """GShard-style EP: tokens route to capacity-bounded per-expert slots,
    all-to-all over the 'model' axis ships slots to their (expert x
    ff-slice) owners, expert GEMMs run batched, a second all-to-all ships
    partial results back (summed over ff slices when experts < tp).

    Inside shard_map each device sees a (B/data, S/model, d) token slab, so
    capacity is per (device, expert); over-capacity tokens drop to the
    residual path (GShard semantics).
    """
    tp = mesh.shape["model"]
    e = cfg.n_experts
    split = moe_ff_split(cfg, tp)
    e_loc = max(e // tp, 1)
    batch_axes = pick_batch_axes(mesh, x.shape[0])

    def local(x_loc, router, w_gate, w_up, w_down):
        b_loc, s_loc, d = x_loc.shape
        t = x_loc.reshape(-1, d)
        n_tok = t.shape[0]
        cap = max(int(cfg.capacity_factor * n_tok * cfg.top_k / e), 4)
        logits = t.astype(jnp.float32) @ router
        gates, ids = _topk_gates(logits, cfg.top_k)  # (T, k)
        flat_ids = ids.reshape(-1)
        flat_gates = gates.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(n_tok), cfg.top_k)
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (T*k, E)
        pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # 0-based rank
        slot = jnp.sum(pos, axis=-1)
        keep = (slot >= 0) & (slot < cap)
        slot_c = jnp.clip(slot, 0, cap - 1)
        buf = jnp.zeros((e, cap, d), x_loc.dtype)
        buf = buf.at[flat_ids, slot_c].add(
            jnp.where(keep[:, None], t[flat_tok], 0.0).astype(x_loc.dtype)
        )
        if split > 1:
            # duplicate each expert's slots to all of its ff-slice owners
            buf = jnp.repeat(buf, split, axis=0)  # (E*split == tp, cap, d)
        buf = buf.reshape(tp, e_loc, cap, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        # recv: (tp, e_loc, cap, d) — every peer's slots for MY experts
        recv = recv.reshape(e_loc, tp * cap, d)
        g_out = jnp.einsum("ecd,edf->ecf", recv, w_gate)
        u_out = jnp.einsum("ecd,edf->ecf", recv, w_up)
        h = jax.nn.silu(g_out.astype(jnp.float32)).astype(recv.dtype) * u_out
        y = jnp.einsum("ecf,efd->ecd", h, w_down)  # partial over ff slice
        y = y.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0)
        back = back.reshape(e, split, cap, d).sum(axis=1)  # sum ff slices
        picked = back[flat_ids, slot_c]  # (T*k, d)
        picked = jnp.where(keep[:, None], picked, 0.0)
        contrib = picked.astype(jnp.float32) * flat_gates[:, None]
        out = jnp.zeros((n_tok, d), jnp.float32).at[flat_tok].add(contrib)
        return out.astype(x_loc.dtype).reshape(b_loc, s_loc, d)

    from jax.experimental.shard_map import shard_map

    tok_spec = (
        P(batch_axes, "model", None) if seq_sharded else P(batch_axes, None, None)
    )
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            tok_spec,
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=tok_spec,
        check_rep=False,
    )
    return fn(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])


def moe_apply(
    params: Params, x: jnp.ndarray, cfg: ModelConfig, mesh=None
) -> jnp.ndarray:
    if cfg.moe_impl == "a2a" and mesh is not None:
        return moe_apply_a2a(params, x, cfg, mesh)
    return moe_apply_dense(params, x, cfg)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(key: jax.Array, cfg: ModelConfig) -> Tuple[Params, Params]:
    k1, k2 = jax.random.split(key)
    dt = cfg.jdtype
    fs = "data" if cfg.fsdp else None
    params = {
        "tok": jax.random.normal(k1, (cfg.vocab_padded, cfg.d_model), dt) * 0.02,
        "head": jax.random.normal(k2, (cfg.d_model, cfg.vocab_padded), dt)
        * (1.0 / math.sqrt(cfg.d_model)),
    }
    specs = {"tok": P("model", fs), "head": P(fs, "model")}
    return params, specs
