"""Per-assigned-architecture smoke tests: REDUCED same-family configs run
one forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke, shape_applicable
from repro.launch.steps import param_counts
from repro.models import lm
from repro.models import whisper as W
from repro.models.common import Family

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, batch=2, seq=16):
    toks = jax.random.randint(KEY, (batch, seq), 0, cfg.vocab)
    extra = {}
    if cfg.family is Family.VLM:
        extra["vision_embeds"] = jax.random.normal(
            KEY, (batch, cfg.n_vision_tokens, cfg.d_model), cfg.jdtype
        )
    if cfg.family is Family.AUDIO:
        extra["frames"] = jax.random.normal(
            KEY, (batch, cfg.n_audio_frames, cfg.d_model), cfg.jdtype
        )
    return toks, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    toks, extra = _inputs(cfg)
    if cfg.family is Family.AUDIO:
        p, _ = W.init_whisper(KEY, cfg, tp=1)
        logits, _ = W.apply_whisper(p, cfg, None, toks, frames=extra["frames"])
        exp_s = toks.shape[1]
    else:
        p, _ = lm.init_lm(KEY, cfg, tp=1)
        logits, _ = lm.apply_lm(p, cfg, None, toks,
                                vision_embeds=extra.get("vision_embeds"))
        exp_s = toks.shape[1] + (cfg.n_vision_tokens if cfg.family is Family.VLM else 0)
    assert logits.shape == (2, exp_s, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One grad + update step: loss finite, params change, no NaNs."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = get_smoke(arch)
    toks, extra = _inputs(cfg, seq=17)
    ocfg = AdamWConfig(lr=1e-3)
    if cfg.family is Family.AUDIO:
        p, _ = W.init_whisper(KEY, cfg, tp=1)
        loss, grads = jax.value_and_grad(W.whisper_loss_fn)(
            p, cfg, None, toks, extra["frames"]
        )
    else:
        p, _ = lm.init_lm(KEY, cfg, tp=1)
        loss, grads = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, cfg, None, toks,
                                  vision_embeds=extra.get("vision_embeds"))
        )(p)
    assert np.isfinite(float(loss)) and float(loss) > 0, arch
    state = adamw_init(p, ocfg)
    new_p, _, m = adamw_update(p, grads, state, ocfg)
    assert np.isfinite(float(m["grad_norm"]))
    # at least one leaf moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(new_p))
    )
    assert moved, arch
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(new_p))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-large-v3"])
def test_smoke_decode_step(arch):
    """Prefill + one decode step on the smoke config."""
    cfg = get_smoke(arch)
    toks, extra = _inputs(cfg, seq=8)
    p, _ = lm.init_lm(KEY, cfg, tp=1)
    cache = lm.init_cache(cfg, 2, 32, tp=1)
    _, cache = lm.apply_lm(p, cfg, None, toks, cache=cache,
                           vision_embeds=extra.get("vision_embeds"))
    lg, cache = lm.apply_lm(p, cfg, None, toks[:, :1], cache=cache)
    assert lg.shape == (2, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(lg))), arch


def test_full_config_param_counts():
    """FULL configs match their published sizes (sanity on exact dims)."""
    expect = {
        "mamba2-1.3b": 1.4e9, "zamba2-7b": 6.7e9, "deepseek-67b": 67e9,
        "llama3-405b": 405e9, "nemotron-4-15b": 15.6e9, "qwen3-8b": 8.2e9,
        "grok-1-314b": 314e9, "whisper-large-v3": 1.6e9,
    }
    for arch, n in expect.items():
        got = param_counts(get_config(arch))["total"]
        assert abs(got - n) / n < 0.12, (arch, got, n)


def test_exact_dims_match_assignment():
    checks = {
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab=50280, ssm_state=128),
        "internvl2-1b": dict(n_layers=24, d_model=896, n_heads=14, n_kv=2,
                             d_ff=4864, vocab=151655),
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv=32,
                          d_ff=14336, vocab=32000, ssm_state=64),
        "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    d_ff=1408, vocab=163840, n_experts=64, top_k=6),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv=8,
                            d_ff=32768, vocab=131072, n_experts=8, top_k=2),
        "deepseek-67b": dict(n_layers=95, d_model=8192, n_heads=64, n_kv=8,
                             d_ff=22016, vocab=102400),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128, n_kv=8,
                            d_ff=53248, vocab=128256),
        "nemotron-4-15b": dict(n_layers=32, d_model=6144, n_heads=48, n_kv=8,
                               d_ff=24576, vocab=256000, act="squared_relu"),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv=8,
                         d_ff=12288, vocab=151936, qk_norm=True),
        "whisper-large-v3": dict(n_layers=32, n_encoder_layers=32, d_model=1280,
                                 n_heads=20, n_kv=20, d_ff=5120, vocab=51866),
    }
    for arch, fields in checks.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_long_context_applicability():
    assert shape_applicable("mamba2-1.3b", "long_500k")
    assert shape_applicable("zamba2-7b", "long_500k")
    for a in ("llama3-405b", "qwen3-8b", "whisper-large-v3", "internvl2-1b",
              "grok-1-314b", "deepseek-67b", "nemotron-4-15b", "moonshot-v1-16b-a3b"):
        assert not shape_applicable(a, "long_500k"), a
