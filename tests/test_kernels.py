"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _optional import given, settings, st

from repro.core import bvq, quantization as q, rotation as rot
from repro.kernels import ops, ref
from repro.kernels.bvq_matmul import bvq_matmul_pallas
from repro.kernels.fwht import block_rotate_pallas
from repro.kernels.w4a8_matmul import w4a8_matmul_pallas


# ---------------------------------------------------------------------------
# FWHT / LRU rotation kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,nb,tokens",
    [
        (28, 5, 1, 16),  # 896 exact (internvl d_model)
        (28, 6, 8, 4),  # 14336 tiled (llama3-8b d_ff, paper example)
        (8, 6, 4, 32),
        (4, 6, 2, 8),
        (32, 6, 1, 64),  # 2048 exact (mamba2 d_model)
        (20, 6, 1, 5),  # 1280 exact (whisper d_model)
        (12, 3, 3, 7),
    ],
)
@pytest.mark.parametrize("transpose", [False, True])
def test_block_rotate_matches_oracle(m, k, nb, tokens, transpose):
    n = (m << k) * nb
    x = jnp.asarray(np.random.RandomState(0).randn(tokens, n).astype(np.float32))
    got = block_rotate_pallas(x, m, k, transpose=transpose)
    want = ref.block_rotate_ref(x, m, k, transpose=transpose)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_rotate_dtypes(dtype):
    x = jnp.asarray(np.random.RandomState(1).randn(8, 512), dtype=dtype)
    got = block_rotate_pallas(x, 8, 6)
    want = ref.block_rotate_ref(x, 8, 6)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_block_rotate_3d_batch():
    x = jnp.asarray(np.random.RandomState(2).randn(2, 5, 896).astype(np.float32))
    got = block_rotate_pallas(x, 28, 5)
    want = ref.block_rotate_ref(x, 28, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("n", [896, 1792, 2048, 4864])
def test_lru_rotate_full_plan(n):
    p = rot.plan_rotation(n)
    x = jnp.asarray(np.random.RandomState(3).randn(6, n).astype(np.float32))
    got = ops.lru_rotate(x, p)
    want = rot.local_rotate(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)
    back = ops.lru_rotate_transpose(got, p)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=2e-4)


# ---------------------------------------------------------------------------
# W4A8 matmul kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (8, 64, 32, 128, 128, 512),
        (128, 512, 256, 128, 128, 512),
        (4, 256, 128, 128, 128, 64),  # multiple K steps
        (96, 768, 384, 32, 128, 256),
        (1, 128, 64, 128, 128, 128),  # decode GEMV shape
    ],
)
def test_w4a8_matches_oracle(m, k, n, bm, bn, bk):
    rng = np.random.RandomState(4)
    xq = jnp.asarray(rng.randint(-127, 128, (m, k)).astype(np.int8))
    wq = jnp.asarray(rng.randint(-7, 8, (k, n)).astype(np.int8))
    wp = q.pack_int4(wq, axis=0)
    sx = jnp.asarray(rng.rand(m, 1).astype(np.float32))
    sw = jnp.asarray(rng.rand(1, n).astype(np.float32))
    got = w4a8_matmul_pallas(xq, wp, sx, sw, bm=bm, bn=bn, bk=bk)
    want = ref.w4a8_matmul_ref2(xq, wp, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_w4a8_integer_exactness():
    """With unit scales the kernel must be bit-exact vs int64 numpy."""
    rng = np.random.RandomState(5)
    xq = rng.randint(-127, 128, (16, 256)).astype(np.int8)
    wq = rng.randint(-7, 8, (256, 64)).astype(np.int8)
    wp = q.pack_int4(jnp.asarray(wq), axis=0)
    got = w4a8_matmul_pallas(
        jnp.asarray(xq), wp,
        jnp.ones((16, 1), jnp.float32), jnp.ones((1, 64), jnp.float32),
    )
    ref64 = xq.astype(np.int64) @ wq.astype(np.int64)
    assert np.array_equal(np.asarray(got).astype(np.int64), ref64)


def test_w4a8_end_to_end_linear():
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(10, 256).astype(np.float32))
    w = jnp.asarray((rng.randn(256, 128) * 0.05).astype(np.float32))
    wq, sw = q.quantize_weight_int(w, bits=4, axis=0)
    wp = q.pack_int4(wq, axis=0)
    y = ops.w4a8_linear(x, wp, sw.reshape(1, -1))
    assert float(q.sqnr_db(x @ w, y)) > 15.0


# ---------------------------------------------------------------------------
# BVQ matmul kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "mk,nn,vec,cbs,bc",
    [
        ((8, 64), 48, 4, 32, 16),
        ((32, 128), 128, 8, 64, 32),
        ((1, 256), 64, 8, 16, 64),  # decode GEMV
        ((16, 96), 96, 4, 16, 48),
    ],
)
def test_bvq_matches_oracle(mk, nn, vec, cbs, bc):
    m, k = mk
    rng = np.random.RandomState(7)
    cfg = bvq.BVQConfig(
        vec_dim=vec, codebook_size=cbs, block_cols=bc, kmeans_iters=4, qat_steps=0
    )
    w = jnp.asarray(rng.randn(k, nn).astype(np.float32))
    bw = bvq.bvq_compress(w, cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    got = bvq_matmul_pallas(x, bw)
    want = ref.bvq_matmul_ref2(x, bw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bvq_linear_wrapper_batched():
    rng = np.random.RandomState(8)
    cfg = bvq.BVQConfig(vec_dim=4, codebook_size=16, block_cols=16, kmeans_iters=4, qat_steps=0)
    w = jnp.asarray(rng.randn(64, 32).astype(np.float32))
    bw = bvq.bvq_compress(w, cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(rng.randn(2, 3, 64).astype(np.float32))
    y = ops.bvq_linear(x, bw)
    want = x.reshape(-1, 64) @ bvq.bvq_reconstruct(bw)
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1, 32), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=16),
    kblocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=50),
)
def test_w4a8_property_random_shapes(m, kblocks, seed):
    k = 64 * kblocks
    n = 32
    rng = np.random.RandomState(seed)
    xq = jnp.asarray(rng.randint(-127, 128, (m, k)).astype(np.int8))
    wq = jnp.asarray(rng.randint(-7, 8, (k, n)).astype(np.int8))
    wp = q.pack_int4(wq, axis=0)
    sx = jnp.asarray(rng.rand(m, 1).astype(np.float32))
    sw = jnp.asarray(rng.rand(1, n).astype(np.float32))
    got = w4a8_matmul_pallas(xq, wp, sx, sw, bk=64)
    want = ref.w4a8_matmul_ref2(xq, wp, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
