"""Device-resident paged serving path: residency, impl, and path parity.

The bit-identity of the default paged path against ``serve_sd`` is covered
8-way in test_serving_batch.py; this module covers what is specific to the
refactor — the legacy host-gather baseline still agrees, the Pallas
kernel-wired impl produces the same greedy tokens, and the summary exposes
the residency telemetry the benchmark reports.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import build_pair
from repro.serving.engine import BatchConfig, serve_batch


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, vocab, size=rng.randint(2, 7)).astype(np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def test_host_path_matches_paged(pair):
    """The legacy host gather/scatter loop (benchmark baseline) and the
    device-resident path run the same per-row programs — outputs and
    scheduling stats must agree exactly."""
    target, draft = pair
    prompts = _prompts(3, seed=2)
    cfg = BatchConfig(max_batch=3, page_size=8, max_tokens=6, draft_len=2)
    outs_p, sum_p = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
    cfg_h = dataclasses.replace(cfg, kv_path="host")
    outs_h, sum_h = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg_h)
    for i, (a, b) in enumerate(zip(outs_p, outs_h)):
        assert bool(jnp.all(a == b)), f"request {i} diverged across kv paths"
    assert sum_p["kv_path"] == "paged" and sum_h["kv_path"] == "host"
    assert sum_p["rounds"] == sum_h["rounds"]
    assert sum_p["kv_copy_s"] == 0.0  # no host K/V copies on the paged path
    assert sum_h["kv_copy_s"] > 0.0  # the tax the refactor removed


def test_pallas_impl_same_greedy_tokens(pair):
    """Routing decode/verify attention through the paged Pallas kernel
    (interpret mode on CPU) keeps the greedy outputs: ULP-level softmax
    reassociation never flips an argmax on these pairs."""
    target, draft = pair
    tp = dataclasses.replace(target, paged_attn_impl="pallas")
    dp = dataclasses.replace(draft, paged_attn_impl="pallas")
    prompts = _prompts(2, seed=9)
    cfg = BatchConfig(max_batch=2, page_size=8, max_tokens=6, draft_len=2)
    ref_outs, _ = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
    got_outs, summary = serve_batch(jax.random.PRNGKey(0), tp, dp, prompts, cfg)
    for i, (a, b) in enumerate(zip(ref_outs, got_outs)):
        assert bool(jnp.all(a == b)), f"request {i} diverged under pallas impl"
    assert summary["emitted"] == 2 * 6


def test_unknown_kv_path_rejected(pair):
    target, draft = pair
    with pytest.raises(ValueError, match="kv_path"):
        serve_batch(
            jax.random.PRNGKey(0), target, draft, _prompts(1),
            BatchConfig(kv_path="floppy"),
        )


def test_pool_pages_released_and_tables_cleared(pair):
    """Finished requests free their (eagerly backed) pages so the queue can
    back-fill; the pool ends empty."""
    target, draft = pair
    prompts = _prompts(4, seed=4)
    need = -(-(max(len(p) for p in prompts) + 6 + 2) // 8)
    cfg = BatchConfig(
        max_batch=4, page_size=8, max_tokens=6, draft_len=2,
        num_pages=2 * need,  # only ~2 concurrent worst-case requests fit
    )
    outs, summary = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
    assert summary["requests"] == 4
    assert summary["target_pool"].used_pages == 0
    assert summary["draft_pool"].used_pages == 0
    # eager backing bounds high water by the page budget
    assert summary["target_pool"].high_water_pages <= 2 * need
