"""Engine-level compressed paged KV (EngineConfig.kv_quant).

The contract under test: kv_quant="none" is BIT-IDENTICAL to the engine
before compressed KV existed (greedy and sampled, gather and Pallas paged
attention, two-phase and fused wdos rounds) — the int8 machinery must be
structurally absent from the dense dispatch, not merely numerically close.
kv_quant="int8" is a relaxed-determinism opt-in: it stays deterministic
across schedulers and attention impls (off == wdos, gather == pallas,
token-for-token) but is only *close* to the dense tokens.  kv_quant="mixed"
runs both storage kinds behind ONE allocator: each row bit-matches the
pure-mode engine of its own kind.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.speculative import SDConfig, sd_generate
from repro.launch.serve import build_pair
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.engine import make_interface


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, vocab, size=rng.randint(2, 7)).astype(np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def _drain(target, draft, prompts, sps, **cfg_kw):
    cfg_kw.setdefault("page_size", 8)
    cfg_kw.setdefault("draft_len", 3)
    eng = Engine(target, draft, EngineConfig(
        max_batch=len(prompts), **cfg_kw
    ))
    outs, summary = eng.run(prompts, sps)
    return outs, summary, eng


def _sd_ref(target, draft, prompt, max_tokens, dl=3):
    """Pre-redesign reference: the dense-cache sd_generate driver."""
    toks, _ = sd_generate(
        jax.random.PRNGKey(0),
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        jnp.asarray(np.asarray(prompt)[None]),
        SDConfig(draft_len=dl, temperature=0.0, max_tokens=max_tokens),
    )
    return toks


# ---------------------------------------------------------------------------
# kv_quant="none" bit-identity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("par_mode", ["off", "wdos"])
@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_none_greedy_bit_identical_to_dense_reference(pair, par_mode, impl):
    """kv_quant="none" tokens == the dense sd_generate reference, under
    BOTH schedulers and BOTH paged-attention impls."""
    import dataclasses
    target, draft = pair
    if impl == "pallas":
        target = dataclasses.replace(target, paged_attn_impl="pallas")
        draft = dataclasses.replace(draft, paged_attn_impl="pallas")
    prompts = _prompts(3, seed=3)
    sp = SamplingParams(max_tokens=10)
    outs, _, _ = _drain(target, draft, prompts, sp,
                        par_mode=par_mode, kv_quant="none")
    for p, o in zip(prompts, outs):
        ref = _sd_ref(target, draft, p, 10)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ref))


def test_none_sampled_bit_identical_to_default_engine(pair):
    """Sampled (temperature/top_p) path: a kv_quant="none" engine emits the
    SAME tokens as an engine built without the knob at all."""
    target, draft = pair
    prompts = _prompts(4, seed=5)
    sps = [SamplingParams(max_tokens=12, temperature=0.8, top_p=0.9, seed=i)
           for i in range(4)]
    base, _, _ = _drain(target, draft, prompts, sps)
    none, _, _ = _drain(target, draft, prompts, sps, kv_quant="none")
    for b, n in zip(base, none):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(n))


# ---------------------------------------------------------------------------
# int8: deterministic across schedulers and impls, close to dense
# ---------------------------------------------------------------------------


def test_int8_off_equals_wdos_and_gather_equals_pallas(pair):
    import dataclasses
    target, draft = pair
    prompts = _prompts(4, seed=7)
    sp = SamplingParams(max_tokens=12)
    off, s_off, _ = _drain(target, draft, prompts, sp,
                           par_mode="off", kv_quant="int8")
    wdos, _, _ = _drain(target, draft, prompts, sp,
                        par_mode="wdos", kv_quant="int8")
    for a, b in zip(off, wdos):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tp = dataclasses.replace(target, paged_attn_impl="pallas")
    dp = dataclasses.replace(draft, paged_attn_impl="pallas")
    pal, _, _ = _drain(tp, dp, prompts, sp, par_mode="off", kv_quant="int8")
    for a, b in zip(off, pal):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s_off["kv_quant"] == "int8"


def test_int8_acceptance_within_bound_of_dense(pair):
    """The opt-in gate: int8 storage may perturb logits, but the
    speculative acceptance rate stays within 0.05 of dense."""
    target, draft = pair
    prompts = _prompts(6, seed=11)
    sp = SamplingParams(max_tokens=16)
    _, s_none, _ = _drain(target, draft, prompts, sp, kv_quant="none")
    _, s_int8, _ = _drain(target, draft, prompts, sp, kv_quant="int8")
    assert abs(s_int8["acceptance_rate"] - s_none["acceptance_rate"]) <= 0.05


# ---------------------------------------------------------------------------
# mixed: one allocator, per-request storage kinds
# ---------------------------------------------------------------------------


def test_mixed_rows_bit_match_pure_engines(pair):
    """A mixed batch interleaving fp and int8 requests: every row's tokens
    == the same prompt drained on the PURE engine of its kind — sharing the
    allocator with the other kind must not leak into either."""
    target, draft = pair
    prompts = _prompts(4, seed=13)
    kinds = ["none", "int8", "int8", "none"]
    sps = [SamplingParams(max_tokens=12, kv_quant=k) for k in kinds]
    mixed, summary, eng = _drain(target, draft, prompts, sps,
                                 kv_quant="mixed")
    sp = SamplingParams(max_tokens=12)
    pure = {}
    for k in ("none", "int8"):
        ps = [p for p, kk in zip(prompts, kinds) if kk == k]
        outs, _, _ = _drain(target, draft, ps, sp, kv_quant=k)
        pure[k] = dict(zip([i for i, kk in enumerate(kinds) if kk == k],
                           outs))
    for k in ("none", "int8"):
        for i, ref in pure[k].items():
            np.testing.assert_array_equal(np.asarray(mixed[i]),
                                          np.asarray(ref))
    assert summary["kv_quant"] == "mixed"
    # mixed accounts BOTH stores' bytes against the shared page pool
    bpt = summary["kv_bytes_per_token"]["target"]
    assert bpt > 0


def test_mixed_default_kind_is_dense(pair):
    """Requests that don't pin kv_quant land on the dense store."""
    target, draft = pair
    (p,) = _prompts(1, seed=17)
    eng = Engine(target, draft, EngineConfig(
        max_batch=1, page_size=8, draft_len=3, kv_quant="mixed"
    ))
    rid = eng.add_request(p, SamplingParams(max_tokens=4))
    assert eng.request(rid).kv_kind == "none"
    while eng.has_unfinished():
        eng.step()


# ---------------------------------------------------------------------------
# Config/request validation and introspection
# ---------------------------------------------------------------------------


def test_request_pinning_incompatible_kind_raises(pair):
    target, draft = pair
    (p,) = _prompts(1)
    for engine_mode, pin in (("none", "int8"), ("int8", "none")):
        eng = Engine(target, draft, EngineConfig(
            max_batch=1, page_size=8, kv_quant=engine_mode
        ))
        with pytest.raises(ValueError, match="kv_quant"):
            eng.add_request(p, SamplingParams(max_tokens=4, kv_quant=pin))


def test_config_validation():
    with pytest.raises(ValueError, match="kv_quant"):
        EngineConfig(kv_quant="fp4")
    with pytest.raises(ValueError, match="kv_quant"):
        SamplingParams(kv_quant="mixed")  # per-request pin must be concrete
    assert EngineConfig(kv_quant="mixed").kv_kinds == ("none", "int8")
    assert EngineConfig(kv_quant="int8").kv_kinds == ("int8",)
    assert EngineConfig(kv_quant="mixed").resolve_kv_quant(None) == "none"
    assert EngineConfig(kv_quant="int8").resolve_kv_quant(None) == "int8"


def test_snapshot_and_metrics_carry_kv_bytes(pair):
    target, draft = pair
    prompts = _prompts(2, seed=19)
    _, summary, eng = _drain(target, draft, prompts,
                             SamplingParams(max_tokens=6), kv_quant="int8")
    snap = eng.stats_snapshot()
    assert snap["kv_quant"] == "int8"
    assert summary["kv_bytes_per_token"]["target"] > 0
    assert summary["kv_bytes_per_token"]["draft"] > 0
    text = eng.metrics.render()
    assert "kv_bytes_total" in text
    assert "kv_bytes_per_token" in text
    assert 'dtype="int8"' in text
