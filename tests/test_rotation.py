"""LRU rotation: orthogonality, invariance, outlier suppression, cost."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _optional import given, settings, st

from repro.core import hadamard, rotation

ASSIGNED_DIMS = [
    896, 1280, 1408, 2048, 3584, 4096, 4864, 5120, 6144, 8192, 12288,
    14336, 16384, 22016, 24576, 32768, 53248,
]


@pytest.mark.parametrize("order", [1, 2, 4, 8, 12, 16, 20, 24, 28, 44, 56, 76, 96])
def test_hadamard_constructions(order):
    h = hadamard.hadamard_matrix(order)
    gram = h.astype(np.int64) @ h.astype(np.int64).T
    assert np.array_equal(gram, order * np.eye(order, dtype=np.int64))
    assert set(np.unique(h)) <= {-1, 1}


@pytest.mark.parametrize("n", ASSIGNED_DIMS)
def test_plan_exists_for_assigned_dims(n):
    p = rotation.plan_rotation(n)
    assert p.k <= rotation.MAX_DEPTH
    assert p.block * p.num_blocks >= n or p.kind == "two_block"
    if p.kind == "exact":
        assert p.block == n
    if p.kind == "tiled":
        assert n % p.block == 0


@pytest.mark.parametrize("n", [352, 768, 896, 1408, 2048])
def test_rotation_matrix_orthogonal(n):
    r = rotation.rotation_matrix(n)
    assert np.allclose(r @ r.T, np.eye(n), atol=1e-9)


@pytest.mark.parametrize("n", [352, 768, 896, 1364, 2048])
def test_local_rotate_matches_dense(n):
    p = rotation.plan_rotation(n)
    x = np.random.RandomState(0).randn(4, n).astype(np.float32)
    fast = np.asarray(rotation.local_rotate(jnp.asarray(x), p))
    ref = x @ rotation.rotation_matrix(n).astype(np.float32)
    np.testing.assert_allclose(fast, ref, atol=2e-4)


@pytest.mark.parametrize("n", [352, 896, 2048, 1792])
def test_transpose_inverts(n):
    p = rotation.plan_rotation(n)
    x = np.random.RandomState(1).randn(3, n).astype(np.float32)
    y = rotation.local_rotate(jnp.asarray(x), p)
    back = rotation.local_rotate_transpose(y, p)
    np.testing.assert_allclose(np.asarray(back), x, atol=2e-4)


def test_computational_invariance():
    n = 1792
    p = rotation.plan_rotation(n)
    rng = np.random.RandomState(2)
    x = rng.randn(8, n).astype(np.float32)
    w = rng.randn(n, 64).astype(np.float32)
    xr = rotation.local_rotate(jnp.asarray(x), p)
    wr = rotation.rotate_weight_in(jnp.asarray(w), p)
    ref = x @ w
    np.testing.assert_allclose(np.asarray(xr @ wr), ref, rtol=2e-4, atol=2e-3)


def test_outlier_suppression():
    n = 3584
    p = rotation.plan_rotation(n)
    rng = np.random.RandomState(3)
    x = rng.randn(32, n).astype(np.float32)
    for ch in (5, 700, 2000, 3583):
        x[:, ch] *= 100.0
    xr = np.asarray(rotation.local_rotate(jnp.asarray(x), p))
    k_before = float(np.mean(np.asarray(rotation.kurtosis(jnp.asarray(x)))))
    k_after = float(np.mean(np.asarray(rotation.kurtosis(jnp.asarray(xr)))))
    assert k_after < k_before / 20.0  # massive outlier mixing
    ratio_before = np.abs(x).max() / np.abs(x).mean()
    ratio_after = np.abs(xr).max() / np.abs(xr).mean()
    assert ratio_after < ratio_before / 5.0


def test_lru_area_saving_matches_paper():
    """Paper: 92.7% area saving vs the global-rotation array (npot dims)."""
    savings = []
    for n in (14336, 22016, 53248, 4864):
        lru = rotation.rotation_area(rotation.plan_rotation(n))
        glob = rotation.global_rotation_area(n)
        savings.append(1.0 - lru / glob)
        assert lru < glob * 0.15, (n, lru, glob)
    mean = sum(savings) / len(savings)
    assert mean > 0.90  # paper: 0.927


def test_paper_npot_factorization_example():
    """Paper's worked example: 14336 (LLaMA3-8B down_proj) = 2^9 x 28 ->
    LRU uses the m=28 npot Hadamard with a depth<=6 FWHT."""
    p = rotation.plan_rotation(14336)
    assert p.m == 28 and p.k == 6 and p.kind == "tiled"


@settings(max_examples=20, deadline=None)
@given(
    logm=st.sampled_from([4, 8, 12, 16, 20]),
    k=st.integers(min_value=0, max_value=6),
)
def test_block_hadamard_property(logm, k):
    b = logm * (1 << k)
    hb = rotation.block_hadamard(logm, k)
    assert np.allclose(hb @ hb.T, np.eye(b), atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=2, max_value=64))
def test_fwht_matches_matrix(logn):
    n = 1 << int(np.ceil(np.log2(logn)))
    x = np.random.RandomState(0).randn(2, n).astype(np.float32)
    h = hadamard.hadamard_matrix(n).astype(np.float32)
    got = np.asarray(rotation.fwht_jnp(jnp.asarray(x)))
    np.testing.assert_allclose(got, x @ h, rtol=1e-4, atol=1e-3)
