"""WDOS discrete-event scheduler."""
import pytest

from repro.core import scheduler as sch
from repro.core.scheduler import Instr, Queue


def test_independent_queues_overlap():
    instrs = [
        Instr(0, Queue.RERAM, 10.0),
        Instr(1, Queue.EMAC, 10.0),
        Instr(2, Queue.COMPUTE, 10.0),
    ]
    s = sch.wdos_schedule(instrs)
    assert s.makespan == 10.0  # fully parallel
    assert sch.inorder_schedule(instrs).makespan == 30.0


def test_dependencies_serialize():
    instrs = [
        Instr(0, Queue.RERAM, 5.0),
        Instr(1, Queue.COMPUTE, 7.0, deps=(0,)),
        Instr(2, Queue.EMAC, 3.0, deps=(1,)),
    ]
    s = sch.wdos_schedule(instrs)
    assert s.makespan == 15.0
    assert s.start[1] == 5.0 and s.start[2] == 12.0


def test_fifo_within_queue():
    instrs = [
        Instr(0, Queue.COMPUTE, 4.0),
        Instr(1, Queue.COMPUTE, 2.0),
    ]
    s = sch.wdos_schedule(instrs)
    assert s.start[1] == 4.0  # same queue: in order


def test_cross_queue_out_of_order():
    """A blocked head in one queue must not stall other queues."""
    instrs = [
        Instr(0, Queue.EMAC, 100.0),
        Instr(1, Queue.COMPUTE, 1.0, deps=(0,)),  # compute blocked on EMAC
        Instr(2, Queue.RERAM, 5.0),  # independent: runs immediately
    ]
    s = sch.wdos_schedule(instrs)
    assert s.start[2] == 0.0
    assert s.finish[1] == 101.0


def test_deadlock_detection():
    # head-of-line cross dependency: q1 head needs q2's SECOND instr
    instrs = [
        Instr(0, Queue.COMPUTE, 1.0, deps=(2,)),
        Instr(1, Queue.EMAC, 1.0, deps=(0,)),
        Instr(2, Queue.EMAC, 1.0),  # behind 1 in the EMAC queue
    ]
    with pytest.raises(RuntimeError):
        sch.wdos_schedule(instrs)


def test_cyclic_dependency_deadlocks():
    """A true cross-queue dependency cycle must raise, not spin."""
    instrs = [
        Instr(0, Queue.COMPUTE, 1.0, deps=(1,)),
        Instr(1, Queue.EMAC, 1.0, deps=(0,)),
    ]
    with pytest.raises(RuntimeError, match="deadlock"):
        sch.wdos_schedule(instrs)


def test_self_dependency_deadlocks():
    with pytest.raises(RuntimeError, match="deadlock"):
        sch.wdos_schedule([Instr(0, Queue.RERAM, 1.0, deps=(0,))])


def test_utilization_zero_makespan():
    """Empty / zero-duration schedules must not divide by zero."""
    s = sch.wdos_schedule([])
    assert s.makespan == 0.0
    for q in Queue:
        assert s.utilization(q) == 0.0
    s0 = sch.wdos_schedule([Instr(0, Queue.COMPUTE, 0.0)])
    assert s0.makespan == 0.0
    assert s0.utilization(Queue.COMPUTE) == 0.0


def test_layer_pipeline_overlaps_load_and_compute():
    b = sch.new_builder()
    # 8 layers, load 2.0 each / compute 1.0 each
    _, last = sch.layer_pipeline_instrs(b, 8, Queue.EMAC, 2.0, 1.0, tag="t")
    s = sch.wdos_schedule(b.instrs)
    # load-bound: 8*2.0 + final compute 1.0
    assert s.makespan == pytest.approx(17.0)
    base = sch.inorder_schedule(b.instrs)
    assert base.makespan == pytest.approx(24.0)
    assert s.utilization(Queue.EMAC) > 0.9


def test_draft_verify_decoupling_speedup():
    """DLM (ReRAM-fed) and TLM (EMAC-fed) rounds overlap under WDOS —
    the silicon-level mechanism behind APSD's PAR mode."""
    b = sch.new_builder()
    _, d_last = sch.layer_pipeline_instrs(b, 4, Queue.RERAM, 1.0, 0.5, tag="dlm")
    _, t_last = sch.layer_pipeline_instrs(b, 8, Queue.EMAC, 3.0, 0.5, tag="tlm")
    s = sch.wdos_schedule(b.instrs)
    assert s.makespan <= 26.0  # ~TLM-bound
    assert sch.inorder_schedule(b.instrs).makespan >= 34.0
