"""FlightRecorder unit tests on synthetic round streams: each anomaly
kind fires exactly once per episode and re-arms after the condition
clears, the ring stays bounded, postmortems round-trip through JSON,
and ``anomalies_total{kind}`` tracks the episode counts.  No models —
the recorder is fed hand-built per-round records (the same dict shape
``Engine._flight_round`` produces).
"""
import json

import pytest

from repro.serving import ANOMALY_KINDS, MetricsRegistry
from repro.serving.flight_recorder import FlightRecorder


def _round(wall_s=0.01, drafted=4, accepted=3, admitted=0, queued=0,
           active=2, free_target=10, free_draft=10, **kw):
    rec = {
        "round": kw.pop("round_idx", 0),
        "mode": "two_phase",
        "rows": active,
        "wall_s": wall_s,
        "drafted": drafted,
        "accepted": accepted,
        "admitted": admitted,
        "queued": queued,
        "active": active,
        "free_pages": {"target": free_target, "draft": free_draft},
        "t": 0.0,
    }
    rec.update(kw)
    return rec


def _warm(fr, n=20, **kw):
    """Feed n healthy rounds (past the default warmup of 16)."""
    fired = []
    for _ in range(n):
        fired += fr.record(_round(**kw))
    return fired


def test_disabled_recorder_is_inert():
    fr = FlightRecorder(0)
    assert not fr.enabled
    assert fr.record(_round()) == []
    snap = fr.snapshot()
    assert snap["rounds_recorded"] == 0 and snap["ring"] == []


def test_ring_stays_bounded():
    fr = FlightRecorder(8)
    for i in range(30):
        fr.record(_round(round_idx=i))
    snap = fr.snapshot()
    assert snap["rounds_recorded"] == 30
    assert len(snap["ring"]) == 8
    # the ring holds the LAST 8 rounds, in order
    assert [r["seq"] for r in snap["ring"]] == list(range(22, 30))


def test_slow_round_fires_once_per_episode_and_rearms():
    m = MetricsRegistry()
    fr = FlightRecorder(64, metrics=m)
    assert _warm(fr, 20) == []  # healthy warmup: nothing fires
    # 10x the median wall -> fires on the transition...
    assert fr.record(_round(wall_s=0.1)) == ["slow_round"]
    # ...but a CONTINUING slow episode does not re-fire
    assert fr.record(_round(wall_s=0.1)) == []
    # recovery re-arms; the next excursion is a new episode
    assert fr.record(_round()) == []
    assert fr.record(_round(wall_s=0.1)) == ["slow_round"]
    assert fr.snapshot()["anomalies"]["slow_round"] == 2
    assert m.value("anomalies_total", kind="slow_round") == 2


def test_slow_round_armed_only_after_warmup():
    fr = FlightRecorder(64)
    # round 3 is 100x the others — inside warmup, must NOT fire (compile
    # stalls look exactly like this)
    for i in range(10):
        assert fr.record(_round(wall_s=1.0 if i == 3 else 0.01)) == []


def test_acceptance_collapse_windowed():
    fr = FlightRecorder(64)
    _warm(fr, 20)  # healthy: accept rate 0.75
    fired = []
    for _ in range(8):  # 8-round window of 4 drafted / 0 accepted
        fired += fr.record(_round(accepted=0))
    assert fired == ["acceptance_collapse"]  # exactly once for the episode
    # recovery clears the window average above the floor -> re-arms
    for _ in range(8):
        assert fr.record(_round()) == []
    fired = []
    for _ in range(8):
        fired += fr.record(_round(accepted=0))
    assert fired == ["acceptance_collapse"]


def test_pool_exhausted_requires_queued_and_zero_free():
    fr = FlightRecorder(64)
    # zero free pages with an EMPTY queue is fine (drain tail)
    assert fr.record(_round(free_target=0)) == []
    # queued work + a dry pool is the anomaly — either pool
    assert fr.record(_round(queued=2, free_target=0)) == ["pool_exhausted"]
    assert fr.record(_round(queued=2, free_target=0)) == []  # latched
    assert fr.record(_round(queued=0)) == []  # clears
    assert fr.record(_round(queued=1, free_draft=0)) == ["pool_exhausted"]


def test_admission_stall_counts_consecutive_rounds():
    fr = FlightRecorder(64, stall_rounds=4)
    fired = []
    for _ in range(3):
        fired += fr.record(_round(queued=1, admitted=0))
    assert fired == []
    # an admission resets the run
    fr.record(_round(queued=1, admitted=1))
    for _ in range(3):
        assert fr.record(_round(queued=1, admitted=0)) == []
    # the 4th consecutive stalled round fires
    assert fr.record(_round(queued=1, admitted=0)) == ["admission_stall"]
    assert fr.record(_round(queued=1, admitted=0)) == []  # latched


def test_postmortem_shape_and_json_roundtrip(tmp_path):
    m = MetricsRegistry()
    fr = FlightRecorder(16, metrics=m, dump_dir=str(tmp_path))
    _warm(fr, 20)
    fr.record(_round(wall_s=0.5))
    snap = fr.snapshot()
    assert len(snap["postmortems"]) == 1
    pm = snap["postmortems"][0]
    assert pm["kind"] == "slow_round"
    assert pm["record"]["wall_s"] == 0.5
    assert pm["record"]["anomalies"] == ["slow_round"]
    assert pm["fired_at_round"] == pm["record"]["seq"] == 20
    assert len(pm["ring"]) <= 16 and pm["ring"][-1] is not None
    # the whole snapshot survives a JSON round-trip (what /debug/flight
    # serves and what dump_dir receives)
    again = json.loads(json.dumps(snap))
    assert again["anomalies"]["slow_round"] == 1
    # the on-disk dump exists and parses
    files = list(tmp_path.glob("flight_slow_round_*.json"))
    assert len(files) == 1
    disk = json.loads(files[0].read_text())
    assert disk["kind"] == "slow_round"


def test_dump_on_demand(tmp_path):
    fr = FlightRecorder(8)
    _warm(fr, 5)
    out = tmp_path / "manual.json"
    snap = fr.dump(str(out), reason="operator")
    assert snap["reason"] == "operator"
    assert snap["dumped_to"] == str(out)
    assert json.loads(out.read_text())["rounds_recorded"] == 5


def test_all_anomaly_series_materialized_at_zero():
    m = MetricsRegistry()
    FlightRecorder(8, metrics=m)
    for kind in ANOMALY_KINDS:
        assert m.value("anomalies_total", kind=kind) == 0.0
    text = m.render()
    for kind in ANOMALY_KINDS:
        assert f'serving_anomalies_total{{kind="{kind}"}} 0' in text


def test_negative_ring_capacity_rejected():
    from repro.serving import EngineConfig
    with pytest.raises(ValueError):
        EngineConfig(flight_ring=-1)
    with pytest.raises(ValueError):
        EngineConfig(profile_every_n=-2)
