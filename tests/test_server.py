"""HTTP completion server: SSE framing, stop/top_p end-to-end through the
wire, disconnect -> abort, backpressure 429, and route/validation errors.

Each test runs a real ``CompletionServer`` on a loopback socket (port 0)
and speaks raw HTTP/1.1 through the shared ``serving.http_client`` —
the same protocol layer a load balancer or the bench harness sees, no
test-only shortcuts.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.launch.serve import build_pair
from repro.serving import (
    AsyncEngine,
    CompletionServer,
    Engine,
    EngineConfig,
    SamplingParams,
)
from repro.serving import http_client as hc


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        [int(t) for t in rng.randint(0, vocab, size=rng.randint(3, 7))]
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def _sync_ref(pair, prompt, sp):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    outs, _ = eng.run([np.asarray(prompt, np.int32)], sp)
    return [int(t) for t in outs[0]]


class _Served:
    """One live server, with the shared client bound to its port."""

    def __init__(self, server):
        self.server = server
        self.port = server.port

    async def request(self, method, path, payload=None):
        return await hc.request(self.port, method, path, payload)

    async def stream_raw(self, payload):
        """POST stream=true; return (status, head, raw SSE body bytes)."""
        return await self.request(
            "POST", "/v1/completions", dict(payload, stream=True)
        )


def _with_server(pair, engine_cfg=None, max_queued=8):
    """Decorator-free harness: run `fn(_Served)` inside a fresh server."""
    target, draft = pair
    cfg = engine_cfg or EngineConfig(
        max_batch=2, page_size=8, max_model_len=128
    )

    def runner(fn):
        async def scenario():
            engine = Engine(target, draft, cfg)
            server = CompletionServer(
                AsyncEngine(engine, max_queued=max_queued)
            )
            await server.start(port=0)
            task = asyncio.ensure_future(server.serve_forever())
            try:
                return await fn(_Served(server))
            finally:
                task.cancel()
                try:
                    await task
                except asyncio.CancelledError:
                    pass
                await server.stop()

        return asyncio.run(scenario())

    return runner


# ---------------------------------------------------------------------------
# SSE framing + bit-identity through the wire
# ---------------------------------------------------------------------------


def test_sse_chunk_framing_and_token_identity(pair):
    prompt = _prompts(1, seed=1)[0]
    ref = _sync_ref(pair, prompt, SamplingParams(max_tokens=10))

    async def fn(srv):
        status, head, body = await srv.stream_raw(
            {"prompt": prompt, "max_tokens": 10}
        )
        assert status == 200
        assert "text/event-stream" in head
        events = [e for e in body.decode().split("\n\n") if e.strip()]
        # framing: every event is a single `data: ` line, stream ends [DONE]
        assert all(e.startswith("data: ") and "\n" not in e for e in events)
        assert events[-1] == "data: [DONE]"
        chunks = [json.loads(e[len("data: "):]) for e in events[:-1]]
        # per-token chunks with a monotone index and exactly one final
        assert [c["index"] for c in chunks] == list(range(len(chunks)))
        assert [c["token"] for c in chunks] == ref
        reasons = [c["finish_reason"] for c in chunks]
        assert reasons[-1] == "length" and set(reasons[:-1]) == {None}
        # detokenized text rides along per chunk
        assert chunks[0]["text"] == f"{ref[0]} "

    _with_server(pair)(fn)


def test_non_streaming_completion_matches_reference(pair):
    prompt = _prompts(1, seed=2)[0]
    ref = _sync_ref(pair, prompt, SamplingParams(max_tokens=8))

    async def fn(srv):
        status, _, body = await srv.request(
            "POST", "/v1/completions", {"prompt": prompt, "max_tokens": 8}
        )
        assert status == 200
        obj = json.loads(body)
        assert obj["token_ids"] == ref
        assert obj["finish_reason"] == "length"
        assert obj["usage"] == {
            "prompt_tokens": len(prompt), "completion_tokens": len(ref),
        }
        assert obj["text"] == "".join(f"{t} " for t in ref)

    _with_server(pair)(fn)


# ---------------------------------------------------------------------------
# stop + top_p end-to-end through HTTP
# ---------------------------------------------------------------------------


def test_stop_sequence_through_http(pair):
    prompt = _prompts(1, seed=3)[0]
    ref = _sync_ref(pair, prompt, SamplingParams(max_tokens=10))
    stop_s = f"{ref[4]} "  # the 5th token's text

    async def fn(srv):
        # whole response: truncated BEFORE the stop string, reason "stop"
        status, _, body = await srv.request(
            "POST", "/v1/completions",
            {"prompt": prompt, "max_tokens": 10, "stop": stop_s},
        )
        obj = json.loads(body)
        assert status == 200
        assert obj["token_ids"] == ref[:4]
        assert obj["finish_reason"] == "stop"
        assert stop_s not in obj["text"]
        # streamed: same truncation, final chunk carries the reason
        status, _, sse = await srv.stream_raw(
            {"prompt": prompt, "max_tokens": 10, "stop": [stop_s]}
        )
        events = [e for e in sse.decode().split("\n\n") if e.strip()]
        chunks = [json.loads(e[len("data: "):]) for e in events[:-1]]
        toks = [c["token"] for c in chunks if c["token"] is not None]
        assert toks == ref[:4]
        assert chunks[-1]["finish_reason"] == "stop"

    _with_server(pair)(fn)


def test_top_p_through_http_deterministic_and_lossless(pair):
    prompt = _prompts(1, seed=4)[0]
    greedy = _sync_ref(pair, prompt, SamplingParams(max_tokens=8))
    sp = SamplingParams(temperature=0.8, top_p=0.85, seed=21, max_tokens=8)
    ref = _sync_ref(pair, prompt, sp)

    async def fn(srv):
        payload = {
            "prompt": prompt, "max_tokens": 8,
            "temperature": 0.8, "top_p": 0.85, "seed": 21,
        }
        status, _, body = await srv.request(
            "POST", "/v1/completions", payload
        )
        assert status == 200
        # nucleus sampling through HTTP == the same SamplingParams run
        # synchronously (per-request key streams, schedule-invariant)
        assert json.loads(body)["token_ids"] == ref
        # and a tiny nucleus collapses to greedy exactly
        status, _, body = await srv.request(
            "POST", "/v1/completions",
            {"prompt": prompt, "max_tokens": 8,
             "temperature": 0.8, "top_p": 1e-6, "seed": 21},
        )
        assert json.loads(body)["token_ids"] == greedy

    _with_server(pair)(fn)


# ---------------------------------------------------------------------------
# disconnect -> abort, health/stats, errors
# ---------------------------------------------------------------------------


def test_client_disconnect_aborts_and_frees_pages(pair):
    p_victim, p_survivor = _prompts(2, seed=5)
    ref = _sync_ref(pair, p_survivor, SamplingParams(max_tokens=10))

    async def fn(srv):
        # open a long streaming completion, read one chunk, hang up
        reader, writer = await hc.open_request(
            srv.port, "POST", "/v1/completions",
            {"prompt": p_victim, "max_tokens": 100, "stream": True},
        )
        await hc.read_head(reader)
        await reader.readuntil(b"\n\n")  # first token chunk is out
        writer.close()  # mid-generation disconnect
        # a healthy neighbour keeps decoding, bit-identical
        status, _, resp = await srv.request(
            "POST", "/v1/completions",
            {"prompt": p_survivor, "max_tokens": 10},
        )
        assert status == 200 and json.loads(resp)["token_ids"] == ref
        # every page returns once the abort lands
        st = {}
        for _ in range(200):
            _, _, sbody = await srv.request("GET", "/stats")
            st = json.loads(sbody)
            if st["target_pool"]["used_pages"] == 0 and st["active"] == 0:
                break
            await asyncio.sleep(0.02)
        assert st["target_pool"]["used_pages"] == 0, st["target_pool"]
        assert st["target_pool"]["reserved_pages"] == 0
        assert st["draft_pool"]["used_pages"] == 0

    _with_server(pair)(fn)


def test_healthz_stats_and_error_routes(pair):
    prompt = _prompts(1, seed=6)[0]

    async def fn(srv):
        status, _, body = await srv.request("GET", "/healthz")
        assert status == 200 and json.loads(body) == {"status": "ok"}
        status, _, body = await srv.request("GET", "/stats")
        st = json.loads(body)
        assert status == 200
        for key in ("queued", "active", "max_batch", "target_pool",
                    "draft_pool", "requests_served", "par_mode"):
            assert key in st, key
        # route + validation errors
        status, _, _ = await srv.request("GET", "/nope")
        assert status == 404
        status, _, _ = await srv.request("GET", "/v1/completions")
        assert status == 405
        status, _, body = await srv.request(
            "POST", "/v1/completions", {"prompt": "not token ids"}
        )
        assert status == 400 and "prompt" in json.loads(body)["error"]
        status, _, _ = await srv.request(
            "POST", "/v1/completions",
            {"prompt": prompt, "temperature": -1.0},
        )
        assert status == 400
        # oversized request rejected cleanly, engine stays healthy
        status, _, _ = await srv.request(
            "POST", "/v1/completions",
            {"prompt": prompt, "max_tokens": 100000},
        )
        assert status == 400
        status, _, _ = await srv.request("GET", "/healthz")
        assert status == 200

    _with_server(pair)(fn)


def test_backpressure_returns_429_when_saturated(pair):
    prompts = _prompts(4, seed=7)

    async def fn(srv):
        hogs = [
            asyncio.ensure_future(srv.stream_raw(
                {"prompt": prompts[i], "max_tokens": 40, "seed": i}
            ))
            for i in range(3)  # 2 decode slots + the 1-deep queue
        ]
        got_429 = False
        for _ in range(200):
            status, _, _ = await srv.request(
                "POST", "/v1/completions",
                {"prompt": prompts[3], "max_tokens": 4, "wait": False},
            )
            if status == 429:
                got_429 = True
                break
            await asyncio.sleep(0.02)
        results = await asyncio.gather(*hogs)
        assert got_429, "saturated queue never surfaced HTTP 429"
        assert all(status == 200 for status, _, _ in results)

    _with_server(pair, max_queued=1)(fn)


def test_malformed_content_length_gets_400(pair):
    async def fn(srv):
        reader, writer = await asyncio.open_connection("127.0.0.1", srv.port)
        writer.write(
            b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
            b"Content-Length: abc\r\n\r\n"
        )
        await writer.drain()
        raw = await reader.read()
        writer.close()
        head = raw.partition(b"\r\n\r\n")[0]
        assert b" 400 " in head.splitlines()[0], head
        # the server survives the malformed request
        status, _, body = await srv.request("GET", "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"

    _with_server(pair)(fn)
