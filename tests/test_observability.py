"""Observability layer: metrics registry semantics, Prometheus scrape
format, Chrome-trace export schema, and the no-perturbation contract —
tokens stay bit-identical with tracing enabled, including under
``par_mode="wdos"``.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.launch.serve import build_pair
from repro.serving import (
    AsyncEngine,
    CompletionServer,
    Engine,
    EngineConfig,
    MetricsRegistry,
    NULL_TRACER,
    RATIO_BUCKETS,
    SamplingParams,
    Tracer,
    validate_chrome_trace,
)
from repro.serving import http_client as hc


# ---------------------------------------------------------------------------
# MetricsRegistry unit tests (no models involved)
# ---------------------------------------------------------------------------


def test_counter_monotonicity():
    m = MetricsRegistry()
    c = m.counter("reqs_total", "h")
    c.inc()
    c.inc(2.5)
    assert m.value("reqs_total") == 3.5
    with pytest.raises(ValueError):
        c.labels().inc(-1.0)
    with pytest.raises(ValueError):
        c.dec()  # counters have no dec at all


def test_gauge_moves_both_ways():
    m = MetricsRegistry()
    g = m.gauge("depth", "h")
    g.set(5)
    g.inc(2)
    g.dec(4)
    assert m.value("depth") == 3.0


def test_histogram_bucketing_cumulative_and_sum():
    m = MetricsRegistry()
    h = m.histogram("lat_seconds", "h", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.7, 3.0):
        h.observe(v)
    assert h.value() == 4  # value() is the observation count
    assert h.sum_value() == pytest.approx(4.25)
    text = m.render()
    # cumulative buckets: le=0.1 -> 1, le=1 -> 3, le=+Inf -> 4
    assert 'serving_lat_seconds_bucket{le="0.1"} 1' in text
    assert 'serving_lat_seconds_bucket{le="1"} 3' in text
    assert 'serving_lat_seconds_bucket{le="+Inf"} 4' in text
    assert "serving_lat_seconds_count 4" in text


def test_labels_and_registration_idempotence():
    m = MetricsRegistry()
    c = m.counter("by_kind_total", "h", ("kind",))
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    assert m.value("by_kind_total", kind="a") == 2.0
    assert c.total() == 3.0
    # same name+kind returns the SAME family; kind mismatch raises
    assert m.counter("by_kind_total", "h", ("kind",)) is c
    with pytest.raises(ValueError):
        m.gauge("by_kind_total")
    with pytest.raises(ValueError):
        c.labels(wrong="x")
    with pytest.raises(ValueError):
        c.inc()  # labeled family has no default child


def test_noop_mode_is_inert():
    m = MetricsRegistry(enabled=False)
    c = m.counter("x_total", "h")
    h = m.histogram("h_seconds", "h")
    c.inc(100)
    h.observe(1.0)
    m.gauge("g").set(9)
    assert m.value("x_total") == 0.0
    assert m.value("h_seconds") == 0.0
    # render still emits headers (families register), but no samples
    assert "# TYPE serving_x_total counter" in m.render()
    assert "serving_x_total 100" not in m.render()


def test_render_prometheus_text_shape():
    m = MetricsRegistry()
    m.counter("a_total", 'help with "quotes"').inc()
    m.counter("l_total", "h", ("pool",)).labels(pool="tar\nget").inc()
    text = m.render()
    assert text.endswith("\n")
    assert "# HELP serving_a_total" in text
    assert "# TYPE serving_a_total counter" in text
    # label values escape newlines
    assert 'serving_l_total{pool="tar\\nget"} 1' in text
    snap = m.snapshot()
    assert snap["serving_a_total"]["type"] == "counter"
    assert snap["serving_l_total"]["series"]["pool=tar\nget"] == 1.0


# ---------------------------------------------------------------------------
# Tracer + Chrome-trace export schema
# ---------------------------------------------------------------------------


def test_tracer_chrome_trace_schema(tmp_path):
    t = Tracer(jsonl_path=str(tmp_path / "events.jsonl"))
    t.instant("engine", "submit", cat="lifecycle", rid=0)
    with t.span("engine", "step#1", cat="step"):
        t.rec("row0", "draft", t.now(), t.now() + 0.001, cat="draft", rid=0)
    t.close()
    trace = t.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    evs = trace["traceEvents"]
    # one thread_name metadata event per track, in first-seen order
    meta = [e for e in evs if e["ph"] == "M"]
    assert [e["args"]["name"] for e in meta] == ["engine", "row0"]
    # complete events carry integer-ish ts/dur; instants are thread-scoped
    x = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 1 for e in x)
    i = [e for e in evs if e["ph"] == "i"]
    assert all(e.get("s") == "t" for e in i)
    # args thread the request id through
    assert any(e.get("args", {}).get("rid") == 0 for e in evs)
    # the JSONL stream has one JSON object per event
    lines = (tmp_path / "events.jsonl").read_text().splitlines()
    assert len(lines) == len(t.events())
    assert all(json.loads(l)["name"] for l in lines)
    # export round-trips through the schema checker
    t.export(str(tmp_path / "trace.json"))
    loaded = json.loads((tmp_path / "trace.json").read_text())
    assert validate_chrome_trace(loaded) == []


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    NULL_TRACER.instant("x", "y")
    NULL_TRACER.rec("x", "y", 0.0, 1.0)
    with NULL_TRACER.span("x", "y"):
        pass
    assert NULL_TRACER.events() == []
    with pytest.raises(RuntimeError):
        NULL_TRACER.export("/dev/null")


def test_validate_chrome_trace_flags_problems():
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [{"ph": "X", "name": "a", "pid": 0, "tid": 0,
                            "ts": 1}]}
    assert any("dur" in p for p in validate_chrome_trace(bad))
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "Z", "name": "a", "pid": 0, "tid": 0}]}
    ) != []


def _one_track_trace(track, spans):
    """Minimal trace: one thread_name M record + X spans on that tid."""
    evs = [{"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
            "args": {"name": track}}]
    evs += [
        {"ph": "X", "name": f"s{i}", "pid": 1, "tid": 1, "ts": ts,
         "dur": dur, "cat": "t"}
        for i, (ts, dur) in enumerate(spans)
    ]
    return {"traceEvents": evs}


def test_validator_requires_thread_name_metadata():
    # a tid never introduced by a thread_name M event is an anonymous
    # track in Perfetto — always a tracer bug here
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 7, "ts": 0, "dur": 5},
    ]}
    assert any("thread_name" in p for p in validate_chrome_trace(bad))
    # the same span with metadata is clean
    assert validate_chrome_trace(_one_track_trace("engine", [(0, 5)])) == []


def test_validator_device_track_overlap_rule():
    overlapping = [(0, 10), (5, 10)]
    # device tracks serialize dispatches -> overlap is broken attribution
    probs = validate_chrome_trace(_one_track_trace("device", overlapping))
    assert any("overlap" in p for p in probs)
    # host tracks nest spans (step contains phase) and are exempt
    assert validate_chrome_trace(
        _one_track_trace("engine", overlapping)
    ) == []
    # back-to-back device spans are fine (1 us slack covers rounding)
    assert validate_chrome_trace(
        _one_track_trace("device", [(0, 10), (10, 4), (15, 2)])
    ) == []


# ---------------------------------------------------------------------------
# Engine end-to-end: metrics populate, tracing never perturbs tokens
# ---------------------------------------------------------------------------


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        np.asarray(rng.randint(0, vocab, size=rng.randint(3, 7)), np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def test_wdos_bit_identity_with_tracing_enabled(pair):
    """The headline no-perturbation contract: a traced+metered wdos run
    emits exactly the tokens of an uninstrumented two-phase run."""
    target, draft = pair
    prompts = _prompts(3, seed=11)
    sp = SamplingParams(max_tokens=12)

    ref_eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
    ref, _ = ref_eng.run(prompts, sp)

    tracer = Tracer()
    eng = Engine(
        target, draft,
        EngineConfig(max_batch=2, page_size=8, par_mode="wdos"),
        trace=tracer,
    )
    outs, summary = eng.run(prompts, sp)
    for a, b in zip(ref, outs):
        assert [int(t) for t in a] == [int(t) for t in b]

    # the trace is Perfetto-loadable and shows per-row staggering
    trace = tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    tracks = {e["args"]["name"] for e in trace["traceEvents"]
              if e["ph"] == "M"}
    assert "engine" in tracks
    assert any(t.startswith("row") for t in tracks)
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"submit", "prefill", "fused_slot", "commit", "finish"} <= names

    # the registry carries the same fused numbers summary() reports
    m = eng.metrics
    assert m.value("requests_submitted_total") == 3
    assert m.value("tokens_drafted_total") > 0
    assert m.value("ttft_seconds") == 3  # one TTFT observation per request
    assert m.value("round_wall_seconds") > 0
    fused = summary["fused"]
    assert fused["slots"] == m.get("fused_slots_total").total()
    assert 0.0 <= summary["acceptance_rate"] <= 1.0
    assert m.value("requests_finished_total", reason="length") == 3


def test_engine_metrics_two_phase_and_round_acceptance(pair):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
    eng.run(_prompts(2, seed=3), SamplingParams(max_tokens=8))
    m = eng.metrics
    assert m.value("steps_total") > 0
    assert m.value("tokens_emitted_total") >= 16
    # per-round acceptance lands in the [0, 1] ratio buckets
    h = m.get("round_acceptance")
    assert h.buckets[:-1] == RATIO_BUCKETS
    assert h.value() > 0
    assert m.value("itl_seconds") > 0  # multi-round requests have gaps
    # levels settle to idle after the drain
    assert m.value("active_requests") == 0
    assert m.value("pool_pages", pool="target", state="used") == 0
    assert m.value("table_upload_seconds_total") > 0


# ---------------------------------------------------------------------------
# /metrics scrape through the real HTTP server
# ---------------------------------------------------------------------------


CORE_SERIES = (
    "serving_ttft_seconds",
    "serving_itl_seconds",
    "serving_round_wall_seconds",
    "serving_admission_wait_seconds",
    "serving_round_acceptance",
    "serving_acceptance_rate",
    "serving_rounds_total",
    "serving_steps_total",
    "serving_queue_depth",
    "serving_active_requests",
    "serving_pool_pages",
    "serving_requests_submitted_total",
    "serving_requests_finished_total",
    "serving_tokens_emitted_total",
    "serving_http_requests_total",
    "serving_http_429_total",
)


def test_metrics_scrape_format_and_core_series(pair):
    target, draft = pair

    async def scenario():
        engine = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
        server = CompletionServer(AsyncEngine(engine, max_queued=8))
        await server.start(port=0)
        task = asyncio.ensure_future(server.serve_forever())
        try:
            prompt = [int(t) for t in _prompts(1, seed=7)[0]]
            status, _, chunks = await hc.sse_request(
                server.port, {"prompt": prompt, "max_tokens": 6}
            )
            assert status == 200 and len(chunks) == 6
            status, head, body = await hc.request(
                server.port, "GET", "/metrics"
            )
            assert status == 200
            assert "text/plain; version=0.0.4" in head
            # the flight recorder is served, with rounds from the drain
            fstatus, _, fbody = await hc.request(
                server.port, "GET", "/debug/flight"
            )
            assert fstatus == 200
            flight = json.loads(fbody.decode())
            assert flight["enabled"] and flight["rounds_recorded"] > 0
            assert flight["ring"][-1]["mode"] == "two_phase"
            return body.decode()
        finally:
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
            await server.stop()

    text = asyncio.run(scenario())
    families = {
        line.split()[2] for line in text.splitlines()
        if line.startswith("# TYPE ")
    }
    assert len(families) >= 12, sorted(families)
    for name in CORE_SERIES:
        assert name in families, f"missing series family {name}"
    # histograms expose the full bucket/sum/count triple
    assert 'serving_ttft_seconds_bucket{le="+Inf"} 1' in text
    assert "serving_ttft_seconds_count 1" in text
    # the scrape counted itself
    assert 'serving_http_requests_total{route="/metrics",status="200"} 1' \
        in text


def test_profiled_wdos_bit_identity_and_device_track(pair):
    """Sampled device-time attribution never perturbs tokens: a wdos run
    with ``profile_every_n=2`` (and the flight recorder on, its default)
    matches an uninstrumented two-phase run token-for-token, while the
    trace gains a non-overlapping device track of per-program spans and
    ``profile_summary()`` reports the fused program."""
    target, draft = pair
    prompts = _prompts(3, seed=11)
    sp = SamplingParams(max_tokens=12)

    ref_eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
    ref, _ = ref_eng.run(prompts, sp)

    tracer = Tracer()
    eng = Engine(
        target, draft,
        EngineConfig(max_batch=2, page_size=8, par_mode="wdos",
                     profile_every_n=2),
        trace=tracer,
    )
    outs, _ = eng.run(prompts, sp)
    for a, b in zip(ref, outs):
        assert [int(t) for t in a] == [int(t) for t in b]

    trace = tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    meta = {e["tid"]: e["args"]["name"] for e in trace["traceEvents"]
            if e["ph"] == "M"}
    dev_tids = {tid for tid, name in meta.items() if name == "device"}
    assert dev_tids, f"no device track in {sorted(meta.values())}"
    dev_names = {e["name"] for e in trace["traceEvents"]
                 if e["ph"] == "X" and e["tid"] in dev_tids}
    assert "fused_wdos" in dev_names, dev_names

    summary = eng.profile_summary()
    assert "fused_wdos" in summary
    fw = summary["fused_wdos"]
    assert fw["calls"] >= 1 and fw["wall_s"] > 0
    # every profiled program with a cost stamp reports finite flops/bytes
    for prog, entry in summary.items():
        assert entry["calls"] >= 1 and entry["wall_s"] > 0, prog

    # the flight recorder rode along: every round recorded.  The tiny
    # test models genuinely draft badly, so acceptance_collapse MAY fire
    # (that's the detector working); the health anomalies must not.
    snap = eng.flight_snapshot()
    assert snap["enabled"] and snap["rounds_recorded"] > 0
    assert snap["anomalies"]["pool_exhausted"] == 0
    assert snap["anomalies"]["admission_stall"] == 0
    json.dumps(snap)  # postmortem/ring payloads must stay JSON-serializable


def test_profiled_tree_bit_identity_spans_and_metrics(pair):
    """Tree speculation under profiling: tokens bit-identical to the
    unprofiled tree engine; engine track carries tree_draft/tree_verify
    spans; the tree metric families count real work; the device track
    shows the tree dispatch programs."""
    target, draft = pair
    prompts = _prompts(4, seed=3)
    sps = [SamplingParams(temperature=0.8, seed=100 + i, max_tokens=12)
           for i in range(4)]
    tree_cfg = dict(max_batch=4, page_size=8, draft_len=3,
                    spec_mode="tree", tree_budget=14, spec_branches=2,
                    branch_threshold=1.0)

    def drain(eng):
        outs = {}
        rids = [eng.add_request(p, sp) for p, sp in zip(prompts, sps)]
        while eng.has_unfinished():
            for out in eng.step():
                outs.setdefault(out.request_id, []).extend(
                    int(t) for t in out.new_token_ids
                )
        return [outs[r] for r in rids]

    ref = drain(Engine(target, draft, EngineConfig(**tree_cfg)))

    tracer = Tracer()
    eng = Engine(target, draft,
                 EngineConfig(profile_every_n=1, **tree_cfg), trace=tracer)
    got = drain(eng)
    assert ref == got

    m = eng.metrics
    assert m.value("tree_nodes_total") > 0
    assert m.value("tree_branches_total") > 0
    assert m.get("tree_accept_depth").value() > 0  # one obs per verify
    # compaction count matches the spans the engine recorded
    n_compact = m.value("tree_compactions_total")

    trace = tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    meta = {e["tid"]: e["args"]["name"] for e in trace["traceEvents"]
            if e["ph"] == "M"}
    by_track = {}
    for e in trace["traceEvents"]:
        if e["ph"] == "X":
            by_track.setdefault(meta[e["tid"]], set()).add(e["name"])
    assert {"tree_draft", "tree_verify"} <= by_track["engine"], by_track
    if n_compact:
        assert "compaction" in by_track["engine"]
        assert "compaction" in by_track["device"]
    assert {"tree_draft", "tree_verify"} <= by_track["device"], by_track

    summary = eng.profile_summary()
    assert {"tree_draft", "tree_verify"} <= set(summary)


def test_tree_and_anomaly_families_registered_when_idle(pair):
    """The tree + flight-recorder families are registered (and zero) on a
    chain-mode engine that never ran — scrape shape is config-independent."""
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
    text = eng.metrics.render()
    for fam in ("serving_tree_nodes_total", "serving_tree_branches_total",
                "serving_tree_accept_depth",
                "serving_tree_compactions_total",
                "serving_anomalies_total"):
        assert f"# TYPE {fam}" in text, fam
    # every anomaly kind is materialized at 0 for delta-friendly scrapes
    from repro.serving import ANOMALY_KINDS
    for kind in ANOMALY_KINDS:
        assert f'serving_anomalies_total{{kind="{kind}"}} 0' in text, kind


def test_stats_snapshot_is_single_view(pair):
    """/stats is served from one worker-published snapshot: the engine
    fields all come from the same dict object, and queue/active/pool keys
    are present and consistent after a drain."""
    target, draft = pair

    async def scenario():
        engine = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
        async with AsyncEngine(engine, max_queued=4) as aeng:
            outs = [
                o async for o in aeng.generate(
                    _prompts(1, seed=9)[0], SamplingParams(max_tokens=5)
                )
            ]
            assert outs[-1].finished
            st = aeng.stats()
            assert st["queued"] == 0 and st["active"] == 0
            assert st["finished_requests"] == 1
            assert st["target_pool"]["used_pages"] == 0
            assert st["pending_admission"] == 0 and st["max_queued"] == 4
            assert 0.0 <= st["acceptance_rate"] <= 1.0
            # the snapshot object itself is replaced wholesale, never
            # mutated: two stats() calls with no engine activity agree
            assert aeng.stats() == st
        return True

    assert asyncio.run(scenario())
