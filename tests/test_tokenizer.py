"""BPE tokenizer (serving/tokenizer.py) + the detokenizer seam under it.

The tokenizer half covers the vocabulary contract: deterministic training,
exact round-trips (pieces are valid ``str``, decode is concatenation), the
JSON persistence the server's ``--tokenizer`` flag loads, and the decimal
fallback for out-of-vocab ids.

The request half drives ``Request.commit`` with REAL multi-char BPE pieces
— the paths ``default_detokenize``'s one-token-one-text rendering never
exercised: stop strings spanning BPE token boundaries, holdback through
multi-byte (non-ASCII) pieces, and ``take_delta`` never retracting text.
"""
import numpy as np
import pytest

from repro.serving.api import SamplingParams
from repro.serving.request import Request
from repro.serving.tokenizer import (
    BPETokenizer,
    DEFAULT_CORPUS,
    DEFAULT_VOCAB_SIZE,
)


@pytest.fixture(scope="module")
def tok():
    return BPETokenizer.trained()


# ---------------------------------------------------------------------------
# Vocabulary contract
# ---------------------------------------------------------------------------


def test_roundtrip_is_exact(tok):
    for text in (
        DEFAULT_CORPUS,
        "the quick brown fox jumps over the lazy dog",
        "résumé café naïve touché — em dash",
        "日本語のテキスト, 中文文本.",
        "stop at 42 -> {} [] !=",
    ):
        ids = tok.encode(text)
        assert tok.decode(ids) == text
        assert all(0 <= i < tok.vocab_size for i in ids)


def test_merges_actually_compress(tok):
    ids = tok.encode(DEFAULT_CORPUS)
    assert len(ids) < len(DEFAULT_CORPUS) / 2  # multi-char pieces dominate
    assert any(len(tok.piece(i)) >= 4 for i in ids)


def test_vocab_fits_smoke_models(tok):
    assert tok.vocab_size <= DEFAULT_VOCAB_SIZE  # every id a valid model token


def test_training_is_deterministic():
    a = BPETokenizer.train(DEFAULT_CORPUS * 2, 300)
    b = BPETokenizer.train(DEFAULT_CORPUS * 2, 300)
    assert a.pieces == b.pieces and a.merges == b.merges


def test_unknown_characters_raise(tok):
    with pytest.raises(ValueError, match="alphabet"):
        tok.encode("Ω particle")


def test_decimal_fallback_for_out_of_vocab(tok):
    assert tok.piece(tok.vocab_size + 7) == f"{tok.vocab_size + 7} "
    assert tok.piece(-1) == "-1 "
    # mixed stream: in-vocab pieces concatenate, stragglers render decimal
    ids = tok.encode("the pool") + [9999]
    assert tok.decode(ids) == "the pool9999 "


def test_save_load_roundtrip(tok, tmp_path):
    path = str(tmp_path / "vocab.json")
    tok.save(path)
    loaded = BPETokenizer.load(path)
    assert loaded.pieces == tok.pieces and loaded.merges == tok.merges
    text = "speculative decoding drafts tokens"
    assert loaded.encode(text) == tok.encode(text)


# ---------------------------------------------------------------------------
# Detokenizer seam: Request.commit with real BPE pieces
# ---------------------------------------------------------------------------


def _req(tok, n, stop):
    return Request(
        rid=0, prompt=np.asarray([1, 2], np.int32), max_new_tokens=n,
        sampling=SamplingParams(max_tokens=n, stop=stop),
        detokenize=tok.piece,
    )


def test_stop_string_spanning_token_boundary(tok):
    """A stop string that no single piece contains — it only exists across
    a BPE token boundary — must still fire, truncating at the token
    boundary before the match."""
    ids = tok.encode("the quick brown fox jumps over the lazy dog. ")
    texts = [tok.piece(i) for i in ids]
    j = next(
        i for i in range(len(texts) - 1)
        if len(texts[i]) >= 2 and len(texts[i + 1]) >= 2
    )
    stop = texts[j][-1] + texts[j + 1][:2]
    assert all(stop not in t for t in texts)  # it genuinely spans pieces
    full = "".join(texts)

    req = _req(tok, len(ids), (stop,))
    for t in ids:
        req.commit([t])
    assert req.stop_hit and req.finish_reason == "stop"
    out_text = "".join(tok.piece(t) for t in req.out)
    assert stop not in out_text
    assert full.startswith(out_text)
    assert len(out_text) <= full.find(stop)
    # truncation lands on a token boundary: out is a prefix of ids
    assert req.out == [int(t) for t in ids[: len(req.out)]]


def test_holdback_with_multibyte_piece(tok):
    """A committed piece containing non-ASCII chars whose text is a proper
    prefix of a stop string is HELD (not delivered) until later text
    proves no match is coming — then flushes, never retracted."""
    piece = next(
        p for p in tok.pieces
        if any(ord(c) > 127 for c in p) and len(p) >= 2
    )
    pid = tok.pieces.index(piece)
    other = tok.pieces.index("q")  # breaks any match continuing the stop
    stop = piece + "zz"

    req = _req(tok, 4, (stop,))
    req.commit([pid])
    assert req.take_delta() == []  # whole piece held back
    assert req.emittable_len() == 0
    req.commit([other])
    assert req.take_delta() == [pid, other]  # flushed in order, none lost
    assert not req.stop_hit


def test_take_delta_monotone_across_holdback_and_stop(tok):
    """Concatenated deltas == final delivered output: held tokens arrive
    late but are never retracted, even when a stop truncates mid-stream."""
    ids = tok.encode("paged attention maps token positions to pages")
    texts = [tok.piece(i) for i in ids]
    full = "".join(texts)
    # stop on text deep in the stream, spanning a boundary when possible
    k = len(full) * 2 // 3
    stop = full[k : k + 3]

    req = _req(tok, len(ids), (stop,))
    deltas, marks = [], []
    for t in ids:
        req.commit([t])
        d = req.take_delta()
        deltas.append(d)
        marks.append(req._delta_mark)
        if req.stop_hit:
            break
    assert marks == sorted(marks)  # the delivery watermark never regresses
    flat = [t for d in deltas for t in d]
    assert flat == req.out[: req.emittable_len()]
    assert req.stop_hit
    assert stop not in "".join(tok.piece(t) for t in flat)


def test_holdback_flushes_at_budget(tok):
    """A held tail must be delivered once the budget resolves the request
    (no future token can complete the match) — holdback delays, it never
    drops tokens."""
    ids = tok.encode("the server batches")
    last_text = tok.piece(ids[-1])
    stop = last_text + "never-matches"

    req = _req(tok, len(ids), (stop,))
    for t in ids[:-1]:
        req.commit([t])
    assert req.take_delta() == [int(t) for t in ids[:-1]]
    req.commit([ids[-1]])  # fills the budget exactly -> holdback resolves
    assert req.take_delta() == [int(ids[-1])]
    assert not req.stop_hit
