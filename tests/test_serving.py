"""Quantized serving paths + end-to-end SD/APSD on real models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bvq as bvq_mod
from repro.core.apsd import APSDConfig
from repro.core.quantization import sqnr_db
from repro.core.speculative import SDConfig
from repro.models import lm
from repro.models.common import Family, ModelConfig
from repro.serving import quantized_lm as qlm
from repro.serving.engine import ServingModel, make_interface, serve_apsd, serve_sd

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(
    name="t", family=Family.DENSE, n_layers=2, d_model=128, n_heads=4,
    n_kv=2, d_ff=256, vocab=97, dtype="float32",
)


@pytest.fixture(scope="module")
def model():
    p, _ = lm.init_lm(KEY, CFG, tp=1)
    return p


def test_rotation_folding_exact(model):
    """bits=None: the rotated/folded model must equal the original."""
    toks = jax.random.randint(KEY, (2, 12), 0, CFG.vocab)
    ref, _ = lm.apply_lm(model, CFG, None, toks)
    qp = qlm.quantize_dense_lm(model, CFG, bits=None, rotate=True)
    got, _ = qlm.apply_quantized_lm(qp, CFG, None, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=5e-5)


def test_w4a8_cache_path_consistent(model):
    qp = qlm.quantize_dense_lm(model, CFG, bits=4, rotate=True)
    toks = jax.random.randint(KEY, (2, 12), 0, CFG.vocab)
    full, _ = qlm.apply_quantized_lm(qp, CFG, None, toks)
    cache = lm.init_cache(CFG, 2, 32, tp=1)
    lgp, cache = qlm.apply_quantized_lm(qp, CFG, None, toks[:, :8], cache=cache)
    lgd, cache = qlm.apply_quantized_lm(qp, CFG, None, toks[:, 8:9], cache=cache)
    np.testing.assert_allclose(np.asarray(full[:, 7]), np.asarray(lgp[:, -1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(full[:, 8]), np.asarray(lgd[:, 0]), atol=1e-4)


def test_rotation_beats_no_rotation_under_outliers(model):
    """The paper's W4A8 accuracy claim: LRU rotation must recover accuracy
    that plain W4A8 loses when activations carry outlier channels."""
    p = dict(model)
    emb = p["embed"]["tok"].at[:, jnp.array([3, 40, 77])].multiply(40.0)
    p = {**p, "embed": {**p["embed"], "tok": emb}}
    toks = jax.random.randint(KEY, (4, 16), 0, CFG.vocab)
    ref, _ = lm.apply_lm(p, CFG, None, toks)
    lg_rot, _ = qlm.apply_quantized_lm(
        qlm.quantize_dense_lm(p, CFG, 4, rotate=True), CFG, None, toks
    )
    lg_nor, _ = qlm.apply_quantized_lm(
        qlm.quantize_dense_lm(p, CFG, 4, rotate=False), CFG, None, toks
    )
    s_rot = float(sqnr_db(ref, lg_rot))
    s_nor = float(sqnr_db(ref, lg_nor))
    assert s_rot > s_nor + 5.0, (s_rot, s_nor)  # >5 dB win from rotation
    agree_rot = float(jnp.mean(jnp.argmax(lg_rot, -1) == jnp.argmax(ref, -1)))
    agree_nor = float(jnp.mean(jnp.argmax(lg_nor, -1) == jnp.argmax(ref, -1)))
    assert agree_rot > agree_nor


def test_bvq_lm_runs(model):
    bcfg = bvq_mod.BVQConfig(vec_dim=4, codebook_size=32, block_cols=32,
                             kmeans_iters=6, qat_steps=0)
    bp = qlm.bvq_compress_lm(model, CFG, bcfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(KEY, (2, 10), 0, CFG.vocab)
    lg, _ = qlm.apply_bvq_lm(bp, CFG, None, toks)
    assert lg.shape == (2, 10, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # compression ratio >4x vs f32 storage
    orig = sum(x.size * 4 for x in jax.tree.leaves(model))
    comp = 0
    for x in jax.tree.leaves(bp):
        itemsize = jnp.dtype(x.dtype).itemsize
        comp += x.size * (0.5 if x.dtype == jnp.int8 else itemsize)
    assert orig / comp > 2.0


def _pair(quantize):
    from repro.launch.serve import build_pair

    return build_pair(seed=0, s_max=128, quantize=quantize)


@pytest.mark.parametrize("quantize", [False, True])
def test_sd_serving_lossless_real_models(quantize):
    """Greedy SD output == greedy AD decode of the SAME target model."""
    from repro.launch.serve import greedy_reference

    target, draft = _pair(quantize)
    prompt = jnp.asarray([[5, 17, 3, 99]], jnp.int32)
    toks, stats = serve_sd(
        jax.random.PRNGKey(0), target, draft, prompt,
        SDConfig(draft_len=3, temperature=0.0, max_tokens=16),
    )
    ref = greedy_reference(target, prompt, 16)
    assert bool(jnp.all(toks == ref))


def test_apsd_serving_lossless_real_models():
    from repro.launch.serve import greedy_reference

    target, draft = _pair(True)
    prompt = jnp.asarray([[5, 17, 3, 99]], jnp.int32)
    toks, stats = serve_apsd(
        jax.random.PRNGKey(0), target, draft, prompt,
        APSDConfig(short_dl=2, long_dl=4, temperature=0.0, max_tokens=16),
    )
    ref = greedy_reference(target, prompt, 16)
    assert bool(jnp.all(toks == ref))
    assert stats.rounds > 0


def test_self_draft_apsd_stays_parallel():
    """Draft == target (quantized same weights) -> near-total acceptance and
    PAR-mode lock-in: the controller behaves as designed on real models."""
    p, _ = lm.init_lm(KEY, CFG, tp=1)
    sm = ServingModel(cfg=CFG, params=p, mode="bf16", s_max=128)
    prompt = jnp.asarray([[5, 17, 3]], jnp.int32)
    toks, stats = serve_apsd(
        jax.random.PRNGKey(1), sm, sm, prompt,
        APSDConfig(short_dl=2, long_dl=4, temperature=0.0, max_tokens=20),
    )
    assert stats.rejected_ratio < 0.05
    assert stats.par_rounds >= stats.rounds - 2
