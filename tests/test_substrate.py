"""Data pipeline, optimizer, checkpointing, fault-tolerant runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLMDataset, make_batch_iterator
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_grads_int8,
    decompress_grads_int8,
    ef_init,
    linear_warmup_cosine,
)
from repro.runtime import ElasticTrainer, FaultToleranceConfig, HeartbeatMonitor, StragglerMitigator


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=3)
    ds = SyntheticLMDataset(cfg)
    b1, b2 = ds.batch(7), ds.batch(7)
    assert np.array_equal(b1, b2)
    assert not np.array_equal(ds.batch(7), ds.batch(8))
    # host slices tile the global batch exactly
    parts = [ds.host_slice(7, h, 4) for h in range(4)]
    assert np.array_equal(np.concatenate(parts), b1)
    assert b1.shape == (8, 17) and b1.min() >= 0 and b1.max() < 101


def test_iterator_resume():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=4)
    it = make_batch_iterator(cfg, start_step=5)
    step, batch = next(it)
    assert step == 5
    ds = SyntheticLMDataset(cfg)
    assert np.array_equal(batch, ds.batch(5))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray(np.random.RandomState(0).randn(32).astype(np.float32))}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < l0 * 1e-2
    assert np.isfinite(float(m["grad_norm"]))


def test_grad_clipping():
    params = {"w": jnp.ones((4,), jnp.float32)}
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)  # lr 0: only inspect metrics
    state = adamw_init(params, cfg)
    g = {"w": jnp.full((4,), 1e6, jnp.float32)}
    _, _, m = adamw_update(params, g, state, cfg)
    assert float(m["grad_norm"]) > 1e6  # norm reported pre-clip


def test_schedule_shapes():
    s = linear_warmup_cosine(jnp.asarray(0), 10, 100)
    e = linear_warmup_cosine(jnp.asarray(100), 10, 100)
    mid = linear_warmup_cosine(jnp.asarray(10), 10, 100)
    assert float(s) == 0.0
    assert 0.9 < float(mid) <= 1.0  # cosine already decaying at warmup end
    assert float(e) == pytest.approx(0.1)


def test_int8_grad_compression_error_feedback():
    rng = np.random.RandomState(0)
    grads = {"a": jnp.asarray(rng.randn(64).astype(np.float32))}
    ef = ef_init(grads)
    # accumulated quantizer bias must stay ~0 over rounds (error feedback)
    total_true = np.zeros(64, np.float32)
    total_deq = np.zeros(64, np.float32)
    for i in range(20):
        g = {"a": jnp.asarray(rng.randn(64).astype(np.float32))}
        q, s, ef = compress_grads_int8(g, ef)
        d = decompress_grads_int8(q, s)
        total_true += np.asarray(g["a"])
        total_deq += np.asarray(d["a"])
    resid = np.abs(total_true - total_deq).max()
    assert resid < 0.2  # bounded by one quantization step, not 20


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": {"mu": jnp.ones((2, 3), jnp.bfloat16), "count": jnp.asarray(7)},
    }
    save_checkpoint(str(tmp_path), 12, tree, {"note": "x"})
    assert latest_step(str(tmp_path)) == 12
    step, loaded, extra = load_checkpoint(str(tmp_path))
    assert step == 12 and extra["note"] == "x"
    np.testing.assert_array_equal(
        np.asarray(loaded["params"]["w"]), np.asarray(tree["params"]["w"])
    )
    assert loaded["opt"]["mu"].dtype == jnp.bfloat16
    assert int(loaded["opt"]["count"]) == 7


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    for s in (0, 5, 10):
        ck.save(s, {"x": jnp.full((4,), s, jnp.float32)})
    ck.close()
    assert latest_step(str(tmp_path)) == 10
    _, t, _ = load_checkpoint(str(tmp_path), 5)
    assert float(t["x"][0]) == 5.0


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="pre-existing seed failure: restoring onto a 1-device mesh yields "
    "SingleDeviceSharding (no .spec) — needs a multi-device mesh "
    "(ROADMAP open item)",
)
def test_checkpoint_reshard(tmp_path):
    """Save unsharded, restore onto a mesh with NamedSharding placement."""
    from jax.sharding import PartitionSpec as P

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 0, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _, loaded, _ = load_checkpoint(
        str(tmp_path), 0, mesh=mesh, specs={"w": P("data", "model")}
    )
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.asarray(tree["w"]))
    assert loaded["w"].sharding.spec == P("data", "model")


# ---------------------------------------------------------------------------
# runtime fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=10.0, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat(0)
    mon.beat(1)
    t[0] = 12.0
    assert mon.dead_hosts() == [2]


def test_straggler_detection():
    sm = StragglerMitigator([0, 1, 2, 3], factor=2.0, window=8)
    for _ in range(8):
        for h in (0, 1, 2):
            sm.record(h, 1.0)
        sm.record(3, 5.0)
    assert sm.stragglers() == [3]


def test_elastic_trainer_survives_failure(tmp_path):
    """Kill a host mid-run; training must resume from the checkpoint on a
    smaller fleet and reach the target step count."""
    cfg = FaultToleranceConfig(ckpt_dir=str(tmp_path), ckpt_every=5)
    failures = iter([None] * 12 + [1] + [None] * 100)

    def build(n_hosts, restore):
        if restore is None:
            state = {"w": jnp.zeros((4,), jnp.float32)}
        else:
            state = jax.tree.map(jnp.asarray, restore[1])

        def step_fn(state, step):
            return {"w": state["w"] + 1.0 / n_hosts}, {"w0": float(state["w"][0])}

        return state, step_fn

    tr = ElasticTrainer(
        cfg, n_hosts=4, build_fn=build, state_to_tree=lambda s: s,
        failure_source=lambda: next(failures), min_hosts=2,
    )
    hist = tr.run(30)
    events = [h["event"] for h in hist]
    assert "restart" in events
    steps_done = [h["step"] for h in hist if h["event"] == "step"]
    assert steps_done[-1] == 29
    assert tr.n_hosts == 3  # fleet shrank by the one failure
    # restart resumed from a checkpointed step, not from zero
    ridx = events.index("restart")
    assert hist[ridx]["step"] > 0
