"""Paged decode-attention kernel vs oracles (interpret mode on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.paged_attn import paged_decode_attention_pallas
from repro.models import layers as L


def _case(seed, b, kvs, g, hd, pool_pages, page_size, max_pages, lengths):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, kvs, g, hd).astype(np.float32))
    kp = jnp.asarray(rng.randn(pool_pages, page_size, kvs, hd).astype(np.float32))
    vp = jnp.asarray(rng.randn(pool_pages, page_size, kvs, hd).astype(np.float32))
    # each request owns a disjoint shuffled page set (as the pool allocator
    # would hand out); unused table slots point at page 0 (masked)
    perm = rng.permutation(pool_pages)[: b * max_pages].reshape(b, max_pages)
    pt = jnp.asarray(perm.astype(np.int32))
    lens = jnp.asarray(np.asarray(lengths, np.int32))
    return q, kp, vp, pt, lens


@pytest.mark.parametrize(
    "b,kvs,g,hd,page_size,max_pages,lengths",
    [
        (1, 1, 1, 16, 4, 2, [5]),
        (2, 2, 2, 32, 8, 4, [1, 32]),
        (3, 2, 4, 64, 16, 2, [16, 7, 29]),
        (4, 4, 1, 32, 8, 3, [3, 24, 17, 8]),
    ],
)
def test_matches_paged_oracle(b, kvs, g, hd, page_size, max_pages, lengths):
    q, kp, vp, pt, lens = _case(
        0, b, kvs, g, hd, b * max_pages + 3, page_size, max_pages, lengths
    )
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_matches_dense_decode_attention():
    """Gathering pages into a contiguous cache and running the dense decode
    path must agree with attending through the page table directly."""
    b, kvs, g, hd, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _case(1, b, kvs, g, hd, b * mp, ps, mp, [11, 27])
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    k_dense = ref.gather_pages_ref(kp, pt)  # (B, S, KVS, hd)
    v_dense = ref.gather_pages_ref(vp, pt)
    h = kvs * g
    # dense path expects (B, 1, H, hd) with H laid out (kv-head, group)-major
    # — exactly the (KVS, G) order of the paged kernel's q
    q_dense = q.reshape(b, 1, h, hd)
    for i in range(b):
        # dense path takes one scalar length; compare row by row
        want = L._decode_attention(
            q_dense[i : i + 1], k_dense[i : i + 1], v_dense[i : i + 1], lens[i]
        )  # (1, 1, H, hd)
        want = want.reshape(kvs, g, hd)
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want), atol=2e-5
        )


def test_page_permutation_invariance():
    """Physical page placement must not matter: permuting the pool pages and
    the table together leaves the output unchanged."""
    b, kvs, g, hd, ps, mp = 2, 2, 1, 16, 4, 3
    q, kp, vp, pt, lens = _case(2, b, kvs, g, hd, 12, ps, mp, [9, 12])
    base = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    perm = np.random.RandomState(3).permutation(12)
    inv = np.argsort(perm)
    kp2 = kp[jnp.asarray(perm)]
    vp2 = vp[jnp.asarray(perm)]
    pt2 = jnp.asarray(inv.astype(np.int32))[pt]
    moved = paged_decode_attention_pallas(q, kp2, vp2, pt2, lens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(moved), atol=1e-6)


def test_unused_table_slots_are_masked():
    """Slots past `length` may point at arbitrary pages without effect."""
    b, kvs, g, hd, ps, mp = 1, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _case(4, b, kvs, g, hd, 8, ps, mp, [10])
    base = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    pt_junk = np.asarray(pt).copy()
    pt_junk[0, 2:] = 7  # length 10 uses ceil(10/8)=2 pages; rest is junk
    got = paged_decode_attention_pallas(q, kp, vp, jnp.asarray(pt_junk), lens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got), atol=0)


def test_ragged_page_tables_one_page_vs_max():
    """One batch mixing a 1-page request with a request spanning every
    table slot (the continuous-batching steady state) must match the
    oracle row-for-row — the short request's unused slots are masked."""
    b, kvs, g, hd, ps, mp = 4, 2, 2, 32, 8, 6
    lengths = [3, ps * mp, 1, ps * (mp - 1) + 5]  # 1 page .. all mp pages
    q, kp, vp, pt, lens = _case(5, b, kvs, g, hd, b * mp, ps, mp, lengths)
    # point the short rows' dead slots at the long rows' pages (worst case)
    pt_np = np.asarray(pt).copy()
    pt_np[0, 1:] = pt_np[1, 1:]
    pt_np[2, 1:] = pt_np[3, 1:]
    pt = jnp.asarray(pt_np)
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("lengths", [[1, 7, 13], [29, 31, 37], [5, 23, 47]])
def test_non_power_of_two_lengths(lengths):
    """Prefix lengths that straddle page boundaries at odd offsets (primes,
    not powers of two) must agree with the gather+dense oracle."""
    b, kvs, g, hd, ps, mp = 3, 2, 2, 48, 8, 6  # hd 48: also non-pow2
    q, kp, vp, pt, lens = _case(6, b, kvs, g, hd, b * mp + 1, ps, mp, lengths)
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# Multi-token verify window (5-D q)
# ---------------------------------------------------------------------------


def _window_case(seed, b, w, kvs, g, hd, pool_pages, page_size, mp, lengths):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, w, kvs, g, hd).astype(np.float32))
    kp = jnp.asarray(rng.randn(pool_pages, page_size, kvs, hd).astype(np.float32))
    vp = jnp.asarray(rng.randn(pool_pages, page_size, kvs, hd).astype(np.float32))
    perm = rng.permutation(pool_pages)[: b * mp].reshape(b, mp)
    return q, kp, vp, jnp.asarray(perm.astype(np.int32)), jnp.asarray(
        np.asarray(lengths, np.int32)
    )


@pytest.mark.parametrize("w,lengths", [(2, [9, 30]), (4, [5, 17]), (3, [3, 32])])
def test_window_matches_oracle(w, lengths):
    """W-query verify windows (the speculative round's [last_tok, drafts...]
    span) match the causally-masked oracle."""
    b, kvs, g, hd, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _window_case(7, b, w, kvs, g, hd, b * mp, ps, mp, lengths)
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens)
    assert got.shape == (b, w, kvs, g, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_window_last_query_equals_single_token_call():
    """The window's LAST query sees the full prefix — it must equal a 4-D
    single-token call at the same length (causal consistency)."""
    b, w, kvs, g, hd, ps, mp = 2, 3, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _window_case(8, b, w, kvs, g, hd, b * mp, ps, mp, [11, 26])
    win = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    single = paged_decode_attention_pallas(q[:, -1], kp, vp, pt, lens)
    np.testing.assert_allclose(
        np.asarray(win[:, -1]), np.asarray(single), atol=1e-6
    )


# ---------------------------------------------------------------------------
# int8 compressed pools (kv_quant="int8"): in-kernel dequant epilogue
# ---------------------------------------------------------------------------


def _quantized_pools(kp, vp):
    """Pool-shaped symmetric int8 quantization: (P, ps, KVS, hd) f32 ->
    int8 values + (P, ps, KVS, 1) f32 scales (the engine's storage rule)."""
    from repro.serving.paged_cache import kv_quantize_np

    kq, ks = kv_quantize_np(np.asarray(kp, np.float32))
    vq, vs = kv_quantize_np(np.asarray(vp, np.float32))
    return (jnp.asarray(kq), jnp.asarray(vq),
            jnp.asarray(ks), jnp.asarray(vs))


@pytest.mark.parametrize(
    "b,kvs,g,hd,page_size,max_pages,lengths",
    [
        (1, 1, 1, 16, 4, 2, [5]),
        (3, 2, 2, 48, 8, 6, [29, 31, 37]),  # non-pow2 hd, prime raggedness
        (4, 4, 1, 32, 8, 3, [3, 24, 17, 8]),
    ],
)
def test_int8_matches_quantized_oracle(b, kvs, g, hd, page_size, max_pages,
                                       lengths):
    """The in-kernel dequant epilogue must match the gather-then-dequant
    oracle exactly (both expand int8*scale to f32 before the fp math)."""
    q, kp, vp, pt, lens = _case(
        10, b, kvs, g, hd, b * max_pages + 3, page_size, max_pages, lengths
    )
    kq, vq, ks, vs = _quantized_pools(kp, vp)
    got = paged_decode_attention_pallas(q, kq, vq, pt, lens,
                                        k_scale=ks, v_scale=vs)
    want = ref.paged_attn_ref(q, kq, vq, pt, lens, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_int8_equals_predequantized_fp_kernel():
    """Dequantizing inside the kernel is numerically equivalent to running
    the fp kernel over pools dequantized up front — the contract that keeps
    the pallas path and the models/layers gather fallback interchangeable."""
    b, kvs, g, hd, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _case(11, b, kvs, g, hd, b * mp, ps, mp, [11, 27])
    kq, vq, ks, vs = _quantized_pools(kp, vp)
    got = paged_decode_attention_pallas(q, kq, vq, pt, lens,
                                        k_scale=ks, v_scale=vs)
    kd = kq.astype(jnp.float32) * ks
    vd = vq.astype(jnp.float32) * vs
    base = paged_decode_attention_pallas(q, kd, vd, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-6)


@pytest.mark.parametrize("lengths", [[1, 7, 13], [5, 23, 47]])
def test_int8_close_to_float_reference(lengths):
    """Quantization error stays small: int8 pools attend within a loose
    tolerance of the ORIGINAL full-precision pools (ragged prime lengths,
    non-pow2 hd)."""
    b, kvs, g, hd, ps, mp = 3, 2, 2, 48, 8, 6
    q, kp, vp, pt, lens = _case(12, b, kvs, g, hd, b * mp + 1, ps, mp, lengths)
    kq, vq, ks, vs = _quantized_pools(kp, vp)
    got = paged_decode_attention_pallas(q, kq, vq, pt, lens,
                                        k_scale=ks, v_scale=vs)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.08)


@pytest.mark.parametrize("w,lengths", [(2, [9, 30]), (4, [5, 17])])
def test_int8_window_matches_oracle(w, lengths):
    """5-D verify windows over int8 pools: the causally-masked window path
    shares the dequant epilogue with the 4-D decode path."""
    b, kvs, g, hd, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _window_case(13, b, w, kvs, g, hd, b * mp, ps, mp,
                                       lengths)
    kq, vq, ks, vs = _quantized_pools(kp, vp)
    got = paged_decode_attention_pallas(q, kq, vq, pt, lens,
                                        k_scale=ks, v_scale=vs)
    want = ref.paged_attn_ref(q, kq, vq, pt, lens, k_scale=ks, v_scale=vs)
    assert got.shape == (b, w, kvs, g, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_int8_window_last_query_equals_single_token_call():
    b, w, kvs, g, hd, ps, mp = 2, 3, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _window_case(14, b, w, kvs, g, hd, b * mp, ps, mp,
                                       [11, 26])
    kq, vq, ks, vs = _quantized_pools(kp, vp)
    win = paged_decode_attention_pallas(q, kq, vq, pt, lens,
                                       k_scale=ks, v_scale=vs)
    single = paged_decode_attention_pallas(q[:, -1], kq, vq, pt, lens,
                                           k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(
        np.asarray(win[:, -1]), np.asarray(single), atol=1e-6
    )


# ---------------------------------------------------------------------------
# Speculation-tree windows (tree_mask): kernel vs oracle vs gather fallback
# ---------------------------------------------------------------------------

from _optional import given, settings, st  # noqa: E402
from repro.core.speculative import tree_ancestor_mask  # noqa: E402


def _random_parents(rng, n):
    """A valid drafting-order topology: node i's parent is -1 (the window
    root) or any earlier node — uniform, so draws range over chains, stars
    and ragged mixed-fanout trees."""
    return [int(rng.randint(-1, i)) for i in range(n)]


def _tree_case(seed, b, w, kvs, g, hd, ps, mp, lengths, node_counts=None):
    """A `_window_case` plus a per-row (W, W) ancestor mask; rows with fewer
    than w - 1 nodes get self-visible-only padding rows (the engine's fixed
    dispatch width)."""
    q, kp, vp, pt, lens = _window_case(
        seed, b, w, kvs, g, hd, b * mp, ps, mp, lengths
    )
    rng = np.random.RandomState(seed + 1)
    tm = np.zeros((b, w, w), np.float32)
    for i in range(b):
        n = w - 1 if node_counts is None else node_counts[i]
        tm[i] = tree_ancestor_mask(_random_parents(rng, n), w)
    return q, kp, vp, pt, lens, jnp.asarray(tm)


@pytest.mark.parametrize(
    "seed,w,lengths", [(20, 3, [9, 30]), (21, 5, [7, 17]), (22, 7, [8, 32])]
)
def test_tree_window_matches_oracle(seed, w, lengths):
    """Random topologies: the kernel's in-window tree mask must agree with
    the gather+dense oracle row-for-row (full prefix + ancestor columns)."""
    b, kvs, g, hd, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens, tm = _tree_case(seed, b, w, kvs, g, hd, ps, mp, lengths)
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens, tree_mask=tm)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens, tree_mask=tm)
    assert got.shape == (b, w, kvs, g, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_chain_tree_mask_equals_causal_window():
    """A chain-shaped tree (lower-triangular ancestor mask) must reproduce
    the causal-window path bit-for-bit-close on BOTH implementations — the
    equivalence spec_mode='tree' relies on when every fan-out is 1."""
    b, w, kvs, g, hd, ps, mp = 2, 4, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens = _window_case(23, b, w, kvs, g, hd, b * mp, ps, mp,
                                       [11, 26])
    chain = tree_ancestor_mask([i - 1 for i in range(w - 1)], w)
    tm = jnp.asarray(np.broadcast_to(chain, (b, w, w)).copy())
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens, tree_mask=tm)
    causal = paged_decode_attention_pallas(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(causal), atol=1e-6)
    got_ref = ref.paged_attn_ref(q, kp, vp, pt, lens, tree_mask=tm)
    causal_ref = ref.paged_attn_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(
        np.asarray(got_ref), np.asarray(causal_ref), atol=1e-6
    )


def test_tree_gather_fallback_matches_oracle():
    """models/layers._tree_window_attention (the non-pallas engine path)
    computes the same tree semantics over a dense gathered cache."""
    b, w, kvs, g, hd, ps, mp = 2, 5, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens, tm = _tree_case(24, b, w, kvs, g, hd, ps, mp, [9, 28])
    kd = ref.gather_pages_ref(kp, pt)
    vd = ref.gather_pages_ref(vp, pt)
    got = L._tree_window_attention(
        q.reshape(b, w, kvs * g, hd), kd, vd, lens, tm
    ).reshape(b, w, kvs, g, hd)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens, tree_mask=tm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_tree_window_ragged_node_counts():
    """Rows with different live node counts share ONE fixed-width dispatch;
    padded (self-visible-only) rows must not perturb any live row."""
    b, w, kvs, g, hd, ps, mp = 3, 6, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens, tm = _tree_case(
        25, b, w, kvs, g, hd, ps, mp, [7, 19, 30], node_counts=[0, 2, 5]
    )
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens, tree_mask=tm)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens, tree_mask=tm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    assert bool(np.isfinite(np.asarray(got)).all())


@pytest.mark.parametrize("seed,w,lengths", [(26, 3, [9, 30]), (27, 5, [7, 17])])
def test_int8_tree_window_matches_oracle(seed, w, lengths):
    """Tree masks compose with the int8 dequant epilogue: quantized pools,
    random topologies, kernel vs gather-then-dequant oracle."""
    b, kvs, g, hd, ps, mp = 2, 2, 2, 32, 8, 4
    q, kp, vp, pt, lens, tm = _tree_case(seed, b, w, kvs, g, hd, ps, mp, lengths)
    kq, vq, ks, vs_ = _quantized_pools(kp, vp)
    got = paged_decode_attention_pallas(q, kq, vq, pt, lens,
                                        k_scale=ks, v_scale=vs_, tree_mask=tm)
    want = ref.paged_attn_ref(q, kq, vq, pt, lens,
                              k_scale=ks, v_scale=vs_, tree_mask=tm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_tree_window_matches_oracle_property(data):
    """Property sweep (hypothesis, skipped when absent — the seeded variants
    above always run): random width, batch, ragged per-row node counts,
    ragged prefix lengths, both precisions."""
    w = data.draw(st.integers(min_value=2, max_value=6), label="w")
    b = data.draw(st.integers(min_value=1, max_value=3), label="b")
    seed = data.draw(st.integers(min_value=0, max_value=2**16 - 1),
                     label="seed")
    quantized = data.draw(st.booleans(), label="int8")
    ps, mp = 8, 3
    counts = [
        data.draw(st.integers(min_value=0, max_value=w - 1), label=f"n{i}")
        for i in range(b)
    ]
    lengths = [
        data.draw(st.integers(min_value=w, max_value=ps * mp), label=f"len{i}")
        for i in range(b)
    ]
    q, kp, vp, pt, lens, tm = _tree_case(
        seed, b, w, 2, 2, 32, ps, mp, lengths, node_counts=counts
    )
    if quantized:
        kp, vp, ks, vs_ = _quantized_pools(kp, vp)
        kw = {"k_scale": ks, "v_scale": vs_}
    else:
        kw = {}
    got = paged_decode_attention_pallas(q, kp, vp, pt, lens, tree_mask=tm, **kw)
    want = ref.paged_attn_ref(q, kp, vp, pt, lens, tree_mask=tm, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
