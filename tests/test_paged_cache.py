"""Paged KV pool allocator invariants (serving/paged_cache.py)."""
import numpy as np
import pytest

from repro.serving.paged_cache import PagedKVPool, pages_for


def make_pool(num_pages=8, page_size=4, n_layers=2, kvh=2, hd=8):
    return PagedKVPool(n_layers, kvh, hd, num_pages=num_pages, page_size=page_size)


def span(pool, l, val=1.0):
    x = np.full((pool.n_layers, l, pool.kv_heads, pool.head_dim), val, np.float32)
    return x, -x


def test_pages_for():
    assert pages_for(0, 4) == 0
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2


def test_append_gather_roundtrip():
    pool = make_pool()
    seq = pool.allocate_sequence(10)
    k1, v1 = span(pool, 6, 1.0)
    seq.append(k1, v1)
    k2, v2 = span(pool, 3, 2.0)
    seq.append(k2, v2)
    assert seq.length == 9 and len(seq.pages) == 3
    kd = np.zeros((pool.n_layers, 12, pool.kv_heads, pool.head_dim), np.float32)
    vd = np.zeros_like(kd)
    seq.gather_into(kd, vd)
    np.testing.assert_array_equal(kd[:, :6], k1)
    np.testing.assert_array_equal(kd[:, 6:9], k2)
    np.testing.assert_array_equal(vd[:, :9], np.concatenate([v1, v2], 1))


def test_reservation_blocks_admission():
    pool = make_pool(num_pages=8, page_size=4)
    a = pool.allocate_sequence(16)  # 4 pages reserved, 0 backed
    assert a is not None and pool.available_pages == 4
    b = pool.allocate_sequence(17)  # needs 5 > 4 available
    assert b is None
    c = pool.allocate_sequence(16)
    assert c is not None and pool.available_pages == 0
    assert pool.allocate_sequence(1) is None


def test_request_larger_than_pool_raises():
    pool = make_pool(num_pages=4, page_size=4)
    with pytest.raises(ValueError, match="capacity"):
        pool.allocate_sequence(17)


def test_rewind_restores_free_pages_and_regrow():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*span(pool, 10))
    assert pool.used_pages == 3
    seq.rewind(6)  # length 4 -> 1 page kept
    assert seq.length == 4 and pool.used_pages == 1
    # rewound pages return to the reservation, so the sequence can regrow
    seq.append(*span(pool, 8, 3.0))
    assert seq.length == 12 and pool.used_pages == 3
    with pytest.raises(ValueError, match="over-rewind"):
        seq.rewind(13)
    with pytest.raises(ValueError):
        seq.rewind(-1)


def test_rewind_is_partial_page_aware():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*span(pool, 9))  # 3 pages, last holds 1 token
    seq.rewind(1)  # length 8: drops the partial page
    assert pool.used_pages == 2
    seq.rewind(1)  # length 7: page boundary not crossed
    assert pool.used_pages == 2


def test_release_returns_pages_and_reservation():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(16)  # reserve 4
    seq.append(*span(pool, 5))  # backs 2 of the 4 reserved pages
    assert pool.free_pages == 6
    assert pool.available_pages == 4  # 6 free minus 2 still-unbacked reserved
    seq.release()
    assert pool.used_pages == 0
    assert pool.available_pages == 8
    assert seq.released
    with pytest.raises(RuntimeError, match="double release"):
        seq.release()


def test_page_reuse_after_release():
    pool = make_pool(num_pages=2, page_size=4)
    a = pool.allocate_sequence(8)
    a.append(*span(pool, 8))
    pages_a = list(a.pages)
    assert pool.allocate_sequence(4) is None  # full
    a.release()
    b = pool.allocate_sequence(8)
    b.append(*span(pool, 8, 9.0))
    assert sorted(b.pages) == sorted(pages_a)  # physical reuse


def test_exceeding_reservation_raises():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(8)  # 2 pages
    with pytest.raises(RuntimeError, match="reservation"):
        seq.append(*span(pool, 9))


def test_gather_into_clamps_page_overhang():
    """A dst buffer that is not a multiple of page_size must not overflow:
    the last page's junk tail is clamped (regression: s_max=110, ps=16)."""
    pool = make_pool(num_pages=8, page_size=16)
    seq = pool.allocate_sequence(110)
    k, v = span(pool, 100, 5.0)
    seq.append(k, v)  # 7 pages = 112 slots > 110-row dst
    kd = np.zeros((pool.n_layers, 110, pool.kv_heads, pool.head_dim), np.float32)
    vd = np.zeros_like(kd)
    seq.gather_into(kd, vd)
    np.testing.assert_array_equal(kd[:, :100], k)
    with pytest.raises(AssertionError):
        short = np.zeros((pool.n_layers, 99, pool.kv_heads, pool.head_dim), np.float32)
        seq.gather_into(short, short.copy())  # dst smaller than valid data


def test_over_rewind_at_page_boundary():
    """Regression: when length sits EXACTLY on a page boundary, rewinding
    one past it must raise (not wrap / pop a non-existent page), and the
    sequence must stay usable afterwards.  Also mirrors the engine's rewind
    contract: n must be >= 0."""
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*span(pool, 8))  # exactly 2 full pages
    assert seq.length == 8 and len(seq.pages) == 2
    with pytest.raises(ValueError, match="over-rewind"):
        seq.rewind(9)
    with pytest.raises(ValueError, match="n >= 0"):
        seq.rewind(-1)
    # state unchanged by the failed rewinds; a full boundary rewind is fine
    assert seq.length == 8 and len(seq.pages) == 2
    seq.rewind(8)
    assert seq.length == 0 and pool.used_pages == 0


def test_rewind_keep_pages_for_device_mode():
    """release_pages=False (device-resident pools): the length drops but
    every backed page stays owned, so the page table is lifetime-stable."""
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*span(pool, 10))  # 3 pages
    pages = list(seq.pages)
    seq.rewind(7, release_pages=False)
    assert seq.length == 3 and seq.pages == pages and pool.used_pages == 3
    with pytest.raises(ValueError, match="over-rewind"):
        seq.rewind(4, release_pages=False)
    seq.advance(9)  # regrow over the kept pages, no new allocation
    assert seq.length == 12 and seq.pages == pages


def test_storageless_pool_is_pure_allocator():
    """alloc_storage=False: bookkeeping works, host data paths refuse."""
    pool = PagedKVPool(2, 2, 8, num_pages=4, page_size=4, alloc_storage=False)
    assert pool.k is None and pool.v is None
    seq = pool.allocate_sequence(8)
    seq.ensure_backed(8)
    assert len(seq.pages) == 2 and pool.used_pages == 2
    seq.advance(5)
    assert seq.length == 5
    with pytest.raises(RuntimeError, match="storage-less"):
        seq.append(
            np.zeros((2, 1, 2, 8), np.float32), np.zeros((2, 1, 2, 8), np.float32)
        )
    with pytest.raises(RuntimeError, match="storage-less"):
        seq.gather_into(
            np.zeros((2, 8, 2, 8), np.float32), np.zeros((2, 8, 2, 8), np.float32)
        )
    seq.release()
    assert pool.used_pages == 0 and pool.available_pages == 4


def test_device_pool_init_has_scratch_page():
    from repro.serving.paged_cache import device_pool_init

    pool = PagedKVPool(3, 2, 8, num_pages=5, page_size=4, alloc_storage=False)
    k, v = device_pool_init(pool)
    assert k.shape == (3, 6, 4, 2, 8)  # num_pages + 1 scratch
    assert v.shape == k.shape


def test_high_water_and_stats():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(16)
    seq.append(*span(pool, 16))
    st = pool.stats()
    assert st.used_pages == 4 and st.high_water_pages == 4
    assert st.utilization == pytest.approx(0.5)
    seq.release()
    assert pool.stats().used_pages == 0
    assert pool.stats().high_water_pages == 4  # sticky


# ---------------------------------------------------------------------------
# int8 compressed pools (kv_quant="int8")
# ---------------------------------------------------------------------------


def make_int8_pool(num_pages=8, page_size=4, n_layers=2, kvh=2, hd=8):
    return PagedKVPool(
        n_layers, kvh, hd, num_pages=num_pages, page_size=page_size,
        kv_quant="int8",
    )


def rspan(pool, l, seed=0):
    rng = np.random.RandomState(seed)
    shape = (pool.n_layers, l, pool.kv_heads, pool.head_dim)
    return (rng.randn(*shape).astype(np.float32),
            rng.randn(*shape).astype(np.float32))


def test_int8_append_gather_roundtrip_close():
    """Host int8 pools: append quantizes, gather dequantizes; the roundtrip
    stays within the symmetric-quantization error bound (absmax/254 per
    slot-head row)."""
    pool = make_int8_pool()
    seq = pool.allocate_sequence(10)
    k1, v1 = rspan(pool, 6, seed=1)
    seq.append(k1, v1)
    k2, v2 = rspan(pool, 3, seed=2)
    seq.append(k2, v2)
    assert pool.k.dtype == np.int8 and pool.k_scale.dtype == np.float32
    kd = np.zeros((pool.n_layers, 12, pool.kv_heads, pool.head_dim), np.float32)
    vd = np.zeros_like(kd)
    seq.gather_into(kd, vd)
    ref_k = np.concatenate([k1, k2], 1)
    ref_v = np.concatenate([v1, v2], 1)
    bound = np.abs(ref_k).max() / 254 + 1e-6
    assert np.abs(kd[:, :9] - ref_k).max() <= bound
    assert np.abs(vd[:, :9] - ref_v).max() <= np.abs(ref_v).max() / 254 + 1e-6


def test_int8_bytes_accounting():
    pool = make_int8_pool(n_layers=2, kvh=2, hd=8)
    dense = make_pool(n_layers=2, kvh=2, hd=8)
    # K+V * layers * heads * (hd int8 bytes + 4B f32 scale)
    assert pool.bytes_per_token() == 2 * 2 * 2 * (8 + 4)
    assert dense.bytes_per_token() == 2 * 2 * 2 * 8 * 4
    assert dense.bytes_per_token() / pool.bytes_per_token() >= 1.8
    seq = pool.allocate_sequence(8)
    seq.append(*rspan(pool, 8))
    st = pool.stats()
    assert st.kv_quant == "int8"
    assert st.bytes_per_token == pool.bytes_per_token()
    assert st.kv_bytes_total == 2 * pool.bytes_per_page()
    assert pool.bytes_per_token_by_kind() == {"int8": pool.bytes_per_token()}


def test_mixed_pool_is_allocator_only_and_sums_bytes():
    with pytest.raises(NotImplementedError, match="allocator-only"):
        PagedKVPool(2, 2, 8, num_pages=4, page_size=4, kv_quant="mixed")
    pool = PagedKVPool(2, 2, 8, num_pages=4, page_size=4,
                       alloc_storage=False, kv_quant="mixed")
    by_kind = pool.bytes_per_token_by_kind()
    assert set(by_kind) == {"float32", "int8"}
    assert pool.bytes_per_token() == sum(by_kind.values())


def test_rewind_invalidates_scales_across_page_boundary():
    """Regression (stale per-page metadata): rewind(release_pages=False)
    must zero the dropped positions' scale entries — including positions in
    KEPT pages and positions in pages past the new boundary — so a stale
    scale can never silently dequantize a later append's bytes.  Freshness
    is restored only by the next append writing value+scale together."""
    pool = make_int8_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*rspan(pool, 10, seed=3))  # 3 pages: 4+4+2
    pages = list(seq.pages)
    flat = lambda p: pool.k_scale[:, p].reshape(pool.n_layers, -1, pool.kv_heads, 1)
    assert np.all(flat(pages[2])[:, :2] > 0)
    # drop 7 positions: new length 3 sits mid-page-0; pages stay owned
    seq.rewind(7, release_pages=False)
    assert seq.pages == pages
    # positions 3 (page 0 tail), 4..7 (page 1), 8..9 (page 2) are zeroed
    assert np.all(flat(pages[0])[:, 3:] == 0)
    assert np.all(flat(pages[1]) == 0)
    assert np.all(flat(pages[2]) == 0)
    # kept prefix scales stay intact
    assert np.all(flat(pages[0])[:, :3] > 0)
    # regrow: append restores freshness and the roundtrip is exact again
    k, v = rspan(pool, 9, seed=4)
    seq.append(k, v)
    kd = np.zeros((pool.n_layers, 12, pool.kv_heads, pool.head_dim), np.float32)
    seq.gather_into(kd, np.zeros_like(kd))
    assert np.abs(kd[:, 3:12] - k).max() <= np.abs(k).max() / 254 + 1e-6


def test_release_zeroes_scales():
    pool = make_int8_pool(num_pages=4, page_size=4)
    seq = pool.allocate_sequence(8)
    seq.append(*rspan(pool, 8, seed=5))
    pages = list(seq.pages)
    seq.release()
    for p in pages:
        assert np.all(pool.k_scale[:, p] == 0)
        assert np.all(pool.v_scale[:, p] == 0)


def test_device_pool_store_shapes():
    from repro.serving.paged_cache import device_pool_store

    pool = PagedKVPool(3, 2, 8, num_pages=5, page_size=4,
                       alloc_storage=False, kv_quant="int8")
    st = device_pool_store(pool)
    assert set(st) == {"k", "v", "k_scale", "v_scale"}
    assert st["k"].shape == (3, 6, 4, 2, 8) and str(st["k"].dtype) == "int8"
    assert st["k_scale"].shape == (3, 6, 4, 2, 1)
    assert str(st["k_scale"].dtype) == "float32"
    dense = device_pool_store(pool, kv_quant="none")
    assert set(dense) == {"k", "v"} and str(dense["k"].dtype) == "float32"
    mixed = PagedKVPool(3, 2, 8, num_pages=5, page_size=4,
                        alloc_storage=False, kv_quant="mixed")
    with pytest.raises(ValueError, match="ONE storage kind"):
        device_pool_store(mixed)


def test_shared_page_refcounts_release_in_any_order():
    """Shared pages free only at the LAST reference: a donor sequence, the
    prefix tree's pin, and a follower mapping the same pages may release
    in any order without freeing a page another holder still maps."""
    pool = make_pool(num_pages=8, page_size=4)
    donor = pool.allocate_sequence(8)
    k, v = span(pool, 8, 3.0)
    donor.append(k, v)
    pages = list(donor.pages)
    for p in pages:  # the tree pins every full block
        pool.incref_page(p)
    follower = pool.allocate_sequence(
        12, shared_pages=pages, shared_tokens=8
    )
    assert [pool.page_ref(p) for p in pages] == [3, 3]
    assert pool.shared_page_count == 2
    free0 = pool.free_pages

    donor.release()  # donor exits first; its pages must NOT free
    assert pool.free_pages == free0
    assert [pool.page_ref(p) for p in pages] == [2, 2]
    kd = np.zeros((pool.n_layers, 12, pool.kv_heads, pool.head_dim), np.float32)
    follower.gather_into(kd, np.zeros_like(kd))
    np.testing.assert_array_equal(kd[:, :8], k)  # rows still readable

    follower.release()  # down to the tree's ref alone
    assert pool.free_pages == free0
    assert [pool.page_ref(p) for p in pages] == [1, 1]
    for p in pages:  # tree eviction drops the last ref -> pages free
        pool._give_page(p, back_to_reservation=False)
    assert pool.free_pages == free0 + 2
    assert pool.shared_page_count == 0


def test_double_release_raises():
    """Regression: releasing a sequence twice must fail loudly instead of
    double-decrefing pages another holder may since have re-acquired."""
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(8)
    seq.append(*span(pool, 6))
    seq.release()
    free0 = pool.free_pages
    with pytest.raises(RuntimeError, match="double release"):
        seq.release()
    assert pool.free_pages == free0  # second call changed nothing

    # the same guard holds for a sequence mapping shared pages
    donor = pool.allocate_sequence(4)
    donor.append(*span(pool, 4))
    pages = list(donor.pages)
    fol = pool.allocate_sequence(8, shared_pages=pages, shared_tokens=4)
    fol.release()
    with pytest.raises(RuntimeError, match="double release"):
        fol.release()
    assert pool.page_ref(pages[0]) == 1  # donor's ref untouched
    donor.release()


# ---------------------------------------------------------------------------
# flat_slots: the tree-compaction indexing contract
# ---------------------------------------------------------------------------


def test_flat_slots_maps_positions_to_pool_rows():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*span(pool, 10))
    pages = list(seq.pages)
    got = seq.flat_slots([0, 3, 4, 9])
    assert got.tolist() == [
        pages[0] * 4 + 0, pages[0] * 4 + 3, pages[1] * 4 + 0, pages[2] * 4 + 1
    ]
    assert seq.flat_slots([]).size == 0


def test_flat_slots_requires_backed_positions():
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(12)
    seq.append(*span(pool, 5))  # 2 pages backed
    with pytest.raises(AssertionError):
        seq.flat_slots([8])  # 3rd page not backed
    seq.release()
    with pytest.raises(AssertionError, match="released"):
        seq.flat_slots([0])


def test_flat_slots_stable_across_tree_advance_rewind():
    """The engine queues compaction moves between advance(W) and
    rewind(W-1-n, release_pages=False); positions must keep mapping through
    the SAME physical pages across that dance."""
    pool = make_pool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(16)
    seq.append(*span(pool, 6))
    before = seq.flat_slots(np.arange(6))
    seq.advance(7)  # the W=7 tree window scattered in place on device
    mid = seq.flat_slots(np.arange(13))
    seq.rewind(5, release_pages=False)  # keep n_acc + 1 = 2
    after = seq.flat_slots(np.arange(8))
    np.testing.assert_array_equal(before, mid[:6])
    np.testing.assert_array_equal(mid[:8], after)


# ---------------------------------------------------------------------------
# Engine regression: abort mid-tree-round frees every sibling reservation
# ---------------------------------------------------------------------------


def test_abort_mid_tree_round_frees_sibling_pages():
    """A tree round reserves the full tree_budget + 1 window on both pools;
    aborting while branches are in flight must return every page (no leaked
    sibling reservations) and leave the other request draining normally."""
    from repro.launch.serve import build_pair
    from repro.serving import Engine, EngineConfig, SamplingParams

    target, draft = build_pair(seed=0, s_max=128, quantize=False)
    eng = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, spec_mode="tree", tree_budget=6,
        spec_branches=2, branch_threshold=1.0, par_mode="wdos",
    ))
    rng = np.random.RandomState(0)
    sp = SamplingParams(temperature=2.0, seed=3, max_tokens=16)
    rid = eng.add_request(rng.randint(0, 512, size=5).astype(np.int32), sp)
    eng.add_request(rng.randint(0, 512, size=4).astype(np.int32), sp)
    eng.step()  # wdos trees stay in flight across steps
    assert eng.abort(rid)
    while eng.has_unfinished():
        eng.step()
    t_st, d_st = eng.pool_stats()
    assert t_st.used_pages == 0, t_st
    assert d_st.used_pages == 0, d_st
