"""Launch layer on a 1-device mesh: input_specs, build_cell lower+compile
with smoke configs (the 512-device production meshes are covered by
`repro.launch.dryrun`, which cannot run inside this test process because
jax's device count is already locked)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.launch.mesh import activate_mesh, make_cpu_mesh
from repro.launch.steps import build_cell, input_specs, param_counts
from repro.models.common import SHAPES, Family, ShapeConfig

MESH = make_cpu_mesh()

SMALL_SHAPES = {
    "train": ShapeConfig("t", 32, 2, "train"),
    "prefill": ShapeConfig("p", 32, 2, "prefill"),
    "decode": ShapeConfig("d", 32, 2, "decode"),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_cell_compiles_smoke(arch, kind):
    cfg = get_smoke(arch)
    if cfg.family is Family.MOE:
        cfg = dataclasses.replace(cfg, moe_impl="a2a")  # exercise shard_map
    shape = SMALL_SHAPES[kind]
    with activate_mesh(MESH):
        cell = build_cell(cfg, shape, MESH, donate=False)
        compiled = cell.fn.lower(*cell.args).compile()
    cost = compiled.cost_analysis()
    cost = cost if isinstance(cost, dict) else cost[0]
    assert float(cost.get("flops", 0)) > 0 or kind == "decode"


def test_input_specs_cover_every_family():
    for arch in ARCH_IDS:
        cfg = get_smoke(arch)
        structs, shardings = input_specs(cfg, SMALL_SHAPES["train"], MESH)
        assert "tokens" in structs and "tokens" in shardings
        if cfg.family is Family.VLM:
            assert "vision_embeds" in structs
        if cfg.family is Family.AUDIO:
            assert "frames" in structs
        for v in structs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_kv_quant_decode_consistency():
    from repro.models import lm
    from repro.models.common import ModelConfig

    cfg = ModelConfig(name="t", family=Family.DENSE, n_layers=2, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=97, dtype="float32",
                      kv_quant=True)
    key = jax.random.PRNGKey(0)
    p, _ = lm.init_lm(key, cfg, tp=1)
    toks = jax.random.randint(key, (2, 12), 0, 97)
    cache = lm.init_cache(cfg, 2, 32, tp=1)
    assert cache["attn"]["k"].dtype == jnp.int8
    lgp, cache = lm.apply_lm(p, cfg, None, toks[:, :8], cache=cache)
    lgd, cache = lm.apply_lm(p, cfg, None, toks[:, 8:9], cache=cache)
    lgf, _ = lm.apply_lm(p, cfg, None, toks[:, :9])
    # int8 KV adds bounded quantization noise
    assert float(jnp.max(jnp.abs(lgf[:, 7] - lgp[:, -1]))) < 0.08
    assert float(jnp.max(jnp.abs(lgf[:, 8] - lgd[:, 0]))) < 0.08


def test_perf_variants_registry():
    from repro.launch.perf import NAMED_VARIANTS

    assert "w4a8+kvq8" in NAMED_VARIANTS
    cfgs = get_smoke("deepseek-67b")
    ov = {k: v for k, v in NAMED_VARIANTS["kvq8"].items() if not k.startswith("__")}
    dataclasses.replace(cfgs, **ov)  # every override must be a real field


def test_param_counts_positive():
    for arch in ARCH_IDS:
        pc = param_counts(get_smoke(arch))
        assert pc["total"] > 0 and pc["active"] > 0
        assert pc["active"] <= pc["total"] * 1.5  # hybrid active can exceed
