"""Stepwise Engine API: mid-flight admission, abort, streaming outputs,
per-request sampling determinism, impl auto-selection, deprecation hygiene.

The closed-batch parity suites (test_serving_batch.py / test_serving_paged.py)
cover greedy bit-identity through the deprecated wrappers; this module covers
what only the stepwise redesign can do — requests joining and leaving a LIVE
batch — plus the sampled (temperature > 0) path.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.speculative import SDConfig, sd_generate
from repro.launch.serve import build_pair
from repro.serving import (
    Engine,
    EngineConfig,
    SamplingParams,
    resolve_paged_attn_impl,
)
from repro.serving.engine import make_interface
from repro.serving.request import RequestState


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, vocab, size=rng.randint(2, 7)).astype(np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def _sd_ref(target, draft, prompt, max_tokens, dl=3):
    """Pre-redesign reference: the dense-cache sd_generate driver."""
    toks, _ = sd_generate(
        jax.random.PRNGKey(0),
        make_interface(target), target.params,
        make_interface(draft), draft.params,
        jnp.asarray(np.asarray(prompt)[None]),
        SDConfig(draft_len=dl, temperature=0.0, max_tokens=max_tokens),
    )
    return toks


# ---------------------------------------------------------------------------
# Mid-flight admission (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_midflight_admission_without_drain(pair):
    """A request added after the batch has run rounds is prefilled and
    scheduled on the NEXT step — the active requests keep decoding
    throughout, and everyone's output matches the solo reference."""
    target, draft = pair
    p0, p1, p2 = _prompts(3, seed=1)
    eng = Engine(target, draft, EngineConfig(max_batch=3, page_size=8, draft_len=3))
    r0 = eng.add_request(p0, SamplingParams(max_tokens=16))
    r1 = eng.add_request(p1, SamplingParams(max_tokens=16))
    eng.step()
    eng.step()
    assert eng.request(r0).rounds == 2 and not eng.request(r0).done
    # late arrival: joins the live batch
    r2 = eng.add_request(p2, SamplingParams(max_tokens=8))
    assert eng.request(r2).state is RequestState.QUEUED
    eng.step()
    # admitted AND ran its first round while r0/r1 kept decoding (no drain)
    assert eng.request(r2).state is not RequestState.QUEUED
    assert eng.request(r2).rounds == 1
    assert eng.request(r0).rounds == 3 and not eng.request(r0).done
    while eng.has_unfinished():
        eng.step()
    for rid, p, m in [(r0, p0, 16), (r1, p1, 16), (r2, p2, 8)]:
        ref = _sd_ref(target, draft, p, m)
        assert bool(jnp.all(eng.output_tokens(rid) == ref)), f"request {rid}"


def test_step_streams_incremental_outputs(pair):
    """Each step's RequestOutputs carry exactly the newly verified tokens;
    their concatenation is the final output; finish arrives once with
    reason "length"."""
    target, draft = pair
    prompts = _prompts(2, seed=2)
    eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8, draft_len=2))
    rids = [eng.add_request(p, SamplingParams(max_tokens=6)) for p in prompts]
    streamed = {rid: [] for rid in rids}
    finishes = {rid: [] for rid in rids}
    while eng.has_unfinished():
        for out in eng.step():
            streamed[out.request_id].extend(out.new_token_ids)
            assert out.prompt_token_ids == [int(t) for t in
                                            prompts[out.request_id]]
            assert out.token_ids == streamed[out.request_id]  # cumulative
            if out.finished:
                finishes[out.request_id].append(out.outputs[0].finish_reason)
    for rid in rids:
        assert streamed[rid] == [int(t) for t in eng.output_tokens(rid)]
        assert len(streamed[rid]) == 6
        assert finishes[rid] == ["length"]


def test_step_on_idle_engine_is_a_noop(pair):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(max_batch=2))
    assert eng.step() == []
    assert not eng.has_unfinished()


# ---------------------------------------------------------------------------
# Abort
# ---------------------------------------------------------------------------


def test_abort_active_returns_pages_and_spares_the_rest(pair):
    target, draft = pair
    p0, p1, p2 = _prompts(3, seed=3)
    eng = Engine(target, draft, EngineConfig(max_batch=3, page_size=8, draft_len=3))
    r0 = eng.add_request(p0, SamplingParams(max_tokens=12))
    r1 = eng.add_request(p1, SamplingParams(max_tokens=12))
    r2 = eng.add_request(p2, SamplingParams(max_tokens=12))
    eng.step()
    t_stats, d_stats = eng.pool_stats()
    used_before = t_stats.used_pages
    assert used_before > 0
    victim_pages = len(eng.request(r1).t_seq.pages)
    assert eng.abort(r1) is True
    t_stats, _ = eng.pool_stats()
    # pages came back immediately, not at drain time
    assert t_stats.used_pages == used_before - victim_pages
    assert eng.request(r1).state is RequestState.FINISHED
    assert eng.request(r1).finish_reason == "abort"
    assert eng.abort(r1) is False  # already finished
    assert eng.abort(999) is False  # unknown id
    while eng.has_unfinished():
        eng.step()
    for rid, p in [(r0, p0), (r2, p2)]:
        ref = _sd_ref(target, draft, p, 12)
        assert bool(jnp.all(eng.output_tokens(rid) == ref)), f"request {rid}"
    t_stats, d_stats = eng.pool_stats()
    assert t_stats.used_pages == 0 and t_stats.reserved_pages == 0
    assert d_stats.used_pages == 0 and d_stats.reserved_pages == 0


def test_abort_queued_request(pair):
    target, draft = pair
    p0, p1 = _prompts(2, seed=4)
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8, draft_len=2))
    r0 = eng.add_request(p0, SamplingParams(max_tokens=8))
    r1 = eng.add_request(p1, SamplingParams(max_tokens=8))
    eng.step()
    assert eng.request(r1).state is RequestState.QUEUED
    assert eng.abort(r1) is True
    assert eng.request(r1).finish_reason == "abort"
    while eng.has_unfinished():
        eng.step()
    assert list(eng.output_tokens(r1)) == []  # never decoded
    ref = _sd_ref(target, draft, p0, 8, dl=2)
    assert bool(jnp.all(eng.output_tokens(r0) == ref))


# ---------------------------------------------------------------------------
# Sampled speculative decoding (temperature > 0)
# ---------------------------------------------------------------------------


def test_sampled_deterministic_across_runs_and_batch_compositions(pair):
    """Fixed per-request seed => the same tokens whether the request runs
    solo or inside a batch of 4, and across repeated runs."""
    target, draft = pair
    prompts = _prompts(4, seed=5)
    sp0 = SamplingParams(temperature=0.8, seed=123, max_tokens=10)
    others = [SamplingParams(temperature=0.8, seed=200 + i, max_tokens=10)
              for i in range(3)]

    solo = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    out_solo, _ = solo.run([prompts[0]], sp0)

    def batch4():
        eng = Engine(target, draft, EngineConfig(max_batch=4, page_size=8))
        return eng.run(prompts, [sp0] + others)

    out_a, _ = batch4()
    out_b, _ = batch4()
    assert bool(jnp.all(out_a[0] == out_solo[0])), "batch composition leaked"
    for a, b in zip(out_a, out_b):
        assert bool(jnp.all(a == b)), "sampled decode not reproducible"
    # a different seed must decouple the stream
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    out_seed2, _ = eng.run(
        [prompts[0]], SamplingParams(temperature=0.8, seed=124, max_tokens=10)
    )
    assert not bool(jnp.all(out_seed2[0] == out_solo[0]))
    # and temperature>0 actually samples (differs from greedy)
    greedy = _sd_ref(target, draft, prompts[0], 10)
    assert not bool(jnp.all(out_solo[0] == greedy))


def test_mixed_greedy_and_sampled_batch_keeps_greedy_bit_identical(pair):
    """A sampled neighbour in the batch must not perturb a greedy row."""
    target, draft = pair
    prompts = _prompts(2, seed=6)
    eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
    outs, _ = eng.run(prompts, [
        SamplingParams(max_tokens=8),  # greedy
        SamplingParams(temperature=1.0, seed=7, max_tokens=8),
    ])
    ref = _sd_ref(target, draft, prompts[0], 8)
    assert bool(jnp.all(outs[0] == ref))


def test_top_k_one_is_greedy(pair):
    """top_k=1 collapses both draft and target distributions to the argmax,
    so sampled decoding degenerates to the greedy output exactly."""
    target, draft = pair
    prompts = _prompts(1, seed=8)
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    outs, _ = eng.run(
        prompts, SamplingParams(temperature=0.7, top_k=1, seed=42, max_tokens=8)
    )
    ref = _sd_ref(target, draft, prompts[0], 8)
    assert bool(jnp.all(outs[0] == ref))


def test_self_draft_sampled_accepts_everything(pair):
    """draft == target => q == p, so the rejection rule accepts every
    draft token (u*q < p for u in [0,1)) — a direct check of the lossless
    acceptance rule's host implementation."""
    target, _ = pair
    prompts = _prompts(2, seed=9)
    eng = Engine(target, target, EngineConfig(max_batch=2, page_size=8))
    _, summary = eng.run(
        prompts, SamplingParams(temperature=0.9, seed=3, max_tokens=10)
    )
    assert summary["acceptance_rate"] == 1.0


# ---------------------------------------------------------------------------
# paged_attn_impl auto-selection
# ---------------------------------------------------------------------------


def test_resolve_paged_attn_impl_branches():
    assert resolve_paged_attn_impl(None, backend="tpu") == "pallas"
    assert resolve_paged_attn_impl("auto", backend="tpu") == "pallas"
    assert resolve_paged_attn_impl(None, backend="cpu") == "gather"
    # the kernel is TPU-dialect Pallas: auto must NOT hand it to GPU
    assert resolve_paged_attn_impl("auto", backend="gpu") == "gather"
    # an explicit impl always wins over the backend
    assert resolve_paged_attn_impl("gather", backend="tpu") == "gather"
    assert resolve_paged_attn_impl("pallas", backend="cpu") == "pallas"
    assert resolve_paged_attn_impl(None) == (
        "pallas" if jax.default_backend() == "tpu" else "gather"
    )
    with pytest.raises(ValueError, match="paged_attn_impl"):
        resolve_paged_attn_impl("floppy")


def test_engine_config_impl_override_end_to_end(pair):
    """EngineConfig.paged_attn_impl="pallas" routes every decode/verify
    through the paged Pallas kernel (interpret mode on CPU) and keeps the
    greedy tokens."""
    target, draft = pair
    prompts = _prompts(2, seed=10)
    ref_eng = Engine(target, draft, EngineConfig(max_batch=2, page_size=8))
    ref_outs, _ = ref_eng.run(prompts, SamplingParams(max_tokens=6))
    eng = Engine(
        target, draft,
        EngineConfig(max_batch=2, page_size=8, paged_attn_impl="pallas"),
    )
    assert eng.target.paged_attn_impl == "pallas"
    outs, _ = eng.run(prompts, SamplingParams(max_tokens=6))
    for a, b in zip(outs, ref_outs):
        assert bool(jnp.all(a == b))


# ---------------------------------------------------------------------------
# Validation + deprecation hygiene
# ---------------------------------------------------------------------------


def test_add_request_validates_against_max_model_len(pair):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(max_batch=1, max_model_len=32))
    with pytest.raises(ValueError, match="max_model_len"):
        eng.add_request(np.arange(2, 12), SamplingParams(max_tokens=64))
    with pytest.raises(ValueError, match="max_model_len"):
        Engine(target, draft, EngineConfig(max_model_len=4096))  # > s_max


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        sp = SamplingParams()
        sp.temperature = 1.0


def test_deprecated_wrappers_warn_exactly_once(pair):
    from repro.serving import api
    from repro.serving.engine import BatchConfig, serve_batch, serve_sd

    target, draft = pair
    prompts = _prompts(1, seed=11)
    cfg = BatchConfig(max_batch=1, page_size=8, max_tokens=4, draft_len=2)
    for name in ("serve_batch", "serve_sd"):
        api._DEPRECATION_EMITTED.discard(name)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
        serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
        serve_sd(
            jax.random.PRNGKey(0), target, draft,
            jnp.asarray(prompts[0][None]),
            SDConfig(draft_len=2, temperature=0.0, max_tokens=4),
        )
        serve_sd(
            jax.random.PRNGKey(0), target, draft,
            jnp.asarray(prompts[0][None]),
            SDConfig(draft_len=2, temperature=0.0, max_tokens=4),
        )
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert sorted(str(w.message).split("(")[0] for w in deps) == [
        "serve_batch", "serve_sd"
    ]


# ---------------------------------------------------------------------------
# top_p (nucleus) sampling through the Engine
# ---------------------------------------------------------------------------


def test_top_p_deterministic_across_batch_compositions(pair):
    """Nucleus sampling keeps the per-request determinism contract: the
    same (prompt, seed, top_p) yields the same tokens solo and batched."""
    target, draft = pair
    prompts = _prompts(3, seed=12)
    sp0 = SamplingParams(temperature=0.8, top_p=0.8, seed=55, max_tokens=8)
    others = [
        SamplingParams(temperature=0.8, top_p=0.9, seed=60 + i, max_tokens=8)
        for i in range(2)
    ]
    solo = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    out_solo, _ = solo.run([prompts[0]], sp0)
    eng = Engine(target, draft, EngineConfig(max_batch=3, page_size=8))
    out_batch, _ = eng.run(prompts, [sp0] + others)
    assert bool(jnp.all(out_batch[0] == out_solo[0]))


def test_top_p_tiny_collapses_to_greedy(pair):
    """top_p -> 0 keeps only the argmax in both distributions, so sampled
    decoding degenerates to the greedy output exactly (like top_k=1)."""
    target, draft = pair
    prompts = _prompts(1, seed=13)
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    outs, _ = eng.run(
        prompts,
        SamplingParams(temperature=0.9, top_p=1e-6, seed=17, max_tokens=8),
    )
    ref = _sd_ref(target, draft, prompts[0], 8)
    assert bool(jnp.all(outs[0] == ref))


def test_top_p_self_draft_lossless_acceptance(pair):
    """draft == target with a shared nucleus filter => q' == p', so the
    rejection rule accepts every draft — top_p is lossless end to end."""
    target, _ = pair
    prompts = _prompts(2, seed=14)
    eng = Engine(target, target, EngineConfig(max_batch=2, page_size=8))
    _, summary = eng.run(
        prompts,
        SamplingParams(temperature=0.9, top_p=0.7, seed=5, max_tokens=10),
    )
    assert summary["acceptance_rate"] == 1.0


def test_top_p_validation():
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)


# ---------------------------------------------------------------------------
# stop sequences (SamplingParams.stop over the detokenized stream)
# ---------------------------------------------------------------------------


def _stop_ref(target, draft, prompt, max_tokens):
    ref = _sd_ref(target, draft, prompt, max_tokens)
    return [int(t) for t in ref]


@pytest.mark.parametrize("par_mode", ["off", "wdos"])
def test_stop_string_truncates_and_frees_pages(pair, par_mode):
    """Generation ends at the first stop match with finish_reason="stop";
    the stop string is excluded from the output; the request's pages
    return through normal retirement — in BOTH round schedulers."""
    target, draft = pair
    prompts = _prompts(2, seed=15)
    ref = _stop_ref(target, draft, prompts[0], 12)
    stop_s = f"{ref[5]} "  # the 6th token's detokenized text
    eng = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, par_mode=par_mode,
    ))
    outs, _ = eng.run(prompts, [
        SamplingParams(max_tokens=12, stop=(stop_s,)),
        SamplingParams(max_tokens=12),  # untouched neighbour
    ])
    assert [int(t) for t in outs[0]] == ref[:5]
    assert eng.request(0).finish_reason == "stop"
    # the neighbour is unperturbed by the early retirement
    ref1 = _stop_ref(target, draft, prompts[1], 12)
    assert [int(t) for t in outs[1]] == ref1
    t_stats, d_stats = eng.pool_stats()
    assert t_stats.used_pages == 0 and d_stats.used_pages == 0


def test_stop_string_spanning_token_boundary(pair):
    """A stop string covering two adjacent tokens' text truncates at the
    FIRST token of the match (both are excluded)."""
    target, draft = pair
    prompts = _prompts(1, seed=16)
    ref = _stop_ref(target, draft, prompts[0], 12)
    stop_s = f"{ref[3]} {ref[4]} "  # spans tokens 3 and 4
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    outs, _ = eng.run(prompts, SamplingParams(max_tokens=12, stop=(stop_s,)))
    assert [int(t) for t in outs[0]] == ref[:3]
    assert eng.request(0).finish_reason == "stop"


def test_stop_earliest_of_multiple_stops_wins(pair):
    target, draft = pair
    prompts = _prompts(1, seed=17)
    ref = _stop_ref(target, draft, prompts[0], 12)
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    outs, _ = eng.run(prompts, SamplingParams(
        max_tokens=12, stop=(f"{ref[7]} ", f"{ref[2]} "),
    ))
    assert [int(t) for t in outs[0]] == ref[:2]


def test_stop_streams_only_surviving_tokens(pair):
    """The per-request sink must never emit a token that the stop
    truncation later removes (within-round holdback)."""
    target, draft = pair
    prompts = _prompts(1, seed=18)
    ref = _stop_ref(target, draft, prompts[0], 12)
    stop_s = f"{ref[4]} "
    eng = Engine(target, draft, EngineConfig(max_batch=1, page_size=8))
    streamed = []
    eng.add_request(
        prompts[0], SamplingParams(max_tokens=12, stop=(stop_s,)),
        sink=streamed.append,
    )
    while eng.has_unfinished():
        eng.step()
    assert streamed == ref[:4]


def test_stop_validation_and_custom_detokenizer(pair):
    with pytest.raises(ValueError, match="stop"):
        SamplingParams(stop=("",))
    # a bare string is promoted to a 1-tuple
    assert SamplingParams(stop="x ").stop == ("x ",)
    # a custom detokenizer changes what the stop strings match against
    target, draft = pair
    prompts = _prompts(1, seed=19)
    ref = _stop_ref(target, draft, prompts[0], 8)
    eng = Engine(
        target, draft, EngineConfig(max_batch=1, page_size=8),
        detokenize=lambda t: f"<{t}>",
    )
    outs, _ = eng.run(prompts, SamplingParams(
        max_tokens=8, stop=(f"<{ref[3]}>",),
    ))
    assert [int(t) for t in outs[0]] == ref[:3]


def test_stop_holdback_never_retracts_streamed_tokens():
    """A stop string spanning a ROUND boundary (committed across two
    commit() calls) must not retract tokens already delivered: the
    holdback rule defers at-risk tokens instead (reviewer repro: without
    holdback the sink saw [5, 7] but the final output was [5])."""
    from repro.serving.api import default_detokenize
    from repro.serving.request import Request

    seen = []
    req = Request(
        rid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=16,
        sink=seen.append,
        sampling=SamplingParams(max_tokens=16, stop=("7 9 ",)),
        detokenize=default_detokenize,
    )
    req.commit([5, 7])  # "7 " is a prefix of the stop string: 7 is at risk
    assert seen == [5]
    assert req.take_delta() == [5]
    req.commit([9, 3])  # completes "7 9 " -> stop; 7 was never delivered
    assert req.stop_hit and req.finish_reason == "stop"
    assert [int(t) for t in req.out] == [5]
    assert seen == [5]  # nothing retracted, nothing leaked
    assert req.take_delta() == []
    # and a held token that turns out SAFE flushes late, not never
    seen2 = []
    req2 = Request(
        rid=1, prompt=np.array([1, 2], np.int32), max_new_tokens=16,
        sink=seen2.append,
        sampling=SamplingParams(max_tokens=16, stop=("7 9 ",)),
        detokenize=default_detokenize,
    )
    req2.commit([5, 7])
    assert seen2 == [5]
    req2.commit([8])  # "7 8 " breaks the partial match: 7 becomes safe
    assert seen2 == [5, 7, 8]
    assert req2.take_delta() == [5, 7, 8]


def test_stop_spanning_round_boundary_engine_invariants(pair):
    """End to end with draft_len=1 (1-2 tokens per round) and a 3-token
    stop string: whatever the round split, the concatenated deltas and the
    per-step cumulative token_ids must agree with the final output — no
    retraction through the streaming surface."""
    target, draft = pair
    prompts = _prompts(1, seed=20)
    ref = [int(t) for t in _sd_ref(target, draft, prompts[0], 14, dl=1)]
    stop_s = f"{ref[5]} {ref[6]} {ref[7]} "
    eng = Engine(target, draft, EngineConfig(
        max_batch=1, page_size=8, draft_len=1,
    ))
    eng.add_request(prompts[0], SamplingParams(max_tokens=14, stop=(stop_s,)))
    streamed = []
    while eng.has_unfinished():
        for out in eng.step():
            streamed.extend(out.new_token_ids)
            assert out.token_ids == streamed  # cumulative == deltas so far
    assert streamed == ref[:5]
    assert eng.request(0).finish_reason == "stop"


def test_stop_never_fires_on_overshoot_beyond_budget():
    """A speculative round can commit past max_tokens; those overshoot
    tokens are never delivered, so a stop string completed only by them
    must NOT fire (regression: the scan used to read the overshoot)."""
    from repro.serving.api import default_detokenize
    from repro.serving.request import Request

    req = Request(
        rid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=2,
        sampling=SamplingParams(max_tokens=2, stop=("7 9 ",)),
        detokenize=default_detokenize,
    )
    req.commit([5, 7, 9])  # 9 is overshoot: the user only ever sees "5 7 "
    assert not req.stop_hit
    assert req.finish_reason is None
    assert req.done  # by budget
    assert req.emittable_len() == 2
    req.finish(step=0)
    assert req.finish_reason == "length"
    assert [int(t) for t in req.out] == [5, 7]
