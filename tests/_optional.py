"""Optional-dependency shims for the test suite.

`hypothesis` is a dev-only dependency (requirements-dev.txt).  Test modules
that mix property-based and example-based tests import `given / settings / st`
from here: when hypothesis is absent the property tests skip individually and
the example tests still run (a bare `from hypothesis import ...` used to error
the whole collection).  Modules that are *entirely* property-based should use
``pytest.importorskip("hypothesis")`` instead.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without dev deps
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """`st.<anything>(...)(.map/.filter/...)` placeholder; supports
        arbitrary attribute/call chaining but is never drawn from (skip)."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
