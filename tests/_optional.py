"""Degradation shims for `hypothesis` in a bare runtime environment.

`hypothesis` is a first-class dev dependency — pinned in
requirements-dev.txt and run by `scripts/ci.sh` — not an optional extra.
This module exists for the OTHER environment: a runtime install
(requirements.txt only) where the suite must still collect and the
example-based tests must still run.  Modules that mix property-based and
example-based tests import `given / settings / st` from here: without
hypothesis the property tests skip individually instead of a bare
`from hypothesis import ...` erroring the whole collection.  Modules that
are *entirely* property-based use ``pytest.importorskip("hypothesis")``
instead (tests/test_properties.py).
"""
import pytest

__all__ = ["given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # pragma: no cover - exercised only without dev deps

    class _StrategyStub:
        """`st.<anything>(...)(.map/.filter/...)` placeholder; supports
        arbitrary attribute/call chaining but is never drawn from (skip)."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
