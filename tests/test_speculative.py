"""Speculative decoding + APSD: losslessness, distribution, controller."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import apsd, speculative as sd, toylm


@pytest.fixture(scope="module")
def markov():
    key = jax.random.PRNGKey(0)
    kt, kd = jax.random.split(key)
    tp = toylm.random_transition_logits(kt, 24, sharpness=1.5)
    dp = tp + 1.2 * jax.random.normal(kd, (24, 24))
    return toylm.make_markov_lm(max_len=8192), tp, dp


PROMPT = jnp.array([[3, 5]], dtype=jnp.int32)


@pytest.mark.parametrize("draft_len", [1, 2, 4, 7])
def test_sd_greedy_lossless(markov, draft_len):
    lm, tp, dp = markov
    ref = toylm.markov_greedy_decode(tp, 5, 40)
    toks, stats = sd.sd_generate(
        jax.random.PRNGKey(1), lm, tp, lm, dp, PROMPT,
        sd.SDConfig(draft_len=draft_len, temperature=0.0, max_tokens=40),
    )
    assert bool(jnp.all(toks == ref))
    assert 0.0 <= float(stats.acceptance_rate) <= 1.0


@pytest.mark.parametrize("short_dl,long_dl", [(2, 4), (2, 6), (4, 8), (1, 2)])
def test_apsd_greedy_lossless(markov, short_dl, long_dl):
    lm, tp, dp = markov
    ref = toylm.markov_greedy_decode(tp, 5, 40)
    toks, stats = apsd.apsd_generate(
        jax.random.PRNGKey(2), lm, tp, lm, dp, PROMPT,
        apsd.APSDConfig(short_dl=short_dl, long_dl=long_dl, temperature=0.0, max_tokens=40),
    )
    assert bool(jnp.all(toks == ref)), (short_dl, long_dl)


def test_apsd_uses_parallel_mode_when_draft_good(markov):
    lm, tp, _ = markov
    _, stats = apsd.apsd_generate(
        jax.random.PRNGKey(3), lm, tp, lm, tp, PROMPT,  # perfect draft
        apsd.APSDConfig(short_dl=2, long_dl=6, temperature=0.0, max_tokens=48),
    )
    assert stats.par_rounds >= stats.rounds - 2  # immediately locks into PAR
    assert stats.rejected_ratio < 0.05


def test_apsd_falls_back_when_draft_bad(markov):
    lm, tp, _ = markov
    dp = toylm.random_transition_logits(jax.random.PRNGKey(9), 24, 1.5)  # unrelated
    _, stats = apsd.apsd_generate(
        jax.random.PRNGKey(4), lm, tp, lm, dp, PROMPT,
        apsd.APSDConfig(short_dl=2, long_dl=6, temperature=0.0, max_tokens=32),
    )
    assert stats.par_rounds < stats.rounds * 0.5  # mostly NONPAR


def test_sampled_sd_matches_target_distribution():
    """L=1 window: emitted token must be distributed exactly as p."""
    vs = 8
    kp, kq, ks = jax.random.split(jax.random.PRNGKey(7), 3)
    p = jax.nn.softmax(2.0 * jax.random.normal(kp, (2, vs)))
    q = jax.nn.softmax(2.0 * jax.random.normal(kq, (1, vs)))

    def one(k):
        k1, k2 = jax.random.split(k)
        d = jax.random.categorical(k1, jnp.log(q[0]))
        out, _, _ = sd.speculative_sample(k2, d[None], p, q)
        return out[0]

    n = 20000
    samples = jax.vmap(one)(jax.random.split(ks, n))
    emp = jnp.bincount(samples, length=vs) / n
    tv = 0.5 * float(jnp.abs(emp - p[0]).sum())
    assert tv < 0.02


def test_speculative_sample_accepts_identical_dists():
    vs = 16
    p_row = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (vs,)))
    p = jnp.tile(p_row, (5, 1))
    q = jnp.tile(p_row, (4, 1))
    accs = []
    for i in range(200):
        k1, k2 = jax.random.split(jax.random.PRNGKey(i))
        d = jax.random.categorical(k1, jnp.log(p_row), shape=(4,))
        _, _, n_acc = sd.speculative_sample(k2, d, p, q)
        accs.append(int(n_acc))
    assert np.mean(accs) == 4.0  # p == q -> always accept


def test_policy_transitions():
    P = apsd.APSDPolicy
    assert P.next_mode(apsd.NONPAR, True, True) == apsd.PAR
    assert P.next_mode(apsd.NONPAR, False, True) == apsd.NONPAR
    assert P.next_mode(apsd.PAR, True, True) == apsd.PAR
    assert P.next_mode(apsd.PAR, True, False) == apsd.NONPAR
    assert P.next_mode(apsd.PAR, False, True) == apsd.NONPAR


# ---------------------------------------------------------------------------
# top-p (nucleus) host-side filter — the SamplingParams.top_p satellite
# ---------------------------------------------------------------------------


def test_top_p_filter_keeps_minimal_nucleus():
    """The filter keeps exactly the smallest top-probability set whose mass
    reaches top_p (inclusive), -inf elsewhere, deterministically."""
    logits = np.log(np.array([0.4, 0.3, 0.2, 0.1], np.float32))
    kept = sd._top_p_filter_host(logits, 0.5)  # 0.4 < 0.5 <= 0.4+0.3
    assert np.isfinite(kept[:2]).all() and np.isinf(kept[2:]).all()
    kept = sd._top_p_filter_host(logits, 0.71)  # needs three tokens
    assert np.isfinite(kept[:3]).all() and np.isinf(kept[3:]).all()
    # top_p >= 1 is the identity (object-level: the fast path)
    assert sd._top_p_filter_host(logits, 1.0) is logits
    # the top token always survives, however small top_p is
    kept = sd._top_p_filter_host(logits, 1e-9)
    assert np.isfinite(kept[0]) and np.isinf(kept[1:]).all()


def test_top_p_filter_batched_rows_independent():
    rng = np.random.RandomState(0)
    logits = rng.randn(5, 32).astype(np.float32)
    whole = sd._top_p_filter_host(logits, 0.6)
    for i in range(5):
        row = sd._top_p_filter_host(logits[i], 0.6)
        assert np.array_equal(whole[i], row)


def test_sample_token_host_top_p_one_is_bitwise_unchanged():
    """top_p=1.0 must leave the historical (temperature, top_k) draw
    untouched — the bit-identity contract for every existing request."""
    rng = np.random.RandomState(1)
    logits = rng.randn(64).astype(np.float32)
    for i in range(10):
        key = jax.random.PRNGKey(i)
        a = sd.sample_token_host(key, logits, 0.8, top_k=8)
        b = sd.sample_token_host(key, logits, 0.8, top_k=8, top_p=1.0)
        assert a == b


def test_sample_token_host_tiny_top_p_is_argmax():
    rng = np.random.RandomState(2)
    logits = rng.randn(64).astype(np.float32)
    for i in range(10):
        tok = sd.sample_token_host(
            jax.random.PRNGKey(i), logits, 1.3, top_p=1e-9
        )
        assert tok == int(np.argmax(logits))


def test_speculative_sample_host_top_p_self_draft_accepts_all():
    """q == p with a shared top_p filter: the rejection rule must accept
    every draft (u*q < p for u in [0,1)) — losslessness of the filtered
    pair, mirroring the engine's self-draft acceptance test."""
    rng = np.random.RandomState(3)
    dl, vs = 4, 32
    logits = rng.randn(dl + 1, vs).astype(np.float32)
    for i in range(20):
        key = jax.random.PRNGKey(100 + i)
        drafts = [
            sd.sample_token_host(
                jax.random.fold_in(key, j), logits[j], 0.9, top_p=0.7
            )
            for j in range(dl)
        ]
        _, n_acc = sd.speculative_sample_host(
            jax.random.fold_in(key, 99), np.asarray(drafts),
            logits, logits[:dl], dl, 0.9, top_p=0.7,
        )
        assert n_acc == dl


def test_speculative_sample_host_top_p_residual_stays_in_nucleus():
    """Every emitted token (accepted or residual) must come from the
    TARGET's nucleus — tokens outside the top_p set have p' == 0 and can
    never be accepted nor sampled from the residual."""
    rng = np.random.RandomState(4)
    dl, vs, top_p = 3, 16, 0.6
    p_logits = rng.randn(dl + 1, vs).astype(np.float32)
    q_logits = rng.randn(dl, vs).astype(np.float32)
    temp = 1.1
    nucleus = [
        set(np.nonzero(np.isfinite(
            sd._top_p_filter_host(p_logits[j] / temp, top_p)
        ))[0].tolist())
        for j in range(dl + 1)
    ]
    for i in range(50):
        key = jax.random.PRNGKey(200 + i)
        drafts = [
            sd.sample_token_host(
                jax.random.fold_in(key, j), q_logits[j], temp, top_p=top_p
            )
            for j in range(dl)
        ]
        out, n_acc = sd.speculative_sample_host(
            jax.random.fold_in(key, 99), np.asarray(drafts),
            p_logits, q_logits, dl, temp, top_p=top_p,
        )
        for j, tok in enumerate(out):
            assert tok in nucleus[j], (i, j, tok)


# ---------------------------------------------------------------------------
# Tree speculation: topology helpers + lossless multi-branch verification
# ---------------------------------------------------------------------------


def test_tree_children_and_depths():
    parents = [-1, -1, 0, 0, 1, 3]
    kids = sd.tree_children(parents)
    assert kids[0] == [0, 1]  # the root's (last_tok's) children
    assert kids[1] == [2, 3]  # node 0 sits at window slot 1
    assert kids[2] == [4]
    assert kids[4] == [5]
    d = sd.tree_depths(parents, 8)
    assert d.tolist() == [0, 1, 1, 2, 2, 2, 3, 0]  # pad slot repeats depth 0


def test_tree_ancestor_mask_topology():
    # root -> node0 -> {node1, node2}; node1 -> node3
    parents = [-1, 0, 0, 1]
    m = sd.tree_ancestor_mask(parents, 6)
    want = np.eye(6, dtype=np.float32)
    want[1, 0] = 1.0                      # node 0 sees the root
    want[2, [0, 1]] = 1.0                 # node 1 sees root + node 0
    want[3, [0, 1]] = 1.0                 # node 2 sees root + node 0
    want[4, [0, 1, 2]] = 1.0              # node 3 sees root, node 0, node 1
    # node 3 must NOT see its parent's sibling (slot 3), pad row only itself
    np.testing.assert_array_equal(m, want)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tree_greedy_fanout1_equals_chain(seed):
    """A chain-shaped tree (every node fan-out 1) must reproduce the chain
    greedy verify decision-for-decision: same committed tokens, same n_acc,
    and the accepted path is the leftmost prefix."""
    rng = np.random.RandomState(seed)
    vs, dl = 16, 4
    p = rng.randn(dl + 1, vs).astype(np.float32)
    drafts = [int(t) for t in rng.randint(0, vs, size=dl)]
    for i in range(min(seed + 1, dl)):  # force a nontrivial accepted prefix
        drafts[i] = int(np.argmax(p[i]))
    chain, n_chain = sd.speculative_accept_greedy_host(drafts, p, dl)
    parents = [i - 1 for i in range(dl)]
    committed, path, n_acc = sd.speculative_tree_accept_greedy_host(
        drafts, parents, p
    )
    assert committed == chain
    assert n_acc == n_chain
    assert path == list(range(n_acc))


@pytest.mark.parametrize("seed", [4, 5, 6, 7])
def test_tree_greedy_commits_argmax_walk(seed):
    """Every token greedy tree verify emits IS the target argmax at its
    position, for arbitrary topologies — the invariant that makes greedy
    tree and greedy chain produce the identical token stream."""
    rng = np.random.RandomState(seed)
    vs, n = 12, 7
    parents = [int(rng.randint(-1, i)) for i in range(n)]
    p = rng.randn(n + 1, vs).astype(np.float32)
    nodes = []
    for i in range(n):
        slot = 0 if parents[i] < 0 else 1 + parents[i]
        if rng.rand() < 0.5:  # half the nodes guess their parent's argmax
            nodes.append(int(np.argmax(p[slot])))
        else:
            nodes.append(int(rng.randint(0, vs)))
    committed, path, n_acc = sd.speculative_tree_accept_greedy_host(
        nodes, parents, p
    )
    assert n_acc == len(path) == len(committed) - 1
    slot = 0
    for j, tok in enumerate(committed):
        assert tok == int(np.argmax(p[slot])), (j, slot)
        if j < len(path):
            assert nodes[path[j]] == tok
            assert (parents[path[j]] < 0 and slot == 0) or (
                slot == 1 + parents[path[j]]
            )
            slot = 1 + path[j]


def test_tree_sample_self_draft_accepts_every_level():
    """q == p: the first candidate at every position passes the u*q < r test
    with probability 1, so a chain tree accepts its full depth (the tree
    analogue of self-draft chain SD accepting everything)."""
    rng = np.random.RandomState(7)
    vs, n = 10, 5
    logits = rng.randn(n + 1, vs).astype(np.float32)
    parents = [i - 1 for i in range(n)]
    nodes = [int(np.argmax(logits[i])) for i in range(n)]
    committed, path, n_acc = sd.speculative_tree_sample_host(
        jax.random.PRNGKey(0), nodes, parents, logits, logits, temperature=1.0
    )
    assert n_acc == n
    assert path == list(range(n))
    assert committed[:n] == nodes


def test_tree_sample_deterministic_in_key():
    rng = np.random.RandomState(8)
    vs, n = 12, 6
    parents = [int(rng.randint(-1, i)) for i in range(n)]
    nodes = [int(t) for t in rng.randint(0, vs, size=n)]
    p = rng.randn(n + 1, vs).astype(np.float32)
    q = rng.randn(n + 1, vs).astype(np.float32)
    a = sd.speculative_tree_sample_host(
        jax.random.PRNGKey(3), nodes, parents, p, q, 0.9, top_k=6
    )
    b = sd.speculative_tree_sample_host(
        jax.random.PRNGKey(3), nodes, parents, p, q, 0.9, top_k=6
    )
    assert a == b


def test_tree_sample_emits_only_nucleus_tokens():
    """Accepted and residual tokens must all lie in the target's filtered
    support — outside tokens have p' == 0 at every walk position."""
    rng = np.random.RandomState(9)
    vs, n, temp, top_p = 16, 5, 1.1, 0.6
    parents = [-1, -1, 0, 1, 2]
    p = rng.randn(n + 1, vs).astype(np.float32)
    q = rng.randn(n + 1, vs).astype(np.float32)
    nucleus = [
        set(np.nonzero(np.isfinite(
            sd._top_p_filter_host(p[j] / temp, top_p)
        ))[0].tolist())
        for j in range(n + 1)
    ]
    for i in range(40):
        nodes = [
            sd.sample_token_host(
                jax.random.fold_in(jax.random.PRNGKey(300 + i), j),
                q[0 if parents[j] < 0 else 1 + parents[j]], temp, top_p=top_p,
            )
            for j in range(n)
        ]
        committed, path, _ = sd.speculative_tree_sample_host(
            jax.random.PRNGKey(400 + i), nodes, parents, p, q, temp,
            top_p=top_p,
        )
        slot = 0
        for j, tok in enumerate(committed):
            assert tok in nucleus[slot], (i, j, tok)
            if j < len(path):
                slot = 1 + path[j]


# ---------------------------------------------------------------------------
# Distribution exactness: chain and tree SD == direct target sampling
# ---------------------------------------------------------------------------
#
# Monte-Carlo harness over a tiny Markov target/draft pair: generate the
# first TWO tokens many times through the speculative samplers (drafts drawn
# i.i.d. from the draft transition row, exactly as the engine drafts) and
# compare the empirical joint against the analytic target joint with both a
# TV-distance gate and a chi-squared gate.  The draft model is deliberately
# far from the target (the power check asserts it), so a biased rejection
# rule — e.g. forgetting the residual renormalization, or reusing a key —
# shifts the joint well past the thresholds.


def _softmax_np(x):
    e = np.exp(x - x.max(axis=-1, keepdims=True))
    return e / e.sum(axis=-1, keepdims=True)


def _joint2_ref(trans, last):
    """Analytic 2-token joint: P(a, b) = softmax(T[last])[a]*softmax(T[a])[b]."""
    p0 = _softmax_np(trans[last])
    return p0[:, None] * _softmax_np(trans)


def _chain_two_tokens(key, rng, trans_t, trans_d, last, dl=2):
    """First two committed tokens through chain SD rounds (drafts i.i.d.
    from the draft chain, verification via speculative_sample_host)."""
    vs = trans_t.shape[0]
    out, cur, r = [], last, 0
    while len(out) < 2:
        drafts, q_rows, p_rows, c = [], [], [trans_t[cur]], cur
        for _ in range(dl):
            t = int(rng.choice(vs, p=_softmax_np(trans_d[c])))
            drafts.append(t)
            q_rows.append(trans_d[c])
            p_rows.append(trans_t[t])
            c = t
        committed, _ = sd.speculative_sample_host(
            jax.random.fold_in(key, r), drafts, np.stack(p_rows),
            np.stack(q_rows), dl, temperature=1.0,
        )
        out.extend(committed)
        cur = out[-1]
        r += 1
    return out[0], out[1]


def _tree_two_tokens(key, rng, trans_t, trans_d, last, depth=2, branches=2):
    """First two committed tokens through tree SD rounds: `branches` root
    children (i.i.d. WITH replacement from the draft row — what keeps the
    walk exact), one grandchild each."""
    vs = trans_t.shape[0]
    out, cur, r = [], last, 0
    while len(out) < 2:
        nodes, parents = [], []

        def tok_at(slot):
            return cur if slot == 0 else nodes[slot - 1]

        frontier = [-1]
        for d in range(depth):
            nxt = []
            for par in frontier:
                ctx = tok_at(0 if par < 0 else 1 + par)
                qp = _softmax_np(trans_d[ctx])
                for _ in range(branches if d == 0 else 1):
                    nodes.append(int(rng.choice(vs, p=qp)))
                    parents.append(par)
                    nxt.append(len(nodes) - 1)
            frontier = nxt
        w = len(nodes) + 1
        p_rows = np.stack([trans_t[tok_at(s)] for s in range(w)])
        q_rows = np.stack([trans_d[tok_at(s)] for s in range(w)])
        committed, _, _ = sd.speculative_tree_sample_host(
            jax.random.fold_in(key, r), nodes, parents, p_rows, q_rows,
            temperature=1.0,
        )
        out.extend(committed)
        cur = out[-1]
        r += 1
    return out[0], out[1]


def _assert_joint_matches(counts, want, n_trials):
    emp = counts / n_trials
    tv = 0.5 * float(np.abs(emp - want).sum())
    assert tv < 0.11, f"TV {tv:.4f} vs target joint"
    # chi-squared over well-populated cells, sparse cells pooled; the bound
    # is mean + 4 sigma of the chi2(dof) null (~3e-5 false-positive rate)
    exp = want.ravel() * n_trials
    obs = counts.ravel()
    big = exp >= 5.0
    chi2 = float((((obs[big] - exp[big]) ** 2) / exp[big]).sum())
    if bool((~big).any()):
        o, e = float(obs[~big].sum()), float(exp[~big].sum())
        chi2 += (o - e) ** 2 / max(e, 1e-9)
        dof = int(big.sum())  # pooled cell adds 1, sum constraint removes 1
    else:
        dof = int(big.sum()) - 1
    assert chi2 < dof + 4.0 * np.sqrt(2.0 * dof), (chi2, dof)


@pytest.fixture(scope="module")
def exactness_pair():
    rng = np.random.RandomState(0)
    vs = 6
    trans_t = (1.2 * rng.randn(vs, vs)).astype(np.float32)
    trans_d = (1.2 * rng.randn(vs, vs)).astype(np.float32)
    want = _joint2_ref(trans_t, 0)
    # power check: naively emitting DRAFT samples would fail the TV gate by
    # a wide margin, so the gate really does constrain the rejection rule
    tv_draft = 0.5 * float(np.abs(want - _joint2_ref(trans_d, 0)).sum())
    assert tv_draft > 0.3, tv_draft
    return trans_t, trans_d, want


def test_chain_sd_two_token_joint_matches_target(exactness_pair):
    trans_t, trans_d, want = exactness_pair
    n_trials = 1500
    rng = np.random.RandomState(1)
    key = jax.random.PRNGKey(11)
    counts = np.zeros_like(want)
    for i in range(n_trials):
        a, b = _chain_two_tokens(
            jax.random.fold_in(key, i), rng, trans_t, trans_d, 0
        )
        counts[a, b] += 1.0
    _assert_joint_matches(counts, want, n_trials)


def test_tree_sd_two_token_joint_matches_target(exactness_pair):
    trans_t, trans_d, want = exactness_pair
    n_trials = 1500
    rng = np.random.RandomState(2)
    key = jax.random.PRNGKey(13)
    counts = np.zeros_like(want)
    for i in range(n_trials):
        a, b = _tree_two_tokens(
            jax.random.fold_in(key, i), rng, trans_t, trans_d, 0
        )
        counts[a, b] += 1.0
    _assert_joint_matches(counts, want, n_trials)


def test_tree_sample_first_token_marginal_under_filters():
    """Single-round marginal with temperature + top-k active: the first
    committed token follows the FILTERED target softmax exactly, whatever
    the deeper tree looks like (3 root siblings + grandchildren here)."""
    vs, temp, top_k = 8, 0.8, 4
    rng0 = np.random.RandomState(3)
    p_rows = rng0.randn(7, vs).astype(np.float32)
    q_rows = rng0.randn(7, vs).astype(np.float32)
    parents = [-1, -1, -1, 0, 1, 2]

    def filtered(row):
        return sd._softmax_host(
            sd._top_k_filter_host(row[None], top_k) / temp
        )[0]

    want = filtered(p_rows[0])
    n_trials = 1500
    rng = np.random.RandomState(4)
    key = jax.random.PRNGKey(17)
    counts = np.zeros(vs)
    for i in range(n_trials):
        nodes = []
        for j, par in enumerate(parents):
            qf = filtered(q_rows[0 if par < 0 else 1 + par])
            nodes.append(int(rng.choice(vs, p=qf)))
        committed, _, _ = sd.speculative_tree_sample_host(
            jax.random.fold_in(key, i), nodes, parents, p_rows, q_rows,
            temp, top_k=top_k,
        )
        counts[committed[0]] += 1.0
    tv = 0.5 * float(np.abs(counts / n_trials - want).sum())
    assert tv < 0.05, tv
