"""Speculative decoding + APSD: losslessness, distribution, controller."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import apsd, speculative as sd, toylm


@pytest.fixture(scope="module")
def markov():
    key = jax.random.PRNGKey(0)
    kt, kd = jax.random.split(key)
    tp = toylm.random_transition_logits(kt, 24, sharpness=1.5)
    dp = tp + 1.2 * jax.random.normal(kd, (24, 24))
    return toylm.make_markov_lm(max_len=8192), tp, dp


PROMPT = jnp.array([[3, 5]], dtype=jnp.int32)


@pytest.mark.parametrize("draft_len", [1, 2, 4, 7])
def test_sd_greedy_lossless(markov, draft_len):
    lm, tp, dp = markov
    ref = toylm.markov_greedy_decode(tp, 5, 40)
    toks, stats = sd.sd_generate(
        jax.random.PRNGKey(1), lm, tp, lm, dp, PROMPT,
        sd.SDConfig(draft_len=draft_len, temperature=0.0, max_tokens=40),
    )
    assert bool(jnp.all(toks == ref))
    assert 0.0 <= float(stats.acceptance_rate) <= 1.0


@pytest.mark.parametrize("short_dl,long_dl", [(2, 4), (2, 6), (4, 8), (1, 2)])
def test_apsd_greedy_lossless(markov, short_dl, long_dl):
    lm, tp, dp = markov
    ref = toylm.markov_greedy_decode(tp, 5, 40)
    toks, stats = apsd.apsd_generate(
        jax.random.PRNGKey(2), lm, tp, lm, dp, PROMPT,
        apsd.APSDConfig(short_dl=short_dl, long_dl=long_dl, temperature=0.0, max_tokens=40),
    )
    assert bool(jnp.all(toks == ref)), (short_dl, long_dl)


def test_apsd_uses_parallel_mode_when_draft_good(markov):
    lm, tp, _ = markov
    _, stats = apsd.apsd_generate(
        jax.random.PRNGKey(3), lm, tp, lm, tp, PROMPT,  # perfect draft
        apsd.APSDConfig(short_dl=2, long_dl=6, temperature=0.0, max_tokens=48),
    )
    assert stats.par_rounds >= stats.rounds - 2  # immediately locks into PAR
    assert stats.rejected_ratio < 0.05


def test_apsd_falls_back_when_draft_bad(markov):
    lm, tp, _ = markov
    dp = toylm.random_transition_logits(jax.random.PRNGKey(9), 24, 1.5)  # unrelated
    _, stats = apsd.apsd_generate(
        jax.random.PRNGKey(4), lm, tp, lm, dp, PROMPT,
        apsd.APSDConfig(short_dl=2, long_dl=6, temperature=0.0, max_tokens=32),
    )
    assert stats.par_rounds < stats.rounds * 0.5  # mostly NONPAR


def test_sampled_sd_matches_target_distribution():
    """L=1 window: emitted token must be distributed exactly as p."""
    vs = 8
    kp, kq, ks = jax.random.split(jax.random.PRNGKey(7), 3)
    p = jax.nn.softmax(2.0 * jax.random.normal(kp, (2, vs)))
    q = jax.nn.softmax(2.0 * jax.random.normal(kq, (1, vs)))

    def one(k):
        k1, k2 = jax.random.split(k)
        d = jax.random.categorical(k1, jnp.log(q[0]))
        out, _, _ = sd.speculative_sample(k2, d[None], p, q)
        return out[0]

    n = 20000
    samples = jax.vmap(one)(jax.random.split(ks, n))
    emp = jnp.bincount(samples, length=vs) / n
    tv = 0.5 * float(jnp.abs(emp - p[0]).sum())
    assert tv < 0.02


def test_speculative_sample_accepts_identical_dists():
    vs = 16
    p_row = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (vs,)))
    p = jnp.tile(p_row, (5, 1))
    q = jnp.tile(p_row, (4, 1))
    accs = []
    for i in range(200):
        k1, k2 = jax.random.split(jax.random.PRNGKey(i))
        d = jax.random.categorical(k1, jnp.log(p_row), shape=(4,))
        _, _, n_acc = sd.speculative_sample(k2, d, p, q)
        accs.append(int(n_acc))
    assert np.mean(accs) == 4.0  # p == q -> always accept


def test_policy_transitions():
    P = apsd.APSDPolicy
    assert P.next_mode(apsd.NONPAR, True, True) == apsd.PAR
    assert P.next_mode(apsd.NONPAR, False, True) == apsd.NONPAR
    assert P.next_mode(apsd.PAR, True, True) == apsd.PAR
    assert P.next_mode(apsd.PAR, True, False) == apsd.NONPAR
    assert P.next_mode(apsd.PAR, False, True) == apsd.NONPAR


# ---------------------------------------------------------------------------
# top-p (nucleus) host-side filter — the SamplingParams.top_p satellite
# ---------------------------------------------------------------------------


def test_top_p_filter_keeps_minimal_nucleus():
    """The filter keeps exactly the smallest top-probability set whose mass
    reaches top_p (inclusive), -inf elsewhere, deterministically."""
    logits = np.log(np.array([0.4, 0.3, 0.2, 0.1], np.float32))
    kept = sd._top_p_filter_host(logits, 0.5)  # 0.4 < 0.5 <= 0.4+0.3
    assert np.isfinite(kept[:2]).all() and np.isinf(kept[2:]).all()
    kept = sd._top_p_filter_host(logits, 0.71)  # needs three tokens
    assert np.isfinite(kept[:3]).all() and np.isinf(kept[3:]).all()
    # top_p >= 1 is the identity (object-level: the fast path)
    assert sd._top_p_filter_host(logits, 1.0) is logits
    # the top token always survives, however small top_p is
    kept = sd._top_p_filter_host(logits, 1e-9)
    assert np.isfinite(kept[0]) and np.isinf(kept[1:]).all()


def test_top_p_filter_batched_rows_independent():
    rng = np.random.RandomState(0)
    logits = rng.randn(5, 32).astype(np.float32)
    whole = sd._top_p_filter_host(logits, 0.6)
    for i in range(5):
        row = sd._top_p_filter_host(logits[i], 0.6)
        assert np.array_equal(whole[i], row)


def test_sample_token_host_top_p_one_is_bitwise_unchanged():
    """top_p=1.0 must leave the historical (temperature, top_k) draw
    untouched — the bit-identity contract for every existing request."""
    rng = np.random.RandomState(1)
    logits = rng.randn(64).astype(np.float32)
    for i in range(10):
        key = jax.random.PRNGKey(i)
        a = sd.sample_token_host(key, logits, 0.8, top_k=8)
        b = sd.sample_token_host(key, logits, 0.8, top_k=8, top_p=1.0)
        assert a == b


def test_sample_token_host_tiny_top_p_is_argmax():
    rng = np.random.RandomState(2)
    logits = rng.randn(64).astype(np.float32)
    for i in range(10):
        tok = sd.sample_token_host(
            jax.random.PRNGKey(i), logits, 1.3, top_p=1e-9
        )
        assert tok == int(np.argmax(logits))


def test_speculative_sample_host_top_p_self_draft_accepts_all():
    """q == p with a shared top_p filter: the rejection rule must accept
    every draft (u*q < p for u in [0,1)) — losslessness of the filtered
    pair, mirroring the engine's self-draft acceptance test."""
    rng = np.random.RandomState(3)
    dl, vs = 4, 32
    logits = rng.randn(dl + 1, vs).astype(np.float32)
    for i in range(20):
        key = jax.random.PRNGKey(100 + i)
        drafts = [
            sd.sample_token_host(
                jax.random.fold_in(key, j), logits[j], 0.9, top_p=0.7
            )
            for j in range(dl)
        ]
        _, n_acc = sd.speculative_sample_host(
            jax.random.fold_in(key, 99), np.asarray(drafts),
            logits, logits[:dl], dl, 0.9, top_p=0.7,
        )
        assert n_acc == dl


def test_speculative_sample_host_top_p_residual_stays_in_nucleus():
    """Every emitted token (accepted or residual) must come from the
    TARGET's nucleus — tokens outside the top_p set have p' == 0 and can
    never be accepted nor sampled from the residual."""
    rng = np.random.RandomState(4)
    dl, vs, top_p = 3, 16, 0.6
    p_logits = rng.randn(dl + 1, vs).astype(np.float32)
    q_logits = rng.randn(dl, vs).astype(np.float32)
    temp = 1.1
    nucleus = [
        set(np.nonzero(np.isfinite(
            sd._top_p_filter_host(p_logits[j] / temp, top_p)
        ))[0].tolist())
        for j in range(dl + 1)
    ]
    for i in range(50):
        key = jax.random.PRNGKey(200 + i)
        drafts = [
            sd.sample_token_host(
                jax.random.fold_in(key, j), q_logits[j], temp, top_p=top_p
            )
            for j in range(dl)
        ]
        out, n_acc = sd.speculative_sample_host(
            jax.random.fold_in(key, 99), np.asarray(drafts),
            p_logits, q_logits, dl, temp, top_p=top_p,
        )
        for j, tok in enumerate(out):
            assert tok in nucleus[j], (i, j, tok)
