"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bvq, quantization as q, rotation as rot
from repro.core.speculative import speculative_accept_greedy, speculative_sample


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=64, max_value=8192))
def test_rotation_plan_exists_and_bounded(n):
    """Every even dim gets a plan with depth <= 6 and a constructible m."""
    n = n * 2  # even dims (all real channel dims are)
    p = rot.plan_rotation(n)
    assert p.k <= rot.MAX_DEPTH
    assert p.block <= n
    from repro.core.hadamard import hadamard_matrix

    hadamard_matrix(p.m)  # must construct


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(min_value=1, max_value=8),
    v=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_speculative_sample_invariants(l, v, seed):
    """Output = accepted draft prefix + exactly one sampled token; padding -1."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    p = jax.nn.softmax(jax.random.normal(k1, (l + 1, v)))
    qd = jax.nn.softmax(jax.random.normal(k2, (l, v)))
    draft = jax.random.categorical(k3, jnp.log(qd))
    out, n_out, n_acc = speculative_sample(key, draft, p, qd)
    n_out, n_acc = int(n_out), int(n_acc)
    assert 0 <= n_acc <= l and n_out == n_acc + 1
    assert np.array_equal(np.asarray(out[:n_acc]), np.asarray(draft[:n_acc]))
    assert 0 <= int(out[n_acc]) < v
    assert all(int(t) == -1 for t in out[n_out:])


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(min_value=1, max_value=8),
    v=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_greedy_accept_invariants(l, v, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (l + 1, v))
    draft = jax.random.randint(k2, (l,), 0, v)
    out, n_out, n_acc = speculative_accept_greedy(draft, logits)
    n_out, n_acc = int(n_out), int(n_acc)
    tlm = np.asarray(jnp.argmax(logits, -1))
    # accepted prefix must equal the target's greedy choices
    for i in range(n_acc):
        assert int(draft[i]) == tlm[i]
    # first rejection (if any) must disagree
    if n_acc < l:
        assert int(draft[n_acc]) != tlm[n_acc]
    assert int(out[n_acc]) == tlm[n_acc]


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(min_value=2, max_value=8),
    cols=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=100),
)
def test_bvq_indices_always_valid(rows, cols, seed):
    rng = np.random.RandomState(seed)
    k, n, v, c, bc = rows * 8, cols * 16, 4, 8, 16
    cfg = bvq.BVQConfig(vec_dim=v, codebook_size=c, block_cols=bc,
                        kmeans_iters=3, qat_steps=0)
    w = jnp.asarray(rng.randn(k, n).astype(np.float32))
    bw = bvq.bvq_compress(w, cfg, jax.random.PRNGKey(seed))
    assert int(jnp.min(bw.indices)) >= 0
    assert int(jnp.max(bw.indices)) < c
    assert bvq.bvq_reconstruct(bw).shape == (k, n)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=128),
    seed=st.integers(min_value=0, max_value=100),
)
def test_act_quant_error_bounded(n, seed):
    """|x - deq(q(x))| <= scale/2 per element (round-to-nearest)."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(4, n).astype(np.float32) * rng.rand() * 10)
    xq, s = q.quantize_act_int8(x)
    err = jnp.abs(xq.astype(jnp.float32) * s - x)
    assert bool(jnp.all(err <= s * 0.5 + 1e-6))
