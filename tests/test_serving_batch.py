"""Continuous-batching runtime: multi-request correctness + scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.speculative import SDConfig
from repro.launch.serve import build_pair, greedy_reference
from repro.serving.engine import BatchConfig, make_interface, serve_batch, serve_sd
from repro.serving.request import Request, RequestState


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, vocab, size=rng.randint(2, 7)).astype(np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


@pytest.fixture(scope="module")
def qpair():
    return build_pair(seed=0, s_max=128, quantize=True)


# ---------------------------------------------------------------------------
# Acceptance criterion: >= 8 concurrent requests, bit-identical to serve_sd
# ---------------------------------------------------------------------------


def _assert_batch_matches_sequential(target, draft, n_req, max_tokens, **cfg_kw):
    prompts = _prompts(n_req)
    cfg = BatchConfig(
        max_batch=n_req, page_size=8, max_tokens=max_tokens, draft_len=3, **cfg_kw
    )
    outs, summary = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
    for i, p in enumerate(prompts):
        ref, _ = serve_sd(
            jax.random.PRNGKey(0), target, draft, jnp.asarray(p[None]),
            SDConfig(draft_len=3, temperature=0.0, max_tokens=max_tokens),
        )
        assert outs[i].shape == ref.shape
        assert bool(jnp.all(outs[i] == ref)), f"request {i} diverged"
    return summary


def test_batch8_bit_identical_to_serve_sd(pair):
    target, draft = pair
    summary = _assert_batch_matches_sequential(target, draft, 8, 12)
    assert summary["requests"] == 8
    assert summary["emitted"] == 8 * 12
    assert summary["target_pool"].used_pages == 0  # everything released
    assert summary["draft_pool"].used_pages == 0
    assert summary["wdos_modeled_speedup"] > 1.0  # cross-request overlap


def test_batch_bit_identical_quantized_pair(qpair):
    """W4A8 target + BVQ draft (the paper pair) through the paged runtime."""
    target, draft = qpair
    _assert_batch_matches_sequential(target, draft, 4, 8)


def test_page_budget_queues_requests(pair):
    """A pool too small for all requests must queue (continuous batching),
    not fail — and still produce identical outputs."""
    target, draft = pair
    prompts = _prompts(6, seed=3)
    # budget: pages for ~2 concurrent worst-case requests of this size
    need = -(-(max(len(p) for p in prompts) + 8 + 3) // 8)
    cfg = BatchConfig(
        max_batch=6, page_size=8, max_tokens=8, draft_len=3,
        num_pages=2 * need,
    )
    outs, summary = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
    for i, p in enumerate(prompts):
        ref, _ = serve_sd(
            jax.random.PRNGKey(0), target, draft, jnp.asarray(p[None]),
            SDConfig(draft_len=3, temperature=0.0, max_tokens=8),
        )
        assert bool(jnp.all(outs[i] == ref))
    assert summary["target_pool"].high_water_pages <= 2 * need
    assert summary["steps"] > summary["rounds"] / max(summary["requests"], 1)


def test_s_max_not_page_multiple(pair):
    """Regression: requests whose pages overhang an s_max that is not a
    multiple of page_size must still decode (and stay bit-identical)."""
    import dataclasses

    target, draft = pair
    t2 = dataclasses.replace(target, s_max=46)
    d2 = dataclasses.replace(draft, s_max=46)
    prompts = _prompts(2, seed=11)
    cfg = BatchConfig(max_batch=2, page_size=16, max_tokens=36, draft_len=3)
    outs, _ = serve_batch(jax.random.PRNGKey(0), t2, d2, prompts, cfg)
    for i, p in enumerate(prompts):
        ref, _ = serve_sd(
            jax.random.PRNGKey(0), t2, d2, jnp.asarray(p[None]),
            SDConfig(draft_len=3, temperature=0.0, max_tokens=36),
        )
        assert bool(jnp.all(outs[i] == ref))


def test_adaptive_draft_lossless(pair):
    """Per-request APSD draft-length adaptation never changes greedy output
    (only scheduling): outputs equal the plain AD reference."""
    target, draft = pair
    prompts = _prompts(4, seed=5)
    cfg = BatchConfig(
        max_batch=4, page_size=8, max_tokens=10, adaptive=True,
        short_dl=2, long_dl=4,
    )
    outs, _ = serve_batch(jax.random.PRNGKey(0), target, draft, prompts, cfg)
    for i, p in enumerate(prompts):
        ref = greedy_reference(target, jnp.asarray(p[None]), 10)
        assert bool(jnp.all(outs[i] == ref))


def test_streaming_sinks_receive_tokens(pair):
    target, draft = pair
    prompts = _prompts(3, seed=7)
    got = [[] for _ in prompts]
    sinks = [got[i].append for i in range(len(prompts))]
    cfg = BatchConfig(max_batch=2, page_size=8, max_tokens=6, draft_len=2)
    outs, _ = serve_batch(
        jax.random.PRNGKey(0), target, draft, prompts, cfg, sinks=sinks
    )
    for i in range(len(prompts)):
        assert got[i] == [int(t) for t in outs[i]]


def test_temperature_unsupported(pair):
    target, draft = pair
    with pytest.raises(NotImplementedError):
        serve_batch(
            jax.random.PRNGKey(0), target, draft, _prompts(1),
            BatchConfig(temperature=0.7),
        )


# ---------------------------------------------------------------------------
# Request lifecycle
# ---------------------------------------------------------------------------


def test_request_lifecycle_and_trim():
    r = Request(rid=0, prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4)
    assert r.state is RequestState.QUEUED and r.last_tok == 3
    r.commit([10, 11, 12])
    assert not r.done and r.committed_len == 6
    r.commit([13, 14])  # overshoot round
    assert r.done and r.last_tok == 14
    r.finish(step=9)
    assert r.out == [10, 11, 12, 13]  # trimmed to the budget
    assert r.state is RequestState.FINISHED and r.finished_step == 9


def test_request_rejects_short_prompt():
    with pytest.raises(ValueError):
        Request(rid=0, prompt=np.array([1], np.int32), max_new_tokens=4)


# ---------------------------------------------------------------------------
# Satellite: engine rewind guard
# ---------------------------------------------------------------------------


def test_interface_rewind_guard(pair):
    target, _ = pair
    iface = make_interface(target)
    _, cache = iface.prefill(target.params, jnp.asarray([[5, 17, 3]], jnp.int32))
    assert int(cache["length"]) == 3
    c2 = iface.rewind(cache, 2)
    assert int(c2["length"]) == 1
    with pytest.raises(ValueError, match="over-rewind"):
        iface.rewind(cache, 4)
    with pytest.raises(ValueError):
        iface.rewind(cache, -1)
