"""Fused cross-request PAR execution (EngineConfig.par_mode="wdos").

The contract under test: switching the engine from two-phase rounds
(draft-all-then-verify-all, par_mode="off") to WDOS-planned fused rounds
changes ONLY the grouping of work into dispatches — greedy and sampled
tokens are bit-identical across the modes and to the single-request
reference — while a staggered-admission workload with heterogeneous draft
windows drains in strictly fewer engine rounds (the schedule-quality win
the paper's out-of-order scheduler exists for).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import MixedSlotPlan, RowPhase, plan_mixed_slot
from repro.core.speculative import SDConfig, sd_generate
from repro.launch.serve import build_pair
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.engine import make_interface


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, vocab, size=rng.randint(2, 7)).astype(np.int32)
        for _ in range(n)
    ]


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


@pytest.fixture(scope="module")
def qpair():
    """The paper pair: W4A8 target + BVQ draft."""
    return build_pair(seed=0, s_max=128, quantize=True)


def _drain(target, draft, prompts, sps, par_mode, **cfg_kw):
    eng = Engine(target, draft, EngineConfig(
        max_batch=len(prompts), page_size=8, par_mode=par_mode, **cfg_kw
    ))
    outs, summary = eng.run(prompts, sps)
    return outs, summary


# ---------------------------------------------------------------------------
# Bit-identity across modes (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_fused_greedy_bit_identical_bf16(pair):
    target, draft = pair
    prompts = _prompts(4, seed=1)
    sp = SamplingParams(max_tokens=12)
    off, _ = _drain(target, draft, prompts, sp, "off", draft_len=3)
    wdos, _ = _drain(target, draft, prompts, sp, "wdos", draft_len=3)
    for i, (a, b) in enumerate(zip(off, wdos)):
        assert bool(jnp.all(a == b)), f"request {i} diverged across modes"
    # and both match the pre-batching single-request reference
    for i, p in enumerate(prompts):
        ref, _ = sd_generate(
            jax.random.PRNGKey(0),
            make_interface(target), target.params,
            make_interface(draft), draft.params,
            jnp.asarray(np.asarray(p)[None]),
            SDConfig(draft_len=3, temperature=0.0, max_tokens=12),
        )
        assert bool(jnp.all(wdos[i] == ref)), f"request {i} vs sd_generate"


def test_fused_parity_quantized_mixed_sampling(qpair):
    """W4A8 target + BVQ draft, greedy and sampled rows mixed in one batch:
    fused rounds must reproduce the two-phase tokens bit for bit (sampled
    determinism rides on the per-request key streams, whose (round,
    position) indices the fused scheduler preserves)."""
    target, draft = qpair
    prompts = _prompts(3, seed=2)
    sps = [
        SamplingParams(max_tokens=10),  # greedy
        SamplingParams(temperature=0.8, seed=11, max_tokens=10),
        SamplingParams(temperature=1.1, top_k=12, seed=5, max_tokens=10),
    ]
    off, _ = _drain(target, draft, prompts, sps, "off", draft_len=3)
    wdos, _ = _drain(target, draft, prompts, sps, "wdos", draft_len=3)
    for i, (a, b) in enumerate(zip(off, wdos)):
        assert bool(jnp.all(a == b)), f"request {i} diverged across modes"


def test_fused_parity_adaptive_controllers(pair):
    """Per-request APSD controllers must walk the same mode sequence under
    fused scheduling (observe() fires once per committed window either
    way), so adaptive batches stay bit-identical too."""
    target, draft = pair
    prompts = _prompts(4, seed=3)
    sp = SamplingParams(max_tokens=14)
    kw = dict(adaptive=True, short_dl=2, long_dl=4)
    off, s_off = _drain(target, draft, prompts, sp, "off", **kw)
    wdos, s_wd = _drain(target, draft, prompts, sp, "wdos", **kw)
    for a, b in zip(off, wdos):
        assert bool(jnp.all(a == b))
    assert s_off["acceptance_rate"] == s_wd["acceptance_rate"]


def test_fused_parity_pallas_impl(pair):
    """The fused dispatch drives the paged Pallas kernel (fixed-width
    causally-padded verify window + role masks) to the same tokens."""
    target, draft = pair
    prompts = _prompts(2, seed=4)
    sp = SamplingParams(max_tokens=8)
    ref, _ = _drain(target, draft, prompts, sp, "wdos", draft_len=3)
    pal, _ = _drain(target, draft, prompts, sp, "wdos", draft_len=3,
                    paged_attn_impl="pallas")
    for a, b in zip(ref, pal):
        assert bool(jnp.all(a == b))


def test_fused_sampled_deterministic_across_runs(pair):
    target, draft = pair
    prompts = _prompts(2, seed=5)
    sps = [SamplingParams(temperature=0.9, seed=21, max_tokens=10),
           SamplingParams(temperature=0.9, seed=22, max_tokens=10)]
    a, _ = _drain(target, draft, prompts, sps, "wdos", draft_len=3)
    b, _ = _drain(target, draft, prompts, sps, "wdos", draft_len=3)
    for x, y in zip(a, b):
        assert bool(jnp.all(x == y)), "fused sampled decode not reproducible"


# ---------------------------------------------------------------------------
# Schedule quality: fused rounds <= two-phase rounds, strictly fewer when
# windows are heterogeneous (the out-of-order win)
# ---------------------------------------------------------------------------


def _staggered_drain(target, draft, prompts, par_mode, max_tokens=24):
    """One request admitted per step for the first len(prompts) steps —
    the continuous-arrival pattern that desynchronizes APSD controllers."""
    eng = Engine(target, draft, EngineConfig(
        max_batch=len(prompts), page_size=8,
        adaptive=True, short_dl=2, long_dl=6, par_mode=par_mode,
    ))
    rids = []
    for p in prompts:
        rids.append(eng.add_request(p, SamplingParams(max_tokens=max_tokens)))
        eng.step()
    while eng.has_unfinished():
        eng.step()
    return [eng.output_tokens(r) for r in rids], eng.summary()


def test_fused_strictly_fewer_rounds_on_staggered_workload(pair):
    """Self-draft (acceptance 1.0) sends each controller NONPAR->PAR after
    its first window; staggered admission therefore mixes 2-token and
    6-token windows for several steps.  The fused scheduler lets short-
    window rows commit multiple windows per round while long-window rows
    draft — strictly fewer rounds to drain, same tokens."""
    target, _ = pair
    off, s_off = _staggered_drain(target, target, _prompts(4, seed=6), "off")
    wdos, s_wd = _staggered_drain(target, target, _prompts(4, seed=6), "wdos")
    for a, b in zip(off, wdos):
        assert bool(jnp.all(a == b))
    assert s_wd["rounds"] < s_off["rounds"], (
        f"fused {s_wd['rounds']} rounds vs two-phase {s_off['rounds']}"
    )
    # the telemetry must witness true cross-request overlap: slots where
    # one request verified while another drafted in the same dispatch
    assert s_wd["fused"]["occupancy"] > 0.0
    assert s_wd["fused"]["modeled_overlap_speedup"] > 1.0


def test_fused_rounds_never_exceed_two_phase(pair):
    """On a homogeneous lockstep workload the fused schedule degenerates to
    the two-phase cadence — never worse."""
    target, draft = pair
    prompts = _prompts(4, seed=7)
    sp = SamplingParams(max_tokens=12)
    _, s_off = _drain(target, draft, prompts, sp, "off", draft_len=3)
    _, s_wd = _drain(target, draft, prompts, sp, "wdos", draft_len=3)
    assert s_wd["rounds"] <= s_off["rounds"]


def test_fused_streams_every_token_and_finishes_once(pair):
    """RequestOutput invariants hold under fused rounds: every step's
    new_token_ids concatenate to the final output, cumulative token_ids
    stay consistent, finish arrives exactly once."""
    target, draft = pair
    prompts = _prompts(2, seed=8)
    eng = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, draft_len=2, par_mode="wdos"
    ))
    rids = [eng.add_request(p, SamplingParams(max_tokens=7)) for p in prompts]
    streamed = {rid: [] for rid in rids}
    finishes = {rid: 0 for rid in rids}
    while eng.has_unfinished():
        for out in eng.step():
            streamed[out.request_id].extend(out.new_token_ids)
            assert out.token_ids == streamed[out.request_id]
            if out.finished:
                finishes[out.request_id] += 1
    for rid in rids:
        assert streamed[rid] == [int(t) for t in eng.output_tokens(rid)]
        assert len(streamed[rid]) == 7
        assert finishes[rid] == 1
    t_stats, d_stats = eng.pool_stats()
    assert t_stats.used_pages == 0 and d_stats.used_pages == 0


def test_par_mode_validation():
    with pytest.raises(ValueError, match="par_mode"):
        EngineConfig(par_mode="sideways")


# ---------------------------------------------------------------------------
# The planner itself (pure scheduling logic)
# ---------------------------------------------------------------------------


def test_plan_mixed_slot_roles_by_readiness():
    rows = [
        RowPhase(slot=0, window=2, drafted=2),  # full -> verify
        RowPhase(slot=1, window=6, drafted=3),  # mid-window -> draft
        RowPhase(slot=2, window=2, drafted=0),  # fresh -> draft
        RowPhase(slot=3, window=4, drafted=4),  # full -> verify
    ]
    plan = plan_mixed_slot(rows)
    assert plan.verify_rows == (0, 3)
    assert plan.draft_rows == (1, 2)
    assert plan.fused  # cross-request draft/verify co-residency
    assert not plan_mixed_slot(rows[1:3]).fused  # draft-only slot
    assert plan_mixed_slot([]).rows == ()
    solo_verify = plan_mixed_slot([RowPhase(slot=0, window=2, drafted=2)])
    assert solo_verify.verify_rows == (0,) and not solo_verify.fused


# ---------------------------------------------------------------------------
# Compressed KV under fused rounds (EngineConfig.kv_quant="mixed")
# ---------------------------------------------------------------------------


def test_wdos_mixed_fp_int8_batch_bit_matches_two_phase(pair):
    """A batch interleaving dense and int8-stored requests, drained under
    the fused wdos scheduler: token-for-token identical to the same mixed
    batch under two-phase rounds — the per-storage-kind dispatch split
    composes with fused cross-request execution, and sharing one page
    allocator across kinds never leaks between rows."""
    target, draft = pair
    prompts = _prompts(4, seed=23)
    sps = [SamplingParams(max_tokens=12, kv_quant=k)
           for k in ("none", "int8", "none", "int8")]
    off, s_off = _drain(target, draft, prompts, sps, "off",
                        draft_len=3, kv_quant="mixed")
    wdos, s_wdos = _drain(target, draft, prompts, sps, "wdos",
                          draft_len=3, kv_quant="mixed")
    for a, b in zip(off, wdos):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s_wdos["par_mode"] == "wdos" and s_wdos["kv_quant"] == "mixed"
    # the fp rows are additionally bit-identical to a PURE dense wdos drain
    dense, _ = _drain(target, draft, [prompts[0], prompts[2]],
                      SamplingParams(max_tokens=12), "wdos",
                      draft_len=3, kv_quant="none")
    np.testing.assert_array_equal(np.asarray(wdos[0]), np.asarray(dense[0]))
    np.testing.assert_array_equal(np.asarray(wdos[2]), np.asarray(dense[1]))
