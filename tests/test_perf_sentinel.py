"""Perf-regression sentinel (scripts/perf_sentinel.py) on synthetic
trajectories: a genuine collapse is caught (exit 1, not appended), run
noise inside the tolerances passes, the first runs bootstrap cleanly,
and missing metrics are skipped rather than failed.  The script lives
outside the package, so it is loaded by file path.
"""
import importlib.util
import io
import json
import os

import pytest

_SENTINEL = os.path.join(
    os.path.dirname(__file__), "..", "scripts", "perf_sentinel.py"
)


@pytest.fixture(scope="module")
def ps():
    spec = importlib.util.spec_from_file_location("perf_sentinel", _SENTINEL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench(ps, **kw):
    return ps._synthetic_bench(**kw)


def _run(ps, tmp_path, rec, window=8):
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps(rec))
    buf = io.StringIO()
    rc = ps.check(str(bench), str(tmp_path / "hist.jsonl"),
                  window=window, out=buf)
    return rc, buf.getvalue()


def test_extract_headline_paths(ps):
    h = ps.extract_headline(_bench(ps, warm=123.0, rounds=7, tree=1.25,
                                   ttft=0.033))
    assert h == {
        "warm_tokens_per_s": 123.0,
        "wdos_rounds_to_drain": 7.0,
        "tree_accepted_per_round": 1.25,
        "ttft_p50_s": 0.033,
    }
    # ttft comes from the HIGHEST arrival rate on the wdos side
    rec = _bench(ps)
    rec["async_load"]["wdos"]["2.0"] = {"ttft_s": {"p50": 9.9}}
    assert ps.extract_headline(rec)["ttft_p50_s"] == 0.05


def test_bootstrap_then_gate(ps, tmp_path):
    # runs 1 and 2 bootstrap (below min_runs prior entries) and append
    for i in range(2):
        rc, txt = _run(ps, tmp_path, _bench(ps))
        assert rc == 0 and "bootstrap" in txt, txt
    # run 3 is actually gated
    rc, txt = _run(ps, tmp_path, _bench(ps))
    assert rc == 0 and "| ok |" in txt


def test_noise_tolerated(ps, tmp_path):
    for warm in (100.0, 104.0, 96.0, 101.0):
        rc, txt = _run(ps, tmp_path, _bench(ps, warm=warm))
        assert rc == 0, txt
    # -20% on a 40%-tolerance wall-clock metric is noise, not regression
    rc, txt = _run(ps, tmp_path, _bench(ps, warm=80.0))
    assert rc == 0, txt


def test_regression_caught_and_not_appended(ps, tmp_path):
    for _ in range(3):
        assert _run(ps, tmp_path, _bench(ps))[0] == 0
    hist = tmp_path / "hist.jsonl"
    n_before = len(ps.load_history(str(hist)))
    # warm tokens/s at -70% breaches the 40% tolerance
    rc, txt = _run(ps, tmp_path, _bench(ps, warm=30.0))
    assert rc == 1 and "REGRESSION" in txt and "warm_tokens_per_s" in txt
    # the collapsed run must not drag the baseline down
    assert len(ps.load_history(str(hist))) == n_before
    # and a healthy run right after still passes
    assert _run(ps, tmp_path, _bench(ps))[0] == 0


def test_lower_is_better_direction(ps, tmp_path):
    for _ in range(3):
        assert _run(ps, tmp_path, _bench(ps, rounds=6))[0] == 0
    # rounds-to-drain DOUBLING is a regression (tolerance 34%)...
    rc, txt = _run(ps, tmp_path, _bench(ps, rounds=12))
    assert rc == 1 and "wdos_rounds_to_drain" in txt
    # ...while 6 -> 7 rounds is within tolerance
    assert _run(ps, tmp_path, _bench(ps, rounds=7))[0] == 0
    # same for TTFT: 100% tolerance means 2.5x fails, 1.5x passes
    assert _run(ps, tmp_path, _bench(ps, ttft=0.125))[0] == 1
    assert _run(ps, tmp_path, _bench(ps, ttft=0.075))[0] == 0


def test_missing_metric_is_skipped(ps, tmp_path):
    rec = _bench(ps)
    del rec["tree_spec"]
    del rec["async_load"]
    rc, txt = _run(ps, tmp_path, rec)
    assert rc == 0 and txt.count("skipped") >= 2
    # history entries carry None for the missing metrics; later gated
    # runs must not trip over them
    for _ in range(3):
        assert _run(ps, tmp_path, _bench(ps))[0] == 0


def test_corrupt_history_lines_skipped(ps, tmp_path):
    hist = tmp_path / "hist.jsonl"
    hist.write_text('not json\n{"no": "headline"}\n'
                    + json.dumps({"headline": {"warm_tokens_per_s": 100.0}})
                    + "\n")
    entries = ps.load_history(str(hist))
    assert len(entries) == 1
    rc, _ = _run(ps, tmp_path, _bench(ps))
    assert rc == 0


def test_self_test_passes(ps, capsys):
    assert ps.self_test() == 0
    assert "ok" in capsys.readouterr().out
