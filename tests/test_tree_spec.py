"""spec_mode="tree": the engine-level tree-speculation contract.

- greedy tree == greedy chain token stream (lossless, every impl/par_mode)
- sampled tree: run-to-run deterministic, fused wdos == two-phase,
  pallas == gather, mixed per-request kv kinds agree across schedulers
- compaction oracle: after rounds that accepted a NON-leftmost branch the
  pool's committed prefix equals a fresh dense prefill of the same tokens
- low-acceptance A/B: branch fan-out strictly raises accepted tokens/round
"""
import numpy as np
import pytest

from repro.launch.serve import build_pair
from repro.serving import Engine, EngineConfig, SamplingParams


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def _prompts(n, seed=0, vocab=512):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=rng.randint(2, 7)).astype(np.int32)
            for _ in range(n)]


def _drain(pair, prompts, sps, **kw):
    target, draft = pair
    eng = Engine(target, draft,
                 EngineConfig(max_batch=len(prompts), page_size=8, **kw))
    outs, summary = eng.run(prompts, sps)
    return [np.asarray(t) for t in outs], summary, eng


TREE = dict(spec_mode="tree", tree_budget=6, spec_branches=2)


@pytest.mark.parametrize(
    "impl,par_mode", [("gather", "off"), ("pallas", "off"), ("gather", "wdos")]
)
def test_greedy_tree_matches_chain_stream(pair, impl, par_mode):
    """Greedy tree verify only ever commits target-argmax tokens, so the
    stream must equal chain speculation token-for-token — the tree changes
    rounds, never content."""
    prompts = _prompts(3, seed=1)
    sp = SamplingParams(max_tokens=10)
    chain, _, _ = _drain(pair, prompts, sp, draft_len=3,
                         paged_attn_impl=impl, par_mode=par_mode)
    tree, s_tree, eng = _drain(pair, prompts, sp, draft_len=3,
                               paged_attn_impl=impl, par_mode=par_mode,
                               **TREE)
    for a, b in zip(chain, tree):
        np.testing.assert_array_equal(a, b)
    assert s_tree["emitted"] == sum(len(t) for t in tree)
    t_st, d_st = eng.pool_stats()
    assert t_st.used_pages == 0 and d_st.used_pages == 0


def test_sampled_tree_parity_and_determinism(pair):
    """Mixed per-request sampling params: reruns are bit-identical, and so
    are the fused-wdos scheduler and the pallas kernel path."""
    prompts = _prompts(3, seed=1)
    sps = [SamplingParams(temperature=0.9, seed=21, max_tokens=10),
           SamplingParams(temperature=1.1, top_k=12, seed=5, max_tokens=10),
           SamplingParams(max_tokens=10)]
    a, _, _ = _drain(pair, prompts, sps, draft_len=3, **TREE)
    b, _, _ = _drain(pair, prompts, sps, draft_len=3, **TREE)
    fused, _, _ = _drain(pair, prompts, sps, draft_len=3, par_mode="wdos",
                         **TREE)
    pallas, _, _ = _drain(pair, prompts, sps, draft_len=3,
                          paged_attn_impl="pallas", **TREE)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(a, fused):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(a, pallas):
        np.testing.assert_array_equal(x, y)


def test_tree_mixed_kv_kinds_parity(pair):
    """Per-request int8/fp pools under tree speculation: the two schedulers
    must agree (compaction runs per storage kind)."""
    prompts = _prompts(3, seed=2)
    sps = [SamplingParams(max_tokens=8, kv_quant=k)
           for k in ("none", "int8", "none")]
    off, _, _ = _drain(pair, prompts, sps, draft_len=3, kv_quant="mixed",
                       **TREE)
    wdos, _, _ = _drain(pair, prompts, sps, draft_len=3, kv_quant="mixed",
                        par_mode="wdos", **TREE)
    for x, y in zip(off, wdos):
        np.testing.assert_array_equal(x, y)


def test_tree_compaction_matches_fresh_prefill(pair):
    """KV-content oracle: drive a branchy sampled drain until at least one
    round accepts a non-leftmost branch (device compaction moved BFS slots
    into chain order), then compare the pool's committed prefix rows with a
    fresh dense prefill of exactly those tokens."""
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(
        max_batch=1, page_size=8, spec_mode="tree", tree_budget=6,
        spec_branches=2, branch_threshold=1.0,
    ))
    moved = []
    orig = eng._compact_pools

    def spy(moves_t, moves_d):
        moved.append(sum(len(src) for src, _ in moves_t.values()))
        orig(moves_t, moves_d)

    eng._compact_pools = spy
    prompt = np.arange(5, 11, dtype=np.int32)
    rid = eng.add_request(
        prompt, SamplingParams(temperature=3.0, seed=9, max_tokens=24)
    )
    while eng.has_unfinished() and not sum(moved):
        eng.step()
    assert sum(moved) > 0, "workload never accepted a non-leftmost branch"
    assert eng.has_unfinished(), "request finished before the oracle ran"

    req = eng._requests[rid]
    length = req.t_seq.length
    emitted = np.asarray(eng.output_tokens(rid))
    committed = np.concatenate([prompt, emitted])[:length].astype(np.int32)

    ref_eng = Engine(target, draft,
                     EngineConfig(max_batch=1, page_size=8, draft_len=3))
    rid2 = ref_eng.add_request(committed, SamplingParams(max_tokens=2))
    ref_eng.step()  # prefill writes positions [0, length); decode writes past

    def pool_rows(engine, request, store_attr, name):
        seq = request.t_seq if store_attr == "_t_store" else request.d_seq
        store = getattr(engine, store_attr)[request.kv_kind]
        arr = np.asarray(store[name])
        flat = arr.reshape(arr.shape[0], -1, *arr.shape[3:])
        return flat[:, seq.flat_slots(np.arange(length))]

    req2 = ref_eng._requests[rid2]
    for store_attr, name in (("_t_store", "k"), ("_t_store", "v"),
                             ("_d_store", "k")):
        got = pool_rows(eng, req, store_attr, name)
        want = pool_rows(ref_eng, req2, store_attr, name)
        np.testing.assert_allclose(got, want, atol=2e-3, err_msg=store_attr)


def test_tree_accepts_more_per_round_on_branchy_workload(pair):
    """The A/B the bench gates: same drafting depth, every position
    branching top-2 with a budget that covers the full fan-out
    (2 + 4 + 8 = 14 > depth 3), on a low-acceptance sampled workload — the
    tree engine must accept strictly more tokens per request-round than
    chain speculation.  (Engine-step counts are batched and can tie; the
    per-request round counters are the comparable denominator.)"""
    prompts = _prompts(4, seed=3)
    sps = [SamplingParams(temperature=1.5, seed=100 + i, max_tokens=16)
           for i in range(4)]

    def acc_per_round(**kw):
        target, draft = pair
        eng = Engine(target, draft,
                     EngineConfig(max_batch=len(prompts), page_size=8, **kw))
        rids = [eng.add_request(p, sp) for p, sp in zip(prompts, sps)]
        while eng.has_unfinished():
            eng.step()
        reqs = [eng._requests[r] for r in rids]
        return (sum(r.accepted for r in reqs)
                / max(sum(r.rounds for r in reqs), 1))

    chain_acc = acc_per_round(draft_len=3)
    tree_acc = acc_per_round(draft_len=3, spec_mode="tree", tree_budget=14,
                             spec_branches=2, branch_threshold=1.0)
    assert tree_acc > chain_acc, (tree_acc, chain_acc)
