"""Copy-on-write prefix cache (serving/prefix_cache.py + engine wiring).

Three layers of coverage:

* radix-tree unit semantics on host pools — match walk, partial (mid-block)
  matches, acquire/release refcounts, LRU eviction that never frees a page
  another holder maps;
* the tentpole determinism contract — tokens with ``prefix_cache=True`` are
  BIT-IDENTICAL to sharing off for every (paged-attn impl, par_mode,
  kv_quant) combination, across full hits, partial hits, and COW;
* the byte-budget satellites — ``EngineConfig.pool_bytes`` admission counts
  compressed bytes (int8 fits ~3.5x the resident requests of fp at the same
  budget), and the engine exports the prefix metric families.
"""
import numpy as np
import pytest

from repro.launch.serve import build_pair
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.paged_cache import PagedKVPool
from repro.serving.prefix_cache import PrefixCache

PS = 4  # unit-test page size


def make_pools(num_pages=32):
    return {
        "target": PagedKVPool(2, 2, 8, num_pages=num_pages, page_size=PS),
        "draft": PagedKVPool(2, 2, 8, num_pages=num_pages, page_size=PS),
    }


def dense_kv(pools, n, seed=0):
    rng = np.random.RandomState(seed)
    out = {}
    for role, p in pools.items():
        k = rng.randn(p.n_layers, n, p.kv_heads, p.head_dim).astype(np.float32)
        out[role] = (k, -k)
    return out


def donate(cache, pools, prompt, upto, seed=0):
    """Simulate a donor request: allocate + append per pool, insert blocks."""
    prompt = np.asarray(prompt, np.int32)
    kv = dense_kv(pools, upto, seed=seed)
    seqs = {}
    for role, p in pools.items():
        seq = p.allocate_sequence(upto + PS)
        seq.append(*kv[role])
        seqs[role] = seq
    cache.insert(
        prompt, "none", {r: s.pages for r, s in seqs.items()}, kv, upto
    )
    return seqs, kv


# ---------------------------------------------------------------------------
# Radix-tree unit semantics
# ---------------------------------------------------------------------------


def test_match_full_blocks_and_cap():
    pools = make_pools()
    cache = PrefixCache(pools, PS)
    prompt = np.arange(10, 23, dtype=np.int32)  # 13 tokens, 3 full blocks
    donate(cache, pools, prompt, upto=12)
    assert cache.node_count == 3

    # identical prompt: full-block walk, capped at plen - 1 = 12
    m = cache.match(prompt, "none")
    assert m is not None and m.tokens_matched == 12 and not m.partial
    assert len(m.shared_pages("target")) == 3

    # a prompt of exactly one cached block + 1: the cap keeps the last
    # token private even though the whole block is cached
    m2 = cache.match(prompt[: PS + 1], "none")
    assert m2 is not None and m2.tokens_matched == PS

    # different kind => different tree
    assert cache.match(prompt, "int8") is None


def test_match_partial_midblock_divergence():
    pools = make_pools()
    cache = PrefixCache(pools, PS)
    prompt = np.arange(10, 23, dtype=np.int32)
    donate(cache, pools, prompt, upto=12)

    fork = prompt.copy()
    fork[6:] = 400 + np.arange(7)  # shares 1 full block + 2 tokens of block 2
    m = cache.match(fork, "none")
    assert m is not None and m.tokens_matched == 6 and m.partial
    # the partially-matched node's page is mapped — COW is the holder's job
    assert len(m.shared_pages("target")) == 2
    k, v = m.prefix_kv("target")
    assert k.shape[1] == 6 and v.shape[1] == 6


def test_prefix_kv_matches_donor_rows():
    pools = make_pools()
    cache = PrefixCache(pools, PS)
    prompt = np.arange(30, 43, dtype=np.int32)
    _, kv = donate(cache, pools, prompt, upto=12, seed=3)
    m = cache.match(prompt, "none")
    for role in ("target", "draft"):
        k, v = m.prefix_kv(role)
        np.testing.assert_array_equal(k, kv[role][0][:, :12])
        np.testing.assert_array_equal(v, kv[role][1][:, :12])


def test_eviction_respects_refcounts_and_lru():
    pools = make_pools()
    cache = PrefixCache(pools, PS)
    p1 = np.arange(0, 9, dtype=np.int32)
    p2 = np.arange(100, 109, dtype=np.int32)
    seqs1, _ = donate(cache, pools, p1, upto=8, seed=1)
    seqs2, _ = donate(cache, pools, p2, upto=8, seed=2)
    assert cache.node_count == 4

    # donors still map every page (pool ref 2): nothing is evictable
    assert cache.evict_one() == 0

    for s in seqs1.values():
        s.release()
    # p1's leaf is now sole-owned by the tree; a live request ref pins it
    m1 = cache.match(p1, "none")
    cache.acquire(m1)
    assert cache.evict_one() == 0
    cache.release(m1)

    # LRU: p1's leaf was touched by the match above... touch it again via
    # p2 ordering — the oldest evictable leaf goes first
    free_before = pools["target"].free_pages
    assert cache.evict_one() == 2  # one page per role
    assert pools["target"].free_pages == free_before + 1
    assert cache.node_count == 3
    assert cache.evictions == 1

    # the interior p1 node is now a leaf and evictable; p2's nodes are
    # still pinned by their donor sequences
    assert cache.evict_one() == 2
    assert cache.evict_one() == 0
    assert cache.node_count == 2
    for s in seqs2.values():
        s.release()


def test_eviction_never_frees_mapped_page():
    pools = make_pools()
    cache = PrefixCache(pools, PS)
    prompt = np.arange(50, 59, dtype=np.int32)
    seqs, _ = donate(cache, pools, prompt, upto=8)
    for s in seqs.values():
        s.release()

    # a follower maps the cached pages (zero NODE refs — not yet acquired):
    # its POOL refs alone must keep eviction away
    m = cache.match(prompt, "none")
    follower = {
        role: p.allocate_sequence(
            12, shared_pages=m.shared_pages(role), shared_tokens=8
        )
        for role, p in pools.items()
    }
    assert cache.evict_one() == 0
    for s in follower.values():
        s.release()
    assert cache.evict_one() == 2


def test_insert_is_idempotent_and_skips_partial_tail():
    pools = make_pools()
    cache = PrefixCache(pools, PS)
    prompt = np.arange(0, 11, dtype=np.int32)  # upto=10: 2 full blocks
    seqs, kv = donate(cache, pools, prompt, upto=10)
    assert cache.node_count == 2  # the 2-token tail block is NOT cached
    n = cache.insert(
        prompt, "none", {r: s.pages for r, s in seqs.items()}, kv, 10
    )
    assert n == 0 and cache.node_count == 2  # re-donation is a no-op


# ---------------------------------------------------------------------------
# Engine bit-identity: the tentpole acceptance matrix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pair():
    return build_pair(seed=0, s_max=128, quantize=False)


def _workload():
    """Donor + mid-block divergence (COW) + full-hit prefix + exact repeat:
    every sharing path the engine implements."""
    donor = np.arange(7, 48, dtype=np.int32)  # 41 tokens, 5 full blocks @8
    fork = np.concatenate([donor[:33], np.arange(200, 208, dtype=np.int32)])
    fullhit = donor[:34].copy()  # prefix of donor: full hit on partial page
    repeat = donor.copy()
    return donor, [fork, fullhit, repeat]


def _run_engine(pair, prefix_on, impl, par_mode, kv_quant):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, draft_len=3, par_mode=par_mode,
        kv_quant=kv_quant, paged_attn_impl=impl, prefix_cache=prefix_on,
    ))
    donor, followers = _workload()
    sp = SamplingParams(max_tokens=6)
    first, _ = eng.run([donor], sp)
    rest, summary = eng.run(followers, sp)
    return [np.asarray(t) for t in first + rest], summary


@pytest.mark.parametrize("impl", ["gather", "pallas"])
@pytest.mark.parametrize("par_mode", ["off", "wdos"])
@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_sharing_bit_identical(pair, impl, par_mode, kv_quant):
    """prefix_cache=True must emit bitwise the tokens of sharing off, for
    every (impl, par_mode, kv_quant) combination, across partial hits
    (seeded tail extend), COW, and full hits (no forward at all)."""
    off, _ = _run_engine(pair, False, impl, par_mode, kv_quant)
    on, summary = _run_engine(pair, True, impl, par_mode, kv_quant)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    st = summary["prefix_cache"]
    assert st["hits"] >= 3  # every follower matched
    assert st["cow_copies"] >= 1  # the partial-page paths copy-on-wrote
    assert st["tokens_saved"] > 0


def test_sharing_survives_abort_and_rerun(pair):
    """Aborting a request holding shared pages must only drop references —
    later requests still hit the same nodes and stay bit-identical."""
    target, draft = pair
    donor, followers = _workload()
    sp = SamplingParams(max_tokens=6)

    eng = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, draft_len=3, prefix_cache=True,
    ))
    ref = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, draft_len=3,
    ))
    eng.run([donor], sp)
    ref.run([donor], sp)

    rid = eng.add_request(followers[0], sp)
    eng.step()
    assert eng.abort(rid)
    t_pool, _d = eng.pool_stats()
    assert t_pool.used_pages > 0  # tree pins survive the abort

    for f in followers:
        got = np.asarray(eng.run([f], sp)[0][0])
        want = np.asarray(ref.run([f], sp)[0][0])
        np.testing.assert_array_equal(got, want)
    assert eng.summary()["prefix_cache"]["hits"] >= 3


# ---------------------------------------------------------------------------
# Byte-budget admission (satellite: compressed bytes, not raw page counts)
# ---------------------------------------------------------------------------


def _resident_at_budget(pair, kv_quant, pool_bytes):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(
        max_batch=16, page_size=8, draft_len=3,
        kv_quant=kv_quant, pool_bytes=pool_bytes,
    ))
    prompt = np.arange(3, 19, dtype=np.int32)  # 16 tokens
    for i in range(16):
        eng.add_request(prompt + i, SamplingParams(max_tokens=24))
    eng.step()
    return eng.num_active()


def test_int8_admits_more_requests_at_same_byte_budget(pair):
    """The batcher admits against POOL BYTES: at one fixed budget an int8
    engine must hold ~3.5x the resident requests of an fp engine (int8
    values + f32 scales vs f32 values)."""
    budget = 256 * 1024
    fp = _resident_at_budget(pair, "none", budget)
    int8 = _resident_at_budget(pair, "int8", budget)
    assert 0 < fp < 16, f"budget not binding for fp ({fp} resident)"
    ratio = int8 / fp
    assert ratio >= 3.0, f"int8/fp resident ratio {ratio:.2f} < 3.0"


# ---------------------------------------------------------------------------
# Metrics export (satellite: prefix metric families)
# ---------------------------------------------------------------------------


def test_prefix_metric_families_export(pair):
    target, draft = pair
    eng = Engine(target, draft, EngineConfig(
        max_batch=2, page_size=8, draft_len=3, prefix_cache=True,
    ))
    donor, followers = _workload()
    sp = SamplingParams(max_tokens=4)
    eng.run([donor], sp)
    eng.run(followers, sp)
    text = eng.metrics.render()
    assert "prefix_hit_rate" in text
    assert "prefill_tokens_saved_total" in text
    assert 'shared_pages{pool="target",state="cached"}' in text
    assert "prefix_cow_total" in text
    # the gauges carry live values, not just registered headers
    hit_lines = [
        ln for ln in text.splitlines()
        if "prefix_hit_rate" in ln and not ln.startswith("#")
    ]
    assert hit_lines and float(hit_lines[0].rsplit(" ", 1)[1]) > 0


# ---------------------------------------------------------------------------
# Tree speculation: shared prefix pages survive tree rewind + compaction
# ---------------------------------------------------------------------------


def test_sharing_bit_identical_with_tree_spec(pair):
    """spec_mode='tree' advances the full window then rewinds W-1-n_acc
    positions every round and compacts accepted branches in place; neither
    may touch a SHARED prefix page — prefix_cache=True must stay
    bit-identical to sharing off, and the donor's nodes must still be
    matchable after the tree drains."""
    target, draft = pair
    donor, followers = _workload()
    sp = SamplingParams(max_tokens=6)

    def run(prefix_on):
        eng = Engine(target, draft, EngineConfig(
            max_batch=2, page_size=8, prefix_cache=prefix_on,
            spec_mode="tree", tree_budget=5, spec_branches=2,
        ))
        first, _ = eng.run([donor], sp)
        rest, summary = eng.run(followers, sp)
        return [np.asarray(t) for t in first + rest], summary, eng

    off, _, _ = run(False)
    on, summary, eng = run(True)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    st = summary["prefix_cache"]
    assert st["hits"] >= 3  # every follower matched despite tree rewinds
    # cached nodes are still intact and matchable post-drain
    again, _ = eng.run([donor.copy()], sp)
    np.testing.assert_array_equal(np.asarray(again[0]), np.asarray(off[0]))
