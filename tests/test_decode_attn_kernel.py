"""Fused int8-KV decode-attention Pallas kernel vs the jnp oracle.

Oracle dots run in bf16 (layers._decode_attention's quantized path), the
kernel in f32 — tolerances cover that rounding gap, far below the int8
cache quantization noise itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import decode_attention_int8_pallas
from repro.models.layers import _decode_attention, _kv_quantize

RNG = np.random.RandomState(0)


def _setup(b, s, kvs, g, hd):
    h = kvs * g
    q = jnp.asarray(RNG.randn(b, 1, h, hd).astype(np.float32))
    k = jnp.asarray(RNG.randn(b, s, kvs, hd).astype(np.float32))
    v = jnp.asarray(RNG.randn(b, s, kvs, hd).astype(np.float32))
    kq, ks = _kv_quantize(k)
    vq, vs = _kv_quantize(v)
    return q, kq, ks, vq, vs


@pytest.mark.parametrize(
    "b,s,kvs,g,hd,block_s",
    [
        (2, 64, 4, 2, 32, 16),
        (1, 128, 2, 4, 64, 32),
        (4, 32, 1, 8, 128, 32),  # GQA-16-like: one kv head per shard
        (2, 64, 4, 2, 32, 64),  # single block
    ],
)
@pytest.mark.parametrize("length", [1, 17, None])
def test_matches_oracle(b, s, kvs, g, hd, block_s, length):
    q, kq, ks, vq, vs = _setup(b, s, kvs, g, hd)
    h = kvs * g
    ln = jnp.asarray(s if length is None else min(length, s), jnp.int32)
    want = _decode_attention(q, kq, vq, ln, k_scale=ks, v_scale=vs)
    qg = q.reshape(b, 1, kvs, g, hd)[:, 0]
    got = decode_attention_int8_pallas(
        qg, kq, ks[..., 0], vq, vs[..., 0], ln, block_s=block_s
    )
    np.testing.assert_allclose(
        np.asarray(got.reshape(b, 1, h, hd)),
        np.asarray(want, np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_masking_exact():
    """Positions beyond `length` must not contribute at all: poisoning the
    tail of the cache must not change the output."""
    b, s, kvs, g, hd = 1, 64, 2, 2, 32
    q, kq, ks, vq, vs = _setup(b, s, kvs, g, hd)
    ln = jnp.asarray(20, jnp.int32)
    qg = q.reshape(b, 1, kvs, g, hd)[:, 0]
    base = decode_attention_int8_pallas(qg, kq, ks[..., 0], vq, vs[..., 0], ln, block_s=16)
    kq2 = kq.at[:, 20:].set(127)
    vs2 = vs.at[:, 20:].set(1e6)
    poisoned = decode_attention_int8_pallas(
        qg, kq2, ks[..., 0], vq, vs2[..., 0], ln, block_s=16
    )
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))
